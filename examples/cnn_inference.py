"""End-to-end CNN inference (the paper's workload): YOLOv3-tiny + VGG16
through the `repro.api` facade — ``repro.compile`` plans every conv once
(co-design decided per layer, cached), prepares params offline (batchnorm
fold, block padding, Winograd weight pre-transform) and jits the
whole-network forward — timed against the unplanned pure-JAX and XLA-oracle
per-layer paths.

  PYTHONPATH=src python examples/cnn_inference.py [--input 416]
"""
import argparse
import time

import jax

import repro
from repro.configs import vgg16, yolov3
from repro.data import image_batch
from repro.models.cnn import cnn_forward, init_cnn


def bench(model, options):
    params = init_cnn(jax.random.PRNGKey(0), model.layers)
    x = image_batch(0, 1, *model.input_hw)
    compiled = repro.compile(model, params, options)
    report = compiled.plan_report()
    runs = (
        ("jax", lambda xx: cnn_forward(params, model.layers, xx, impl="jax")),
        ("xla", lambda xx: cnn_forward(params, model.layers, xx, impl="xla")),
        ("compiled", compiled.run),   # planned + folded + fused + prepared
    )
    for tag, fwd in runs:
        fn = jax.jit(fwd) if tag != "compiled" else fwd
        out = fn(x)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = fn(x)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        print(f"  {model.name:12s} impl={tag:10s} out={tuple(out.shape)} "
              f"{dt*1e3:.1f} ms")
    algos = {}
    for row in report["layers"]:
        algos[row["algorithm"]] = algos.get(row["algorithm"], 0) + 1
    print(f"  {model.name:12s} planned conv layers by algorithm: {algos} "
          f"(tunes={report['tunes']}, elided={report['elided_boundaries']})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--input", type=int, default=224)
    args = ap.parse_args()
    hw = (args.input, args.input)
    # One persistent cache serves both models: the second invocation of this
    # example re-tunes nothing.
    options = repro.ExecutionOptions(impl="jax")
    print("== YOLOv3-tiny ==")
    bench(yolov3.TINY_MODEL.with_input_hw(hw), options)
    print("== VGG16 ==")
    bench(vgg16.MODEL.with_input_hw(hw), options)


if __name__ == "__main__":
    main()
