"""End-to-end CNN inference (the paper's workload): YOLOv3-tiny + VGG16
with per-layer algorithm selection, timed per algorithm path.

  PYTHONPATH=src python examples/cnn_inference.py [--input 416]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import vgg16, yolov3
from repro.data import image_batch
from repro.models.cnn import cnn_forward, conv_layer_dims, init_cnn


def bench(name, layers, hw):
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = image_batch(0, 1, *hw)
    for impl in ("jax", "xla"):
        fn = jax.jit(lambda p, xx: cnn_forward(p, layers, xx, impl=impl))
        out = fn(params, x)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = fn(params, x)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        print(f"  {name:12s} impl={impl:4s} out={tuple(out.shape)} {dt*1e3:.1f} ms")
    dims = conv_layer_dims(layers, *hw)
    algos = {}
    for d in dims:
        key = ("winograd" if d["kernel"] == 3 and d["stride"] == 1 else
               "direct" if d["kernel"] == 1 else "im2col")
        algos[key] = algos.get(key, 0) + 1
    print(f"  {name:12s} conv layers by algorithm: {algos}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--input", type=int, default=224)
    args = ap.parse_args()
    hw = (args.input, args.input)
    print("== YOLOv3-tiny ==")
    bench("yolov3-tiny", yolov3.TINY_LAYERS, hw)
    print("== VGG16 ==")
    bench("vgg16", vgg16.LAYERS, hw)


if __name__ == "__main__":
    main()
