"""End-to-end CNN inference (the paper's workload): YOLOv3-tiny + VGG16
with per-layer algorithm selection, timed per algorithm path, then the same
networks fully planned (core/planner.py: co-design decided once, cached),
and finally the fused deployment path (``cnn_infer``: batchnorm folded into
the conv weights, bias + activation fused into the kernels' output stage).

  PYTHONPATH=src python examples/cnn_inference.py [--input 416]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import vgg16, yolov3
from repro.core.planner import Planner
from repro.data import image_batch
from repro.models.cnn import (
    cnn_forward,
    cnn_infer,
    fold_batchnorm,
    init_cnn,
    plan_layers,
)


def bench(name, layers, hw, planner):
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = image_batch(0, 1, *hw)
    tunes_before = planner.stats["tunes"]
    plans = plan_layers(layers, *hw, planner)
    net_tunes = planner.stats["tunes"] - tunes_before
    plans_t = tuple(plans)
    folded = fold_batchnorm(params, layers)   # once, offline
    runs = (
        ("jax", params,
         lambda p, xx: cnn_forward(p, layers, xx, impl="jax")),
        ("xla", params,
         lambda p, xx: cnn_forward(p, layers, xx, impl="xla")),
        ("jax+plan", params,
         lambda p, xx: cnn_forward(p, layers, xx, impl="jax", plans=plans_t)),
        ("jax+fused", folded,
         lambda p, xx: cnn_infer(p, layers, xx, impl="jax", plans=plans_t,
                                 fold_bn=False)),
    )
    for tag, ps, fwd in runs:
        fn = jax.jit(fwd)
        out = fn(ps, x)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = fn(ps, x)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        print(f"  {name:12s} impl={tag:10s} out={tuple(out.shape)} {dt*1e3:.1f} ms")
    algos = {}
    for plan in plans:
        if plan is not None:
            algos[plan.algorithm.value] = algos.get(plan.algorithm.value, 0) + 1
    print(f"  {name:12s} planned conv layers by algorithm: {algos} "
          f"(tunes={net_tunes})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--input", type=int, default=224)
    args = ap.parse_args()
    hw = (args.input, args.input)
    planner = Planner()   # persistent cache: second invocation re-tunes nothing
    print("== YOLOv3-tiny ==")
    bench("yolov3-tiny", yolov3.TINY_LAYERS, hw, planner)
    print("== VGG16 ==")
    bench("vgg16", vgg16.LAYERS, hw, planner)


if __name__ == "__main__":
    main()
