"""Batched serving example: continuous batching over mixed-length requests,
via the facade — LM configs compile through the same ``repro.compile`` entry
point as CNNs; ``.serve()`` is the prefill/decode engine.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

import repro
from repro import configs
from repro.models import transformer as tf


def main():
    cfg = configs.smoke_config("llama3.2-1b", seq_len=64)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    compiled = repro.compile(cfg, params)
    engine = compiled.serve(batch_size=4, capacity=128)

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for i in range(8):
        prompt = rng.integers(1, cfg.vocab_size, size=4 + (i % 3) * 2)
        engine.submit(prompt, max_new_tokens=8 + 2 * (i % 2))
    results = engine.run()
    dt = time.monotonic() - t0
    tokens = sum(len(v) for v in results.values())
    print(f"{len(results)} requests, {tokens} new tokens in {dt:.2f}s")
    for uid, toks in sorted(results.items()):
        print(f"  req {uid}: {toks}")


if __name__ == "__main__":
    main()
