"""Quickstart: the paper's contribution in six lines, then a tour.

  PYTHONPATH=src python examples/quickstart.py

1. Convolve with automatic algorithm selection (1x1 -> direct GEMM,
   3x3 s1 -> Winograd, else im2col+GEMM) — paper §II.c/§VII.
2. Run the same convs through the Pallas TPU kernels (interpret mode here).
3. Autotune GEMM blocking for a YOLOv3 layer under a VMEM budget — the
   paper's co-design loop (§V/§VI) on TPU terms.
4. The whole lifecycle through the public facade: ``repro.compile`` plans,
   prepares, and jits a network once; ``.run`` / ``.serve`` /
   ``.plan_report`` / ``.save`` are the four verbs deployment needs.
"""
import jax
import jax.numpy as jnp

import repro
from repro.core import ConvSpec, conv2d, conv2d_reference, select_algorithm
from repro.core.codesign import MB
from repro.core.vmem_model import GemmShape, autotune_gemm

rng = jax.random.PRNGKey(0)
x = jax.random.normal(rng, (1, 56, 56, 64))

print("== 1. algorithm selection ==")
for k, s in [(1, 1), (3, 1), (3, 2), (5, 1)]:
    spec = ConvSpec(64, 128, (k, k), (s, s), (k // 2, k // 2))
    print(f"  {k}x{k} stride {s} -> {select_algorithm(spec).value}")

print("== 2. conv dispatch (pure JAX vs Pallas interpret vs XLA oracle) ==")
spec = ConvSpec(64, 128, (3, 3), (1, 1), (1, 1))
w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 64, 128)) * 0.05
y_jax = conv2d(x, w, spec, impl="jax")
y_pl = conv2d(x, w, spec, impl="pallas", interpret=True)
y_ref = conv2d_reference(x, w, spec)
print(f"  out {y_jax.shape}; |jax-ref|={float(jnp.abs(y_jax-y_ref).max()):.2e}"
      f"  |pallas-ref|={float(jnp.abs(y_pl-y_ref).max()):.2e}")

print("== 3. co-design: block autotuning under a VMEM budget ==")
shape = GemmShape(256, 5776, 1152)  # YOLOv3 L10 GEMM
for budget in (1 * MB, 4 * MB, 16 * MB):
    cfg, est = autotune_gemm(shape, vmem_budget=budget)
    print(f"  VMEM {budget // MB:>2}MB -> block ({cfg.bm},{cfg.bn},{cfg.bk}) "
          f"t={est.total_s * 1e6:.0f}us bound={est.bound}")

print("== 4. the facade: compile -> run / serve / plan_report ==")
from repro.configs import yolov3  # noqa: E402

model = yolov3.TINY_MODEL.with_input_hw((64, 64))     # small for the demo
params = model.init_params(jax.random.PRNGKey(2))
compiled = repro.compile(model, params,
                         repro.ExecutionOptions(impl="jax", cache_path=None))
y = compiled.run(jax.random.normal(jax.random.PRNGKey(3), (1, 64, 64, 3)))
report = compiled.plan_report()
algos = {}
for row in report["layers"]:
    algos[row["algorithm"]] = algos.get(row["algorithm"], 0) + 1
print(f"  {report['model']}: out {tuple(y.shape)}, "
      f"planned conv layers by algorithm: {algos} "
      f"(elided boundaries: {report['elided_boundaries']})")
engine = compiled.serve(buckets=(1, 2))
uid = engine.submit(jnp.zeros((64, 64, 3)))
print(f"  served request {uid} -> {engine.run()[uid].shape} "
      f"(bucket stats: {engine.stats['batches']})")
