"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpointing + resume (deliverable (b)).

  PYTHONPATH=src python examples/train_lm.py --steps 300

Uses a scaled llama3.2 config (~100M params) on the synthetic markov
stream; prints loss every 20 steps (should fall well below ln(vocab)).
"""
import argparse
import dataclasses

from repro import configs
from repro.configs.base import ShapeSpec
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import TrainRunConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default="/tmp/repro_train_lm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: llama3.2 family, 8 layers, d=512, vocab 32k.
    cfg = dataclasses.replace(
        configs.get_config("llama3.2-1b"),
        name="llama-100m",
        num_layers=8,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32000,
        dtype="float32",
    )
    print(f"model: {cfg.param_count()/1e6:.1f}M params")
    shape = ShapeSpec("train", args.seq_len, args.batch, "train")
    opt = AdamWConfig(lr=warmup_cosine(3e-4, 50, args.steps))
    run = TrainRunConfig(steps=args.steps, checkpoint_every=100,
                         log_every=20, out_dir=args.out)
    metrics = train(cfg, shape, opt, run)
    print("final:", metrics)


if __name__ == "__main__":
    main()
