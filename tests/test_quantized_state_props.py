"""Property tests for the block-wise int8 optimizer-state quantizer
(optim/quantized_state.py), plus plain-pytest edge cases.

The quantizer shares its scaling idiom with the inference path
(core/quant.py: max-abs / 127, clamp floor); the properties pinned here are
the contract both rely on:

  round-trip error    |x - dq(q(x))| <= scale/2 per block (round-to-nearest
                      on a grid of step ``scale``)
  zero preservation   all-zero blocks survive exactly (the 1e-12 floor
                      avoids 0/0, and round(0) == 0)
  shape faithfulness  any shape round-trips to exactly its own shape, with
                      the non-multiple-of-256 tail padded internally and
                      cropped back out

Hypothesis is a dev-extra (pyproject [dev]); the module skips cleanly where
it is not installed so the core suite carries no new dependency.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.quantized_state import BLOCK, QTensor, dequantize, quantize

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402


def _round_trip_bound(x: np.ndarray) -> None:
    """Assert the per-block round-trip error bound on one array."""
    t = quantize(jnp.asarray(x))
    dq = np.asarray(dequantize(t))
    assert dq.shape == x.shape
    assert np.all(np.isfinite(dq))
    scale = np.asarray(t.scale, np.float64)
    flat_err = np.abs(dq.reshape(-1).astype(np.float64)
                      - x.reshape(-1).astype(np.float64))
    n = flat_err.shape[0]
    # Per-element bound: half the step of the block the element lives in
    # (plus fp32 slack for the division/multiplication round trip).
    block_of = np.arange(n) // BLOCK
    bound = scale[block_of] / 2.0
    slack = np.maximum(np.abs(x.reshape(-1)), 1.0) * 1e-6
    assert np.all(flat_err <= bound + slack), (
        float(np.max(flat_err - bound)), float(np.max(scale))
    )


# ---------------------------------------------------------------------------
# Hypothesis properties.

finite_f32 = st.floats(
    min_value=-1e30, max_value=1e30,
    allow_nan=False, allow_infinity=False, width=32,
)


@settings(max_examples=50, deadline=None)
@given(
    data=st.lists(finite_f32, min_size=1, max_size=700),
)
def test_round_trip_error_bound_random_lengths(data):
    """Arbitrary finite fp32 content at arbitrary (non-multiple-of-BLOCK)
    lengths round-trips within half a quantization step per block."""
    _round_trip_bound(np.asarray(data, np.float32))


@settings(max_examples=25, deadline=None)
@given(
    shape=st.lists(st.integers(1, 9), min_size=1, max_size=4),
    seed=st.integers(0, 2**31 - 1),
    log_scale=st.floats(min_value=-30.0, max_value=30.0),
)
def test_round_trip_extreme_dynamic_range(shape, seed, log_scale):
    """Normal data scaled across ~60 decades of magnitude: the per-block
    scale adapts, the bound holds, nothing overflows to inf or collapses
    to NaN."""
    x = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    x = x * np.float32(10.0 ** log_scale)
    _round_trip_bound(x)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 4 * BLOCK + 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_mixed_magnitude_blocks(n, seed):
    """Blocks with wildly different magnitudes quantize independently:
    a large block does not destroy a small block's precision."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    # Scale alternating BLOCK-sized runs by 1e6.
    for start in range(0, n, 2 * BLOCK):
        x[start:start + BLOCK] *= 1e6
    _round_trip_bound(x)


# ---------------------------------------------------------------------------
# Plain edge cases (run even without hypothesis installed... except that the
# importorskip above is module-level; these double as the enumerated cases
# the properties are seeded around).


def test_zero_tensor_roundtrips_exactly():
    x = jnp.zeros((3, BLOCK + 7))
    t = quantize(x)
    assert bool(jnp.all(t.q == 0))
    dq = dequantize(t)
    np.testing.assert_array_equal(np.asarray(dq), np.zeros((3, BLOCK + 7)))


def test_single_element():
    t = quantize(jnp.asarray([-3.75], jnp.float32))
    dq = dequantize(t)
    assert dq.shape == (1,)
    np.testing.assert_allclose(dq, [-3.75], rtol=1e-2)
    # max-abs calibration: the extreme element itself is exact.
    assert abs(float(dq[0]) + 3.75) <= 3.75 / 127.0 / 2 + 1e-7


def test_scalar_shape():
    t = quantize(jnp.asarray(2.5, jnp.float32))
    dq = dequantize(t)
    assert dq.shape == ()
    np.testing.assert_allclose(float(dq), 2.5, rtol=1e-2)


def test_non_multiple_block_padding_is_invisible():
    """The internal pad to a BLOCK multiple never leaks: a (BLOCK + 1,)
    tensor whose tail element is the block max still reconstructs it."""
    x = np.ones(BLOCK + 1, np.float32) * 0.001
    x[-1] = 100.0
    t = quantize(jnp.asarray(x))
    assert t.q.shape == (2, BLOCK)
    dq = np.asarray(dequantize(t))
    assert dq.shape == (BLOCK + 1,)
    np.testing.assert_allclose(dq[-1], 100.0, rtol=1e-2)


def test_subnormal_block_floor():
    """A block whose max-abs sits below the 1e-12 floor quantizes to zeros
    (not NaN/inf) and dequantizes to exact zeros times the stored scale."""
    x = jnp.full((BLOCK,), 1e-20, jnp.float32)
    t = quantize(x)
    dq = dequantize(t)
    assert bool(jnp.all(jnp.isfinite(dq)))
    # Error is at most the original magnitude (everything rounds to 0).
    assert float(jnp.max(jnp.abs(dq - x))) <= 1e-20


def test_qtensor_is_a_pytree():
    """QTensor flattens/unflattens through jax.tree_util — the property the
    optimizer relies on to carry quantized moments in its state tree."""
    import jax

    t = quantize(jnp.arange(10, dtype=jnp.float32))
    leaves, treedef = jax.tree_util.tree_flatten(t)
    assert len(leaves) == 2
    t2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(t2, QTensor) and t2.shape == (10,)
    np.testing.assert_array_equal(
        np.asarray(dequantize(t2)), np.asarray(dequantize(t))
    )
