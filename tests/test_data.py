"""Data pipeline: determinism, structure, learnability, spec conformance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SHAPES, ShapeSpec
from repro.data import batch_for, image_batch, markov_tokens


def test_determinism():
    cfg = configs.smoke_config("llama3.2-1b")
    shape = ShapeSpec("t", 32, 4, "train")
    b1 = batch_for(cfg, shape, step=3, seed=1)
    b2 = batch_for(cfg, shape, step=3, seed=1)
    b3 = batch_for(cfg, shape, step=4, seed=1)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = configs.smoke_config("llama3.2-1b")
    shape = ShapeSpec("t", 32, 4, "train")
    b = batch_for(cfg, shape, step=0)
    # labels[t] must be the successor of tokens[t] in the same stream
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1])
    )


def test_markov_structure_is_learnable():
    """The affine rule predicts ~(1-noise) of transitions — an oracle gets
    much better than chance, so training loss can actually fall."""
    toks = np.asarray(markov_tokens(jax.random.PRNGKey(0), 16, 256, 97,
                                    noise=0.2))
    pred = (7 * toks[:, :-1] + 31) % 97
    acc = (pred == toks[:, 1:]).mean()
    assert acc > 0.7


@pytest.mark.parametrize("arch", configs.ARCHS)
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k", "decode_32k"])
def test_batches_match_input_specs(arch, shape_name):
    """batch_for output must exactly match input_specs shapes/dtypes
    (scaled down so CPU can materialize it)."""
    cfg = configs.smoke_config(arch)
    base = SHAPES[shape_name]
    if base.kind == "decode" and not cfg.supports_decode:
        pytest.skip("encoder-only")
    small = ShapeSpec(base.name, 64, 2, base.kind)
    specs = configs.input_specs(cfg, small)
    batch = batch_for(cfg, small, step=0)
    assert set(specs) == set(batch)
    for k in specs:
        assert specs[k].shape == batch[k].shape, k
        assert specs[k].dtype == batch[k].dtype, k


def test_image_batch():
    img = image_batch(0, 2, 32, 48)
    assert img.shape == (2, 32, 48, 3)
    assert bool(jnp.isfinite(img).all())
    img2 = image_batch(0, 2, 32, 48)
    np.testing.assert_array_equal(np.asarray(img), np.asarray(img2))
