"""Pipeline execution: cost-balanced stage partitioner + GPipe executor.

Three layers under test:

  * the partitioner (core/netplan): legal cut points (layout-elision chains
    and route/shortcut spans forbid cuts), cost-balanced exact search over
    the tick-synchronous latency model, the naive equal-layer-count
    strawman it must beat, and the auto microbatch chooser;
  * the v6 plan-cache "pipelines" section: warm loads reconstruct the
    partition with zero re-partitions;
  * the executor (distributed/pipeline): GPipe schedule over forced host
    devices must match the single-device ``run_network`` bit-for-bit-close
    (fp32 allclose; int8 SQNR-gated), exercised in subprocesses so the
    main test process keeps its single-device view (see conftest).
"""
import jax
import pytest

from repro.configs import vgg16, yolov3
from repro.core.netplan import (
    choose_n_micro,
    equal_count_partition,
    legal_cut_points,
    modeled_pipeline_latency,
    partition_network,
    plan_network,
    plan_pipeline,
    PipelinePlan,
)
from repro.core.planner import Planner
from repro.models.cnn import layer_ref_spans


def _plan(layers, hw=32, batch=4, impl="jax", dtype="float32"):
    planner = Planner(impl=impl, cache_path=None)
    return plan_network(layers, hw, hw, planner, batch=batch, dtype=dtype)


# ---------------------------------------------------------------------------
# Legal cut points


def test_legal_cut_points_vgg16_all_boundaries():
    # VGG-16 is a pure chain (no routes/shortcuts) and the jax impl keeps
    # every boundary logically laid out — every internal boundary is legal.
    netplan = _plan(vgg16.LAYERS)
    n = len(netplan.steps)
    assert legal_cut_points(netplan) == list(range(1, n))


def test_legal_cut_points_yolo_route_spans_forbidden():
    # yolov3-tiny's route layers reach back (16 <- 13, 19 <- {18, 8}): any
    # cut strictly inside a (producer, consumer] span would strand the
    # producer's activation on an earlier chip.
    netplan = _plan(yolov3.TINY_LAYERS)
    cuts = legal_cut_points(netplan)
    spans = layer_ref_spans([s.layer for s in netplan.steps])
    assert any(r + 1 < j for r, j in spans), "expected real route spans"
    for b in cuts:
        assert not any(r < b <= j for r, j in spans), b
    # The widest span (8 -> 19) forbids boundaries 9..19 specifically.
    assert all(not (9 <= b <= 19) for b in cuts)
    assert 8 in cuts and 20 in cuts


def test_legal_cut_points_respect_elision_chains():
    # Under the pallas impl the planner elides channel crop/re-pad pairs,
    # leaving physically-padded (non-trivial) boundary layouts; a cut there
    # would ship a physically-laid-out activation across the chip edge.
    netplan = _plan(vgg16.LAYERS, impl="pallas")
    nontrivial = [b for b in range(1, len(netplan.steps))
                  if not netplan.steps[b - 1].out_layout.trivial]
    assert nontrivial, "expected elided boundaries under the pallas impl"
    cuts = set(legal_cut_points(netplan))
    assert not cuts & set(nontrivial)


# ---------------------------------------------------------------------------
# Cost-balanced partitioning


@pytest.mark.parametrize("layers,name", [(vgg16.LAYERS, "vgg16"),
                                         (yolov3.TINY_LAYERS, "yolo")])
@pytest.mark.parametrize("batch", [4, 8])
def test_partition_balanced_beats_equal_count(layers, name, batch):
    """Acceptance: at 4 stages the cost-balanced partition's modeled
    latency strictly beats naive equal-layer-count splitting, scored by
    the planner's own predict_conv_time totals."""
    netplan = _plan(layers, batch=batch)
    balanced = partition_network(netplan, 4)
    naive = equal_count_partition(netplan, 4)
    assert balanced.modeled_latency_s() < naive.modeled_latency_s(), (
        name, balanced.stage_bounds, naive.stage_bounds)


def test_partition_structure_and_balance():
    netplan = _plan(vgg16.LAYERS)
    pp = partition_network(netplan, 4)
    n = len(netplan.steps)
    # Contiguous cover.
    assert pp.stage_bounds[0][0] == 0 and pp.stage_bounds[-1][1] == n
    for (a0, z0), (a1, _) in zip(pp.stage_bounds, pp.stage_bounds[1:]):
        assert z0 == a1 and a0 < z0
    # Every cut legal.
    legal = set(legal_cut_points(netplan))
    assert all(a in legal for a, _ in pp.stage_bounds[1:])
    # The balanced max stage is no worse than the naive strawman's.
    naive = equal_count_partition(netplan, 4)
    assert max(pp.stage_seconds) <= max(naive.stage_seconds) + 1e-12
    # n_micro tiles the batch.
    assert netplan.batch % pp.n_micro == 0


def test_partition_rejects_impossible_stage_counts():
    netplan = _plan(yolov3.TINY_LAYERS)
    with pytest.raises(ValueError):
        partition_network(netplan, len(legal_cut_points(netplan)) + 2)
    with pytest.raises(ValueError):
        partition_network(netplan, 0)


def test_equal_count_partition_cuts_are_legal():
    netplan = _plan(yolov3.TINY_LAYERS)
    naive = equal_count_partition(netplan, 4)
    legal = set(legal_cut_points(netplan))
    assert all(a in legal for a, _ in naive.stage_bounds[1:])


def test_pipeline_plan_json_roundtrip():
    pp = PipelinePlan(stage_bounds=((0, 3), (3, 7)),
                      stage_seconds=(1e-4, 2e-4), n_micro=2)
    assert PipelinePlan.from_json(pp.to_json()) == pp
    assert pp.n_stages == 2
    assert pp.bubble_fraction() == pytest.approx(1 / 3)


# ---------------------------------------------------------------------------
# Microbatch chooser + latency model


def test_choose_n_micro_monotone_then_saturating():
    # More batch -> more (or equal) microbatches, until the per-tick
    # overhead outweighs the bubble shrink and the chooser saturates.
    stage_seconds = (1e-3, 1e-3)
    ms = [choose_n_micro(stage_seconds, b) for b in (1, 2, 4, 8, 16, 32)]
    assert all(a <= b for a, b in zip(ms, ms[1:])), ms
    assert ms[0] == 1
    assert ms[-1] == ms[-2], f"expected saturation, got {ms}"


def test_choose_n_micro_divides_batch():
    for batch in (1, 3, 6, 8):
        m = choose_n_micro((1e-3, 5e-4, 2e-4), batch)
        assert batch % m == 0


def test_modeled_latency_tick_sum():
    # 2 stages, 2 microbatches, zero overhead: ticks are (s0), (max(s0,s1)),
    # (s1) at half the full-batch stage seconds each.
    t = modeled_pipeline_latency((2.0, 4.0), 2, tick_overhead_s=0.0)
    assert t == pytest.approx(1.0 + 2.0 + 2.0)
    # n_micro=1 degenerates to the sequential sum.
    assert modeled_pipeline_latency((2.0, 4.0), 1, 0.0) == pytest.approx(6.0)


# ---------------------------------------------------------------------------
# v6 cache: pipelines section


def test_pipeline_cache_warm_load_zero_repartition(tmp_path):
    cache = str(tmp_path / "plans.json")
    cold = Planner(impl="jax", cache_path=cache)
    pp_cold = plan_pipeline(vgg16.LAYERS, 32, 32, cold, 4, batch=4)
    cold.save()

    warm = Planner(impl="jax", cache_path=cache)
    pp_warm = plan_pipeline(vgg16.LAYERS, 32, 32, warm, 4, batch=4)
    assert pp_warm == pp_cold
    assert warm.pipeline_hits == 1
    assert warm.network_hits == 1      # the netplan warm-loads too
    assert warm.stats["tunes"] == 0


def test_pipeline_cache_scoped_by_stage_count(tmp_path):
    cache = str(tmp_path / "plans.json")
    planner = Planner(impl="jax", cache_path=cache)
    pp2 = plan_pipeline(vgg16.LAYERS, 32, 32, planner, 2, batch=4)
    pp4 = plan_pipeline(vgg16.LAYERS, 32, 32, planner, 4, batch=4)
    assert pp2.n_stages == 2 and pp4.n_stages == 4
    assert planner.pipeline_hits == 0  # distinct keys: both were cold


# ---------------------------------------------------------------------------
# verify_pipeline


def test_verify_pipeline_clean_on_partitioner_output():
    from repro.analysis import verify_pipeline

    netplan = _plan(yolov3.TINY_LAYERS)
    pp = partition_network(netplan, 4)
    report = verify_pipeline(netplan, pp, name="yolo-tiny")
    assert report.ok and report.clean, report.summary()
    assert report.passes_run == ("pipeline",)


def test_verify_pipeline_flags_illegal_cut_and_bad_seconds():
    from repro.analysis import verify_pipeline

    netplan = _plan(yolov3.TINY_LAYERS)
    n = len(netplan.steps)
    # Cut at 12 lands inside the (8 -> 19) route span.
    bad = PipelinePlan(stage_bounds=((0, 12), (12, n)),
                       stage_seconds=(1.0, 2.0), n_micro=3)
    report = verify_pipeline(netplan, bad)
    msgs = [f.message for f in report.findings]
    assert not report.ok
    assert any("illegal" in m for m in msgs), msgs
    assert any("disagree" in m for m in msgs), msgs          # fake seconds
    assert any("does not tile" in m for m in msgs), msgs     # 4 % 3 != 0


def test_verify_pipeline_flags_non_cover():
    from repro.analysis import verify_pipeline

    netplan = _plan(vgg16.LAYERS)
    bad = PipelinePlan(stage_bounds=((0, 5), (7, len(netplan.steps))),
                       stage_seconds=(1.0, 1.0), n_micro=1)
    report = verify_pipeline(netplan, bad)
    assert not report.ok


# ---------------------------------------------------------------------------
# GPipe executor vs single device (subprocess: forced host devices)


PARITY_CODE = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import vgg16, yolov3
    from repro.core.netplan import (partition_network, plan_network,
                                    prepare_net_params, run_network)
    from repro.core.planner import Planner
    from repro.distributed.pipeline import PipelineExecutor
    from repro.models.cnn import init_cnn

    assert jax.device_count() == 4, jax.device_count()
    layers = {layers}
    hw = 32
    for batch in (4, 8):
        planner = Planner(impl="jax", cache_path=None)
        netplan = plan_network(layers, hw, hw, planner, batch=batch)
        params = init_cnn(jax.random.PRNGKey(0), layers)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, hw, hw, 3))
        ref = run_network(netplan, prepare_net_params(netplan, params), x)
        pp = partition_network(netplan, 4)
        ex = PipelineExecutor(netplan, pp, params)
        assert ex.n_micro >= 1 and batch % ex.n_micro == 0
        got = ex(x)
        assert got.shape == ref.shape, (got.shape, ref.shape)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("PARITY_OK", batch, pp.stage_bounds)
"""


def test_pipeline_parity_vgg16_batch_4_8():
    from conftest import run_with_devices

    out = run_with_devices(4, PARITY_CODE.format(layers="vgg16.LAYERS"))
    assert out.count("PARITY_OK") == 2


def test_pipeline_parity_yolov3_tiny_batch_4_8():
    from conftest import run_with_devices

    out = run_with_devices(
        4, PARITY_CODE.format(layers="yolov3.TINY_LAYERS"))
    assert out.count("PARITY_OK") == 2


def test_ci_smoke_pipeline_interpret_parity():
    """A small planned net through the Pallas kernels in interpret mode,
    pipelined over 2 stages x 2 microbatches — the CI smoke subset."""
    from conftest import run_with_devices

    out = run_with_devices(2, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.netplan import (partition_network, plan_network,
                                        prepare_net_params, run_network)
        from repro.core.planner import Planner
        from repro.distributed.pipeline import PipelineExecutor
        from repro.models.cnn import CNNLayer, init_cnn

        C = CNNLayer
        # 128-lane-aligned channels keep the boundary layouts trivial
        # (physical == logical) so the partitioner has legal cut points.
        layers = (
            C("conv", out_channels=128, kernel=3, activation="relu"),
            C("maxpool", size=2, stride=2),
            C("conv", out_channels=64, kernel=1, pad=0, batch_norm=False,
              activation="linear"),
        )
        planner = Planner(impl="pallas", cache_path=None)
        netplan = plan_network(layers, 8, 8, planner, batch=4)
        params = init_cnn(jax.random.PRNGKey(0), layers)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 3))
        prepared = prepare_net_params(netplan, params)
        ref = run_network(netplan, prepared, x, interpret=True)
        pp = partition_network(netplan, 2, n_micro=2)
        ex = PipelineExecutor(netplan, pp, params, interpret=True)
        got = ex(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("SMOKE_OK", pp.stage_bounds)
    """)
    assert "SMOKE_OK" in out


def test_ci_smoke_pipeline_forward_int8_roundtrip():
    """The generic schedule must carry int8 activations without upcasting:
    the last-stage psum broadcast uses zeros_like, so an int8 stage_fn's
    output survives the collective bit-exact (the jnp.where(..., 0.0)
    regression this pins would upcast to float32)."""
    from conftest import run_with_devices

    out = run_with_devices(2, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_forward
        from repro.launch.mesh import make_stage_mesh

        mesh = make_stage_mesh(2)
        # Per-stage int8 offsets, stacked over the stage axis.
        stacked = jnp.asarray([[1], [2]], jnp.int8)

        def stage_fn(p, x):
            return x + p[0]

        x = jnp.arange(4 * 3, dtype=jnp.int8).reshape(4, 3)
        out = pipeline_forward(mesh, stage_fn, stacked, x, n_micro=2)
        assert out.dtype == jnp.int8, out.dtype
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(x) + 3)
        print("INT8_OK", out.dtype)
    """)
    assert "INT8_OK" in out


def test_pipeline_parity_int8_network():
    """int8 network through the pipeline: stages run the quantized kernels
    (fp32 activations between layers, per-layer quantization inside the
    stage body) and must match the single-device int8 executor at SQNR
    levels far above the quantization floor."""
    from conftest import run_with_devices

    out = run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import vgg16
        from repro.core.netplan import (partition_network, plan_network,
                                        prepare_net_params,
                                        pretransform_flags, run_network)
        from repro.core.planner import Planner
        from repro.core.quant import sqnr_db
        from repro.distributed.pipeline import PipelineExecutor
        from repro.models.cnn import init_cnn

        layers, hw, batch = vgg16.LAYERS, 32, 4
        planner = Planner(impl="jax", cache_path=None)
        netplan = plan_network(layers, hw, hw, planner, batch=batch,
                               dtype="int8")
        params = init_cnn(jax.random.PRNGKey(0), layers)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, hw, hw, 3))
        prepared = prepare_net_params(netplan, params, pretransform=True,
                                      calibration=x)
        flags = pretransform_flags(netplan, True)
        ref = run_network(netplan, prepared, x, pretransformed=flags)
        pp = partition_network(netplan, 4)
        ex = PipelineExecutor(netplan, pp, params, calibration=x)
        got = ex(x)
        q = sqnr_db(np.asarray(ref), np.asarray(got))
        assert q > 40.0, q
        print("INT8_NET_OK", q)
    """)
    assert "INT8_NET_OK" in out


def test_stage_mesh_requires_enough_devices():
    from repro.launch.mesh import make_stage_mesh

    with pytest.raises(ValueError):
        make_stage_mesh(jax.device_count() + 1)


# ---------------------------------------------------------------------------
# Facade integration (single forced-device-count subprocess)


def test_facade_pipeline_options_and_report():
    from conftest import run_with_devices

    out = run_with_devices(4, """
        import jax, numpy as np
        import repro
        from repro.models.cnn import init_cnn
        from repro.configs import vgg16

        desc = vgg16.MODEL.with_input_hw((32, 32))
        params = init_cnn(jax.random.PRNGKey(0), desc.layers)
        opts = repro.ExecutionOptions(impl="jax", batch=4, cache_path=None,
                                      pipeline_stages=4, validate="plan")
        compiled = repro.compile(desc, params, opts)
        report = compiled.plan_report()
        pipe = report["pipeline"]
        assert pipe["n_stages"] == 4
        assert 0.0 < pipe["bubble_fraction"] < 1.0
        assert len(pipe["stage_bounds"]) == 4
        assert all("stage" in row for row in report["layers"])

        x = np.random.default_rng(0).normal(
            size=(4, 32, 32, 3)).astype(np.float32)
        got = compiled.run(x)
        single = repro.compile(
            desc, params, opts.replace(pipeline_stages=0))
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(single.run(x)),
                                   rtol=1e-5, atol=1e-5)
        print("FACADE_OK", pipe["stage_bounds"])
    """)
    assert "FACADE_OK" in out


def test_execution_options_pipeline_validation():
    import repro

    with pytest.raises(ValueError):
        repro.ExecutionOptions(pipeline_stages=1)
    with pytest.raises(ValueError):
        repro.ExecutionOptions(microbatch=0)
    with pytest.raises(ValueError):
        repro.ExecutionOptions(microbatch="bogus")
    o = repro.ExecutionOptions(pipeline_stages=4, microbatch="auto")
    assert o.pipeline_stages == 4 and o.microbatch == "auto"
