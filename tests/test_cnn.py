"""Darknet-style CNNs: per-kernel ops, full networks, Table IV dims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import vgg16, yolov3
from repro.core.conv_spec import arithmetic_intensity
from repro.models.cnn import (
    activate_array,
    add_bias,
    batchnorm_inference,
    cnn_forward,
    conv_layer_dims,
    init_cnn,
    normalize,
    scale_bias,
)


def test_darknet_kernels():
    x = jnp.asarray([[-2.0, 0.0, 3.0]])
    np.testing.assert_allclose(activate_array(x, "leaky"), [[-0.2, 0.0, 3.0]])
    np.testing.assert_allclose(activate_array(x, "relu"), [[0.0, 0.0, 3.0]])
    np.testing.assert_allclose(activate_array(x, "linear"), x)
    np.testing.assert_allclose(add_bias(x, jnp.float32(1.0)), x + 1)
    np.testing.assert_allclose(scale_bias(x, jnp.float32(2.0)), x * 2)
    n = normalize(x, 1.0, 4.0)
    np.testing.assert_allclose(n, (x - 1.0) / 2.0, rtol=1e-4)


def test_batchnorm_inference_matches_formula():
    p = {"gamma": jnp.float32(2.0), "beta": jnp.float32(0.5),
         "mean": jnp.float32(1.0), "var": jnp.float32(4.0)}
    x = jnp.asarray([3.0])
    got = batchnorm_inference(x, p)
    np.testing.assert_allclose(got, (3 - 1) / 2 * 2 + 0.5, rtol=1e-4)


@pytest.mark.parametrize("layers,hw", [
    (vgg16.LAYERS, (64, 64)),
    (yolov3.TINY_LAYERS, (64, 64)),
    (yolov3.LAYERS_20, (64, 64)),
])
def test_network_forward(layers, hw):
    rng = jax.random.PRNGKey(0)
    params = init_cnn(rng, layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, *hw, 3))
    out = cnn_forward(params, layers, x, impl="jax")
    assert bool(jnp.isfinite(out).all())


def test_jax_impl_matches_xla_impl():
    layers = yolov3.TINY_LAYERS[:6]
    params = init_cnn(jax.random.PRNGKey(2), layers)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 32, 3))
    a = cnn_forward(params, layers, x, impl="jax")
    b = cnn_forward(params, layers, x, impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)


def test_vgg16_conv_count():
    convs = [l for l in vgg16.LAYERS if l.kind == "conv"]
    fcs = [l for l in vgg16.LAYERS if l.kind == "fc"]
    assert len(convs) == 13 and len(fcs) == 3  # paper §II.B
    assert all(l.kernel == 3 and l.stride == 1 for l in convs)


def test_yolov3_tiny_conv_count():
    convs = [l for l in yolov3.TINY_LAYERS if l.kind == "conv"]
    assert len(convs) == 13  # paper §II.B


def test_layer_dims_match_paper_table_iv():
    """First YOLOv3 layers at 608x608 must reproduce Table IV M,N,K + AI."""
    dims = conv_layer_dims(yolov3.LAYERS_20, 608, 608)
    by_layer = {d["layer"]: d for d in dims}
    # L1 (paper) == our conv 0; L2 == conv 1; L3 == conv 2
    for ours, (name, m, n, k, ai, _) in [(0, yolov3.TABLE_IV[0]),
                                         (1, yolov3.TABLE_IV[1]),
                                         (2, yolov3.TABLE_IV[2])]:
        d = by_layer[ours]
        assert (d["M"], d["N"], d["K"]) == (m, n, k), (name, d)
        assert abs(arithmetic_intensity(m, n, k) - ai) / ai < 0.05
