"""Algebraic validation of the F(6x6,3x3) Winograd transform set."""
import numpy as np

from repro.core.winograd import AT, BT, G, OUT_TILE, TILE, winograd_flops


def test_1d_f63_identity():
    """A^T [(G g) * (B^T d)] == valid 1D convolution, for random d, g."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        d = rng.normal(size=TILE)
        g = rng.normal(size=3)
        lhs = AT @ ((G @ g) * (BT @ d))
        ref = np.correlate(d, g, mode="valid")  # 6 outputs
        np.testing.assert_allclose(lhs, ref, rtol=1e-10, atol=1e-10)


def test_2d_tile_identity():
    """A^T [U * V] A == direct 3x3 valid conv of an 8x8 tile (fp64)."""
    rng = np.random.default_rng(1)
    for _ in range(20):
        d = rng.normal(size=(TILE, TILE))
        g = rng.normal(size=(3, 3))
        u = G @ g @ G.T
        v = BT @ d @ BT.T
        y = AT @ (u * v) @ AT.T
        ref = np.zeros((OUT_TILE, OUT_TILE))
        for i in range(OUT_TILE):
            for j in range(OUT_TILE):
                ref[i, j] = np.sum(d[i : i + 3, j : j + 3] * g)
        np.testing.assert_allclose(y, ref, rtol=1e-9, atol=1e-9)


def test_flop_model_reduction():
    """F(6,3) multiply reduction is 36*9/64 = 5.0625x per tile."""
    f = winograd_flops(oh=36, ow=36, cin=64, cout=64)
    assert abs(f["mult_reduction"] - 5.0625) < 1e-9
    # End-to-end (with transforms) must still be a real reduction for
    # reasonable channel counts — the source of the paper's 2.4x.
    assert f["winograd_flops"] < f["direct_flops"]


def test_transform_matrix_shapes():
    assert BT.shape == (8, 8) and G.shape == (8, 3) and AT.shape == (6, 8)
