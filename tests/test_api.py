"""The `repro.api` facade: compile() -> CompiledModel acceptance surface.

Pins the PR-5 contract (and, post-deprecation, its PR-10 tightening):
  - ``repro.compile(model, params, options).run(x)`` is the single entry
    point and reproduces the pre-facade jitted path (``_cnn_infer``)
    **bit-exactly** (and the XLA oracle within fp32 tolerance) for
    VGG-16 / YOLOv3-tiny;
  - ``ExecutionOptions`` round-trips through ``save()``/``load()`` with
    zero re-tunes (the v4 plan cache carries the tuning);
  - ``.serve()`` rides the bucket ladder without re-plumbing planner/cache;
  - the PR-5 deprecation shims (``cnn_infer`` / ``plan_layers`` / configs'
    plan helpers / direct ``CNNServingEngine`` construction) are gone after
    their one-release window;
  - LM configs compile through the same entry point (run + serve).
"""
import json
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.models.cnn import CNNLayer, cnn_forward, init_cnn

C = CNNLayer


def _tiny_net():
    layers = (
        C("conv", out_channels=16, kernel=3, activation="relu"),
        C("maxpool", size=2, stride=2),
        C("conv", out_channels=8, kernel=1, pad=0, batch_norm=False,
          activation="linear"),
    )
    return repro.CNNModel(layers, (8, 8), name="tiny"), init_cnn(
        jax.random.PRNGKey(0), layers
    )


def _tol(ref):
    scale = float(jnp.max(jnp.abs(ref)))
    return dict(rtol=1e-4, atol=1e-4 * max(scale, 1.0))


# ---------------------------------------------------------------------------
# Public surface


def test_public_surface():
    """`import repro; repro.compile(...)` is the documented entry point."""
    import repro.api

    assert repro.compile is repro.api.compile
    assert repro.ExecutionOptions is repro.api.ExecutionOptions
    for name in ("compile", "load", "ExecutionOptions", "CNNModel",
                 "CompiledModel", "Model", "ConvSpec", "Planner",
                 "NetworkExecutor", "conv2d"):
        assert name in repro.__all__, name
        assert hasattr(repro, name), name
    # Lazy serving attributes resolve (and only on demand).
    assert repro.CNNServingEngine is not None
    assert repro.ServingEngine is not None
    with pytest.raises(AttributeError):
        _ = repro.not_a_thing


def test_import_repro_clean_under_deprecation_errors():
    """CI contract: importing the public package fires no
    DeprecationWarning."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning", "-c",
         "import repro"],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr


# ---------------------------------------------------------------------------
# ExecutionOptions


def test_options_validation():
    with pytest.raises(ValueError):
        repro.ExecutionOptions(impl="cuda")
    with pytest.raises(ValueError):
        repro.ExecutionOptions(mode="guess")
    with pytest.raises(ValueError):
        repro.ExecutionOptions(batch=0)
    with pytest.raises(ValueError):
        repro.ExecutionOptions(buckets=())
    with pytest.raises(ValueError):
        repro.ExecutionOptions(buckets=(0, 4))


def test_options_normalize_and_roundtrip():
    opts = repro.ExecutionOptions(buckets=(8, 1, 4, 4), dtype=jnp.float32)
    assert opts.buckets == (1, 4, 8)
    assert opts.dtype == "float32"
    assert repro.ExecutionOptions.from_json(opts.to_json()) == opts
    assert hash(opts) == hash(repro.ExecutionOptions.from_json(opts.to_json()))
    assert opts.replace(batch=4).batch == 4 and opts.batch == 1
    # Unknown keys in old artifacts are ignored, not fatal.
    d = opts.to_json()
    d["some_future_field"] = 1
    assert repro.ExecutionOptions.from_json(d) == opts


def test_compile_rejects_bare_layers_without_input_hw():
    model, params = _tiny_net()
    with pytest.raises(ValueError):
        repro.compile(model.layers, params)
    compiled = repro.compile(
        model.layers, params,
        repro.ExecutionOptions(cache_path=None), input_hw=(8, 8),
    )
    assert compiled.model.input_hw == (8, 8)
    with pytest.raises(TypeError):
        repro.compile(object(), params)


# ---------------------------------------------------------------------------
# compile().run(): bit-exact vs the pre-facade jitted path, fp32-close vs
# the XLA oracle


@pytest.mark.parametrize("model_name", ["vgg16", "yolov3-tiny"])
def test_compile_run_bit_exact_vs_cnn_infer_and_oracle(model_name):
    from repro.configs import vgg16, yolov3

    desc = {"vgg16": vgg16.MODEL, "yolov3-tiny": yolov3.TINY_MODEL}[
        model_name
    ].with_input_hw((32, 32))
    params = init_cnn(jax.random.PRNGKey(0), desc.layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    # pretransform=False so both paths transform Winograd weights at the
    # same point in the graph — bit-exactness, not just closeness.
    compiled = repro.compile(desc, params, repro.ExecutionOptions(
        impl="jax", cache_path=None, batch=2, pretransform=False,
    ))
    got = compiled.run(x)

    plans = tuple(s.plan for s in compiled.network_plan(2).steps)
    from repro.models.cnn import _cnn_infer

    ref = _cnn_infer(params, desc.layers, x, impl="jax", plans=plans)
    assert jnp.array_equal(got, ref), (
        f"facade diverged from _cnn_infer by "
        f"{float(jnp.abs(got - ref).max())}"
    )
    oracle = cnn_forward(params, desc.layers, x, impl="xla")
    np.testing.assert_allclose(got, oracle, **_tol(oracle))


def test_compile_run_pallas_interpret_smoke():
    """The CI facade smoke: compile -> run on the Pallas kernels in
    interpret mode matches the oracle, with prepared (pre-transformed,
    block-padded) params."""
    model, params = _tiny_net()
    compiled = repro.compile(model, params, repro.ExecutionOptions(
        impl="pallas", interpret=True, cache_path=None,
    ))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 3))
    got = compiled.run(x)
    ref = cnn_forward(params, model.layers, x, impl="xla")
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_run_compiles_batches_on_demand_and_caches():
    model, params = _tiny_net()
    compiled = repro.compile(model, params,
                             repro.ExecutionOptions(cache_path=None))
    assert set(compiled._executors) == {1}          # options.batch, eagerly
    x2 = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    compiled.run(x2)
    compiled.run(x2)
    assert set(compiled._executors) == {1, 2}
    with pytest.raises(ValueError):
        compiled.run(jnp.zeros((8, 8, 3)))           # not (B, H, W, C)


# ---------------------------------------------------------------------------
# plan_report


def test_plan_report_structure():
    model, params = _tiny_net()
    compiled = repro.compile(model, params,
                             repro.ExecutionOptions(cache_path=None))
    rep = compiled.plan_report()
    assert rep["kind"] == "cnn" and rep["model"] == "tiny"
    n_convs = sum(1 for l in model.layers if l.kind == "conv")
    assert len(rep["layers"]) == n_convs
    for row in rep["layers"]:
        assert {"algorithm", "kernel_blocks", "predicted_s", "source",
                "elided"} <= set(row)
    assert rep["predicted_total_s"] > 0
    assert rep["tunes"] >= n_convs                  # cold cache


# ---------------------------------------------------------------------------
# save()/load(): options round-trip, plan cache carries the tuning


def test_save_load_zero_retunes(tmp_path):
    model, params = _tiny_net()
    cache = os.path.join(tmp_path, "plans.json")
    opts = repro.ExecutionOptions(impl="jax", cache_path=cache, batch=2,
                                  buckets=(1, 2))
    compiled = repro.compile(model, params, opts)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    y = compiled.run(x)
    art = compiled.save(os.path.join(tmp_path, "tiny.compiled.json"))

    with open(art) as f:
        data = json.load(f)
    assert data["format"] == repro.api.SAVE_FORMAT
    assert data["model"]["digest"] == model.digest

    loaded = repro.load(art, model, params)
    assert loaded.options == opts                   # full option round-trip
    assert loaded.planner.stats["tunes"] == 0       # cache v4 carried it
    assert loaded.planner.network_hits >= 1
    assert jnp.array_equal(loaded.run(x), y)


def test_load_rejects_mismatched_model(tmp_path):
    model, params = _tiny_net()
    art = repro.compile(
        model, params,
        repro.ExecutionOptions(cache_path=os.path.join(tmp_path, "p.json")),
    ).save(os.path.join(tmp_path, "a.json"))
    other = repro.CNNModel(model.layers[:1], (8, 8), name="other")
    with pytest.raises(ValueError):
        repro.load(art, other, params)
    # Geometry is identity too: same layers at another resolution must not
    # load silently (plans are shape-keyed — it would cold-retune).
    with pytest.raises(ValueError, match="input_hw"):
        repro.load(art, model.with_input_hw((16, 16)), params)
    # A bare layer table inherits the artifact's geometry.
    inherited = repro.load(art, model.layers, params)
    assert inherited.model.input_hw == model.input_hw
    assert inherited.planner.stats["tunes"] == 0


# ---------------------------------------------------------------------------
# serve(): the engine consumes the compilation


def test_serve_rides_compilation_without_warning(tmp_path):
    model, params = _tiny_net()
    compiled = repro.compile(model, params, repro.ExecutionOptions(
        cache_path=os.path.join(tmp_path, "plans.json"), buckets=(1, 2),
    ))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = compiled.serve()
    assert eng.planner is compiled.planner          # no re-plumbing
    assert eng.buckets == (1, 2)
    imgs = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (3, 8, 8, 3))
    )
    out = eng.infer(imgs)
    ref = np.asarray(compiled.run(jnp.asarray(imgs)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    assert eng.stats["batches"] == {1: 1, 2: 1}


# ---------------------------------------------------------------------------
# The PR-5 deprecation shims are gone after their one-release window


def test_legacy_shims_removed():
    """The facade is the only entry point: the one-release shims
    (``cnn_infer`` / ``plan_layers`` / configs' plan helpers / the
    ``_deprecation`` module itself) no longer exist, while the internals
    the facade rides (``_cnn_infer`` / ``_plan_layers``) remain."""
    import repro.models.cnn as cnn
    from repro.configs import vgg16, yolov3

    for mod, gone in ((cnn, ("cnn_infer", "plan_layers")),
                      (vgg16, ("plan_network", "network_plan")),
                      (yolov3, ("plan_network", "network_plan"))):
        for name in gone:
            assert not hasattr(mod, name), f"{mod.__name__}.{name}"
    assert hasattr(cnn, "_cnn_infer") and hasattr(cnn, "_plan_layers")
    with pytest.raises(ImportError):
        from repro import _deprecation  # noqa: F401


def test_cnn_engine_requires_compilation():
    """Direct ``CNNServingEngine(layers, params, ...)`` construction was a
    deprecated shim; it now raises, pointing at the facade path — which
    still works."""
    model, params = _tiny_net()
    from repro.serving import CNNServingEngine

    with pytest.raises(TypeError, match="from_compiled"):
        CNNServingEngine(model.layers, params, (8, 8), buckets=(2,),
                         impl="jax", cache_path=None)
    compiled = repro.compile(model, params, repro.ExecutionOptions(
        impl="jax", cache_path=None, buckets=(2,),
    ))
    eng = compiled.serve()
    imgs = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    )
    ref = np.asarray(compiled.run(jnp.asarray(imgs)))
    np.testing.assert_allclose(eng.infer(imgs), ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# LM configs ride the same facade


def test_lm_compile_run_and_serve(tmp_path):
    from repro import configs
    from repro.models import transformer as tf

    cfg = configs.smoke_config("llama3.2-1b", seq_len=32)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    compiled = repro.compile(cfg, params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 1,
                                cfg.vocab_size)
    logits = compiled.run(tokens)
    ref, _ = tf.forward(cfg, params, {"tokens": jnp.asarray(tokens,
                                                            jnp.int32)})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    rep = compiled.plan_report()
    assert rep["kind"] == "lm" and rep["model"] == cfg.name

    engine = compiled.serve(batch_size=2, capacity=64)
    uid = engine.submit(np.array([3, 5, 7]), max_new_tokens=4)
    results = engine.run()
    assert len(results[uid]) == 4

    art = compiled.save(os.path.join(tmp_path, "lm.compiled.json"))
    loaded = repro.load(art, cfg, params)
    assert loaded.options == compiled.options
