"""End-to-end behaviour tests for the full system."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ShapeSpec
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import TrainRunConfig, train


def test_training_reduces_loss(tmp_path):
    """A few dozen steps on the markov stream must cut loss well below
    ln(vocab) (chance)."""
    cfg = configs.smoke_config("llama3.2-1b")
    shape = ShapeSpec("t", 32, 8, "train")
    opt = AdamWConfig(lr=warmup_cosine(3e-3, 5, 60))
    run = TrainRunConfig(steps=60, checkpoint_every=30, log_every=10,
                         out_dir=str(tmp_path))
    metrics = train(cfg, shape, opt, run)
    chance = float(np.log(cfg.vocab_size))
    assert metrics["loss"] < 0.75 * chance, metrics


def test_crash_resume_continues_from_checkpoint(tmp_path):
    """Kill after step N, restart: loop resumes from the checkpoint step and
    metrics keep improving (fault-tolerance path)."""
    cfg = configs.smoke_config("qwen1.5-0.5b")
    shape = ShapeSpec("t", 32, 4, "train")
    opt = AdamWConfig(lr=warmup_cosine(2e-3, 5, 50))
    run1 = TrainRunConfig(steps=20, checkpoint_every=10, log_every=5,
                          out_dir=str(tmp_path))
    train(cfg, shape, opt, run1)
    # "crash" happened; restart targeting more steps
    run2 = TrainRunConfig(steps=40, checkpoint_every=10, log_every=5,
                          out_dir=str(tmp_path))
    m2 = train(cfg, shape, opt, run2)
    log = [json.loads(l) for l in
           open(os.path.join(str(tmp_path), "metrics.jsonl"))]
    steps = [r["step"] for r in log]
    assert 20 in steps and max(steps) == 39
    # resumed run must not restart from step 0 after the first run's end
    first_after_resume = [s for s in steps if s >= 20]
    assert min(first_after_resume) == 20
    assert m2["loss"] < log[0]["loss"]


def test_moe_training_step_balanced(tmp_path):
    cfg = configs.smoke_config("granite-moe-1b-a400m")
    shape = ShapeSpec("t", 32, 4, "train")
    opt = AdamWConfig(lr=warmup_cosine(1e-3, 2, 20))
    run = TrainRunConfig(steps=20, checkpoint_every=20, log_every=5,
                         out_dir=str(tmp_path))
    metrics = train(cfg, shape, opt, run)
    assert np.isfinite(metrics["loss"])
    assert metrics.get("moe_dropped_frac", 0.0) < 0.9


def test_cell_matrix_counts():
    """40 assigned cells; 31 runnable; 9 documented skips."""
    cells = list(configs.all_cells())
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(runnable) == 31 and len(skipped) == 9
    for _, _shape, _, reason in skipped:
        assert reason  # every skip carries a recorded reason


def test_grad_accum_matches_full_batch():
    """grad_accum=2 over a batch == single step over the same batch
    (same loss; params close)."""
    from repro.data import batch_for
    from repro.models import transformer as tf
    from repro.optim import adamw, constant
    from repro.train.step import make_train_step

    cfg = configs.smoke_config("llama3.2-1b")
    shape = ShapeSpec("t", 32, 8, "train")
    opt_cfg = AdamWConfig(lr=constant(1e-3))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(opt_cfg, params)
    batch = batch_for(cfg, shape, 0)
    p1, _, m1 = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=1))(
        params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=2))(
        params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_chunked_vocab_loss_matches_full():
    import dataclasses

    from repro.data import batch_for
    from repro.models import transformer as tf
    from repro.train.step import loss_fn

    cfg = configs.smoke_config("qwen1.5-0.5b")
    shape = ShapeSpec("t", 32, 4, "train")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = batch_for(cfg, shape, 0)
    l1, _ = loss_fn(cfg, params, batch)
    cfg2 = dataclasses.replace(cfg, loss_vocab_chunk=8)
    l2, _ = loss_fn(cfg2, params, batch)
    assert abs(float(l1) - float(l2)) < 1e-3
