"""Network-level executor: conformance, layout elision, sharding, serving.

The acceptance surface of the NetworkPlan/NetworkExecutor subsystem
(core/netplan.py):

  - executor output == the per-layer ``cnn_forward`` path == the XLA oracle
    for VGG-16 and YOLOv3-tiny at batch 1/4/8 (spatial dims scaled down so
    the suite stays fast — the layer-boundary math is resolution-free);
  - the jaxpr of a planned 2-conv chain contains **no** interior pad/slice
    ops once layouts are compatible (the crop+re-pad pair is elided);
  - elision is *numerically* invisible on the pallas interpret path;
  - shard_map data parallelism over the batch axis matches single-device;
  - the CNN serving engine's bucket dispatch returns per-request outputs
    identical to direct inference, and re-opens warm from the v4 cache.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.netplan import (
    Layout,
    NetworkExecutor,
    plan_network,
    prepare_net_params,
    run_network,
)
from repro.core.planner import Planner
from repro.models.cnn import CNNLayer, cnn_forward, init_cnn

C = CNNLayer


def _models():
    from repro.configs import vgg16, yolov3

    return {"vgg16": vgg16.LAYERS, "yolov3-tiny": yolov3.TINY_LAYERS}


def _tol(ref):
    scale = float(jnp.max(jnp.abs(ref)))
    return dict(rtol=1e-4, atol=1e-4 * max(scale, 1.0))


# ---------------------------------------------------------------------------
# Conformance: executor vs per-layer forward vs XLA oracle


@pytest.mark.parametrize("model", ["vgg16", "yolov3-tiny"])
@pytest.mark.parametrize("batch", [1, 4, 8])
def test_executor_matches_per_layer_and_oracle(model, batch):
    """Acceptance: the planned executor run is numerically equal (fp32
    tolerance) to the per-layer cnn_forward path and the XLA oracle."""
    layers = _models()[model]
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, 32, 32, 3))

    planner = Planner(impl="jax", cache_path=None)
    netplan = plan_network(layers, 32, 32, planner, batch=batch)
    executor = NetworkExecutor(netplan, params)
    got = executor(x)

    oracle = cnn_forward(params, layers, x, impl="xla")
    plans = [s.plan for s in netplan.steps]
    perlayer = cnn_forward(params, layers, x, impl="jax", plans=plans)
    np.testing.assert_allclose(got, oracle, **_tol(oracle))
    np.testing.assert_allclose(got, perlayer, **_tol(perlayer))


def test_executor_pallas_elision_matches_reference():
    """Layout persistence on the pallas interpret path: a mixed net whose
    channel pads genuinely flow (conv -> pool -> conv -> conv) matches the
    trivially-laid-out jax reference."""
    layers = (
        C("conv", out_channels=24, kernel=3, activation="relu"),
        C("maxpool", size=2, stride=2),
        C("conv", out_channels=40, kernel=1, pad=0, batch_norm=False,
          activation="leaky"),
        C("conv", out_channels=17, kernel=3, activation="leaky"),
    )
    params = init_cnn(jax.random.PRNGKey(2), layers)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 12, 3))
    ref = cnn_forward(params, layers, x, impl="xla")

    planner = Planner(impl="pallas", cache_path=None)
    netplan = plan_network(layers, 12, 12, planner, batch=2)
    assert netplan.elided_boundaries >= 1, "expected at least one elision"
    executor = NetworkExecutor(netplan, params, interpret=True)
    got = executor(x)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_executor_batch_keyed_network_cache(tmp_path):
    """Plans are batch-keyed and the network entry persists: a fresh
    planner on the same cache rebuilds each batch's NetworkPlan with zero
    tunes and a network-entry hit per batch."""
    layers = _models()["vgg16"]
    cache = os.path.join(tmp_path, "plans.json")
    p1 = Planner(impl="jax", cache_path=cache, autosave=False)
    np1 = plan_network(layers, 32, 32, p1, batch=1)
    np4 = plan_network(layers, 32, 32, p1, batch=4)
    p1.save()
    assert p1.stats["tunes"] > 0 and p1.network_hits == 0

    p2 = Planner(impl="jax", cache_path=cache)
    np1b = plan_network(layers, 32, 32, p2, batch=1)
    np4b = plan_network(layers, 32, 32, p2, batch=4)
    assert p2.stats["tunes"] == 0 and p2.network_hits == 2
    assert np1b == np1 and np4b == np4


# ---------------------------------------------------------------------------
# Layout elision: the jaxpr has no interior pad/slice ops


# The walker now lives in the static-analysis subsystem (it is the elision
# pass's foundation); the test keeps its old local name.
from repro.analysis import boundary_ops as _boundary_ops  # noqa: E402


def test_two_conv_chain_jaxpr_has_no_interior_pad_or_slice():
    """Acceptance: a planned 2-conv chain with compatible layouts compiles
    to a jaxpr with zero pad/slice ops outside the kernels — entry needs no
    pad (channels lane-aligned), the boundary is elided, exit needs no crop."""
    layers = (
        C("conv", out_channels=256, kernel=1, pad=0, batch_norm=False,
          activation="relu"),
        C("conv", out_channels=128, kernel=1, pad=0, batch_norm=False,
          activation="linear"),
    )
    params = init_cnn(jax.random.PRNGKey(0), layers, in_channels=128)
    planner = Planner(impl="pallas", cache_path=None)
    netplan = plan_network(layers, 8, 8, planner, in_channels=128, batch=2)
    prepared = prepare_net_params(netplan, params, pretransform=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 128))

    bad = _boundary_ops(
        lambda p, xx: run_network(netplan, p, xx, interpret=True),
        prepared, x,
    )
    assert not bad, f"interior pad/slice ops survived elision: {bad}"

    # And the chain still computes the right thing.
    got = run_network(netplan, prepared, x, interpret=True)
    ref = cnn_forward(params, layers, x, impl="xla")
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_padded_chain_pads_once_and_crops_once():
    """With unaligned channels the executor still owns the boundaries: one
    entry pad, one exit crop, nothing in between (the 24->40 boundary's
    crop+re-pad pair is elided)."""
    layers = (
        C("conv", out_channels=40, kernel=1, pad=0, batch_norm=False,
          activation="relu"),
        C("conv", out_channels=24, kernel=1, pad=0, batch_norm=False,
          activation="linear"),
    )
    params = init_cnn(jax.random.PRNGKey(0), layers, in_channels=24)
    planner = Planner(impl="pallas", cache_path=None)
    netplan = plan_network(layers, 8, 8, planner, in_channels=24, batch=2)
    assert not netplan.steps[0].out_layout.trivial, "boundary should elide"
    prepared = prepare_net_params(netplan, params, pretransform=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 24))
    ops = _boundary_ops(
        lambda p, xx: run_network(netplan, p, xx, interpret=True),
        prepared, x,
    )
    assert ops.count("pad") == 1 and ops.count("slice") == 1, ops


def test_row_tile_snapped_to_divisor_of_oh():
    """Network-level adjustment: the im2col row tile toh divides OH, so the
    kernel's row-block pad/crop pair vanishes identically."""
    from repro.core.conv_spec import ConvAlgorithm

    layers = (
        C("conv", out_channels=32, kernel=3, stride=2, activation="leaky"),
    )
    planner = Planner(impl="pallas", cache_path=None)
    # 28x28 stride-2 -> OH = 14; an autotuned toh of e.g. 8 would emit 16
    # rows; the plan must land on a divisor of 14.
    netplan = plan_network(layers, 28, 28, planner, batch=1)
    step = netplan.steps[0]
    assert step.plan.algorithm is ConvAlgorithm.IM2COL_GEMM
    toh = step.plan.kernel_blocks[0]
    assert step.out_hw[0] % toh == 0, (toh, step.out_hw)

    # Prime OH (149): the best divisor is 1 — the snap must NOT take it
    # (one program per output row); the tuned tile stays and the executor
    # crops the row tail instead.
    prime = (
        C("conv", out_channels=32, kernel=3, stride=2, activation="leaky"),
        C("conv", out_channels=32, kernel=5, stride=1, pad=2,
          activation="leaky"),
    )
    netplan_p = plan_network(prime, 297, 297, Planner(impl="pallas",
                                                      cache_path=None),
                             batch=1)
    step_p = netplan_p.steps[1]        # 149x149 input, 5x5 -> im2col
    assert step_p.plan.algorithm is ConvAlgorithm.IM2COL_GEMM
    assert step_p.out_hw[0] == 149
    assert step_p.plan.kernel_blocks[0] > 1


def test_layout_invariants():
    lo = Layout(24, 104)
    assert lo.phys_c == 128 and not lo.trivial
    assert Layout.from_json(lo.to_json()) == lo
    assert Layout(24).trivial


# ---------------------------------------------------------------------------
# Data-parallel batch execution (shard_map over the batch axis)


def test_executor_shard_map_matches_single_device():
    from conftest import run_with_devices

    out = run_with_devices(2, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.netplan import NetworkExecutor, plan_network
        from repro.core.planner import Planner
        from repro.models.cnn import CNNLayer, init_cnn

        C = CNNLayer
        layers = (
            C("conv", out_channels=16, kernel=3, activation="relu"),
            C("maxpool", size=2, stride=2),
            C("conv", out_channels=8, kernel=1, pad=0, batch_norm=False,
              activation="linear"),
        )
        params = init_cnn(jax.random.PRNGKey(0), layers)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 3))
        planner = Planner(impl="jax", cache_path=None)
        netplan = plan_network(layers, 8, 8, planner, batch=4)
        sharded = NetworkExecutor(netplan, params)          # 2 devices
        single = NetworkExecutor(netplan, params,
                                 devices=jax.devices()[:1])  # fallback
        assert sharded.mesh is not None and single.mesh is None
        np.testing.assert_allclose(np.asarray(sharded(x)),
                                   np.asarray(single(x)),
                                   rtol=1e-5, atol=1e-5)
        print("SHARDED_OK", sharded(x).shape)
    """)
    assert "SHARDED_OK" in out


# ---------------------------------------------------------------------------
# CNN serving engine: bucket dispatch + warm plan-per-bucket cache


def _tiny_net():
    layers = (
        C("conv", out_channels=16, kernel=3, activation="relu"),
        C("maxpool", size=2, stride=2),
        C("conv", out_channels=8, kernel=1, pad=0, batch_norm=False,
          activation="linear"),
    )
    params = init_cnn(jax.random.PRNGKey(0), layers)
    return layers, params


def _facade_engine(layers, params, buckets, cache_path, **kw):
    """Engine over the tiny net via the facade (direct construction of
    ``CNNServingEngine`` was a one-release shim and is gone)."""
    import repro

    compiled = repro.compile(
        repro.CNNModel(layers, (8, 8), name="tiny-netplan"), params,
        repro.ExecutionOptions(impl="jax", cache_path=cache_path,
                               buckets=tuple(buckets)),
    )
    return compiled.serve(**kw)


def test_cnn_engine_bucket_dispatch_and_results(tmp_path):
    layers, params = _tiny_net()
    cache = os.path.join(tmp_path, "plans.json")
    eng = _facade_engine(layers, params, (1, 2, 4), cache)
    rng = np.random.default_rng(0)
    imgs = rng.normal(size=(5, 8, 8, 3)).astype(np.float32)
    uids = [eng.submit(im) for im in imgs]
    results = eng.run()
    assert set(results) == set(uids)
    # 5 pending -> one full 4-bucket, then the 1-bucket; nothing padded.
    assert eng.stats["batches"] == {1: 1, 2: 0, 4: 1}
    assert eng.stats["padded_slots"] == 0

    # Per-request outputs equal direct single-image inference.
    ref = np.asarray(
        cnn_forward(params, layers, jnp.asarray(imgs), impl="xla")
    )
    for i, u in enumerate(uids):
        np.testing.assert_allclose(results[u], ref[i], rtol=1e-4, atol=1e-4)


def test_cnn_engine_pads_tail_bucket(tmp_path):
    layers, params = _tiny_net()
    eng = _facade_engine(layers, params, (4,),
                         os.path.join(tmp_path, "p.json"))
    rng = np.random.default_rng(1)
    imgs = rng.normal(size=(3, 8, 8, 3)).astype(np.float32)
    out = eng.infer(imgs)
    assert out.shape[0] == 3
    assert eng.stats["padded_slots"] == 1
    assert eng.stats["batches"][4] == 1


def test_cnn_engine_rejects_bad_shapes_and_buckets(tmp_path):
    from repro.serving import CNNServingEngine

    layers, params = _tiny_net()
    # Bucket validation still fires before the constructed-from-compilation
    # check, so an empty ladder is a ValueError, not the shim TypeError.
    with pytest.raises(ValueError):
        CNNServingEngine(layers, params, (8, 8), buckets=(),
                         cache_path=None)
    eng = _facade_engine(layers, params, (1,), None)
    with pytest.raises(ValueError):
        eng.submit(np.zeros((4, 4, 3), np.float32))


def test_cnn_engine_warm_cache_per_bucket(tmp_path):
    layers, params = _tiny_net()
    cache = os.path.join(tmp_path, "plans.json")
    cold = _facade_engine(layers, params, (1, 2), cache)
    assert cold.planner.stats["tunes"] > 0
    warm = _facade_engine(layers, params, (1, 2), cache)
    assert warm.warm and warm.planner.network_hits == 2


# ---------------------------------------------------------------------------
# CI smoke: tiny interpret-mode executor chain + one engine round-trip


def test_ci_smoke_two_layer_chain_interpret():
    """CI executor smoke: a 2-layer planned chain through the pallas
    kernels in interpret mode."""
    layers = (
        C("conv", out_channels=16, kernel=3, activation="relu"),
        C("conv", out_channels=8, kernel=1, pad=0, batch_norm=False,
          activation="linear"),
    )
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 3))
    planner = Planner(impl="pallas", cache_path=None)
    netplan = plan_network(layers, 8, 8, planner, batch=1)
    got = NetworkExecutor(netplan, params, interpret=True)(x)
    ref = cnn_forward(params, layers, x, impl="xla")
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_ci_smoke_engine_bucket_roundtrip(tmp_path):
    """CI serving smoke: one bucket round-trip through the engine."""
    layers, params = _tiny_net()
    eng = _facade_engine(layers, params, (2,),
                         os.path.join(tmp_path, "p.json"))
    imgs = np.random.default_rng(2).normal(size=(2, 8, 8, 3)).astype(
        np.float32
    )
    out = eng.infer(imgs)
    assert out.shape[0] == 2 and np.isfinite(out).all()
    assert eng.stats["batches"][2] == 1
