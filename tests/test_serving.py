"""Serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = configs.smoke_config("llama3.2-1b", seq_len=64)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_completes_requests(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_size=2, capacity=64)
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(1, cfg.vocab_size, 5), max_new_tokens=4)
            for _ in range(3)]
    results = eng.run()
    assert set(results) == set(uids)
    for toks in results.values():
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab_size for t in toks)


def test_engine_greedy_matches_manual_decode(setup):
    """Single request, greedy: engine output == manual prefill+argmax loop."""
    cfg, params = setup
    prompt = np.array([5, 9, 2, 7], np.int32)
    eng = ServingEngine(cfg, params, batch_size=1, capacity=64)
    eng.submit(prompt, max_new_tokens=5)
    got = list(eng.run().values())[0]

    cache = tf.init_cache(cfg, 1, 64)
    toks = jnp.asarray(prompt)[None]
    for t in range(len(prompt)):
        logits, cache = tf.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
    expect = []
    pos = len(prompt)
    nxt = int(jnp.argmax(logits[0]))
    for _ in range(5):
        expect.append(nxt)
        logits, cache = tf.decode_step(
            cfg, params, cache, jnp.asarray([[nxt]], jnp.int32), jnp.int32(pos)
        )
        nxt = int(jnp.argmax(logits[0]))
        pos += 1
    assert got == expect


def test_engine_rejects_encoder_archs():
    cfg = configs.smoke_config("hubert-xlarge")
    with pytest.raises(AssertionError):
        ServingEngine(cfg, {}, 1, 16)


def test_engine_batches_recurrent_archs():
    """Recurrent stacks (rglru here) now continuous-batch: the live-slot
    mask (jnp.where around every state write in decode_step) keeps
    non-decoding rows' state frozen during slot-local prefill, and
    admission resets the freed slot's state rows — so batch_size > 1 is
    legal where it used to raise."""
    cfg = configs.smoke_config("recurrentgemma-9b", seq_len=32)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, batch_size=2, capacity=32)
    rng = np.random.default_rng(1)
    uids = [eng.submit(rng.integers(1, cfg.vocab_size, 4), max_new_tokens=2)
            for _ in range(3)]
    results = eng.run()
    assert set(results) == set(uids)
    assert all(len(t) == 2 for t in results.values())


def test_engine_rejects_empty_prompt(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_size=1, capacity=64)
    with pytest.raises(ValueError):
        eng.submit(np.array([], np.int32))


class _RecordingEngine(ServingEngine):
    """ServingEngine that records, per request uid, the logits row each
    output token was sampled from.  Greedy argmax alone degenerates on a
    random-init model (it repeats the last prompt token, so a corrupted KV
    cache could still pass); full logits trajectories discriminate."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.logits_by_uid = {}

    def _decode_one_step(self):
        live = [(i, r) for i, r in enumerate(self.slot_req) if r is not None]
        before = {r.uid: len(r.out_tokens) for _, r in live}
        self._captured = {}
        super()._decode_one_step()
        for i, r in live:
            if len(r.out_tokens) > before[r.uid]:
                self.logits_by_uid.setdefault(r.uid, []).append(
                    self._captured[i]
                )

    def _sample(self, logits):
        # _decode_one_step samples live slots in ascending index order.
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        self._captured[live[len(self._captured)]] = logits.copy()
        return super()._sample(logits)


@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "recurrentgemma-9b", "xlstm-125m"]
)
def test_continuous_batching_matches_single_request(setup, arch):
    """Mixed prompt lengths + mid-flight admission: the per-step logits of
    every request must match its single-request decode.  Regression test
    for the shared-max-position KV-cache desync and the mid-flight
    admission corrupting live slots' caches — and, for the recurrent archs
    (rglru / mlstm+slstm), for the masked per-row state updates plus the
    admission-time slot state reset that make batching them legal at all."""
    if arch == "llama3.2-1b":
        cfg, params = setup
    else:
        cfg = configs.smoke_config(arch, seq_len=64)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.array([5, 9, 2, 7], np.int32),
               np.array([3, 1], np.int32),
               np.array([11, 4, 6, 8, 2, 10], np.int32)]

    def decode(batch_size, reqs):
        eng = _RecordingEngine(cfg, params, batch_size=batch_size,
                               capacity=64)
        uids = [eng.submit(p, max_new_tokens=3) for p in reqs]
        results = eng.run()
        return [(results[u], np.stack(eng.logits_by_uid[u])) for u in uids]

    # Reference: each prompt decoded alone.
    refs = [decode(1, [p])[0] for p in prompts]
    # Batched: 2 slots, 3 requests -> the third admits mid-flight into the
    # slot freed by whichever of the first two finishes, at a position
    # behind the still-running request.
    got = decode(2, prompts)

    for (ref_out, ref_logits), (out, logits) in zip(refs, got):
        np.testing.assert_allclose(logits, ref_logits, rtol=1e-5, atol=1e-5)
        assert out == ref_out
