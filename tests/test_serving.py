"""Serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as tf
from repro.serving import ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = configs.smoke_config("llama3.2-1b", seq_len=64)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_completes_requests(setup):
    cfg, params = setup
    eng = ServingEngine(cfg, params, batch_size=2, capacity=64)
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(1, cfg.vocab_size, 5), max_new_tokens=4)
            for _ in range(3)]
    results = eng.run()
    assert set(results) == set(uids)
    for toks in results.values():
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab_size for t in toks)


def test_engine_greedy_matches_manual_decode(setup):
    """Single request, greedy: engine output == manual prefill+argmax loop."""
    cfg, params = setup
    prompt = np.array([5, 9, 2, 7], np.int32)
    eng = ServingEngine(cfg, params, batch_size=1, capacity=64)
    eng.submit(prompt, max_new_tokens=5)
    got = list(eng.run().values())[0]

    cache = tf.init_cache(cfg, 1, 64)
    toks = jnp.asarray(prompt)[None]
    for t in range(len(prompt)):
        logits, cache = tf.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                       jnp.int32(t))
    expect = []
    pos = len(prompt)
    nxt = int(jnp.argmax(logits[0]))
    for _ in range(5):
        expect.append(nxt)
        logits, cache = tf.decode_step(
            cfg, params, cache, jnp.asarray([[nxt]], jnp.int32), jnp.int32(pos)
        )
        nxt = int(jnp.argmax(logits[0]))
        pos += 1
    assert got == expect


def test_engine_rejects_encoder_archs():
    cfg = configs.smoke_config("hubert-xlarge")
    with pytest.raises(AssertionError):
        ServingEngine(cfg, {}, 1, 16)
