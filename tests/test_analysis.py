"""Compile-time plan verifier: clean-plan proofs and mutation coverage.

The acceptance surface of the static-analysis subsystem (repro.analysis):

  - a cleanly planned VGG-16 / YOLOv3-tiny verifies with **zero** findings
    at fp32 and int8, full level (trace + all five passes) and plan level;
  - each analysis pass catches exactly its injected NetworkPlan corruption:
      oversized kernel block            -> vmem (budget proof)
      wrong declared accumulator dtype  -> dtype (int8 legality lint)
      forced un-elided boundary         -> elision (layout-contract proof)
      bogus Layout (inflated phys_c)    -> traffic (HBM byte audit)
    ... and *only* that pass fires, so a red verifier report names the
    defect rather than burying it in cascading noise;
  - the promoted jaxpr boundary walker descends into pjit and cond call
    params (the old test-local walker silently skipped tuple-valued
    sub-jaxprs);
  - the facade gate: ``ExecutionOptions(validate=...)`` is validated, and
    ``CompiledModel.verify_report()`` returns a clean report for a planned
    model.

Everything here is trace-only (``jax.make_jaxpr``): no kernel runs, no
device execution, so the whole file stays fast enough for tier-1.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    PlanVerificationError,
    boundary_ops,
    verify_network,
)
from repro.core.conv_spec import ConvAlgorithm
from repro.core.netplan import (
    Layout,
    plan_network,
    prepare_net_params,
    resolve_algorithm,
)
from repro.core.planner import Planner
from repro.models.cnn import init_cnn

# Reduced geometries matching the CLI smoke runs: the layer-boundary and
# block math the verifier proves is resolution-free.
CASES = {
    "vgg16": dict(hw=(64, 64)),
    "yolov3-tiny": dict(hw=(128, 128)),
}


def _layers(model):
    from repro.configs import vgg16, yolov3

    return {"vgg16": vgg16.LAYERS, "yolov3-tiny": yolov3.TINY_LAYERS}[model]


def _plan(model, dtype="float32", batch=1):
    h, w = CASES[model]["hw"]
    planner = Planner(impl="pallas", cache_path=None)
    return plan_network(
        _layers(model), h, w, planner, in_channels=3, batch=batch,
        dtype=dtype,
    )


def _verify(netplan, params=None):
    layers = tuple(s.layer for s in netplan.steps)
    if params is None:
        params = init_cnn(jax.random.PRNGKey(0), layers)
    prepared = prepare_net_params(netplan, params, pretransform=True)
    return verify_network(netplan, prepared)


def _with_mutated_plan(netplan, idx, **plan_changes):
    """Rebuild the netplan with one step's ConvPlan corrupted.

    Rebuilding (rather than patching the step in place) keeps the stored
    layouts self-consistent with the mutated plan, so the *only* defect the
    verifier can find is the one the mutation injects."""
    from repro.core.netplan import build_network_plan

    plans = [
        dataclasses.replace(s.plan, **plan_changes)
        if s.index == idx and s.plan is not None else s.plan
        for s in netplan.steps
    ]
    return build_network_plan(
        [s.layer for s in netplan.steps], *netplan.input_hw,
        in_channels=netplan.in_channels, batch=netplan.batch,
        plans=plans, impl=netplan.impl, dtype=netplan.dtype_name,
    )


def _replace_step(netplan, idx, **changes):
    steps = list(netplan.steps)
    steps[idx] = dataclasses.replace(steps[idx], **changes)
    return dataclasses.replace(netplan, steps=tuple(steps))


def _only_pass(report, pass_name):
    """The report is red, and every finding belongs to ``pass_name``."""
    assert not report.ok
    assert report.by_pass(pass_name), report.findings
    others = [f for f in report.findings if f.pass_name != pass_name]
    assert not others, others


def _algo(step):
    return resolve_algorithm(step.spec, step.plan, *step.in_hw)


# ---------------------------------------------------------------------------
# Clean plans verify with zero findings


@pytest.mark.parametrize("model", list(CASES))
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_clean_plan_zero_findings(model, dtype):
    """Acceptance: full-level verification of a cleanly planned network is
    green — the five byte passes plus the four kernel-interior passes all
    run, no findings, per-kernel metrics present."""
    report = _verify(_plan(model, dtype=dtype))
    assert report.ok and not report.findings, report.findings
    assert set(report.passes_run) == {
        "structure", "vmem", "traffic", "elision", "dtype",
        "race", "bounds", "accum", "overflow",
    }
    assert report.kernels
    for row in report.kernels:
        assert row["vmem_bytes"] <= row["vmem_budget"]


@pytest.mark.parametrize("model", list(CASES))
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_clean_plan_kernel_level_zero_findings(model, dtype):
    """The kernel rung alone (structure + race/bounds/accum/overflow) also
    certifies the zoo clean, and its metric rows carry the interior facts
    (recovered reduction axes, Mosaic schedule, corner count; the int8
    accumulator bound on q8 kernels)."""
    layers = tuple(_layers(model))
    netplan = _plan(model, dtype=dtype)
    params = init_cnn(jax.random.PRNGKey(0), layers)
    prepared = prepare_net_params(netplan, params, pretransform=True)
    report = verify_network(netplan, prepared, level="kernel")
    assert report.ok and not report.findings, report.findings
    assert set(report.passes_run) == {
        "structure", "race", "bounds", "accum", "overflow"
    }
    for row in report.kernels:
        assert "reduction_axes" in row and "bounds_points_checked" in row
        assert row["dimension_semantics"] is not None
    if dtype == "int8":
        q8 = [r for r in report.kernels if "_q8" in r["kernel"]]
        assert q8 and all(
            0 < r["acc_bound"] <= 2**31 - 1 and r["acc_headroom"] >= 1.0
            for r in q8
        )


def test_plan_level_zero_findings():
    """Plan-level (no trace) verification is also green, and cheap enough
    that it never needs prepared parameters."""
    report = verify_network(_plan("vgg16"), level="plan")
    assert report.ok and not report.findings
    assert set(report.passes_run) == {"vmem", "elision"}


# ---------------------------------------------------------------------------
# Mutation coverage: each pass flags exactly its defect


@pytest.mark.parametrize("model", list(CASES))
def test_oversized_block_flags_vmem_only(model):
    """An im2col output block inflated to 2048 lanes pushes the weight slab
    past the 16 MiB budget; the vmem pass (and only it) goes red."""
    netplan = _plan(model)
    idx = max(
        s.index for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
        and _algo(s) is ConvAlgorithm.IM2COL_GEMM
    )
    toh, bc, _ = netplan.steps[idx].plan.kernel_blocks
    report = _verify(
        _with_mutated_plan(netplan, idx, kernel_blocks=(toh, bc, 2048))
    )
    _only_pass(report, "vmem")
    assert any(
        f.step == idx and "budget" in f.message
        for f in report.by_pass("vmem")
    )


@pytest.mark.parametrize("model", list(CASES))
def test_wrong_dtype_flags_dtype_only(model):
    """Flipping a quantized step's declared dtype to fp32 *after* the
    parameters were prepared leaves an int8 kernel running under an
    fp32-claiming plan — the dtype pass pins it to the step; the byte-level
    passes stay quiet rather than cascading itemsize noise."""
    netplan = _plan(model, dtype="int8")
    idx = min(
        s.index for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
        and s.plan.dtype == "int8"
    )
    layers = tuple(s.layer for s in netplan.steps)
    params = init_cnn(jax.random.PRNGKey(0), layers)
    prepared = prepare_net_params(netplan, params, pretransform=True)
    step = netplan.steps[idx]
    bad = dataclasses.replace(step.plan, dtype="float32")
    mutated = _replace_step(netplan, idx, plan=bad)
    report = verify_network(mutated, prepared)
    _only_pass(report, "dtype")
    assert any(f.step == idx for f in report.by_pass("dtype"))


@pytest.mark.parametrize("model", list(CASES))
def test_forced_unelided_boundary_flags_elision_only(model):
    """Forcing a trivial out_layout where the layout rules elide the
    boundary is a planning-contract violation: the executor faithfully runs
    the cropped boundary (so structure/vmem/traffic/dtype stay green), but
    the elision decision check goes red against the re-derived reference."""
    netplan = _plan(model)
    idx = min(
        s.index for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
        and s.out_layout.pad_c > 0
    )
    oc = netplan.steps[idx].spec.out_channels
    report = _verify(_replace_step(netplan, idx, out_layout=Layout(oc)))
    _only_pass(report, "elision")
    assert any(f.step == idx for f in report.by_pass("elision"))


@pytest.mark.parametrize("model", list(CASES))
def test_bogus_layout_flags_traffic_only(model):
    """Doubling a boundary's physical channel count (producer out_layout +
    consumer in_layout, so the plan stays self-consistent and executable)
    moves real HBM bytes the reference layouts never asked for — the
    traffic audit flags it; footprints and decisions are unchanged."""
    netplan = _plan(model)
    pairs = []
    convs = [
        s for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
    ]
    for s, t in zip(convs, convs[1:]):
        if s.out_layout.pad_c > 0 and t.in_layout.phys_c == s.out_layout.phys_c:
            pairs.append((s.index, t.index))
    src, dst = pairs[0]
    oc = netplan.steps[src].spec.out_channels
    phys = netplan.steps[src].out_layout.phys_c
    fat = Layout(oc, 2 * phys - oc)         # doubled, still block-divisible
    mutated = _replace_step(netplan, src, out_layout=fat)
    mutated = _replace_step(
        mutated, dst,
        in_layout=Layout(netplan.steps[dst].in_layout.c,
                         fat.phys_c - netplan.steps[dst].in_layout.c),
    )
    report = _verify(mutated)
    _only_pass(report, "traffic")
    assert any(f.step in (src, dst) for f in report.by_pass("traffic"))


# ---------------------------------------------------------------------------
# Kernel-interior mutation coverage: each injected kernel defect is caught
# by exactly one of the four interior passes (race / bounds / accum /
# overflow), so a red report names the defect class.


def _interior_report(pairs):
    from repro.analysis.passes import (
        accum_pass,
        bounds_pass,
        overflow_pass,
        race_pass,
    )
    from repro.analysis.report import VerifyReport

    report = VerifyReport(
        level="kernel", passes_run=("race", "bounds", "accum", "overflow")
    )
    race_pass(report, pairs)
    bounds_pass(report, pairs)
    accum_pass(report, pairs)
    overflow_pass(report, pairs)
    return report


def _records(fn, *args):
    from repro.analysis import pallas_calls

    recs = pallas_calls(jax.make_jaxpr(fn)(*args))
    assert recs, "no pallas_call recovered from the trace"
    return recs


def test_noninjective_index_map_flags_race_only():
    """Two grid programs mapped to the same output block: (i, j) -> (i+j,)
    collides at (0,1)/(1,0).  The race pass produces the concrete witness;
    bounds stays green (the map's range fits the operand), accum/overflow
    have nothing to say (no scratch, no q8)."""
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def fn(x):
        return pl.pallas_call(
            kernel,
            grid=(2, 2),
            in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i + j, j))],
            out_specs=pl.BlockSpec((8, 128), lambda i, j: (i + j, 0)),
            out_shape=jax.ShapeDtypeStruct((24, 128), jnp.float32),
            interpret=True,
        )(x)

    (rec,) = _records(fn, jnp.ones((24, 256), jnp.float32))
    report = _interior_report([(rec, {"step": 0, "reduction_axes": ()})])
    _only_pass(report, "race")
    assert any("not injective" in f.message for f in report.by_pass("race"))


def test_oob_block_window_flags_bounds_only():
    """An index map shifted by one block ((i, j) -> (i+1, j)) drives the
    last grid row's window past the operand extent.  Bounds flags it with
    the offending corner; the shifted map is still injective, so race stays
    green."""
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def fn(x):
        return pl.pallas_call(
            kernel,
            grid=(2, 2),
            in_specs=[pl.BlockSpec((8, 128), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((8, 128), lambda i, j: (i + 1, j)),
            out_shape=jax.ShapeDtypeStruct((16, 256), jnp.float32),
            interpret=True,
        )(x)

    (rec,) = _records(fn, jnp.ones((16, 256), jnp.float32))
    report = _interior_report([(rec, {"step": 0, "reduction_axes": ()})])
    _only_pass(report, "bounds")
    f = report.by_pass("bounds")[0]
    assert "escapes" in f.message and f.actual > f.expected


def test_flipped_init_guard_flags_accum_only():
    """An accumulator initialized under the *last*-step guard instead of the
    first: every earlier reduction step reads stale VMEM.  The accum pass
    pins the flipped predicate; the flush guard is still correct, so the
    race pass (which owns the flush obligation) stays green."""
    from jax.experimental import pallas as pl

    def kernel(a_ref, b_ref, o_ref, acc_ref):
        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _init():                                    # wrong step!
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += a_ref[...] @ b_ref[...]

        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _flush():
            o_ref[...] = acc_ref[...]

    def fn(a, b):
        from jax.experimental.pallas import tpu as pltpu

        return pl.pallas_call(
            kernel,
            grid=(1, 1, 2),
            in_specs=[
                pl.BlockSpec((8, 128), lambda i, j, k: (i, k)),
                pl.BlockSpec((128, 128), lambda i, j, k: (k, j)),
            ],
            out_specs=pl.BlockSpec((8, 128), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32)],
            interpret=True,
        )(a, b)

    (rec,) = _records(
        fn, jnp.ones((8, 256), jnp.float32), jnp.ones((256, 128), jnp.float32)
    )
    report = _interior_report([(rec, {"step": 0, "reduction_axes": (2,)})])
    _only_pass(report, "accum")
    assert any(
        "initializing write is guarded on step 1" in f.message
        for f in report.by_pass("accum")
    )


def test_overflow_shape_flags_overflow_only():
    """A q8 GEMM deep enough that K*127^2 exceeds int32: the real kernel
    (structurally sound — race/bounds/accum all green) is rejected purely
    by the interval certificate.  K = 133248 > floor((2^31-1)/127^2)."""
    from repro.kernels.gemm.ops import gemm_call_descriptor, matmul_padded_call

    kp = 133248                                     # 1041 K-blocks of 128
    block = (8, 128, 128)

    def fn(a, b, scale):
        return matmul_padded_call(
            a, b, block, variant="6loop", interpret=True, scale_p=scale,
        )

    (rec,) = _records(
        fn,
        jnp.ones((8, kp), jnp.int8),
        jnp.ones((kp, 128), jnp.int8),
        jnp.ones((1, 128), jnp.float32),
    )
    desc = gemm_call_descriptor(8, 128, kp, block, dtype_bytes=1, scale=True)
    desc["step"] = 0
    report = _interior_report([(rec, desc)])
    _only_pass(report, "overflow")
    f = report.by_pass("overflow")[0]
    assert f.actual == kp * 127 * 127 and f.actual > f.expected


def test_declared_k_drift_flags_overflow():
    """The descriptor's declared reduction depth must match the traced
    operand shapes — plan/trace drift is an overflow-pass error even when
    both depths are individually safe."""
    from repro.kernels.gemm.ops import gemm_call_descriptor, matmul_padded_call

    def fn(a, b, scale):
        return matmul_padded_call(
            a, b, (8, 128, 128), variant="6loop", interpret=True,
            scale_p=scale,
        )

    (rec,) = _records(
        fn,
        jnp.ones((8, 256), jnp.int8),
        jnp.ones((256, 128), jnp.int8),
        jnp.ones((1, 128), jnp.float32),
    )
    desc = gemm_call_descriptor(8, 128, 512, (8, 128, 128), dtype_bytes=1,
                                scale=True)        # lies: traced K is 256
    desc["step"] = 0
    report = _interior_report([(rec, desc)])
    _only_pass(report, "overflow")
    assert report.by_pass("overflow")[0].expected == 512


def test_three_pass_winograd_kernels_analyze_clean():
    """The non-fused Winograd path (input transform / tuple multiply /
    output transform) — three pallas_calls the zoo's planner rarely picks —
    still certifies clean under all four interior passes."""
    from repro.core.conv_spec import ConvSpec
    from repro.kernels.winograd.ops import conv2d_winograd_pallas

    spec = ConvSpec(64, 64)
    recs = _records(
        lambda x, w, b: conv2d_winograd_pallas(
            x, w, spec, fused=False, interpret=True, bias=b
        ),
        jnp.zeros((1, 32, 32, 64), jnp.float32),
        jnp.zeros((3, 3, 64, 64), jnp.float32),
        jnp.zeros((64,), jnp.float32),
    )
    assert len(recs) == 3
    pairs = [(r, {"step": i}) for i, r in enumerate(recs)]
    report = _interior_report(pairs)
    assert report.clean, report.findings


# ---------------------------------------------------------------------------
# Boundary walker recursion (the promoted tests/test_netplan.py walker)


def test_boundary_walker_descends_into_pjit():
    @jax.jit
    def inner(x):
        return jnp.pad(x, ((0, 1), (0, 0)))

    def fn(x):
        return inner(x) * 2.0

    assert "pad" in boundary_ops(fn, jnp.ones((4, 4)))


def test_boundary_walker_descends_into_cond_branches():
    """cond branches arrive as a *tuple* of ClosedJaxprs in eqn params —
    exactly the shape the old test-local walker silently skipped."""

    def fn(x):
        return jax.lax.cond(
            x.sum() > 0,
            lambda v: jnp.pad(v, ((0, 1), (0, 0))),
            lambda v: jnp.concatenate([v, v[:1]]),
            x,
        )

    ops = boundary_ops(fn, jnp.ones((4, 4)))
    assert "pad" in ops


def test_channel_census_descends_switch_branches():
    """Regression (PR-7 gap): the channel-boundary census skipped cond_p
    sub-jaxprs because their invars omit the branch selector, so a pad on
    the tainted activation *inside* a ``lax.switch`` branch — exactly how
    PR-9 pipeline stage bodies appear in the traced jaxpr — was invisible
    to full-level verification."""
    from repro.analysis import channel_boundary_ops

    def fn(idx, x):
        return jax.lax.switch(
            idx,
            [
                lambda v: jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, 8))),
                lambda v: jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, 8))) * 2.0,
            ],
            x,
        )

    jaxpr = jax.make_jaxpr(fn)(0, jnp.ones((1, 4, 4, 8)))
    ops = channel_boundary_ops(jaxpr, taint_invar=-1)
    assert ops and all(op.kind == "pad" for op in ops), ops


def test_verify_pipeline_kernel_level():
    """verify_pipeline's kernel rung traces every stage slice at microbatch
    size and runs the interior passes over each stage's pallas_calls —
    requiring prepared params, and covering all plan steps exactly once."""
    from repro.analysis import verify_pipeline
    from repro.core.netplan import NetworkExecutor, plan_pipeline

    netplan = _plan("vgg16", batch=4)
    planner = Planner(impl="pallas", cache_path=None)
    pipeplan = plan_pipeline(
        _layers("vgg16"), *CASES["vgg16"]["hw"], planner, 2,
        in_channels=3, batch=4, netplan=netplan,
    )
    with pytest.raises(ValueError, match="parameter"):
        verify_pipeline(netplan, pipeplan, level="kernel")
    ex = NetworkExecutor(netplan, init_cnn(
        jax.random.PRNGKey(0), tuple(_layers("vgg16"))
    ), interpret=True, pretransform=True)
    report = verify_pipeline(
        netplan, pipeplan, name="vgg16", params=ex.params,
        pretransformed=ex.pretransformed, level="kernel",
    )
    assert report.ok and not report.findings, report.findings
    assert set(report.passes_run) == {
        "pipeline", "structure", "race", "bounds", "accum", "overflow"
    }
    planned = {
        s.index for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
    }
    assert {row["step"] for row in report.kernels} == planned


# ---------------------------------------------------------------------------
# Facade wiring


def test_execution_options_validate_is_checked():
    from repro.api import ExecutionOptions

    with pytest.raises(ValueError):
        ExecutionOptions(validate="bogus")
    assert ExecutionOptions(validate="plan").validate == "plan"


def test_facade_verify_report_clean():
    """repro.compile(...).verify_report() is green for a planned model and
    the validate='full' executor gate admits it."""
    import repro
    from repro.api import ExecutionOptions
    from repro.api.model import as_model
    from repro.models.cnn import CNNLayer

    model = as_model(
        (
            CNNLayer("conv", out_channels=32, kernel=3),
            CNNLayer("conv", out_channels=32, kernel=3),
        ),
        input_hw=(32, 32),
        name="chain2",
    )
    params = model.init_params(jax.random.PRNGKey(0))
    opts = ExecutionOptions(
        impl="pallas", mode="cost", interpret=True, cache_path=None,
        validate="full",
    )
    compiled = repro.compile(model, params, opts)
    report = compiled.verify_report()
    assert report.ok and not report.findings, report.findings
    assert report.level == "full"
    # the gate itself: executor construction under validate='full' passes
    assert compiled.executor(1) is not None


def test_plan_verification_error_carries_report():
    report = verify_network(_plan("vgg16"), level="plan")
    err = PlanVerificationError(report)
    assert err.report is report
