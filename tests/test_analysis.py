"""Compile-time plan verifier: clean-plan proofs and mutation coverage.

The acceptance surface of the static-analysis subsystem (repro.analysis):

  - a cleanly planned VGG-16 / YOLOv3-tiny verifies with **zero** findings
    at fp32 and int8, full level (trace + all five passes) and plan level;
  - each analysis pass catches exactly its injected NetworkPlan corruption:
      oversized kernel block            -> vmem (budget proof)
      wrong declared accumulator dtype  -> dtype (int8 legality lint)
      forced un-elided boundary         -> elision (layout-contract proof)
      bogus Layout (inflated phys_c)    -> traffic (HBM byte audit)
    ... and *only* that pass fires, so a red verifier report names the
    defect rather than burying it in cascading noise;
  - the promoted jaxpr boundary walker descends into pjit and cond call
    params (the old test-local walker silently skipped tuple-valued
    sub-jaxprs);
  - the facade gate: ``ExecutionOptions(validate=...)`` is validated, and
    ``CompiledModel.verify_report()`` returns a clean report for a planned
    model.

Everything here is trace-only (``jax.make_jaxpr``): no kernel runs, no
device execution, so the whole file stays fast enough for tier-1.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    PlanVerificationError,
    boundary_ops,
    verify_network,
)
from repro.core.conv_spec import ConvAlgorithm
from repro.core.netplan import (
    Layout,
    plan_network,
    prepare_net_params,
    resolve_algorithm,
)
from repro.core.planner import Planner
from repro.models.cnn import init_cnn

# Reduced geometries matching the CLI smoke runs: the layer-boundary and
# block math the verifier proves is resolution-free.
CASES = {
    "vgg16": dict(hw=(64, 64)),
    "yolov3-tiny": dict(hw=(128, 128)),
}


def _layers(model):
    from repro.configs import vgg16, yolov3

    return {"vgg16": vgg16.LAYERS, "yolov3-tiny": yolov3.TINY_LAYERS}[model]


def _plan(model, dtype="float32", batch=1):
    h, w = CASES[model]["hw"]
    planner = Planner(impl="pallas", cache_path=None)
    return plan_network(
        _layers(model), h, w, planner, in_channels=3, batch=batch,
        dtype=dtype,
    )


def _verify(netplan, params=None):
    layers = tuple(s.layer for s in netplan.steps)
    if params is None:
        params = init_cnn(jax.random.PRNGKey(0), layers)
    prepared = prepare_net_params(netplan, params, pretransform=True)
    return verify_network(netplan, prepared)


def _with_mutated_plan(netplan, idx, **plan_changes):
    """Rebuild the netplan with one step's ConvPlan corrupted.

    Rebuilding (rather than patching the step in place) keeps the stored
    layouts self-consistent with the mutated plan, so the *only* defect the
    verifier can find is the one the mutation injects."""
    from repro.core.netplan import build_network_plan

    plans = [
        dataclasses.replace(s.plan, **plan_changes)
        if s.index == idx and s.plan is not None else s.plan
        for s in netplan.steps
    ]
    return build_network_plan(
        [s.layer for s in netplan.steps], *netplan.input_hw,
        in_channels=netplan.in_channels, batch=netplan.batch,
        plans=plans, impl=netplan.impl, dtype=netplan.dtype_name,
    )


def _replace_step(netplan, idx, **changes):
    steps = list(netplan.steps)
    steps[idx] = dataclasses.replace(steps[idx], **changes)
    return dataclasses.replace(netplan, steps=tuple(steps))


def _only_pass(report, pass_name):
    """The report is red, and every finding belongs to ``pass_name``."""
    assert not report.ok
    assert report.by_pass(pass_name), report.findings
    others = [f for f in report.findings if f.pass_name != pass_name]
    assert not others, others


def _algo(step):
    return resolve_algorithm(step.spec, step.plan, *step.in_hw)


# ---------------------------------------------------------------------------
# Clean plans verify with zero findings


@pytest.mark.parametrize("model", list(CASES))
@pytest.mark.parametrize("dtype", ["float32", "int8"])
def test_clean_plan_zero_findings(model, dtype):
    """Acceptance: full-level verification of a cleanly planned network is
    green — all five passes run, no findings, per-kernel metrics present."""
    report = _verify(_plan(model, dtype=dtype))
    assert report.ok and not report.findings, report.findings
    assert set(report.passes_run) == {
        "structure", "vmem", "traffic", "elision", "dtype"
    }
    assert report.kernels
    for row in report.kernels:
        assert row["vmem_bytes"] <= row["vmem_budget"]


def test_plan_level_zero_findings():
    """Plan-level (no trace) verification is also green, and cheap enough
    that it never needs prepared parameters."""
    report = verify_network(_plan("vgg16"), level="plan")
    assert report.ok and not report.findings
    assert set(report.passes_run) == {"vmem", "elision"}


# ---------------------------------------------------------------------------
# Mutation coverage: each pass flags exactly its defect


@pytest.mark.parametrize("model", list(CASES))
def test_oversized_block_flags_vmem_only(model):
    """An im2col output block inflated to 2048 lanes pushes the weight slab
    past the 16 MiB budget; the vmem pass (and only it) goes red."""
    netplan = _plan(model)
    idx = max(
        s.index for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
        and _algo(s) is ConvAlgorithm.IM2COL_GEMM
    )
    toh, bc, _ = netplan.steps[idx].plan.kernel_blocks
    report = _verify(
        _with_mutated_plan(netplan, idx, kernel_blocks=(toh, bc, 2048))
    )
    _only_pass(report, "vmem")
    assert any(
        f.step == idx and "budget" in f.message
        for f in report.by_pass("vmem")
    )


@pytest.mark.parametrize("model", list(CASES))
def test_wrong_dtype_flags_dtype_only(model):
    """Flipping a quantized step's declared dtype to fp32 *after* the
    parameters were prepared leaves an int8 kernel running under an
    fp32-claiming plan — the dtype pass pins it to the step; the byte-level
    passes stay quiet rather than cascading itemsize noise."""
    netplan = _plan(model, dtype="int8")
    idx = min(
        s.index for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
        and s.plan.dtype == "int8"
    )
    layers = tuple(s.layer for s in netplan.steps)
    params = init_cnn(jax.random.PRNGKey(0), layers)
    prepared = prepare_net_params(netplan, params, pretransform=True)
    step = netplan.steps[idx]
    bad = dataclasses.replace(step.plan, dtype="float32")
    mutated = _replace_step(netplan, idx, plan=bad)
    report = verify_network(mutated, prepared)
    _only_pass(report, "dtype")
    assert any(f.step == idx for f in report.by_pass("dtype"))


@pytest.mark.parametrize("model", list(CASES))
def test_forced_unelided_boundary_flags_elision_only(model):
    """Forcing a trivial out_layout where the layout rules elide the
    boundary is a planning-contract violation: the executor faithfully runs
    the cropped boundary (so structure/vmem/traffic/dtype stay green), but
    the elision decision check goes red against the re-derived reference."""
    netplan = _plan(model)
    idx = min(
        s.index for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
        and s.out_layout.pad_c > 0
    )
    oc = netplan.steps[idx].spec.out_channels
    report = _verify(_replace_step(netplan, idx, out_layout=Layout(oc)))
    _only_pass(report, "elision")
    assert any(f.step == idx for f in report.by_pass("elision"))


@pytest.mark.parametrize("model", list(CASES))
def test_bogus_layout_flags_traffic_only(model):
    """Doubling a boundary's physical channel count (producer out_layout +
    consumer in_layout, so the plan stays self-consistent and executable)
    moves real HBM bytes the reference layouts never asked for — the
    traffic audit flags it; footprints and decisions are unchanged."""
    netplan = _plan(model)
    pairs = []
    convs = [
        s for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
    ]
    for s, t in zip(convs, convs[1:]):
        if s.out_layout.pad_c > 0 and t.in_layout.phys_c == s.out_layout.phys_c:
            pairs.append((s.index, t.index))
    src, dst = pairs[0]
    oc = netplan.steps[src].spec.out_channels
    phys = netplan.steps[src].out_layout.phys_c
    fat = Layout(oc, 2 * phys - oc)         # doubled, still block-divisible
    mutated = _replace_step(netplan, src, out_layout=fat)
    mutated = _replace_step(
        mutated, dst,
        in_layout=Layout(netplan.steps[dst].in_layout.c,
                         fat.phys_c - netplan.steps[dst].in_layout.c),
    )
    report = _verify(mutated)
    _only_pass(report, "traffic")
    assert any(f.step in (src, dst) for f in report.by_pass("traffic"))


# ---------------------------------------------------------------------------
# Boundary walker recursion (the promoted tests/test_netplan.py walker)


def test_boundary_walker_descends_into_pjit():
    @jax.jit
    def inner(x):
        return jnp.pad(x, ((0, 1), (0, 0)))

    def fn(x):
        return inner(x) * 2.0

    assert "pad" in boundary_ops(fn, jnp.ones((4, 4)))


def test_boundary_walker_descends_into_cond_branches():
    """cond branches arrive as a *tuple* of ClosedJaxprs in eqn params —
    exactly the shape the old test-local walker silently skipped."""

    def fn(x):
        return jax.lax.cond(
            x.sum() > 0,
            lambda v: jnp.pad(v, ((0, 1), (0, 0))),
            lambda v: jnp.concatenate([v, v[:1]]),
            x,
        )

    ops = boundary_ops(fn, jnp.ones((4, 4)))
    assert "pad" in ops


# ---------------------------------------------------------------------------
# Facade wiring


def test_execution_options_validate_is_checked():
    from repro.api import ExecutionOptions

    with pytest.raises(ValueError):
        ExecutionOptions(validate="bogus")
    assert ExecutionOptions(validate="plan").validate == "plan"


def test_facade_verify_report_clean():
    """repro.compile(...).verify_report() is green for a planned model and
    the validate='full' executor gate admits it."""
    import repro
    from repro.api import ExecutionOptions
    from repro.api.model import as_model
    from repro.models.cnn import CNNLayer

    model = as_model(
        (
            CNNLayer("conv", out_channels=32, kernel=3),
            CNNLayer("conv", out_channels=32, kernel=3),
        ),
        input_hw=(32, 32),
        name="chain2",
    )
    params = model.init_params(jax.random.PRNGKey(0))
    opts = ExecutionOptions(
        impl="pallas", mode="cost", interpret=True, cache_path=None,
        validate="full",
    )
    compiled = repro.compile(model, params, opts)
    report = compiled.verify_report()
    assert report.ok and not report.findings, report.findings
    assert report.level == "full"
    # the gate itself: executor construction under validate='full' passes
    assert compiled.executor(1) is not None


def test_plan_verification_error_carries_report():
    report = verify_network(_plan("vgg16"), level="plan")
    err = PlanVerificationError(report)
    assert err.report is report
