"""Cross-product conv conformance suite: every algorithm x impl x shape
variant x epilogue mode against the XLA oracle.

Routing gaps (like the Pallas DIRECT path silently dropping padding) cannot
land silently again: each eligible (algorithm, impl, stride, padding,
kernel, epilogue) cell is asserted against ``conv2d_reference`` followed by
the unfused reference epilogue.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv_spec import (
    ConvAlgorithm,
    ConvSpec,
    Epilogue,
    apply_epilogue,
)
from repro.core.conv2d import conv2d, conv2d_reference


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), jnp.float32)


def _eligible(algo: ConvAlgorithm, k: int, s: int) -> bool:
    """Which forced algorithms can run a (k, k) stride-s conv at all."""
    if algo is ConvAlgorithm.DIRECT:
        return k == 1
    if algo is ConvAlgorithm.WINOGRAD:
        return k == 3 and s == 1
    return True  # im2col+GEMM is the generic path


ALGOS = [ConvAlgorithm.DIRECT, ConvAlgorithm.IM2COL_GEMM, ConvAlgorithm.WINOGRAD]


@pytest.mark.parametrize("algo", ALGOS, ids=lambda a: a.value)
@pytest.mark.parametrize("impl", ["jax", "pallas"])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("pad", [0, 1])
@pytest.mark.parametrize("k", [1, 3])
@pytest.mark.parametrize("fused", [False, True], ids=["plain", "epilogue"])
def test_conv_conformance(algo, impl, stride, pad, k, fused):
    if not _eligible(algo, k, stride):
        pytest.skip(f"{algo.value} ineligible for k={k} s={stride}")
    spec = ConvSpec(4, 8, (k, k), (stride, stride), (pad, pad), algorithm=algo)
    oh, ow = spec.out_hw(10, 12)
    assert oh >= 1 and ow >= 1
    x = _rand((2, 10, 12, 4), seed=k * 100 + stride * 10 + pad)
    w = _rand((k, k, 4, 8), seed=7)
    epi = (
        Epilogue(bias=_rand((8,), seed=9), activation="leaky")
        if fused else None
    )
    got = conv2d(x, w, spec, impl=impl, interpret=True, epilogue=epi)
    ref = apply_epilogue(conv2d_reference(x, w, spec), epi)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_pallas_direct_1x1_padding_regression():
    """The confirmed DIRECT-path bug: kernels/conv_ops.py subsampled
    x[:, ::sh, ::sw, :] without ever applying spec.padding, so a padded 1x1
    conv returned (1, 8, 8, 8) where the oracle returns (1, 10, 10, 8) —
    silently wrong shape *and* values."""
    spec = ConvSpec(4, 8, kernel_size=(1, 1), padding=(1, 1))
    x = _rand((1, 8, 8, 4), seed=1)
    w = _rand((1, 1, 4, 8), seed=2)
    ref = conv2d_reference(x, w, spec)
    assert ref.shape == (1, 10, 10, 8)
    got = conv2d(x, w, spec, impl="pallas", interpret=True)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Network-level acceptance: fused epilogue vs reference for every conv layer
# of the paper's two networks.


def _network_layer_specs(layers, h, w, in_ch=3):
    """(spec, h, w) for every conv layer at its actual input resolution."""
    from repro.models.cnn import _conv_spec

    out = []
    ch = []
    cur_ch, cur_h, cur_w = in_ch, h, w
    for l in layers:
        if l.kind == "conv":
            spec = _conv_spec(l, cur_ch)
            out.append((spec, cur_h, cur_w, l.activation))
            cur_h, cur_w = spec.out_hw(cur_h, cur_w)
            cur_ch = l.out_channels
        elif l.kind == "maxpool":
            cur_h, cur_w = -(-cur_h // l.stride), -(-cur_w // l.stride)
        elif l.kind == "upsample":
            cur_h, cur_w = cur_h * l.size, cur_w * l.size
        elif l.kind == "route":
            cur_ch = sum(ch[j][0] for j in l.from_layers)
            cur_h, cur_w = ch[l.from_layers[0]][1], ch[l.from_layers[0]][2]
        elif l.kind == "fc":
            cur_ch = l.out_channels
        ch.append((cur_ch, cur_h, cur_w))
    return out


@pytest.mark.parametrize("model", ["vgg16", "yolov3-tiny"])
def test_fused_epilogue_every_conv_layer(model):
    """Acceptance: fused conv+bias+activation matches conv2d_reference +
    unfused epilogue within 1e-4 for every conv layer shape of VGG-16 and
    YOLOv3-tiny (channel counts as published; spatial dims scaled down so
    the suite stays fast — the epilogue math is resolution-independent)."""
    from repro.configs import vgg16, yolov3

    layers = vgg16.LAYERS if model == "vgg16" else yolov3.TINY_LAYERS
    seen = set()
    for i, (spec, h, w, act) in enumerate(
        _network_layer_specs(layers, 32, 32)
    ):
        key = (spec.in_channels, spec.out_channels, spec.kernel_size,
               spec.stride, h, w)
        if key in seen or h < spec.kh or w < spec.kw:
            continue
        seen.add(key)
        x = _rand((1, h, w, spec.in_channels), seed=i)
        wt = _rand(
            (spec.kh, spec.kw, spec.in_channels, spec.out_channels), seed=i + 1
        ) * (1.0 / (spec.kh * spec.in_channels ** 0.5))
        bias = _rand((spec.out_channels,), seed=i + 2)
        epi = Epilogue(bias=bias, activation=act)
        ref = apply_epilogue(conv2d_reference(x, wt, spec), epi)
        got = conv2d(x, wt, spec, epilogue=epi)
        scale = float(jnp.max(jnp.abs(ref)))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4 * scale)


@pytest.mark.parametrize("model", ["vgg16", "yolov3-tiny"])
def test_cnn_infer_matches_unfused_forward(model):
    """Whole-network acceptance: the jitted fused entry point (batchnorm
    folded, epilogues in-kernel) matches the unfused XLA-conv forward."""
    from repro.configs import vgg16, yolov3
    from repro.models.cnn import cnn_forward, cnn_infer, init_cnn

    layers = vgg16.LAYERS if model == "vgg16" else yolov3.TINY_LAYERS
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    ref = cnn_forward(params, layers, x, impl="xla")
    got = cnn_infer(params, layers, x)
    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4 * scale)


def test_fold_batchnorm_matches_batchnorm_inference():
    """Folded weights+bias reproduce conv -> bn exactly (up to fp32)."""
    from repro.models.cnn import (
        CNNLayer,
        batchnorm_inference,
        fold_batchnorm,
        init_cnn,
    )

    layers = (CNNLayer("conv", out_channels=8, kernel=3, batch_norm=True),)
    params = init_cnn(jax.random.PRNGKey(3), layers)
    # Non-trivial bn statistics.
    bn = {
        "gamma": _rand((8,), 4) + 2.0,
        "beta": _rand((8,), 5),
        "mean": _rand((8,), 6),
        "var": jnp.abs(_rand((8,), 7)) + 0.5,
    }
    params[0]["bn"] = bn
    folded = fold_batchnorm(params, layers)
    assert "bn" not in folded[0] and "b" in folded[0]
    spec = ConvSpec(3, 8, (3, 3), (1, 1), (1, 1))
    x = _rand((1, 12, 12, 3), 8)
    ref = batchnorm_inference(conv2d_reference(x, params[0]["w"], spec), bn)
    got = conv2d_reference(x, folded[0]["w"], spec) + folded[0]["b"]
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
