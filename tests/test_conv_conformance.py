"""Cross-product conv conformance suite: every algorithm x impl x shape
variant x epilogue mode against the XLA oracle.

Routing gaps (like the Pallas DIRECT path silently dropping padding) cannot
land silently again: each eligible (algorithm, impl, stride, padding,
kernel, epilogue) cell is asserted against ``conv2d_reference`` followed by
the unfused reference epilogue.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv_spec import (
    ConvAlgorithm,
    ConvSpec,
    Epilogue,
    apply_epilogue,
)
from repro.core.conv2d import conv2d, conv2d_reference


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), jnp.float32)


def _eligible(algo: ConvAlgorithm, k: int, s: int) -> bool:
    """Which forced algorithms can run a (k, k) stride-s conv at all."""
    if algo is ConvAlgorithm.DIRECT:
        return k == 1
    if algo is ConvAlgorithm.WINOGRAD:
        return k == 3 and s == 1
    return True  # im2col+GEMM is the generic path


ALGOS = [ConvAlgorithm.DIRECT, ConvAlgorithm.IM2COL_GEMM, ConvAlgorithm.WINOGRAD]


@pytest.mark.parametrize("algo", ALGOS, ids=lambda a: a.value)
@pytest.mark.parametrize("impl", ["jax", "pallas"])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("pad", [0, 1])
@pytest.mark.parametrize("k", [1, 3])
@pytest.mark.parametrize("fused", [False, True], ids=["plain", "epilogue"])
def test_conv_conformance(algo, impl, stride, pad, k, fused):
    if not _eligible(algo, k, stride):
        pytest.skip(f"{algo.value} ineligible for k={k} s={stride}")
    spec = ConvSpec(4, 8, (k, k), (stride, stride), (pad, pad), algorithm=algo)
    oh, ow = spec.out_hw(10, 12)
    assert oh >= 1 and ow >= 1
    x = _rand((2, 10, 12, 4), seed=k * 100 + stride * 10 + pad)
    w = _rand((k, k, 4, 8), seed=7)
    epi = (
        Epilogue(bias=_rand((8,), seed=9), activation="leaky")
        if fused else None
    )
    got = conv2d(x, w, spec, impl=impl, interpret=True, epilogue=epi)
    ref = apply_epilogue(conv2d_reference(x, w, spec), epi)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# Winograd edge cases: the fused single-pass megakernel and the 3-pass
# pipeline against the oracle on every awkward shape class.


@pytest.mark.parametrize("fused", [True, False], ids=["megakernel", "3pass"])
@pytest.mark.parametrize("h,w", [(10, 14), (13, 7), (9, 16), (11, 23)])
def test_winograd_crop_path(h, w, fused):
    """Output sizes not divisible by 6: the tile grid over-covers and the
    final crop must discard exactly the padded rows/cols."""
    spec = ConvSpec(4, 8, (3, 3), (1, 1), (1, 1),
                    algorithm=ConvAlgorithm.WINOGRAD)
    oh, ow = spec.out_hw(h, w)
    assert oh % 6 != 0 or ow % 6 != 0
    from repro.kernels.winograd import conv2d_winograd_pallas

    x = _rand((2, h, w, 4), seed=h * 31 + w)
    wt = _rand((3, 3, 4, 8), seed=3)
    got = conv2d_winograd_pallas(x, wt, spec, interpret=True, fused=fused)
    ref = conv2d_reference(x, wt, spec)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("fused", [True, False], ids=["megakernel", "3pass"])
@pytest.mark.parametrize("blocks", [(8, 128, 128), (16, 128, 128),
                                    (8, 8, 8), (32, 16, 8)])
def test_winograd_block_padding_path(blocks, fused):
    """T/C/O not divisible by the block tuple: tiles (2*2*3=12), channels (5)
    and out-channels (7) all need zero-padding to block multiples, and the
    padded rows must not leak into the cropped result."""
    spec = ConvSpec(5, 7, (3, 3), (1, 1), (1, 1),
                    algorithm=ConvAlgorithm.WINOGRAD)
    from repro.kernels.winograd import conv2d_winograd_pallas

    x = _rand((2, 12, 12, 5), seed=sum(blocks))
    wt = _rand((3, 3, 5, 7), seed=5)
    got = conv2d_winograd_pallas(
        x, wt, spec, blocks=blocks, interpret=True, fused=fused
    )
    ref = conv2d_reference(x, wt, spec)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("fused", [True, False], ids=["megakernel", "3pass"])
def test_winograd_pretransformed_weights(fused):
    """Offline weight transform (inference mode): (8, 8, C, O) weights skip
    the in-graph G g G^T and must produce identical results."""
    from repro.core.winograd import transform_weights
    from repro.kernels.winograd import conv2d_winograd_pallas

    spec = ConvSpec(4, 6, (3, 3), (1, 1), (1, 1))
    x = _rand((1, 13, 17, 4), seed=41)
    wt = _rand((3, 3, 4, 6), seed=42)
    u = transform_weights(wt)
    got = conv2d_winograd_pallas(
        x, u, spec, pretransformed=True, interpret=True, fused=fused
    )
    ref = conv2d_reference(x, wt, spec)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("activation", ["linear", "relu", "leaky"])
@pytest.mark.parametrize("with_bias", [False, True], ids=["nobias", "bias"])
def test_winograd_fused_epilogue_cross_product(activation, with_bias):
    """The megakernel's in-VMEM epilogue (bias + activation on the fp32
    inverse-transform result) across the full cross-product, on a shape that
    exercises the crop and channel-padding paths at once."""
    from repro.kernels.winograd import conv2d_winograd_pallas

    spec = ConvSpec(5, 9, (3, 3), (1, 1), (1, 1))
    x = _rand((2, 10, 13, 5), seed=51)
    wt = _rand((3, 3, 5, 9), seed=52)
    bias = _rand((9,), seed=53) if with_bias else None
    got = conv2d_winograd_pallas(
        x, wt, spec, interpret=True, fused=True,
        bias=bias, activation=activation,
    )
    epi = Epilogue(bias=bias, activation=activation)
    ref = apply_epilogue(conv2d_reference(x, wt, spec), epi)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_winograd_fused_matches_3pass_bitwise_shape():
    """Both realizations are the same math at the same blocking — they must
    agree far tighter than either agrees with the oracle."""
    from repro.kernels.winograd import conv2d_winograd_pallas

    spec = ConvSpec(4, 8, (3, 3), (1, 1), (1, 1))
    x = _rand((1, 18, 18, 4), seed=61)
    wt = _rand((3, 3, 4, 8), seed=62)
    a = conv2d_winograd_pallas(x, wt, spec, blocks=(8, 128, 128),
                               interpret=True, fused=True)
    b = conv2d_winograd_pallas(x, wt, spec, blocks=(8, 128, 128),
                               interpret=True, fused=False)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_winograd_fused_traffic_model_2x():
    """Acceptance: the megakernel's modeled HBM bytes are >= 2x lower than
    the 3-pass pipeline's over the VGG-16 + YOLOv3 3x3 stride-1 layer set
    (the eliminated V/M round-trips are 2*tiles*64*(Cin+Cout) elements)."""
    from benchmarks.common import vgg16_gemms, yolov3_20_gemms
    from repro.core.vmem_model import winograd_traffic_bytes

    unfused_total = fused_total = 0
    n_layers = 0
    for dims in (vgg16_gemms(), yolov3_20_gemms()):
        for d in dims:
            if d["kernel"] != 3 or d["stride"] != 1:
                continue
            spec = ConvSpec(d["cin"], d["cout"], (3, 3), (1, 1), (1, 1))
            oh, ow = spec.out_hw(d["h"], d["w"])
            unfused_total += winograd_traffic_bytes(
                oh, ow, d["cin"], d["cout"], fused=False
            )
            fused_total += winograd_traffic_bytes(
                oh, ow, d["cin"], d["cout"], fused=True
            )
            n_layers += 1
    assert n_layers >= 15  # both networks actually contributed layers
    assert fused_total > 0
    assert unfused_total / fused_total >= 2.0


def test_winograd_pick_blocks_budgets_full_footprint():
    """Satellite: pick_blocks must budget the whole kernel footprint (weight
    block + M scratch + output block), not just the input-transform block."""
    from repro.core.vmem_model import winograd_kernel_vmem_bytes
    from repro.kernels.winograd.ops import pick_blocks

    for fused in (True, False):
        for t, c, o in ((4096, 512, 512), (4096, 384, 384), (20, 512, 512)):
            for budget in (1 << 20, 4 << 20, 10 << 20, 16 << 20, 64 << 20):
                bt, bc, bo = pick_blocks(
                    t, c, o, vmem_budget=budget, fused=fused
                )
                # Never below the (sublane, lane) granularity floor, even
                # when shrinking from a non-power-of-two start (384, 24...).
                assert bt % 8 == 0 and bc % 128 == 0 and bo % 128 == 0
                footprint = winograd_kernel_vmem_bytes(bt, bc, bo, fused=fused)
                # Either the footprint fits, or we are at the floor and
                # cannot shrink further.
                assert footprint <= budget or (bt, bc, bo) == (8, 128, 128)


def test_im2col_pick_blocks_budgets_full_footprint():
    """Satellite: the im2col pick_blocks must budget the whole per-program
    footprint — the (kh, kw, bc, bo) weight block and the bias row on top
    of the input slab and accumulator the old heuristic stopped at
    (mirroring the PR 3 fix to the Winograd pick_blocks)."""
    from repro.core.vmem_model import im2col_kernel_vmem_bytes
    from repro.kernels.im2col_gemm.ops import pick_blocks

    for hp, wp, c, o, oh, ow in (
        (18, 18, 512, 1024, 16, 16),      # deep layer: weight block dominates
        (226, 226, 64, 64, 224, 224),     # shallow layer: slab dominates
        (34, 34, 384, 768, 32, 32),
    ):
        for budget in (1 << 20, 3 << 20, 8 << 20, 64 << 20):
            toh, bc, bo = pick_blocks(
                hp, wp, c, o, oh, ow, vmem_budget=budget
            )
            assert toh >= 1 and bc % 8 == 0 and bo % 128 == 0
            footprint = im2col_kernel_vmem_bytes(hp, wp, toh, ow, bc, bo)
            # Either the full footprint fits, or every knob is at its floor.
            assert footprint <= budget or (toh, bc, bo) == (1, 8, 128), (
                (hp, wp, c, o), budget, (toh, bc, bo), footprint
            )

    # The confirmed gap: a config where the old heuristic (input slab +
    # accumulator only) accepts blocks whose *full* footprint overflows.
    budget = 3 << 20
    toh, bc, bo = pick_blocks(18, 18, 512, 1024, 16, 16, vmem_budget=budget)
    assert im2col_kernel_vmem_bytes(18, 18, toh, 16, bc, bo) <= budget
    old_slab_only = (
        2 * 18 * 18 * 128 * 4 <= 2 * budget // 3     # old bc check passes
        and 16 * 16 * 256 * 4 <= budget // 3         # old toh check passes
    )
    overflow = im2col_kernel_vmem_bytes(18, 18, 16, 16, 128, 256) > budget
    assert old_slab_only and overflow, (
        "test setup: the old heuristic should overflow here"
    )


def test_pallas_direct_1x1_padding_regression():
    """The confirmed DIRECT-path bug: kernels/conv_ops.py subsampled
    x[:, ::sh, ::sw, :] without ever applying spec.padding, so a padded 1x1
    conv returned (1, 8, 8, 8) where the oracle returns (1, 10, 10, 8) —
    silently wrong shape *and* values."""
    spec = ConvSpec(4, 8, kernel_size=(1, 1), padding=(1, 1))
    x = _rand((1, 8, 8, 4), seed=1)
    w = _rand((1, 1, 4, 8), seed=2)
    ref = conv2d_reference(x, w, spec)
    assert ref.shape == (1, 10, 10, 8)
    got = conv2d(x, w, spec, impl="pallas", interpret=True)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Pre-transformed weights are an explicit flag, never a shape sniff.  The
# old detection (``pretransformed = (w.shape[0] != spec.kh)``) was ambiguous
# for kh == 8 kernels: raw 8x8 weights are (8, 8, C, O) exactly like an
# offline-transformed 3x3's, so any 8x8-aware path was one refactor away
# from misrouting them through the Winograd inverse transform.


@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_conv_8x8_kernel_raw_weights_regression(impl):
    """An 8x8-kernel conv — whose raw weights share the (8, 8, C, O) shape
    of pre-transformed Winograd weights — must route as a plain conv."""
    spec = ConvSpec(4, 8, kernel_size=(8, 8), padding=(4, 4))
    x = _rand((1, 16, 16, 4), seed=7)
    w = _rand((8, 8, 4, 8), seed=8)
    ref = conv2d_reference(x, w, spec)
    got = conv2d(x, w, spec, impl=impl, interpret=True)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_conv2d_explicit_pretransformed_flag(impl):
    """conv2d(pretransformed=True) routes offline-transformed (8, 8, C, O)
    weights without any shape inference."""
    from repro.core.winograd import transform_weights

    spec = ConvSpec(4, 6, (3, 3), (1, 1), (1, 1),
                    algorithm=ConvAlgorithm.WINOGRAD)
    x = _rand((1, 12, 12, 4), seed=9)
    wt = _rand((3, 3, 4, 6), seed=10)
    u = transform_weights(wt)
    ref = conv2d_reference(x, wt, spec)
    got = conv2d(x, u, spec, impl=impl, interpret=True, pretransformed=True)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_network_with_8x8_conv_pretransform_flags():
    """End-to-end flag carriage: a network mixing an 8x8 conv with
    Winograd-eligible 3x3 convs, prepared with the offline weight transform
    (``pretransform=True``), must flow the explicit per-layer flags from
    ``prepare_net_params`` to execution — the 3x3 layers' (8, 8, C, O)
    weights route pre-transformed, the 8x8 layer's identically-shaped raw
    weights do not."""
    from repro.core.netplan import (
        NetworkExecutor,
        plan_network,
        pretransform_flags,
    )
    from repro.core.planner import Planner
    from repro.models.cnn import CNNLayer, cnn_forward, init_cnn

    layers = (
        CNNLayer("conv", out_channels=8, kernel=8, activation="relu"),
        CNNLayer("conv", out_channels=6, kernel=3, activation="leaky"),
        CNNLayer("conv", out_channels=5, kernel=3, activation="linear"),
    )
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
    ref = cnn_forward(params, layers, x, impl="xla")
    planner = Planner(impl="jax", cache_path=None)
    netplan = plan_network(layers, 16, 16, planner, batch=1)
    flags = pretransform_flags(netplan, True)
    assert flags[0] is False, "raw 8x8 kernel misread as pre-transformed"
    assert any(flags), "test setup: no Winograd layer left to pre-transform"
    got = NetworkExecutor(netplan, params, pretransform=True)(x)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)
    # And through the facade, which carries the same flags.
    import repro

    compiled = repro.compile(
        layers, params, repro.ExecutionOptions(impl="jax", cache_path=None),
        input_hw=(16, 16),
    )
    np.testing.assert_allclose(compiled.run(x), ref, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# Network-level acceptance: fused epilogue vs reference for every conv layer
# of the paper's two networks.


def _network_layer_specs(layers, h, w, in_ch=3):
    """(spec, h, w) for every conv layer at its actual input resolution."""
    from repro.models.cnn import _conv_spec

    out = []
    ch = []
    cur_ch, cur_h, cur_w = in_ch, h, w
    for l in layers:
        if l.kind == "conv":
            spec = _conv_spec(l, cur_ch)
            out.append((spec, cur_h, cur_w, l.activation))
            cur_h, cur_w = spec.out_hw(cur_h, cur_w)
            cur_ch = l.out_channels
        elif l.kind == "maxpool":
            cur_h, cur_w = -(-cur_h // l.stride), -(-cur_w // l.stride)
        elif l.kind == "upsample":
            cur_h, cur_w = cur_h * l.size, cur_w * l.size
        elif l.kind == "route":
            cur_ch = sum(ch[j][0] for j in l.from_layers)
            cur_h, cur_w = ch[l.from_layers[0]][1], ch[l.from_layers[0]][2]
        elif l.kind == "fc":
            cur_ch = l.out_channels
        ch.append((cur_ch, cur_h, cur_w))
    return out


@pytest.mark.parametrize("model", ["vgg16", "yolov3-tiny"])
def test_fused_epilogue_every_conv_layer(model):
    """Acceptance: fused conv+bias+activation matches conv2d_reference +
    unfused epilogue within 1e-4 for every conv layer shape of VGG-16 and
    YOLOv3-tiny (channel counts as published; spatial dims scaled down so
    the suite stays fast — the epilogue math is resolution-independent)."""
    from repro.configs import vgg16, yolov3

    layers = vgg16.LAYERS if model == "vgg16" else yolov3.TINY_LAYERS
    seen = set()
    for i, (spec, h, w, act) in enumerate(
        _network_layer_specs(layers, 32, 32)
    ):
        key = (spec.in_channels, spec.out_channels, spec.kernel_size,
               spec.stride, h, w)
        if key in seen or h < spec.kh or w < spec.kw:
            continue
        seen.add(key)
        x = _rand((1, h, w, spec.in_channels), seed=i)
        wt = _rand(
            (spec.kh, spec.kw, spec.in_channels, spec.out_channels), seed=i + 1
        ) * (1.0 / (spec.kh * spec.in_channels ** 0.5))
        bias = _rand((spec.out_channels,), seed=i + 2)
        epi = Epilogue(bias=bias, activation=act)
        ref = apply_epilogue(conv2d_reference(x, wt, spec), epi)
        got = conv2d(x, wt, spec, epilogue=epi)
        scale = float(jnp.max(jnp.abs(ref)))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4 * scale)


@pytest.mark.parametrize("model", ["vgg16", "yolov3-tiny"])
def test_cnn_infer_matches_unfused_forward(model):
    """Whole-network acceptance: the jitted fused entry point (batchnorm
    folded, epilogues in-kernel) matches the unfused XLA-conv forward."""
    from repro.configs import vgg16, yolov3
    from repro.models.cnn import _cnn_infer, cnn_forward, init_cnn

    layers = vgg16.LAYERS if model == "vgg16" else yolov3.TINY_LAYERS
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    ref = cnn_forward(params, layers, x, impl="xla")
    got = _cnn_infer(params, layers, x)
    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4 * scale)


# ---------------------------------------------------------------------------
# Int8 quantized inference vs the fp32 oracle.  The conformance metric is
# SQNR (signal-to-quantization-noise, dB) rather than allclose: quantization
# error is by construction larger than fp32 rounding, and the acceptance
# criterion from the int8 PR is >= 30 dB against the fp32 reference.


INT8_SQNR_DB = 30.0


def _quantize_case(x, w, bias, activation):
    """Offline quantization exactly as prepare_net_params performs it:
    per-input-channel activation scales folded into the weights, then
    per-output-channel weight scales; returns (xq, wq, epilogue)."""
    from repro.core.quant import (
        activation_scales,
        quantize_activation,
        quantize_conv_weights,
    )

    sx = activation_scales(x, axis=(0, 1, 2))
    xq = quantize_activation(x, sx)
    wq, ws = quantize_conv_weights(w, sx)
    return xq, wq, Epilogue(bias=bias, activation=activation, scale=ws)


INT8_ALGOS = [ConvAlgorithm.DIRECT, ConvAlgorithm.IM2COL_GEMM]


@pytest.mark.parametrize("algo", INT8_ALGOS, ids=lambda a: a.value)
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("pad", [0, 1])
@pytest.mark.parametrize("k", [1, 3])
@pytest.mark.parametrize("fused", [False, True], ids=["plain", "epilogue"])
def test_int8_conv_conformance(algo, stride, pad, k, fused):
    """The int8 dtype axis of the conformance cross-product: every eligible
    (algorithm, stride, padding, kernel, epilogue) cell runs the quantized
    Pallas kernel (int8 operands, int32 accumulation, fused dequant) and
    must reach >= 30 dB SQNR against conv2d_reference on the same fp32
    inputs.  Winograd is deliberately absent: int8 never routes there
    (core/quant.py::winograd_int8_budget_ok)."""
    from repro.core.quant import sqnr_db
    from repro.kernels.conv_ops import conv2d_pallas

    if not _eligible(algo, k, stride):
        pytest.skip(f"{algo.value} ineligible for k={k} s={stride}")
    spec = ConvSpec(8, 16, (k, k), (stride, stride), (pad, pad),
                    algorithm=algo)
    x = _rand((2, 10, 12, 8), seed=k * 100 + stride * 10 + pad)
    w = _rand((k, k, 8, 16), seed=7) * 0.2
    bias = _rand((16,), seed=9) * 0.1 if fused else None
    activation = "leaky" if fused else "linear"
    ref = apply_epilogue(
        conv2d_reference(x, w, spec),
        Epilogue(bias=bias, activation=activation),
    )
    xq, wq, epi = _quantize_case(x, w, bias, activation)
    got = conv2d_pallas(xq, wq, spec, algo, interpret=True, epilogue=epi)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    assert got.dtype == jnp.float32
    q = float(sqnr_db(ref, got))
    assert q >= INT8_SQNR_DB, f"SQNR {q:.1f} dB < {INT8_SQNR_DB} dB"


def test_int8_pure_jnp_matches_pallas_kernel():
    """The pure-jnp int8 path (fp32 integer math + apply_epilogue dequant)
    and the Pallas int8 kernel are the same integer computation — they must
    agree to fp32 rounding, far tighter than either agrees with the
    oracle."""
    from repro.core.im2col import conv2d_im2col
    from repro.kernels.conv_ops import conv2d_pallas

    spec = ConvSpec(8, 16, (3, 3), (1, 1), (1, 1))
    x = _rand((2, 10, 10, 8), seed=21)
    w = _rand((3, 3, 8, 16), seed=22) * 0.2
    bias = _rand((16,), seed=23) * 0.1
    xq, wq, epi = _quantize_case(x, w, bias, "relu")
    a = conv2d_pallas(xq, wq, spec, ConvAlgorithm.IM2COL_GEMM,
                      interpret=True, epilogue=epi)
    b = conv2d_im2col(
        xq.astype(jnp.float32), wq.astype(jnp.float32), spec, epilogue=epi
    )
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_int8_never_routes_to_winograd():
    """The dispatcher refuses int8 Winograd outright — the F(6, 3) transform
    amplification blows the error budget, so reaching that path is a planner
    bug, not a numerics question."""
    from repro.kernels.conv_ops import conv2d_pallas

    spec = ConvSpec(8, 16, (3, 3), (1, 1), (1, 1))
    x = _rand((1, 12, 12, 8), seed=31)
    w = _rand((3, 3, 8, 16), seed=32)
    xq, wq, epi = _quantize_case(x, w, None, "linear")
    with pytest.raises(AssertionError, match="Winograd"):
        conv2d_pallas(xq, wq, spec, ConvAlgorithm.WINOGRAD,
                      interpret=True, epilogue=epi)


def test_int8_im2col_traffic_at_most_half_of_fp32():
    """Acceptance: the modeled int8 im2col+GEMM HBM traffic is <= 0.5x fp32
    (int8 operands, fp32 output writes included) for every layer the
    planner's traffic gate admits — which is every k>=3 conv past the cin=3
    entry in both networks.  The gate and the ratio must also agree layer
    by layer: the layers it rejects (the cin=3 entry; YOLO's 1x1 detection
    head, whose fp32 output writes dominate) genuinely exceed 0.5x."""
    from repro.core.quant import (
        INT8_TRAFFIC_THRESHOLD,
        int8_traffic_ratio,
        int8_worthwhile,
    )
    from repro.configs import vgg16, yolov3

    checked = rejected = 0
    for layers in (vgg16.LAYERS, yolov3.TINY_LAYERS):
        for spec, h, w, _act in _network_layer_specs(layers, 416, 416):
            ratio = int8_traffic_ratio(spec, h, w)
            assert int8_worthwhile(spec, h, w) == (
                ratio <= INT8_TRAFFIC_THRESHOLD
            ), (spec, ratio)
            if spec.kh >= 3 and spec.in_channels >= 16:
                assert ratio <= INT8_TRAFFIC_THRESHOLD, (spec, ratio)
                checked += 1
            elif not int8_worthwhile(spec, h, w):
                rejected += 1
    assert checked >= 15
    assert rejected >= 1  # the gate actually rejects something real


@pytest.mark.parametrize("model", ["vgg16", "yolov3-tiny"])
@pytest.mark.parametrize("batch", [1, 4, 8])
def test_int8_network_acceptance(model, batch, tmp_path):
    """Whole-network acceptance: ``repro.compile(..., dtype='int8')`` runs
    VGG-16 and YOLOv3-tiny end-to-end (32x32 input so the suite stays fast;
    channel structure as published) and the network output reaches >= 30 dB
    SQNR against the fp32 compilation of the same params at batches 1/4/8.
    Also pins the planner policy: the cin=3 entry conv stays fp32 (the
    traffic gate fails), deeper convs quantize, and a warm v5 cache
    re-tunes nothing."""
    import repro
    from repro.api import ExecutionOptions
    from repro.configs import vgg16, yolov3
    from repro.core.quant import sqnr_db
    from repro.models.cnn import init_cnn

    m = (vgg16.MODEL if model == "vgg16" else yolov3.TINY_MODEL)
    m = m.with_input_hw((32, 32))
    params = init_cnn(jax.random.PRNGKey(0), m.layers, m.in_channels)
    x = jnp.asarray(
        np.random.default_rng(batch).normal(size=(batch, 32, 32, 3)),
        jnp.float32,
    )
    cache = str(tmp_path / "plans.json")
    fp32 = repro.compile(
        m, params, ExecutionOptions(impl="jax", cache_path=cache, batch=batch)
    )
    ref = fp32.run(x)
    opts = ExecutionOptions(
        impl="jax", cache_path=cache, dtype="int8", batch=batch
    )
    q = repro.compile(m, params, opts, calibration=x)
    out = q.run(x)
    assert out.shape == ref.shape
    quality = float(sqnr_db(ref, out))
    assert quality >= INT8_SQNR_DB, (
        f"{model} batch={batch}: whole-network SQNR {quality:.1f} dB"
    )
    rows = q.plan_report()["layers"]
    dtypes = [r["dtype"] for r in rows]
    assert dtypes[0] == "float32", "cin=3 entry conv must stay fp32"
    assert dtypes.count("int8") >= len(dtypes) - 2, dtypes
    # Warm path: a fresh compilation against the same v5 cache re-tunes
    # zero layers — the per-layer dtype rides the plan entries.
    warm = repro.compile(m, params, opts, calibration=x)
    rep = warm.plan_report()
    assert rep["tunes"] == 0 and rep["network_hits"] >= 1, rep


def test_int8_network_pallas_interpret_smoke():
    """The Pallas int8 kernels end-to-end (interpret mode): a small conv
    stack through the facade with dtype='int8' and impl='pallas' must match
    its own fp32 compilation to >= 30 dB."""
    import repro
    from repro.api import ExecutionOptions
    from repro.core.quant import sqnr_db
    from repro.models.cnn import CNNLayer, init_cnn

    layers = (
        CNNLayer("conv", out_channels=32, kernel=3, activation="leaky"),
        CNNLayer("maxpool", size=2, stride=2),
        CNNLayer("conv", out_channels=48, kernel=3, activation="relu"),
        CNNLayer("conv", out_channels=32, kernel=1, activation="linear"),
    )
    params = init_cnn(jax.random.PRNGKey(2), layers, in_channels=16)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16, 16))
    fp32 = repro.compile(
        layers, params,
        repro.ExecutionOptions(impl="pallas", interpret=True,
                               cache_path=None),
        input_hw=(16, 16), in_channels=16,
    )
    ref = fp32.run(x)
    q = repro.compile(
        layers, params,
        repro.ExecutionOptions(impl="pallas", interpret=True,
                               cache_path=None, dtype="int8"),
        input_hw=(16, 16), in_channels=16, calibration=x,
    )
    out = q.run(x)
    quality = float(sqnr_db(ref, out))
    assert quality >= INT8_SQNR_DB, f"SQNR {quality:.1f} dB"
    assert any(r["dtype"] == "int8" for r in q.plan_report()["layers"])


def test_fold_batchnorm_matches_batchnorm_inference():
    """Folded weights+bias reproduce conv -> bn exactly (up to fp32)."""
    from repro.models.cnn import (
        CNNLayer,
        batchnorm_inference,
        fold_batchnorm,
        init_cnn,
    )

    layers = (CNNLayer("conv", out_channels=8, kernel=3, batch_norm=True),)
    params = init_cnn(jax.random.PRNGKey(3), layers)
    # Non-trivial bn statistics.
    bn = {
        "gamma": _rand((8,), 4) + 2.0,
        "beta": _rand((8,), 5),
        "mean": _rand((8,), 6),
        "var": jnp.abs(_rand((8,), 7)) + 0.5,
    }
    params[0]["bn"] = bn
    folded = fold_batchnorm(params, layers)
    assert "bn" not in folded[0] and "b" in folded[0]
    spec = ConvSpec(3, 8, (3, 3), (1, 1), (1, 1))
    x = _rand((1, 12, 12, 3), 8)
    ref = batchnorm_inference(conv2d_reference(x, params[0]["w"], spec), bn)
    got = conv2d_reference(x, folded[0]["w"], spec) + folded[0]["b"]
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
