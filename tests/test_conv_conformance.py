"""Cross-product conv conformance suite: every algorithm x impl x shape
variant x epilogue mode against the XLA oracle.

Routing gaps (like the Pallas DIRECT path silently dropping padding) cannot
land silently again: each eligible (algorithm, impl, stride, padding,
kernel, epilogue) cell is asserted against ``conv2d_reference`` followed by
the unfused reference epilogue.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv_spec import (
    ConvAlgorithm,
    ConvSpec,
    Epilogue,
    apply_epilogue,
)
from repro.core.conv2d import conv2d, conv2d_reference


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), jnp.float32)


def _eligible(algo: ConvAlgorithm, k: int, s: int) -> bool:
    """Which forced algorithms can run a (k, k) stride-s conv at all."""
    if algo is ConvAlgorithm.DIRECT:
        return k == 1
    if algo is ConvAlgorithm.WINOGRAD:
        return k == 3 and s == 1
    return True  # im2col+GEMM is the generic path


ALGOS = [ConvAlgorithm.DIRECT, ConvAlgorithm.IM2COL_GEMM, ConvAlgorithm.WINOGRAD]


@pytest.mark.parametrize("algo", ALGOS, ids=lambda a: a.value)
@pytest.mark.parametrize("impl", ["jax", "pallas"])
@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("pad", [0, 1])
@pytest.mark.parametrize("k", [1, 3])
@pytest.mark.parametrize("fused", [False, True], ids=["plain", "epilogue"])
def test_conv_conformance(algo, impl, stride, pad, k, fused):
    if not _eligible(algo, k, stride):
        pytest.skip(f"{algo.value} ineligible for k={k} s={stride}")
    spec = ConvSpec(4, 8, (k, k), (stride, stride), (pad, pad), algorithm=algo)
    oh, ow = spec.out_hw(10, 12)
    assert oh >= 1 and ow >= 1
    x = _rand((2, 10, 12, 4), seed=k * 100 + stride * 10 + pad)
    w = _rand((k, k, 4, 8), seed=7)
    epi = (
        Epilogue(bias=_rand((8,), seed=9), activation="leaky")
        if fused else None
    )
    got = conv2d(x, w, spec, impl=impl, interpret=True, epilogue=epi)
    ref = apply_epilogue(conv2d_reference(x, w, spec), epi)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# Winograd edge cases: the fused single-pass megakernel and the 3-pass
# pipeline against the oracle on every awkward shape class.


@pytest.mark.parametrize("fused", [True, False], ids=["megakernel", "3pass"])
@pytest.mark.parametrize("h,w", [(10, 14), (13, 7), (9, 16), (11, 23)])
def test_winograd_crop_path(h, w, fused):
    """Output sizes not divisible by 6: the tile grid over-covers and the
    final crop must discard exactly the padded rows/cols."""
    spec = ConvSpec(4, 8, (3, 3), (1, 1), (1, 1),
                    algorithm=ConvAlgorithm.WINOGRAD)
    oh, ow = spec.out_hw(h, w)
    assert oh % 6 != 0 or ow % 6 != 0
    from repro.kernels.winograd import conv2d_winograd_pallas

    x = _rand((2, h, w, 4), seed=h * 31 + w)
    wt = _rand((3, 3, 4, 8), seed=3)
    got = conv2d_winograd_pallas(x, wt, spec, interpret=True, fused=fused)
    ref = conv2d_reference(x, wt, spec)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("fused", [True, False], ids=["megakernel", "3pass"])
@pytest.mark.parametrize("blocks", [(8, 128, 128), (16, 128, 128),
                                    (8, 8, 8), (32, 16, 8)])
def test_winograd_block_padding_path(blocks, fused):
    """T/C/O not divisible by the block tuple: tiles (2*2*3=12), channels (5)
    and out-channels (7) all need zero-padding to block multiples, and the
    padded rows must not leak into the cropped result."""
    spec = ConvSpec(5, 7, (3, 3), (1, 1), (1, 1),
                    algorithm=ConvAlgorithm.WINOGRAD)
    from repro.kernels.winograd import conv2d_winograd_pallas

    x = _rand((2, 12, 12, 5), seed=sum(blocks))
    wt = _rand((3, 3, 5, 7), seed=5)
    got = conv2d_winograd_pallas(
        x, wt, spec, blocks=blocks, interpret=True, fused=fused
    )
    ref = conv2d_reference(x, wt, spec)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("fused", [True, False], ids=["megakernel", "3pass"])
def test_winograd_pretransformed_weights(fused):
    """Offline weight transform (inference mode): (8, 8, C, O) weights skip
    the in-graph G g G^T and must produce identical results."""
    from repro.core.winograd import transform_weights
    from repro.kernels.winograd import conv2d_winograd_pallas

    spec = ConvSpec(4, 6, (3, 3), (1, 1), (1, 1))
    x = _rand((1, 13, 17, 4), seed=41)
    wt = _rand((3, 3, 4, 6), seed=42)
    u = transform_weights(wt)
    got = conv2d_winograd_pallas(
        x, u, spec, pretransformed=True, interpret=True, fused=fused
    )
    ref = conv2d_reference(x, wt, spec)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("activation", ["linear", "relu", "leaky"])
@pytest.mark.parametrize("with_bias", [False, True], ids=["nobias", "bias"])
def test_winograd_fused_epilogue_cross_product(activation, with_bias):
    """The megakernel's in-VMEM epilogue (bias + activation on the fp32
    inverse-transform result) across the full cross-product, on a shape that
    exercises the crop and channel-padding paths at once."""
    from repro.kernels.winograd import conv2d_winograd_pallas

    spec = ConvSpec(5, 9, (3, 3), (1, 1), (1, 1))
    x = _rand((2, 10, 13, 5), seed=51)
    wt = _rand((3, 3, 5, 9), seed=52)
    bias = _rand((9,), seed=53) if with_bias else None
    got = conv2d_winograd_pallas(
        x, wt, spec, interpret=True, fused=True,
        bias=bias, activation=activation,
    )
    epi = Epilogue(bias=bias, activation=activation)
    ref = apply_epilogue(conv2d_reference(x, wt, spec), epi)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_winograd_fused_matches_3pass_bitwise_shape():
    """Both realizations are the same math at the same blocking — they must
    agree far tighter than either agrees with the oracle."""
    from repro.kernels.winograd import conv2d_winograd_pallas

    spec = ConvSpec(4, 8, (3, 3), (1, 1), (1, 1))
    x = _rand((1, 18, 18, 4), seed=61)
    wt = _rand((3, 3, 4, 8), seed=62)
    a = conv2d_winograd_pallas(x, wt, spec, blocks=(8, 128, 128),
                               interpret=True, fused=True)
    b = conv2d_winograd_pallas(x, wt, spec, blocks=(8, 128, 128),
                               interpret=True, fused=False)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_winograd_fused_traffic_model_2x():
    """Acceptance: the megakernel's modeled HBM bytes are >= 2x lower than
    the 3-pass pipeline's over the VGG-16 + YOLOv3 3x3 stride-1 layer set
    (the eliminated V/M round-trips are 2*tiles*64*(Cin+Cout) elements)."""
    from benchmarks.common import vgg16_gemms, yolov3_20_gemms
    from repro.core.vmem_model import winograd_traffic_bytes

    unfused_total = fused_total = 0
    n_layers = 0
    for dims in (vgg16_gemms(), yolov3_20_gemms()):
        for d in dims:
            if d["kernel"] != 3 or d["stride"] != 1:
                continue
            spec = ConvSpec(d["cin"], d["cout"], (3, 3), (1, 1), (1, 1))
            oh, ow = spec.out_hw(d["h"], d["w"])
            unfused_total += winograd_traffic_bytes(
                oh, ow, d["cin"], d["cout"], fused=False
            )
            fused_total += winograd_traffic_bytes(
                oh, ow, d["cin"], d["cout"], fused=True
            )
            n_layers += 1
    assert n_layers >= 15  # both networks actually contributed layers
    assert fused_total > 0
    assert unfused_total / fused_total >= 2.0


def test_winograd_pick_blocks_budgets_full_footprint():
    """Satellite: pick_blocks must budget the whole kernel footprint (weight
    block + M scratch + output block), not just the input-transform block."""
    from repro.core.vmem_model import winograd_kernel_vmem_bytes
    from repro.kernels.winograd.ops import pick_blocks

    for fused in (True, False):
        for t, c, o in ((4096, 512, 512), (4096, 384, 384), (20, 512, 512)):
            for budget in (1 << 20, 4 << 20, 10 << 20, 16 << 20, 64 << 20):
                bt, bc, bo = pick_blocks(
                    t, c, o, vmem_budget=budget, fused=fused
                )
                # Never below the (sublane, lane) granularity floor, even
                # when shrinking from a non-power-of-two start (384, 24...).
                assert bt % 8 == 0 and bc % 128 == 0 and bo % 128 == 0
                footprint = winograd_kernel_vmem_bytes(bt, bc, bo, fused=fused)
                # Either the footprint fits, or we are at the floor and
                # cannot shrink further.
                assert footprint <= budget or (bt, bc, bo) == (8, 128, 128)


def test_im2col_pick_blocks_budgets_full_footprint():
    """Satellite: the im2col pick_blocks must budget the whole per-program
    footprint — the (kh, kw, bc, bo) weight block and the bias row on top
    of the input slab and accumulator the old heuristic stopped at
    (mirroring the PR 3 fix to the Winograd pick_blocks)."""
    from repro.core.vmem_model import im2col_kernel_vmem_bytes
    from repro.kernels.im2col_gemm.ops import pick_blocks

    for hp, wp, c, o, oh, ow in (
        (18, 18, 512, 1024, 16, 16),      # deep layer: weight block dominates
        (226, 226, 64, 64, 224, 224),     # shallow layer: slab dominates
        (34, 34, 384, 768, 32, 32),
    ):
        for budget in (1 << 20, 3 << 20, 8 << 20, 64 << 20):
            toh, bc, bo = pick_blocks(
                hp, wp, c, o, oh, ow, vmem_budget=budget
            )
            assert toh >= 1 and bc % 8 == 0 and bo % 128 == 0
            footprint = im2col_kernel_vmem_bytes(hp, wp, toh, ow, bc, bo)
            # Either the full footprint fits, or every knob is at its floor.
            assert footprint <= budget or (toh, bc, bo) == (1, 8, 128), (
                (hp, wp, c, o), budget, (toh, bc, bo), footprint
            )

    # The confirmed gap: a config where the old heuristic (input slab +
    # accumulator only) accepts blocks whose *full* footprint overflows.
    budget = 3 << 20
    toh, bc, bo = pick_blocks(18, 18, 512, 1024, 16, 16, vmem_budget=budget)
    assert im2col_kernel_vmem_bytes(18, 18, toh, 16, bc, bo) <= budget
    old_slab_only = (
        2 * 18 * 18 * 128 * 4 <= 2 * budget // 3     # old bc check passes
        and 16 * 16 * 256 * 4 <= budget // 3         # old toh check passes
    )
    overflow = im2col_kernel_vmem_bytes(18, 18, 16, 16, 128, 256) > budget
    assert old_slab_only and overflow, (
        "test setup: the old heuristic should overflow here"
    )


def test_pallas_direct_1x1_padding_regression():
    """The confirmed DIRECT-path bug: kernels/conv_ops.py subsampled
    x[:, ::sh, ::sw, :] without ever applying spec.padding, so a padded 1x1
    conv returned (1, 8, 8, 8) where the oracle returns (1, 10, 10, 8) —
    silently wrong shape *and* values."""
    spec = ConvSpec(4, 8, kernel_size=(1, 1), padding=(1, 1))
    x = _rand((1, 8, 8, 4), seed=1)
    w = _rand((1, 1, 4, 8), seed=2)
    ref = conv2d_reference(x, w, spec)
    assert ref.shape == (1, 10, 10, 8)
    got = conv2d(x, w, spec, impl="pallas", interpret=True)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Pre-transformed weights are an explicit flag, never a shape sniff.  The
# old detection (``pretransformed = (w.shape[0] != spec.kh)``) was ambiguous
# for kh == 8 kernels: raw 8x8 weights are (8, 8, C, O) exactly like an
# offline-transformed 3x3's, so any 8x8-aware path was one refactor away
# from misrouting them through the Winograd inverse transform.


@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_conv_8x8_kernel_raw_weights_regression(impl):
    """An 8x8-kernel conv — whose raw weights share the (8, 8, C, O) shape
    of pre-transformed Winograd weights — must route as a plain conv."""
    spec = ConvSpec(4, 8, kernel_size=(8, 8), padding=(4, 4))
    x = _rand((1, 16, 16, 4), seed=7)
    w = _rand((8, 8, 4, 8), seed=8)
    ref = conv2d_reference(x, w, spec)
    got = conv2d(x, w, spec, impl=impl, interpret=True)
    assert got.shape == ref.shape, (got.shape, ref.shape)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_conv2d_explicit_pretransformed_flag(impl):
    """conv2d(pretransformed=True) routes offline-transformed (8, 8, C, O)
    weights without any shape inference."""
    from repro.core.winograd import transform_weights

    spec = ConvSpec(4, 6, (3, 3), (1, 1), (1, 1),
                    algorithm=ConvAlgorithm.WINOGRAD)
    x = _rand((1, 12, 12, 4), seed=9)
    wt = _rand((3, 3, 4, 6), seed=10)
    u = transform_weights(wt)
    ref = conv2d_reference(x, wt, spec)
    got = conv2d(x, u, spec, impl=impl, interpret=True, pretransformed=True)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_network_with_8x8_conv_pretransform_flags():
    """End-to-end flag carriage: a network mixing an 8x8 conv with
    Winograd-eligible 3x3 convs, prepared with the offline weight transform
    (``pretransform=True``), must flow the explicit per-layer flags from
    ``prepare_net_params`` to execution — the 3x3 layers' (8, 8, C, O)
    weights route pre-transformed, the 8x8 layer's identically-shaped raw
    weights do not."""
    from repro.core.netplan import (
        NetworkExecutor,
        plan_network,
        pretransform_flags,
    )
    from repro.core.planner import Planner
    from repro.models.cnn import CNNLayer, cnn_forward, init_cnn

    layers = (
        CNNLayer("conv", out_channels=8, kernel=8, activation="relu"),
        CNNLayer("conv", out_channels=6, kernel=3, activation="leaky"),
        CNNLayer("conv", out_channels=5, kernel=3, activation="linear"),
    )
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16, 3))
    ref = cnn_forward(params, layers, x, impl="xla")
    planner = Planner(impl="jax", cache_path=None)
    netplan = plan_network(layers, 16, 16, planner, batch=1)
    flags = pretransform_flags(netplan, True)
    assert flags[0] is False, "raw 8x8 kernel misread as pre-transformed"
    assert any(flags), "test setup: no Winograd layer left to pre-transform"
    got = NetworkExecutor(netplan, params, pretransform=True)(x)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)
    # And through the facade, which carries the same flags.
    import repro

    compiled = repro.compile(
        layers, params, repro.ExecutionOptions(impl="jax", cache_path=None),
        input_hw=(16, 16),
    )
    np.testing.assert_allclose(compiled.run(x), ref, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# Network-level acceptance: fused epilogue vs reference for every conv layer
# of the paper's two networks.


def _network_layer_specs(layers, h, w, in_ch=3):
    """(spec, h, w) for every conv layer at its actual input resolution."""
    from repro.models.cnn import _conv_spec

    out = []
    ch = []
    cur_ch, cur_h, cur_w = in_ch, h, w
    for l in layers:
        if l.kind == "conv":
            spec = _conv_spec(l, cur_ch)
            out.append((spec, cur_h, cur_w, l.activation))
            cur_h, cur_w = spec.out_hw(cur_h, cur_w)
            cur_ch = l.out_channels
        elif l.kind == "maxpool":
            cur_h, cur_w = -(-cur_h // l.stride), -(-cur_w // l.stride)
        elif l.kind == "upsample":
            cur_h, cur_w = cur_h * l.size, cur_w * l.size
        elif l.kind == "route":
            cur_ch = sum(ch[j][0] for j in l.from_layers)
            cur_h, cur_w = ch[l.from_layers[0]][1], ch[l.from_layers[0]][2]
        elif l.kind == "fc":
            cur_ch = l.out_channels
        ch.append((cur_ch, cur_h, cur_w))
    return out


@pytest.mark.parametrize("model", ["vgg16", "yolov3-tiny"])
def test_fused_epilogue_every_conv_layer(model):
    """Acceptance: fused conv+bias+activation matches conv2d_reference +
    unfused epilogue within 1e-4 for every conv layer shape of VGG-16 and
    YOLOv3-tiny (channel counts as published; spatial dims scaled down so
    the suite stays fast — the epilogue math is resolution-independent)."""
    from repro.configs import vgg16, yolov3

    layers = vgg16.LAYERS if model == "vgg16" else yolov3.TINY_LAYERS
    seen = set()
    for i, (spec, h, w, act) in enumerate(
        _network_layer_specs(layers, 32, 32)
    ):
        key = (spec.in_channels, spec.out_channels, spec.kernel_size,
               spec.stride, h, w)
        if key in seen or h < spec.kh or w < spec.kw:
            continue
        seen.add(key)
        x = _rand((1, h, w, spec.in_channels), seed=i)
        wt = _rand(
            (spec.kh, spec.kw, spec.in_channels, spec.out_channels), seed=i + 1
        ) * (1.0 / (spec.kh * spec.in_channels ** 0.5))
        bias = _rand((spec.out_channels,), seed=i + 2)
        epi = Epilogue(bias=bias, activation=act)
        ref = apply_epilogue(conv2d_reference(x, wt, spec), epi)
        got = conv2d(x, wt, spec, epilogue=epi)
        scale = float(jnp.max(jnp.abs(ref)))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4 * scale)


@pytest.mark.parametrize("model", ["vgg16", "yolov3-tiny"])
def test_cnn_infer_matches_unfused_forward(model):
    """Whole-network acceptance: the jitted fused entry point (batchnorm
    folded, epilogues in-kernel) matches the unfused XLA-conv forward."""
    from repro.configs import vgg16, yolov3
    from repro.models.cnn import cnn_forward, cnn_infer, init_cnn

    layers = vgg16.LAYERS if model == "vgg16" else yolov3.TINY_LAYERS
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    ref = cnn_forward(params, layers, x, impl="xla")
    got = cnn_infer(params, layers, x)
    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4 * scale)


def test_fold_batchnorm_matches_batchnorm_inference():
    """Folded weights+bias reproduce conv -> bn exactly (up to fp32)."""
    from repro.models.cnn import (
        CNNLayer,
        batchnorm_inference,
        fold_batchnorm,
        init_cnn,
    )

    layers = (CNNLayer("conv", out_channels=8, kernel=3, batch_norm=True),)
    params = init_cnn(jax.random.PRNGKey(3), layers)
    # Non-trivial bn statistics.
    bn = {
        "gamma": _rand((8,), 4) + 2.0,
        "beta": _rand((8,), 5),
        "mean": _rand((8,), 6),
        "var": jnp.abs(_rand((8,), 7)) + 0.5,
    }
    params[0]["bn"] = bn
    folded = fold_batchnorm(params, layers)
    assert "bn" not in folded[0] and "b" in folded[0]
    spec = ConvSpec(3, 8, (3, 3), (1, 1), (1, 1))
    x = _rand((1, 12, 12, 3), 8)
    ref = batchnorm_inference(conv2d_reference(x, params[0]["w"], spec), bn)
    got = conv2d_reference(x, folded[0]["w"], spec) + folded[0]["b"]
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
