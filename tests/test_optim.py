"""Optimizer + quantized-state tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings, strategies as st

from repro.optim import AdamWConfig, adamw, constant, warmup_cosine
from repro.optim.quantized_state import dequantize, quantize


def _rosenbrockish_loss(p):
    return jnp.sum((p["a"] - 1.0) ** 2) + jnp.sum((p["b"] + 2.0) ** 2)


@pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges(moment_dtype):
    cfg = AdamWConfig(lr=constant(0.05), weight_decay=0.0,
                      moment_dtype=moment_dtype)
    params = {"a": jnp.zeros((4, 4)), "b": jnp.ones((8,))}
    state = adamw.init(cfg, params)

    @jax.jit
    def step(p, s):
        g = jax.grad(_rosenbrockish_loss)(p)
        return adamw.update(cfg, g, s, p)

    for _ in range(300):
        params, state, m = step(params, state)
    assert float(_rosenbrockish_loss(params)) < 1e-2


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=constant(0.1), weight_decay=1.0)
    params = {"w": jnp.full((4, 4), 5.0), "scale": jnp.full((4,), 5.0)}
    state = adamw.init(cfg, params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    new_params, _, _ = adamw.update(cfg, zeros, state, params)
    assert float(jnp.max(new_params["w"])) < 5.0       # decayed
    np.testing.assert_allclose(new_params["scale"], 5.0)  # not decayed


def test_grad_clipping():
    cfg = AdamWConfig(lr=constant(0.0), grad_clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(cfg, params)
    _, _, m = adamw.update(cfg, {"w": jnp.full((4,), 100.0)}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2000),
    scale=st.floats(1e-6, 1e4),
    seed=st.integers(0, 2**31),
)
def test_quantize_roundtrip_bound(n, scale, seed):
    """Property: |x - deq(q(x))| <= blockmax/127 elementwise, any shape."""
    x = jnp.asarray(
        np.random.default_rng(seed).normal(size=n) * scale, jnp.float32
    )
    t = quantize(x)
    back = dequantize(t)
    assert back.shape == x.shape
    err = np.abs(np.asarray(back - x))
    bound = np.abs(np.asarray(x)).max() / 127.0 + 1e-9
    assert err.max() <= bound * 1.0001


def test_quantized_state_memory_ratio():
    """int8 moments take ~25% + scale overhead of fp32 moments."""
    x = jnp.ones((1024, 1024), jnp.float32)
    t = quantize(x)
    q_bytes = t.q.size * 1 + t.scale.size * 4
    assert q_bytes < 0.27 * x.size * 4


def test_warmup_cosine_schedule():
    fn = warmup_cosine(1.0, warmup_steps=10, total_steps=100, final_frac=0.1)
    assert float(fn(0)) == 0.0
    assert float(fn(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(fn(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(fn(5)) == pytest.approx(0.5, rel=1e-3)
