"""Planner: persistent plan cache, cost-model routing parity, and planned
conv2d correctness against the XLA oracle."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codesign import select_algorithm_by_cost
from repro.core.conv_spec import ConvAlgorithm, ConvSpec
from repro.core.conv2d import conv2d, conv2d_reference
from repro.core.planner import ConvPlan, Planner, plan_key

# The three layer classes the selector distinguishes (paper §VII.A).
LAYER_CASES = [
    # (spec, h, w)
    (ConvSpec(8, 16, (1, 1), (1, 1), (0, 0)), 14, 14),        # direct 1x1
    (ConvSpec(8, 16, (3, 3), (1, 1), (1, 1)), 20, 20),        # 3x3 stride-1
    (ConvSpec(8, 16, (3, 3), (2, 2), (1, 1)), 20, 20),        # strided
    (ConvSpec(4, 6, (5, 5), (2, 2), (2, 2)), 17, 17),         # generic
]


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), jnp.float32)


def test_plan_cache_round_trip(tmp_path):
    """write -> reload in a fresh Planner -> every lookup is a hit."""
    cache = os.path.join(tmp_path, "plans.json")
    p1 = Planner(cache_path=cache)
    plans = [p1.plan(s, h, w, batch=2) for s, h, w in LAYER_CASES]
    assert p1.stats == {"hits": 0, "tunes": len(LAYER_CASES)}
    assert os.path.exists(cache)

    p2 = Planner(cache_path=cache)
    replans = [p2.plan(s, h, w, batch=2) for s, h, w in LAYER_CASES]
    assert p2.stats == {"hits": len(LAYER_CASES), "tunes": 0}
    assert replans == plans  # identical decisions, not just same algorithms

    # The file itself is versioned JSON with round-trippable plan records.
    from repro.core.planner import PLAN_CACHE_VERSION

    data = json.load(open(cache))
    assert data["version"] == PLAN_CACHE_VERSION
    assert len(data["plans"]) == len(LAYER_CASES)
    for d in data["plans"].values():
        assert ConvPlan.from_json(d).to_json() == d


def test_cache_key_distinguishes_shape_dtype_batch():
    spec = ConvSpec(8, 16)
    k = lambda **kw: plan_key(spec, kw.get("h", 20), kw.get("w", 20),
                              kw.get("batch", 1), "tpu_v5e",
                              kw.get("dtype", "float32"), "jax")
    base = k()
    assert k(h=21) != base
    assert k(batch=2) != base
    assert k(dtype="bfloat16") != base
    # mode and VMEM budget change the decision, so they change the key:
    # a measure-mode planner must never reuse a cost-model plan.
    assert plan_key(spec, 20, 20, 1, "tpu_v5e", "float32", "jax",
                    mode="measure") != base
    assert plan_key(spec, 20, 20, 1, "tpu_v5e", "float32", "jax",
                    vmem_budget=2 * 1024 * 1024) != base


def test_corrupt_cache_is_cold_start(tmp_path):
    cache = os.path.join(tmp_path, "plans.json")
    with open(cache, "w") as f:
        f.write("{not json")
    p = Planner(cache_path=cache)           # must not raise
    spec, h, w = LAYER_CASES[0]
    p.plan(spec, h, w)
    assert p.stats["tunes"] == 1
    json.load(open(cache))                  # overwritten with a valid cache


def test_cost_plan_matches_cost_selector_routing():
    """Cost-mode plans route exactly like select_algorithm_by_cost."""
    planner = Planner(cache_path=None)
    shapes = [(ConvSpec(c, o, (3, 3), (1, 1), (1, 1)), h, h)
              for c, o, h in [(16, 32, 104), (256, 512, 13), (64, 128, 52)]]
    for spec, h, w in shapes + LAYER_CASES:
        plan = planner.plan(spec, h, w)
        assert plan.algorithm is select_algorithm_by_cost(spec, h, w)
        assert plan.source == "cost_model"
        assert plan.predicted_s > 0
        assert plan.block.vmem_bytes() <= planner.vmem_budget


def test_forced_algorithm_is_respected():
    spec = ConvSpec(8, 16, (3, 3), (1, 1), (1, 1),
                    algorithm=ConvAlgorithm.IM2COL_GEMM)
    plan = Planner(cache_path=None).plan(spec, 20, 20)
    assert plan.algorithm is ConvAlgorithm.IM2COL_GEMM


@pytest.mark.parametrize("case", range(len(LAYER_CASES)))
def test_planned_conv2d_matches_reference(case):
    """conv2d driven by a plan == XLA oracle for 1x1 / 3x3-s1 / strided."""
    spec, h, w = LAYER_CASES[case]
    planner = Planner(cache_path=None)
    plan = planner.plan(spec, h, w, batch=2)
    x = _rand((2, h, w, spec.in_channels), case)
    wt = _rand((spec.kh, spec.kw, spec.in_channels, spec.out_channels), case + 10)
    got = conv2d(x, wt, spec, plan=plan)
    ref = conv2d_reference(x, wt, spec)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_planned_conv2d_pallas_uses_plan_blocks():
    """A pallas-impl plan threads its block sizes into the kernels and still
    matches the oracle (interpret mode on CPU)."""
    planner = Planner(cache_path=None, impl="pallas")
    for spec, h, w in LAYER_CASES[:3]:
        plan = planner.plan(spec, h, w)
        assert plan.impl == "pallas"
        x = _rand((1, h, w, spec.in_channels), 3)
        wt = _rand((spec.kh, spec.kw, spec.in_channels, spec.out_channels), 4)
        got = conv2d(x, wt, spec, plan=plan, interpret=True)
        ref = conv2d_reference(x, wt, spec)
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)


def test_measure_mode_smoke():
    """Measure mode times real candidates and its winner is numerically right."""
    planner = Planner(cache_path=None, mode="measure", measure_reps=1)
    spec = ConvSpec(4, 8, (3, 3), (1, 1), (1, 1))
    plan = planner.plan(spec, 12, 12)
    assert plan.source == "measured"
    assert plan.algorithm in (ConvAlgorithm.WINOGRAD, ConvAlgorithm.IM2COL_GEMM)
    assert plan.predicted_s > 0
    x, wt = _rand((1, 12, 12, 4), 5), _rand((3, 3, 4, 8), 6)
    np.testing.assert_allclose(
        conv2d(x, wt, spec, plan=plan), conv2d_reference(x, wt, spec),
        rtol=2e-4, atol=2e-4,
    )


def test_planner_threads_through_cnn_forward(tmp_path):
    """_plan_layers + cnn_forward(plans=...) == unplanned forward, and the
    whole network's plans persist."""
    import jax

    from repro.models.cnn import CNNLayer, _plan_layers, cnn_forward, init_cnn

    layers = (
        CNNLayer("conv", out_channels=8, kernel=3, stride=1),
        CNNLayer("maxpool", size=2, stride=2),
        CNNLayer("conv", out_channels=12, kernel=1, stride=1, pad=0),
        CNNLayer("conv", out_channels=12, kernel=3, stride=2),
    )
    cache = os.path.join(tmp_path, "net.json")
    planner = Planner(cache_path=cache)
    plans = _plan_layers(layers, 16, 16, planner, in_channels=3)
    assert [p is not None for p in plans] == [True, False, True, True]

    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = _rand((2, 16, 16, 3), 9)
    planned = cnn_forward(params, layers, x, plans=plans)
    unplanned = cnn_forward(params, layers, x)
    np.testing.assert_allclose(planned, unplanned, rtol=2e-4, atol=2e-4)

    warm = Planner(cache_path=cache)
    _plan_layers(layers, 16, 16, warm, in_channels=3)
    assert warm.stats["tunes"] == 0


def test_plan_records_fused_epilogue(tmp_path):
    """Planner(fuse_epilogue=True) stamps plans, keys them separately from
    unfused plans, and round-trips the flag through the JSON cache."""
    cache = os.path.join(tmp_path, "fused.json")
    spec = ConvSpec(8, 16)
    fused = Planner(cache_path=cache, fuse_epilogue=True)
    plain = Planner(cache_path=cache)
    pf = fused.plan(spec, 20, 20)
    pu = plain.plan(spec, 20, 20)
    assert pf.fused_epilogue and not pu.fused_epilogue
    assert plan_key(spec, 20, 20, 1, "tpu_v5e", "float32", "jax",
                    fuse_epilogue=True) != plan_key(
        spec, 20, 20, 1, "tpu_v5e", "float32", "jax")
    # Both live in the same cache file; a warm fused planner re-tunes nothing.
    warm = Planner(cache_path=cache, fuse_epilogue=True)
    assert warm.plan(spec, 20, 20).fused_epilogue
    assert warm.stats["tunes"] == 0


def test_winograd_plan_records_fused_megakernel(tmp_path):
    """Cache v3: Winograd plans record the single-pass megakernel decision,
    autotune (bt, bc, bo) against the fused footprint, persist it, and a warm
    planner re-tunes nothing."""
    from repro.core.vmem_model import winograd_kernel_vmem_bytes

    cache = os.path.join(tmp_path, "wino.json")
    spec = ConvSpec(64, 128, (3, 3), (1, 1), (1, 1))
    planner = Planner(cache_path=cache)
    plan = planner.plan(spec, 152, 152)
    assert plan.algorithm is ConvAlgorithm.WINOGRAD
    assert plan.winograd_fused          # model: fused never loses
    bt, bc, bo = plan.kernel_blocks
    assert winograd_kernel_vmem_bytes(bt, bc, bo, fused=True) \
        <= planner.vmem_budget

    # Round-trips through the JSON cache, zero re-tunes on a warm planner.
    warm = Planner(cache_path=cache)
    replan = warm.plan(spec, 152, 152)
    assert warm.stats == {"hits": 1, "tunes": 0}
    assert replan == plan and replan.winograd_fused

    data = json.load(open(cache))
    assert data["version"] == 6   # v6: pipelines section (+v5 per-plan dtype)
    (record,) = data["plans"].values()
    assert record["winograd_fused"] is True


def test_winograd_fused_policy_keys_separately():
    """The wf policy (auto / forced-on / forced-off) is part of the cache
    key, and forcing the 3-pass pipeline changes the plan."""
    spec = ConvSpec(64, 128, (3, 3), (1, 1), (1, 1))
    base = plan_key(spec, 152, 152, 1, "tpu_v5e", "float32", "jax")
    assert plan_key(spec, 152, 152, 1, "tpu_v5e", "float32", "jax",
                    winograd_fused=True) != base
    assert plan_key(spec, 152, 152, 1, "tpu_v5e", "float32", "jax",
                    winograd_fused=False) != base

    forced_off = Planner(cache_path=None, winograd_fused=False)
    plan = forced_off.plan(spec, 152, 152)
    assert not plan.winograd_fused
    # The 3-pass pipeline pays the V/M round-trips in the model.
    auto = Planner(cache_path=None).plan(spec, 152, 152)
    assert auto.predicted_s <= plan.predicted_s


def test_measure_mode_times_both_winograd_realizations():
    """On the pallas impl, measure mode times the megakernel against the
    3-pass pipeline; whichever wins, the plan stays numerically correct."""
    spec = ConvSpec(4, 8, (3, 3), (1, 1), (1, 1),
                    algorithm=ConvAlgorithm.WINOGRAD)
    planner = Planner(cache_path=None, mode="measure", impl="pallas",
                      measure_reps=1)
    plan = planner.plan(spec, 12, 12)
    assert plan.source == "measured"
    assert plan.algorithm is ConvAlgorithm.WINOGRAD
    x, wt = _rand((1, 12, 12, 4), 15), _rand((3, 3, 4, 8), 16)
    np.testing.assert_allclose(
        conv2d(x, wt, spec, plan=plan, interpret=True),
        conv2d_reference(x, wt, spec),
        rtol=5e-4, atol=5e-4,
    )


def test_fused_plan_drives_cnn_forward_fusion():
    """A fused_epilogue plan opts its layer into in-kernel fusion even when
    cnn_forward isn't asked to fuse globally — outputs must match the
    unfused path (on bn-folded params)."""
    import jax

    from repro.models.cnn import (
        CNNLayer, _plan_layers, cnn_forward, fold_batchnorm, init_cnn,
    )

    layers = (
        CNNLayer("conv", out_channels=8, kernel=3, stride=1),
        CNNLayer("conv", out_channels=12, kernel=1, stride=1, pad=0,
                 batch_norm=False),
    )
    planner = Planner(cache_path=None, fuse_epilogue=True)
    plans = _plan_layers(layers, 16, 16, planner, in_channels=3)
    assert all(p.fused_epilogue for p in plans)

    params = fold_batchnorm(init_cnn(jax.random.PRNGKey(0), layers), layers)
    x = _rand((1, 16, 16, 3), 11)
    fused = cnn_forward(params, layers, x, plans=plans)
    unfused = cnn_forward(params, layers, x)
    np.testing.assert_allclose(fused, unfused, rtol=2e-4, atol=2e-4)
