"""Distribution layer: FT state machines (in-process) + sharding rules,
pipeline parallelism, and compressed all-reduce (subprocess, forced devices)."""
import json

import jax
import pytest

from conftest import run_with_devices

# The multi-device subprocess tests build meshes with explicit axis_types;
# jax.sharding.AxisType arrived after 0.4.x — skip (not fail) on older jax.
requires_axis_type = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="jax.sharding.AxisType not available on this jax "
           f"({jax.__version__}); needs jax >= 0.5",
)
from repro.distributed.ft import (
    ElasticPlanner,
    FailureDetector,
    Heartbeat,
    StragglerMonitor,
)


# ---------------------------------------------------------------------------
# Fault tolerance (pure state machines)


def test_heartbeat_and_failure_detector(tmp_path):
    d = str(tmp_path / "hb")
    for r in range(4):
        Heartbeat(d, r).beat(step=10, now=1000.0)
    det = FailureDetector(d, world_size=4, timeout=60.0)
    assert det.dead_ranks(now=1030.0) == []
    Heartbeat(d, 2).beat(step=11, now=1030.0)
    assert det.dead_ranks(now=1090.0) == [0, 1, 3]
    det5 = FailureDetector(d, world_size=5, timeout=60.0)
    assert 4 in det5.dead_ranks(now=1030.0)  # never beat -> dead


def test_straggler_monitor():
    mon = StragglerMonitor(window=10, threshold=2.0)
    for _ in range(9):
        assert not mon.record(1.0)
    assert mon.record(5.0)  # 5x median
    assert mon.slow_count == 1


def test_elastic_planner_shrinks_dp():
    planner = ElasticPlanner(mesh_shape=(16, 16), hosts_per_dp_row=1)
    plan = planner.plan(world_size=16, dead=[3, 7])
    assert plan.new_mesh_shape == (8, 16)  # 14 -> nearest divisor 8
    assert plan.restart_from_checkpoint
    assert plan.dropped_hosts == (3, 7)
    assert planner.grad_accum_factor(plan) == 2  # preserve global batch


def test_elastic_planner_no_failures():
    planner = ElasticPlanner(mesh_shape=(2, 16, 16))
    plan = planner.plan(world_size=32, dead=[])
    assert plan.new_mesh_shape == (2, 16, 16)
    assert not plan.restart_from_checkpoint


# ---------------------------------------------------------------------------
# Multi-device behavior (subprocess with forced host devices)


@requires_axis_type
def test_sharding_rules_on_real_mesh():
    out = run_with_devices(8, """
        import jax, jax.numpy as jnp, json
        from repro import configs
        from repro.models import transformer as tf
        from repro.distributed import sharding as shd

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        cfg = configs.smoke_config("llama3.2-1b")
        params_abs = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
        sh = shd.param_sharding(params_abs, mesh)
        flat = jax.tree_util.tree_flatten_with_path(sh)[0]
        report = {}
        for path, ns in flat:
            key = "/".join(str(getattr(p, "key", p)) for p in path)
            report[key] = str(ns.spec)
        print(json.dumps(report))
    """)
    report = json.loads(out.strip().splitlines()[-1])
    wq = [v for k, v in report.items() if k.endswith("mixer/wq")]
    assert wq and all("'model'" in v for v in wq), wq
    wo = [v for k, v in report.items() if k.endswith("mixer/wo")]
    assert wo and all(v.startswith("PartitionSpec(None, 'model'")
                      for v in wo), wo
    emb = [v for k, v in report.items() if k.endswith("embed/table")]
    assert emb and "'model'" in emb[0]


@requires_axis_type
def test_sharded_train_step_runs_and_matches_single_device():
    """The same train step on a (2,2) mesh and on 1 device gives the same
    loss (SPMD correctness end-to-end)."""
    out = run_with_devices(4, """
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.configs.base import ShapeSpec
        from repro.data import batch_for
        from repro.models import transformer as tf
        from repro.optim import AdamWConfig, adamw, constant
        from repro.train.step import make_train_step
        from repro.distributed import sharding as shd
        from repro.distributed.context import use_mesh

        cfg = configs.smoke_config("granite-moe-1b-a400m")
        shape = ShapeSpec("t", 32, 4, "train")
        opt_cfg = AdamWConfig(lr=constant(1e-3))
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(opt_cfg, params)
        batch = batch_for(cfg, shape, 0)
        step = make_train_step(cfg, opt_cfg)

        # single device
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        p_sh = shd.param_sharding(params, mesh)
        o_sh = shd.opt_state_sharding(opt, params, mesh)
        b_sh = shd.batch_sharding(batch, mesh)
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt, o_sh)
        batch_s = jax.device_put(batch, b_sh)
        with use_mesh(mesh):
            p2, o2, m2 = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
            )(params_s, opt_s, batch_s)
        print("LOSS", float(m1["loss"]), float(m2["loss"]))
    """)
    line = [l for l in out.splitlines() if l.startswith("LOSS")][0]
    l1, l2 = map(float, line.split()[1:])
    assert abs(l1 - l2) / max(abs(l1), 1e-9) < 2e-2, (l1, l2)


@requires_axis_type
def test_pipeline_parallelism_matches_serial():
    out = run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_forward

        mesh = jax.make_mesh((4,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(size=(4, 16, 16)) * 0.5, jnp.float32)
        x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
        with mesh:
            y = pipeline_forward(mesh, stage_fn, ws, x, n_micro=4)
        ref = x
        for s in range(4):
            ref = jnp.tanh(ref @ ws[s])
        print("ERR", float(jnp.max(jnp.abs(y - ref))))
    """)
    err = float([l for l in out.splitlines() if l.startswith("ERR")][0].split()[1])
    assert err < 1e-5


@requires_axis_type
def test_compressed_allreduce_and_convergence():
    out = run_with_devices(4, """
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import (
            compressed_allreduce_mean, compression_ratio)

        mesh = jax.make_mesh((4,), ("dp",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)  # per-dev rows

        @partial(shard_map, mesh=mesh, in_specs=(P("dp"), P("dp")),
                 out_specs=(P("dp"), P("dp")))
        def cavg(grad, err):
            m, e = compressed_allreduce_mean(grad[0], err[0], "dp")
            return m[None], e[None]

        err0 = jnp.zeros_like(g)
        mean, err = cavg(g, err0)
        exact = jnp.mean(g, axis=0)
        rel = float(jnp.max(jnp.abs(mean[0] - exact)) /
                    jnp.max(jnp.abs(exact)))
        print("REL", rel)
        # Wire-traffic reduction at a realistic gradient size.
        print("RATIO", compression_ratio((1024, 1024)))
        # Convergence: EF-compressed SGD solves a least-squares problem.
        w = jnp.zeros((64,))
        tgt = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        efs = jnp.zeros((4, 64))
        for i in range(200):
            grads = jnp.stack([2 * (w - tgt) + 0.01 * jnp.asarray(
                rng.normal(size=(64,)), jnp.float32) for _ in range(4)])
            mean, efs = cavg(grads, efs)
            w = w - 0.05 * mean[0]
        print("DIST", float(jnp.linalg.norm(w - tgt)))
    """)
    vals = {l.split()[0]: float(l.split()[1]) for l in out.splitlines()
            if l.split() and l.split()[0] in ("REL", "RATIO", "DIST")}
    assert vals["REL"] < 0.02          # int8 quantization error is small
    assert vals["RATIO"] > 3.5         # ~4x wire-bytes reduction
    assert vals["DIST"] < 0.2          # EF-compressed training converges


@requires_axis_type
def test_zero_spec_adds_dp_axis():
    out = run_with_devices(8, """
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import zero_spec
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        s = zero_spec((64, 128), P(None, "model"), mesh)
        print("SPEC", s)
    """)
    line = [l for l in out.splitlines() if l.startswith("SPEC")][0]
    assert "'data'" in line
