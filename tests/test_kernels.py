"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings, strategies as st

from repro.core.conv_spec import ConvSpec
from repro.core.conv2d import conv2d, conv2d_reference
from repro.kernels.gemm import blocked_matmul, matmul_ref
from repro.kernels.im2col_gemm import conv2d_pallas_im2col
from repro.kernels.winograd import conv2d_winograd_pallas
from repro.kernels.winograd.kernel import (
    input_transform_pallas,
    output_transform_pallas,
    tuple_multiply_pallas,
)
from repro.kernels.winograd.ref import (
    input_transform_ref,
    output_transform_ref,
    tuple_multiply_ref,
)


def _rand(shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# Blocked GEMM


@pytest.mark.parametrize("shape", [(5, 7, 3), (64, 256, 128), (100, 300, 200),
                                   (8, 128, 128), (33, 190, 65)])
@pytest.mark.parametrize("variant", ["6loop", "3loop"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_blocked_matmul_sweep(shape, variant, dtype):
    m, n, k = shape
    a, b = _rand((m, k), 1, dtype), _rand((k, n), 2, dtype)
    got = blocked_matmul(a, b, variant=variant, interpret=True)
    ref = matmul_ref(a, b)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_blocked_matmul_explicit_blocks():
    a, b = _rand((64, 256), 3), _rand((256, 512), 4)
    for blk in [(8, 128, 128), (16, 256, 128), (64, 512, 256)]:
        got = blocked_matmul(a, b, block=blk, interpret=True)
        np.testing.assert_allclose(got, matmul_ref(a, b), rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 70), n=st.integers(1, 300), k=st.integers(1, 300),
       seed=st.integers(0, 2**31))
def test_blocked_matmul_property(m, n, k, seed):
    a, b = _rand((m, k), seed), _rand((k, n), seed + 1)
    got = blocked_matmul(a, b, interpret=True)
    np.testing.assert_allclose(got, matmul_ref(a, b), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Fused im2col+GEMM conv


@pytest.mark.parametrize("case", [
    dict(h=12, w=14, c=5, o=7, k=3, s=1, p=1),
    dict(h=13, w=11, c=4, o=6, k=3, s=2, p=1),
    dict(h=10, w=10, c=3, o=5, k=5, s=1, p=2),
    dict(h=9, w=16, c=8, o=16, k=3, s=3, p=0),
    dict(h=8, w=8, c=16, o=32, k=1, s=1, p=0),
])
def test_im2col_gemm_kernel(case):
    spec = ConvSpec(case["c"], case["o"], (case["k"], case["k"]),
                    (case["s"], case["s"]), (case["p"], case["p"]))
    x = _rand((2, case["h"], case["w"], case["c"]), 11)
    w = _rand((case["k"], case["k"], case["c"], case["o"]), 12)
    got = conv2d_pallas_im2col(x, w, spec, interpret=True)
    ref = conv2d_reference(x, w, spec)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_im2col_gemm_explicit_blocks():
    spec = ConvSpec(8, 16, (3, 3), (1, 1), (1, 1))
    x, w = _rand((1, 16, 16, 8), 13), _rand((3, 3, 8, 16), 14)
    ref = conv2d_reference(x, w, spec)
    for blocks in [(4, 8, 128), (8, 8, 128), (16, 8, 256)]:
        got = conv2d_pallas_im2col(x, w, spec, blocks=blocks, interpret=True)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Winograd kernels (per-stage + end-to-end)


def test_winograd_input_transform_kernel():
    tiles = _rand((16, 8, 8, 8), 21)
    got = input_transform_pallas(tiles, bt=8, bc=8, interpret=True)
    np.testing.assert_allclose(got, input_transform_ref(tiles), rtol=1e-4,
                               atol=1e-4)


def test_winograd_tuple_multiply_kernel():
    v, u = _rand((64, 16, 8), 22), _rand((64, 8, 12), 23)
    got = tuple_multiply_pallas(v, u, bt=8, bc=8, bo=4, interpret=True)
    np.testing.assert_allclose(got, tuple_multiply_ref(v, u), rtol=1e-4,
                               atol=1e-4)


def test_winograd_output_transform_kernel():
    m = _rand((8, 8, 16, 8), 24)
    got = output_transform_pallas(m, bt=8, bo=8, interpret=True)
    np.testing.assert_allclose(got, output_transform_ref(m), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("case", [
    dict(h=12, w=14, c=5, o=7), dict(h=6, w=6, c=3, o=4),
    dict(h=20, w=26, c=16, o=32), dict(h=13, w=7, c=2, o=9),
])
def test_winograd_conv_end_to_end(case):
    spec = ConvSpec(case["c"], case["o"], (3, 3), (1, 1), (1, 1))
    x = _rand((2, case["h"], case["w"], case["c"]), 31)
    w = _rand((3, 3, case["c"], case["o"]), 32)
    got = conv2d_winograd_pallas(x, w, spec, interpret=True)
    ref = conv2d_reference(x, w, spec)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)


def test_winograd_pretransformed_weights():
    from repro.core.winograd import transform_weights

    spec = ConvSpec(4, 6, (3, 3), (1, 1), (1, 1))
    x, w = _rand((1, 12, 12, 4), 33), _rand((3, 3, 4, 6), 34)
    u = transform_weights(w)
    got = conv2d_winograd_pallas(x, u, spec, pretransformed=True, interpret=True)
    np.testing.assert_allclose(got, conv2d_reference(x, w, spec), rtol=5e-4,
                               atol=5e-4)


# ---------------------------------------------------------------------------
# Dispatcher


@settings(max_examples=10, deadline=None)
@given(k=st.sampled_from([1, 3, 5]), s=st.integers(1, 2), seed=st.integers(0, 2**31))
def test_pallas_dispatch_property(k, s, seed):
    spec = ConvSpec(4, 8, (k, k), (s, s), (k // 2, k // 2))
    x = _rand((1, 10, 12, 4), seed)
    w = _rand((k, k, 4, 8), seed + 1)
    got = conv2d(x, w, spec, impl="pallas", interpret=True)
    ref = conv2d_reference(x, w, spec)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)
