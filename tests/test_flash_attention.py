"""Flash-attention Pallas kernel vs oracle: shape/feature sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import attention_ref, flash_attention


def _run(b, s, h, hd, causal, window, cap, bq=16, bk=16, seed=0):
    rng = np.random.default_rng(seed)
    q, k, v = (jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
               for _ in range(3))
    got = flash_attention(q, k, v, causal=causal, window=window,
                          logit_cap=cap, bq=bq, bk=bk, interpret=True)
    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    ref = attention_ref(flat(q), flat(k), flat(v), causal=causal,
                        window=window, logit_cap=cap)
    ref = ref.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("case", [
    dict(b=2, s=64, h=3, hd=16, causal=True, window=0, cap=0.0),
    dict(b=1, s=128, h=2, hd=32, causal=True, window=32, cap=0.0, bq=32, bk=64),
    dict(b=2, s=48, h=2, hd=16, causal=True, window=0, cap=50.0),
    dict(b=1, s=64, h=1, hd=16, causal=False, window=0, cap=0.0),
    dict(b=1, s=50, h=2, hd=16, causal=True, window=0, cap=0.0),  # padded
])
def test_flash_attention_cases(case):
    _run(**case)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(17, 96), h=st.integers(1, 3),
       window=st.sampled_from([0, 8, 24]), seed=st.integers(0, 2**31))
def test_flash_attention_property(s, h, window, seed):
    _run(b=1, s=s, h=h, hd=16, causal=True, window=window, cap=0.0, seed=seed)


def test_flash_attention_bf16():
    rng = np.random.default_rng(1)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.bfloat16)
               for _ in range(3))
    got = flash_attention(q, k, v, bq=16, bk=16, interpret=True)
    assert got.dtype == jnp.bfloat16
    assert bool(jnp.isfinite(got.astype(jnp.float32)).all())
