"""Co-design model invariants + roofline machinery (HLO parsing)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings, strategies as st

from repro.core.codesign import MB, layer_roofline, sweep_cache_size, sweep_lanes
from repro.core.conv_spec import ConvSpec
from repro.core.vmem_model import GemmShape, autotune_gemm, candidate_blocks
from repro.roofline.analysis import parse_collectives


@settings(max_examples=30, deadline=None)
@given(m=st.integers(8, 4096), n=st.integers(128, 8192), k=st.integers(128, 8192),
       budget=st.sampled_from([1 * MB, 4 * MB, 16 * MB]))
def test_autotune_respects_budget(m, n, k, budget):
    cfg, est = autotune_gemm(GemmShape(m, n, k), vmem_budget=budget)
    assert cfg.vmem_bytes() <= budget
    assert est.total_s > 0


def test_bigger_cache_never_hurts():
    """Paper Fig 7: larger caches monotonically improve (or hold) the best
    achievable time — the model must reproduce that."""
    shape = GemmShape(256, 5776, 1152)
    best = np.inf
    for budget in (1 * MB, 2 * MB, 4 * MB, 8 * MB, 16 * MB, 64 * MB):
        _, est = autotune_gemm(shape, vmem_budget=budget)
        assert est.total_s <= best * (1 + 1e-9)
        best = min(best, est.total_s)


def test_longer_vectors_need_bigger_cache():
    """Paper's central co-design finding: at 1MB the widest block is NOT
    optimal; with a big budget it is."""
    shape = GemmShape(256, 369664, 1152)
    sweeps = sweep_cache_size(shape, budgets=(1 * MB, 64 * MB))
    small = min(sweeps[1 * MB], key=lambda p: p.estimate.total_s)
    big = min(sweeps[64 * MB], key=lambda p: p.estimate.total_s)
    assert big.bn >= small.bn
    assert big.estimate.total_s <= small.estimate.total_s


def test_more_lanes_help_long_vectors_most():
    """Paper §VI.B.c: lanes scale better at long vector lengths."""
    shape = GemmShape(1024, 8192, 4096)
    pts = sweep_lanes(shape, vmem_budget=16 * MB)
    times = [p.estimate.total_s for p in pts]
    assert times[-1] <= times[0]  # 8 lanes never slower than 1


def test_layer_roofline_ai_ordering():
    """Higher-AI layers achieve a >= fraction of peak (roofline shape)."""
    low = layer_roofline(ConvSpec(3, 32, (3, 3), (1, 1), (1, 1)), 608, 608)
    high = layer_roofline(ConvSpec(512, 1024, (3, 3), (1, 1), (1, 1)), 26, 26)
    assert high["AI"] > low["AI"]
    assert high["pct_of_peak"] >= low["pct_of_peak"]


def test_candidate_blocks_alignment():
    for cfg in candidate_blocks(4 * MB):
        assert cfg.bm % 8 == 0 and cfg.bn % 128 == 0 and cfg.bk % 128 == 0


def test_cost_selector_refines_paper_rule():
    """Beyond-paper: on v5e, 3x3/s1 eligibility additionally requires the
    layer be activation-dominated (EXPERIMENTS.md §Perf CNN section)."""
    from repro.core.codesign import select_algorithm_by_cost
    from repro.core.conv_spec import ConvAlgorithm

    early = ConvSpec(64, 128, (3, 3), (1, 1), (1, 1))
    deep = ConvSpec(256, 512, (3, 3), (1, 1), (1, 1))
    assert select_algorithm_by_cost(early, 152, 152) is ConvAlgorithm.WINOGRAD
    assert select_algorithm_by_cost(deep, 38, 38) is ConvAlgorithm.IM2COL_GEMM
    # non-eligible shapes keep the paper's rules
    one = ConvSpec(64, 64, (1, 1), (1, 1), (0, 0))
    assert select_algorithm_by_cost(one, 64, 64) is ConvAlgorithm.DIRECT


def test_auto_cost_dispatch_correctness():
    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro.core.conv2d import conv2d, conv2d_reference
    from repro.core.conv_spec import ConvAlgorithm

    spec = dc.replace(ConvSpec(8, 16, (3, 3), (1, 1), (1, 1)),
                      algorithm=ConvAlgorithm.AUTO_COST)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 20, 20, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 16)) * 0.1
    np.testing.assert_allclose(
        np.asarray(conv2d(x, w, spec)),
        np.asarray(conv2d_reference(x, w, spec)), rtol=2e-4, atol=2e-4,
    )


# ---------------------------------------------------------------------------
# Roofline HLO parsing


HLO_SAMPLE = """
  %all-reduce.1 = f32[32,256]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true
  %ag = bf16[64,128]{1,0} all-gather(%x), replica_groups=[4,2]<=[8], dimensions={0}
  %rs = f32[8,16]{1,0} reduce-scatter(%y), replica_groups={{0,1,2,3}}
  %cp = f32[128]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %done = f32[8] all-reduce-done(%foo)
"""


def test_parse_collectives():
    ops = parse_collectives(HLO_SAMPLE)
    kinds = [o.kind for o in ops]
    assert kinds == ["all-reduce", "all-gather", "reduce-scatter",
                     "collective-permute"]
    ar = ops[0]
    assert ar.result_bytes == 32 * 256 * 4 and ar.group_size == 4
    ag = ops[1]
    assert ag.result_bytes == 64 * 128 * 2 and ag.group_size == 2
    rs = ops[2]
    assert rs.group_size == 4
    # wire models
    assert ar.wire_bytes == pytest.approx(2 * ar.result_bytes * 3 / 4)
    assert ag.wire_bytes == pytest.approx(ag.result_bytes * 1 / 2)
    assert rs.wire_bytes == pytest.approx(rs.result_bytes * 3)
    assert ops[3].wire_bytes == 128 * 4


def test_model_flops_formulas():
    from repro import configs
    from repro.configs.base import SHAPES
    from repro.roofline.analysis import model_flops_for

    cfg = configs.get_config("llama3.2-1b")
    n = cfg.param_count()
    t = SHAPES["train_4k"]
    assert model_flops_for(cfg, t) == pytest.approx(
        6.0 * n * t.global_batch * t.seq_len)
    d = SHAPES["decode_32k"]
    assert model_flops_for(cfg, d) == pytest.approx(2.0 * n * d.global_batch)
    # MoE uses active params
    moe = configs.get_config("arctic-480b")
    assert model_flops_for(moe, t) < 6.0 * moe.param_count() * t.global_batch * t.seq_len
