"""Core conv algorithms vs the XLA oracle + selector rules (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings, strategies as st

from repro.core.conv_spec import (
    ConvAlgorithm,
    ConvSpec,
    arithmetic_intensity,
    select_algorithm,
)
from repro.core.conv2d import conv2d, conv2d_reference


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(6, 20),
    w=st.integers(6, 20),
    c=st.integers(1, 8),
    o=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 2),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**31),
)
def test_conv2d_matches_oracle(h, w, c, o, k, stride, pad, seed):
    spec = ConvSpec(c, o, (k, k), (stride, stride), (pad, pad))
    oh, ow = spec.out_hw(h, w)
    if oh < 1 or ow < 1:
        return
    x = _rand((2, h, w, c), seed)
    wt = _rand((k, k, c, o), seed + 1)
    got = conv2d(x, wt, spec)
    ref = conv2d_reference(x, wt, spec)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_selector_rules():
    mk = lambda k, s: ConvSpec(8, 8, (k, k), (s, s), (k // 2, k // 2))
    assert select_algorithm(mk(1, 1)) is ConvAlgorithm.DIRECT
    assert select_algorithm(mk(3, 1)) is ConvAlgorithm.WINOGRAD
    # paper §VII.A: stride-2 3x3 measured 1.4x SLOWER with winograd
    assert select_algorithm(mk(3, 2)) is ConvAlgorithm.IM2COL_GEMM
    assert select_algorithm(mk(5, 1)) is ConvAlgorithm.IM2COL_GEMM
    forced = ConvSpec(8, 8, (3, 3), algorithm=ConvAlgorithm.IM2COL_GEMM)
    assert select_algorithm(forced) is ConvAlgorithm.IM2COL_GEMM


def test_dilated_conv_im2col():
    spec = ConvSpec(4, 6, (3, 3), (1, 1), (2, 2), dilation=(2, 2))
    x = _rand((1, 12, 12, 4), 7)
    wt = _rand((3, 3, 4, 6), 8)
    np.testing.assert_allclose(
        conv2d(x, wt, spec), conv2d_reference(x, wt, spec), rtol=2e-4, atol=2e-4
    )


def test_arithmetic_intensity_matches_paper():
    """Paper Table IV: AI(L10: M=256,N=5776,K=1152) = 101 (fp32)."""
    assert abs(arithmetic_intensity(256, 5776, 1152) - 101) < 1.0
    assert abs(arithmetic_intensity(32, 369664, 27) - 7.32) < 0.05
    assert abs(arithmetic_intensity(512, 1444, 2304) - 162) < 1.0


def test_gemm_dims_formula():
    """M = n_filters, K = k*k*c, N = oh*ow (paper §IV.A)."""
    spec = ConvSpec(3, 32, (3, 3), (1, 1), (1, 1))
    m, n, k = spec.gemm_dims(608, 608)
    assert (m, n, k) == (32, 608 * 608, 27)
