"""MoE layer: routing invariants, capacity semantics, aux losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip, not error
from hypothesis import given, settings, strategies as st

from repro.models.moe import apply_moe, init_moe


def _setup(d=16, f=32, e=8, seed=0):
    return init_moe(jax.random.PRNGKey(seed), d, f, e, jnp.float32)


def test_output_shape_and_finite():
    p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    y, aux = apply_moe(p, x, top_k=2)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["dropped_frac"]) >= 0.0


def test_high_capacity_drops_nothing():
    p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16))
    _, aux = apply_moe(p, x, top_k=2, capacity_factor=8.0)
    assert float(aux["dropped_frac"]) == 0.0


def test_tiny_capacity_drops_tokens():
    p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 16))
    _, aux = apply_moe(p, x, top_k=2, capacity_factor=0.1)
    assert float(aux["dropped_frac"]) > 0.3


def test_combine_weights_convexity():
    """With capacity high enough for no drops, scaling all expert outputs by
    c scales the MoE output by c (combine weights sum to 1)."""
    p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 16))
    y1, _ = apply_moe(p, x, top_k=2, capacity_factor=8.0)
    p2 = dict(p, w_down=p["w_down"] * 2.0)
    y2, _ = apply_moe(p2, x, top_k=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1),
                               rtol=1e-4, atol=1e-5)


def test_load_balance_loss_range():
    """Uniform routing -> lb loss ~1; concentrated routing -> ~E."""
    p = _setup(e=8)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 64, 16))
    _, aux = apply_moe(p, x, top_k=2)
    assert 0.5 < float(aux["load_balance"]) < 8.5


def test_sharded_dispatch_matches_default():
    """The masked scatter-add (DP-shardable) dispatch computes the same
    outputs as the waste-row dispatch, drops included (same rank/keep)."""
    p = _setup(e=8, seed=3)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 32, 16))
    for cf in (0.2, 1.25, 8.0):
        y1, a1 = apply_moe(p, x, top_k=2, capacity_factor=cf,
                           sharded_dispatch=False)
        y2, a2 = apply_moe(p, x, top_k=2, capacity_factor=cf,
                           sharded_dispatch=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-6)
        assert float(a1["dropped_frac"]) == float(a2["dropped_frac"])


@settings(max_examples=10, deadline=None)
@given(e=st.sampled_from([4, 8, 16]), k=st.integers(1, 4),
       seed=st.integers(0, 2**31))
def test_moe_gradient_flows(e, k, seed):
    p = _setup(e=e, seed=seed % 100)
    x = jax.random.normal(jax.random.PRNGKey(seed % 1000), (2, 8, 16))

    def loss(p_):
        y, _ = apply_moe(p_, x, top_k=min(k, e))
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
