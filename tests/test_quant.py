"""Unit tests for the int8 quantization machinery (core/quant.py) and the
planner policy that decides, per layer, whether an int8 request actually
executes in int8.

The conformance suite (test_conv_conformance.py) owns the kernel-vs-oracle
SQNR gates; this file pins the offline pieces: scale computation, the
round-trip error bound, the Winograd error budget, the traffic gate, and
the v5 plan-cache semantics of per-layer dtype resolution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conv_spec import ConvAlgorithm, ConvSpec
from repro.core.quant import (
    INT8_TRAFFIC_THRESHOLD,
    QMAX,
    WINOGRAD_SQNR_BUDGET_DB,
    activation_scales,
    int8_traffic_ratio,
    int8_worthwhile,
    quantize_activation,
    quantize_conv_weights,
    sqnr_db,
    winograd_int8_budget_ok,
    winograd_int8_sqnr_estimate_db,
    winograd_transform_amplification,
)


def _rand(shape, seed):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape), jnp.float32
    )


# ---------------------------------------------------------------------------
# Scales and round-trip error.


def test_activation_scales_per_channel():
    x = _rand((2, 6, 6, 4), 0) * jnp.asarray([1.0, 10.0, 0.1, 100.0])
    s = activation_scales(x, axis=(0, 1, 2))
    assert s.shape == (4,)
    np.testing.assert_allclose(
        s, jnp.max(jnp.abs(x), axis=(0, 1, 2)) / QMAX, rtol=1e-6
    )


def test_quantize_activation_round_trip_bound():
    """|x - dequant(quant(x))| <= scale/2 elementwise: symmetric
    round-to-nearest with a per-channel scale covering the range."""
    x = _rand((2, 8, 8, 8), 1) * jnp.asarray([0.01 * (i + 1) for i in range(8)])
    s = activation_scales(x, axis=(0, 1, 2))
    xq = quantize_activation(x, s)
    assert xq.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(xq.astype(jnp.int32)))) <= 127
    dq = xq.astype(jnp.float32) * s
    assert float(jnp.max(jnp.abs(x - dq))) <= float(jnp.max(s)) / 2 + 1e-7


def test_quantize_activation_zero_channel_survives():
    """An all-zero channel gets the scale floor, quantizes to 0, and
    dequantizes back to exactly 0 — no NaN/inf from a 0/0."""
    x = _rand((1, 4, 4, 3), 2).at[..., 1].set(0.0)
    s = activation_scales(x, axis=(0, 1, 2))
    assert bool(jnp.all(s > 0))
    xq = quantize_activation(x, s)
    assert bool(jnp.all(xq[..., 1] == 0))
    assert bool(jnp.all(jnp.isfinite(xq.astype(jnp.float32) * s)))


def test_quantize_conv_weights_folds_input_scales():
    """The per-input-channel activation scale is folded into the weights
    before per-output-channel quantization: dequantized effective weights
    reproduce w * sx to within the weight quantization step."""
    w = _rand((3, 3, 4, 8), 3) * 0.3
    sx = jnp.asarray([0.5, 1.0, 2.0, 4.0]) / QMAX
    wq, ws = quantize_conv_weights(w, sx)
    assert wq.dtype == jnp.int8 and ws.shape == (8,)
    eff = wq.astype(jnp.float32) * ws          # folded-weight reconstruction
    want = w * sx[None, None, :, None]
    assert float(jnp.max(jnp.abs(eff - want))) <= float(jnp.max(ws)) / 2 + 1e-7


def test_sqnr_db_basics():
    x = _rand((64,), 4)
    assert sqnr_db(x, x) == float("inf")
    noisy = x + 0.01 * _rand((64,), 5)
    q = sqnr_db(x, noisy)
    assert 20.0 < q < 60.0
    # Scaling both signals together leaves SQNR unchanged.
    assert abs(sqnr_db(10 * x, 10 * noisy) - q) < 1e-6


# ---------------------------------------------------------------------------
# The Winograd int8 error budget: F(6, 3) fails it, so int8 3x3 layers run
# im2col+GEMM.


def test_winograd_amplification_exceeds_budget():
    amp = winograd_transform_amplification()
    assert amp > 10.0  # F(6, 3) BT row sums are large by construction
    est = winograd_int8_sqnr_estimate_db()
    assert est < WINOGRAD_SQNR_BUDGET_DB
    assert not winograd_int8_budget_ok()
    # A sufficiently lax budget would pass — the predicate reads its
    # threshold rather than hard-coding False.
    assert winograd_int8_budget_ok(threshold_db=est - 1.0)


# ---------------------------------------------------------------------------
# The traffic gate.


def test_traffic_gate_rejects_shallow_accepts_deep():
    deep = ConvSpec(256, 512, (3, 3), (1, 1), (1, 1))
    entry = ConvSpec(3, 64, (3, 3), (1, 1), (1, 1))
    assert int8_worthwhile(deep, 32, 32)
    assert not int8_worthwhile(entry, 224, 224), (
        "cin=3: fp32 output writes dominate, int8 saves < 2x"
    )
    r = int8_traffic_ratio(deep, 32, 32)
    assert 0.25 <= r <= INT8_TRAFFIC_THRESHOLD


# ---------------------------------------------------------------------------
# Planner policy: per-layer dtype resolution, v5 cache round-trip.


def _plan(spec, h=16, w=16, dtype="int8", **kw):
    from repro.core.planner import Planner

    return Planner(impl="pallas", cache_path=None, **kw).plan(
        spec, h, w, dtype=dtype
    )


def test_planner_int8_deep_3x3_is_im2col():
    p = _plan(ConvSpec(256, 512, (3, 3), (1, 1), (1, 1)))
    assert p.dtype == "int8"
    assert p.algorithm is ConvAlgorithm.IM2COL_GEMM, (
        "int8 3x3 must not route to Winograd"
    )
    assert not p.winograd_fused


def test_planner_int8_1x1_is_direct():
    """1x1 convs quantize only where the weight bytes dominate (tiny
    spatial dims — YOLO's deep 1x1s at low resolution); there the int8
    plan keeps the DIRECT GEMM route."""
    spec = ConvSpec(256, 512, (1, 1), (1, 1), (0, 0))
    p = _plan(spec, h=4, w=4)
    assert p.dtype == "int8"
    assert p.algorithm is ConvAlgorithm.DIRECT
    # At large spatial dims the fp32 output write dominates and the same
    # layer stays fp32 — the gate is shape-aware, not kernel-size-aware.
    assert _plan(spec, h=64, w=64).dtype == "float32"


def test_planner_int8_entry_layer_stays_fp32():
    p = _plan(ConvSpec(3, 64, (3, 3), (1, 1), (1, 1)), h=64, w=64)
    assert p.dtype == "float32", (
        "the traffic gate must keep the cin=3 entry conv fp32"
    )


def test_planner_int8_beats_fp32_prediction():
    """Where int8 is chosen, its modeled time beats the fp32 plan for the
    same layer — the policy never quantizes at a predicted slowdown."""
    spec = ConvSpec(256, 512, (3, 3), (1, 1), (1, 1))
    p8 = _plan(spec)
    p32 = _plan(spec, dtype="float32")
    assert p8.dtype == "int8"
    assert p8.predicted_s < p32.predicted_s


def test_planner_measure_mode_delegates_int8_to_cost_model():
    """Quantization is a policy decision, not a measurement: measure-mode
    planners resolve int8 through the same cost-model gate."""
    from repro.core.planner import Planner

    planner = Planner(impl="pallas", mode="measure", cache_path=None)
    p = planner.plan(ConvSpec(256, 512, (3, 3), (1, 1), (1, 1)), 16, 16,
                     dtype="int8")
    assert p.dtype == "int8"
    assert p.source == "cost_model"


def test_plan_dtype_cache_round_trip(tmp_path):
    """The resolved per-layer dtype rides the plan entry (since v5), and a
    warm planner re-tunes nothing for the same int8 request."""
    from repro.core.planner import PLAN_CACHE_VERSION, Planner

    assert PLAN_CACHE_VERSION >= 5
    cache = str(tmp_path / "plans.json")
    spec = ConvSpec(128, 256, (3, 3), (1, 1), (1, 1))
    p1 = Planner(impl="pallas", cache_path=cache)
    a = p1.plan(spec, 16, 16, dtype="int8")
    b = p1.plan(spec, 16, 16, dtype="float32")
    assert (a.dtype, b.dtype) == ("int8", "float32")
    p1.save()
    p2 = Planner(impl="pallas", cache_path=cache)
    a2 = p2.plan(spec, 16, 16, dtype="int8")
    b2 = p2.plan(spec, 16, 16, dtype="float32")
    assert p2.stats["tunes"] == 0, "warm v5 cache must re-tune nothing"
    assert a2.dtype == "int8" and b2.dtype == "float32"
    assert a2.algorithm is a.algorithm and a2.kernel_blocks == a.kernel_blocks


def test_execution_options_int8_surface():
    """ExecutionOptions: 'int8' validates, input_dtype stays fp32 (images
    are never cast to int8 at the boundary), and unknown dtypes are
    rejected loudly."""
    from repro.api import ExecutionOptions

    o = ExecutionOptions(dtype="int8")
    assert o.dtype == "int8" and o.input_dtype == "float32"
    assert ExecutionOptions(dtype="float32").input_dtype == "float32"
    with pytest.raises(ValueError, match="dtype"):
        ExecutionOptions(dtype="int4")


def test_calibration_walk_matches_entry_distribution():
    """calibrate_activation_scales records scales at each conv's *input*:
    for the first conv they must equal the calibration batch's own
    per-channel scales."""
    from repro.core.netplan import plan_network
    from repro.core.planner import Planner
    from repro.core.quant import calibrate_activation_scales
    from repro.models.cnn import CNNLayer, fold_batchnorm, init_cnn

    layers = (
        CNNLayer("conv", out_channels=32, kernel=3, activation="relu"),
        CNNLayer("conv", out_channels=48, kernel=3, activation="leaky"),
    )
    params = fold_batchnorm(
        init_cnn(jax.random.PRNGKey(0), layers, in_channels=16), layers
    )
    netplan = plan_network(
        layers, 16, 16, Planner(impl="jax", cache_path=None),
        in_channels=16, batch=1,
    )
    x = _rand((2, 16, 16, 16), 6)
    scales = calibrate_activation_scales(netplan, params, x)
    assert set(scales) == {0, 1}
    np.testing.assert_allclose(
        scales[0], activation_scales(x, axis=(0, 1, 2)), rtol=1e-6
    )
    assert scales[1].shape == (32,)
    assert bool(jnp.all(scales[1] > 0))
