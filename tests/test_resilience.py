"""Serving resilience: every degradation path proven under injected faults.

The acceptance surface of serving/resilience.py + serving/faults.py:

  - zero-cost happy path: with no faults, engines produce bit-identical
    outputs and identical plan-cache bytes vs the plain compiled executor;
  - each injected fault class is caught by exactly its intended handler —
    executor exception → ladder fallback, NaN row → request-level failure,
    deadline expiry → eviction, queue overflow → Backpressure, cache
    corruption → quarantine + salvage;
  - no request is ever lost or served twice under injection;
  - the per-bucket circuit breaker walks CLOSED → OPEN → HALF_OPEN probe →
    CLOSED deterministically (counted in dispatches, not wall time).
"""
import json
import os

import jax
import numpy as np
import pytest

import repro
from repro import configs
from repro.api import CNNModel, ExecutionOptions
from repro.core.planner import (
    PLAN_CACHE_VERSION,
    Planner,
    salvage_cache_text,
)
from repro.models import transformer as tf
from repro.models.cnn import CNNLayer, init_cnn
from repro.serving import (
    Backpressure,
    CNNServingEngine,
    DeadlineExceeded,
    FakeClock,
    FaultPlan,
    FaultSpec,
    InvalidRequest,
    QueueNotDrained,
    RequestFailed,
    ServingEngine,
    ServingError,
    is_failure,
)
from repro.serving.faults import corrupt_cache_file

C = CNNLayer

LAYERS = (
    C("conv", out_channels=8, kernel=3, activation="relu"),
    C("conv", out_channels=4, kernel=1, pad=0, batch_norm=False,
      activation="linear"),
)
HW = (8, 8)


def _compiled(cache_path=None, impl="jax", buckets=(1, 2), **opt_kw):
    model = CNNModel(LAYERS, HW, name="resilience-tiny")
    params = init_cnn(jax.random.PRNGKey(0), LAYERS)
    opts = ExecutionOptions(
        impl=impl, cache_path=cache_path, buckets=buckets, batch=buckets[0],
        **opt_kw,
    )
    return repro.compile(model, params, opts)


def _images(n, seed=1):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, *HW, 3)).astype(np.float32)


# ---------------------------------------------------------------------------
# Zero-cost happy path


def test_happy_path_bit_identical_and_counters_zero():
    compiled = _compiled()
    imgs = _images(3)
    eng = compiled.serve()
    uids = [eng.submit(img) for img in imgs]
    results = eng.run()
    # Compare at the batch sizes the engine actually dispatched (plans are
    # batch-keyed): bucket 2 for the first pair, bucket 1 for the tail.
    direct = {
        uids[0]: np.asarray(compiled.run(imgs[:2]))[0],
        uids[1]: np.asarray(compiled.run(imgs[:2]))[1],
        uids[2]: np.asarray(compiled.run(imgs[2:3]))[0],
    }
    for u in uids:
        assert np.array_equal(np.asarray(results[u]), direct[u]), (
            "resilience must be bit-invisible on the happy path"
        )
    h = eng.health()
    assert h["evictions"] == h["rejections"] == h["retries"] == 0
    assert h["request_failures"] == h["fallback_batches"] == 0
    assert h["faults_injected"] == 0
    assert h["fallback_depth"] == 0
    for b in h["buckets"].values():
        assert b["state"] == "CLOSED" and b["depth"] == 0


def test_happy_path_cache_bytes_stable(tmp_path):
    cache = str(tmp_path / "plans.json")
    eng = _compiled(cache_path=cache).serve()
    eng.submit(_images(1)[0])
    eng.run()
    before = open(cache, "rb").read()
    # A second cold process over the same cache: serving again (even with a
    # fault driving the ladder) must not grow or rewrite the cache — the
    # fallback rungs never plan.
    faults = FaultPlan([FaultSpec("exception", rung="primary", times=2)])
    eng2 = _compiled(cache_path=cache).serve(faults=faults)
    eng2.submit(_images(1)[0])
    eng2.run()
    assert open(cache, "rb").read() == before


# ---------------------------------------------------------------------------
# Admission: backpressure, validation, deadlines, priority


def test_backpressure_typed_rejection():
    eng = _compiled(max_queue=2).serve()
    eng.submit(_images(1)[0])
    eng.submit(_images(1)[0])
    with pytest.raises(Backpressure) as ei:
        eng.submit(_images(1)[0])
    assert ei.value.queue_len == 2 and ei.value.max_queue == 2
    assert eng.health()["rejections"] == 1
    # Draining the queue re-opens admission.
    eng.run()
    eng.submit(_images(1)[0])


def test_submit_validation_cnn():
    eng = _compiled().serve()
    bad = _images(1)[0]
    bad[0, 0, 0] = np.nan
    with pytest.raises(InvalidRequest):
        eng.submit(bad)
    with pytest.raises(ValueError):        # InvalidRequest IS a ValueError
        eng.submit(np.zeros((4, 4, 3), np.float32))
    with pytest.raises(InvalidRequest):
        eng.submit(np.zeros((*HW, 3), np.complex64))
    with pytest.raises(InvalidRequest):
        eng.submit(_images(1)[0], deadline_s=-1.0)
    assert eng.health()["queue_len"] == 0, "no rejected payload was enqueued"


def test_deadline_eviction_no_double_serve():
    clock = FakeClock()
    eng = _compiled(buckets=(1, 2)).serve(clock=clock)
    u_exp = eng.submit(_images(1, seed=2)[0], deadline_s=1.0)
    u_ok = eng.submit(_images(1, seed=3)[0])
    clock.advance(5.0)
    results = eng.run()
    assert isinstance(results[u_exp], DeadlineExceeded)
    assert results[u_exp].deadline == pytest.approx(1.0)
    assert not is_failure(results[u_ok])
    assert eng.health()["evictions"] == 1
    # No double serve: the evicted uid never reappears.
    assert eng.run() == {} and eng.health()["evictions"] == 1


def test_default_deadline_from_options():
    clock = FakeClock()
    eng = _compiled(default_deadline_s=2.0).serve(clock=clock)
    u = eng.submit(_images(1)[0])
    clock.advance(3.0)
    results = eng.run()
    assert isinstance(results[u], DeadlineExceeded)


def test_priority_dispatch_order():
    eng = _compiled(buckets=(1,)).serve()
    u_low = eng.submit(_images(1, seed=4)[0], priority=0)
    u_high = eng.submit(_images(1, seed=5)[0], priority=5)
    first = eng.step()
    assert set(first) == {u_high}, "higher priority dispatches first"
    second = eng.step()
    assert set(second) == {u_low}


# ---------------------------------------------------------------------------
# Fallback ladder


def test_retry_recovers_transient_exception():
    faults = FaultPlan([FaultSpec("exception", rung="primary", times=1)])
    compiled = _compiled()
    eng = compiled.serve(faults=faults)
    img = _images(1)[0]
    u = eng.submit(img)
    results = eng.run()
    # One transient failure + one retry at the same rung: served by the
    # fast path, bit-identical, breaker never trips.
    assert np.array_equal(
        np.asarray(results[u]), np.asarray(compiled.run(img[None]))[0]
    )
    h = eng.health()
    assert h["retries"] == 1 and h["fallback_depth"] == 0
    assert h["faults_injected"] == 1


def test_exception_falls_back_to_xla_ref():
    # times=2 outlasts the default retry, forcing a rung descent.
    faults = FaultPlan([FaultSpec("exception", rung="primary", times=2)])
    compiled = _compiled()
    eng = compiled.serve(faults=faults)
    img = _images(1)[0]
    u = eng.submit(img)
    results = eng.run()
    ref = np.asarray(compiled.run(img[None]))[0]
    np.testing.assert_allclose(
        np.asarray(results[u]), ref, rtol=1e-4, atol=1e-4
    )
    h = eng.health()
    assert h["fallback_depth"] == 1 and h["fallback_batches"] == 1
    assert h["buckets"]["1"]["rung"] == "xla-ref"
    assert h["buckets"]["1"]["state"] == "OPEN"


def test_pallas_exception_falls_back_to_interpret_bit_compatible():
    compiled = _compiled(impl="pallas")
    img = _images(1)[0]
    clean = compiled.serve()
    u0 = clean.submit(img)
    want = np.asarray(clean.run()[u0])

    faults = FaultPlan([FaultSpec("exception", rung="primary", times=2)])
    eng = compiled.serve(faults=faults)
    u = eng.submit(img)
    got = np.asarray(eng.run()[u])
    # The interpret rung executes the same NetworkPlan with the same
    # prepared params — bit-compatible with the unfaulted pallas path.
    assert np.array_equal(got, want)
    assert eng.health()["buckets"]["1"]["rung"] == "pallas-interpret"
    assert [r for r in eng.health()["ladder"]] == [
        "primary", "pallas-interpret", "xla-ref"
    ]


def test_nan_row_is_request_level_not_batch_level():
    # Poison row 1 of the 2-wide bucket past the retry budget: that one
    # request fails, its co-batched neighbour is served bit-identically.
    faults = FaultPlan(
        [FaultSpec("nan", rung="primary", rows=(1,), times=2)]
    )
    compiled = _compiled()
    eng = compiled.serve(faults=faults)
    imgs = _images(2)
    u0, u1 = (eng.submit(img) for img in imgs)
    results = eng.run()
    assert isinstance(results[u1], RequestFailed)
    assert results[u1].rung == "primary"
    assert np.array_equal(
        np.asarray(results[u0]), np.asarray(compiled.run(imgs))[0]
    )
    h = eng.health()
    assert h["request_failures"] == 1
    assert h["fallback_depth"] == 0, "row-level poison must not trip the breaker"


def test_fully_nan_batch_descends_ladder():
    faults = FaultPlan([FaultSpec("nan", rung="primary", times=2)])
    compiled = _compiled()
    eng = compiled.serve(faults=faults)
    imgs = _images(2)
    uids = [eng.submit(img) for img in imgs]
    results = eng.run()
    ref = np.asarray(compiled.run(imgs))
    for i, u in enumerate(uids):
        assert np.isfinite(np.asarray(results[u])).all()
        np.testing.assert_allclose(
            np.asarray(results[u]), ref[i], rtol=1e-4, atol=1e-4
        )
    assert eng.health()["fallback_depth"] == 1


def test_breaker_trip_probe_recover_cycle():
    faults = FaultPlan([FaultSpec("exception", rung="primary", times=1)])
    eng = _compiled(buckets=(1,), retries=0).serve(
        faults=faults, probe_after=2
    )

    def one(seed):
        u = eng.submit(_images(1, seed=seed)[0])
        return eng.run()[u]

    one(10)                       # trip: primary raises, xla-ref serves
    b = eng.health()["buckets"]["1"]
    assert b == {
        **b, "state": "OPEN", "depth": 1, "trips": 1, "steps_until_probe": 2,
    }
    one(11)                       # countdown 2 -> 1, still degraded
    b = eng.health()["buckets"]["1"]
    assert b["state"] == "OPEN" and b["steps_until_probe"] == 1
    out = one(12)                 # countdown hits 0: HALF_OPEN probes rung 0
    b = eng.health()["buckets"]["1"]
    assert b["state"] == "CLOSED" and b["depth"] == 0
    assert b["probes"] == 1 and b["recoveries"] == 1
    assert np.isfinite(np.asarray(out)).all()
    # Fully recovered: the next dispatch runs the fast path, no probe.
    one(13)
    assert eng.health()["buckets"]["1"]["probes"] == 1


def test_failed_probe_reopens():
    # Faults on every primary attempt: the probe itself fails and the
    # breaker re-arms at the degraded depth instead of flapping.
    faults = FaultPlan([FaultSpec("exception", rung="primary", times=99)])
    eng = _compiled(buckets=(1,), retries=0).serve(
        faults=faults, probe_after=1
    )
    for seed in (20, 21, 22):
        u = eng.submit(_images(1, seed=seed)[0])
        assert not is_failure(eng.run()[u])
    b = eng.health()["buckets"]["1"]
    assert b["state"] == "OPEN" and b["depth"] == 1 and b["probes"] >= 1


def test_ladder_exhausted_fails_requests_not_engine():
    faults = FaultPlan([FaultSpec("exception", times=99)])   # every rung
    eng = _compiled(buckets=(1,), retries=0).serve(faults=faults)
    u = eng.submit(_images(1)[0])
    results = eng.run()
    assert isinstance(results[u], RequestFailed)
    # The engine survives; once the fault script is spent, it serves again
    # (probing back up from the pinned deepest rung).
    while not faults.exhausted:
        faults.draw(0, None, "primary")
    u2 = eng.submit(_images(1)[0])
    assert not is_failure(eng.run()[u2])


def test_fallback_off_fails_fast():
    faults = FaultPlan([FaultSpec("exception", times=1)])
    eng = _compiled(fallback="off", retries=0, buckets=(1,)).serve(
        faults=faults
    )
    assert eng.health()["ladder"] == ["primary"]
    u = eng.submit(_images(1)[0])
    assert isinstance(eng.run()[u], RequestFailed)


def test_infer_raises_typed_error_on_failures():
    faults = FaultPlan([FaultSpec("exception", times=99)])
    eng = _compiled(fallback="off", retries=0, buckets=(1, 2)).serve(
        faults=faults
    )
    with pytest.raises(ServingError):
        eng.infer(_images(2))


def test_latency_fault_expires_next_request():
    clock = FakeClock()
    faults = FaultPlan(
        [FaultSpec("latency", rung="primary", latency_s=10.0, times=1)]
    )
    eng = _compiled(buckets=(1,)).serve(clock=clock, faults=faults)
    u1 = eng.submit(_images(1, seed=6)[0], deadline_s=5.0)
    u2 = eng.submit(_images(1, seed=7)[0], deadline_s=5.0)
    results = eng.run()
    # The spike lands while u1 is already dispatched (it serves); u2 is
    # then past its deadline and must be evicted, not served stale.
    assert not is_failure(results[u1])
    assert isinstance(results[u2], DeadlineExceeded)


def test_queue_not_drained_carries_partials():
    eng = _compiled(buckets=(1,)).serve()
    uids = [eng.submit(img) for img in _images(3)]
    with pytest.raises(QueueNotDrained) as ei:
        eng.run(max_steps=1)
    assert set(ei.value.results) == {uids[0]}
    assert ei.value.remaining == uids[1:]
    # The remaining work is still queued and drains normally.
    rest = eng.run()
    assert set(rest) == set(uids[1:])


# ---------------------------------------------------------------------------
# Fault harness determinism


def test_seeded_fault_plan_deterministic():
    a = FaultPlan.seeded(7, n_faults=5, steps=10)
    b = FaultPlan.seeded(7, n_faults=5, steps=10)
    assert [vars(s) for s in a.specs] == [vars(s) for s in b.specs]
    c = FaultPlan.seeded(8, n_faults=5, steps=10)
    assert [vars(s) for s in a.specs] != [vars(s) for s in c.specs]


def test_fault_plan_draw_logs_and_exhausts():
    plan = FaultPlan([FaultSpec("exception", step=2, times=1)])
    assert plan.draw(1, 1, "primary") is None
    assert plan.draw(2, 1, "primary") is not None
    assert plan.draw(2, 1, "primary") is None      # budget spent
    assert plan.exhausted
    assert plan.injected == 1 and len(plan.log) == 3


# ---------------------------------------------------------------------------
# LM engine


@pytest.fixture(scope="module")
def lm_setup():
    cfg = configs.smoke_config("llama3.2-1b", seq_len=64)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n, length=4, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, length) for _ in range(n)]


def test_lm_submit_validation(lm_setup):
    cfg, params = lm_setup
    eng = ServingEngine(cfg, params, batch_size=1, capacity=64)
    with pytest.raises(ValueError):
        eng.submit(np.array([], np.int32))
    with pytest.raises(InvalidRequest):
        eng.submit(np.array([0.5, 1.5], np.float32))
    with pytest.raises(InvalidRequest):
        eng.submit(np.array([cfg.vocab_size + 3], np.int64))
    with pytest.raises(InvalidRequest):
        eng.submit(np.array([-1], np.int64))


def test_lm_backpressure_and_deadline(lm_setup):
    cfg, params = lm_setup
    clock = FakeClock()
    eng = ServingEngine(cfg, params, batch_size=1, capacity=64,
                        max_queue=1, clock=clock)
    p = _prompts(cfg, 2)
    u1 = eng.submit(p[0], max_new_tokens=2, deadline_s=1.0)
    with pytest.raises(Backpressure):
        eng.submit(p[1], max_new_tokens=2)
    clock.advance(2.0)
    results = eng.run()
    assert isinstance(results[u1], DeadlineExceeded)
    assert eng.health()["evictions"] == 1


def test_lm_decode_exception_falls_back_to_eager(lm_setup):
    cfg, params = lm_setup
    prompts = _prompts(cfg, 2, seed=3)
    clean = ServingEngine(cfg, params, batch_size=2, capacity=64)
    uids = [clean.submit(p, max_new_tokens=3) for p in prompts]
    want = clean.run()

    faults = FaultPlan(
        [FaultSpec("exception", rung="jit-decode", times=2)]
    )
    eng = ServingEngine(cfg, params, batch_size=2, capacity=64,
                        faults=faults)
    uids2 = [eng.submit(p, max_new_tokens=3) for p in prompts]
    got = eng.run()
    for u, u2 in zip(uids, uids2):
        assert got[u2] == want[u], "eager rung must decode the same tokens"
    h = eng.health()
    # The eager rung absorbed the fault, and the default probe cadence
    # climbed the breaker back to the jitted path before the run ended.
    assert h["fallback_batches"] >= 1 and h["faults_injected"] == 2
    b = h["buckets"]["decode"]
    assert b["trips"] >= 1 and b["recoveries"] >= 1
    assert b["state"] == "CLOSED" and b["depth"] == 0


def test_lm_nan_row_fails_one_request(lm_setup):
    cfg, params = lm_setup
    prompts = _prompts(cfg, 2, length=2, seed=4)
    # Steps 1-2 are the two single-slot prefills; step 3 is the first joint
    # decode — poison logits row 1 there, past a zero retry budget.
    faults = FaultPlan(
        [FaultSpec("nan", rung="jit-decode", rows=(1,), step=3, times=1)]
    )
    eng = ServingEngine(cfg, params, batch_size=2, capacity=64,
                        faults=faults, retries=0)
    u0 = eng.submit(prompts[0], max_new_tokens=3)
    u1 = eng.submit(prompts[1], max_new_tokens=3)
    results = eng.run()
    assert isinstance(results[u1], RequestFailed)
    assert isinstance(results[u0], list) and len(results[u0]) == 3
    assert eng.health()["request_failures"] == 1


def test_lm_queue_not_drained(lm_setup):
    cfg, params = lm_setup
    eng = ServingEngine(cfg, params, batch_size=1, capacity=64)
    p = _prompts(cfg, 2, seed=5)
    u1 = eng.submit(p[0], max_new_tokens=4)
    u2 = eng.submit(p[1], max_new_tokens=4)
    with pytest.raises(QueueNotDrained) as ei:
        eng.run(max_steps=1)
    assert u2 in ei.value.remaining
    results = eng.run()
    assert set(results) == {u1, u2}


# ---------------------------------------------------------------------------
# Plan-cache corruption: quarantine + salvage


def _tuned_cache(tmp_path, name="plans.json"):
    cache = str(tmp_path / name)
    _compiled(cache_path=cache)
    assert os.path.exists(cache)
    return cache


def test_corrupt_cache_quarantined_and_cold_retune(tmp_path):
    cache = _tuned_cache(tmp_path)
    original = open(cache, "rb").read()
    corrupt_cache_file(cache, mode="truncate")
    corrupted = open(cache, "rb").read()

    with pytest.warns(RuntimeWarning, match="quarantined"):
        compiled = _compiled(cache_path=cache)
    # The engine works end to end off the recovered cache state.
    eng = compiled.serve()
    eng.submit(_images(1)[0])
    assert not any(is_failure(v) for v in eng.run().values())
    # The corrupt bytes were moved aside intact, not clobbered.
    qpath = f"{cache}.corrupt-{os.getpid()}"
    assert os.path.exists(qpath)
    assert open(qpath, "rb").read() == corrupted
    # The rewritten cache is valid JSON again...
    data = json.loads(open(cache).read())
    assert data["plans"]
    # ...and saving again never overwrites the quarantined copy.
    compiled.planner._dirty = True
    compiled.planner.save()
    assert open(qpath, "rb").read() == corrupted
    assert original  # (unused sanity hold on the pristine bytes)


def test_salvage_recovers_parseable_entries(tmp_path):
    cache = _tuned_cache(tmp_path)
    text = open(cache).read()
    n_plans = len(json.loads(text)["plans"])
    assert n_plans >= 1
    # Trailing garbage fails json.load but leaves every entry parseable:
    # salvage must recover all of them and the re-opened planner runs warm.
    open(cache, "a").write("\ngarbage{{{not json")
    with pytest.warns(RuntimeWarning, match="salvaged"):
        compiled = _compiled(cache_path=cache)
    assert compiled.planner.stats["tunes"] == 0, (
        "every salvaged entry should produce a cache hit, not a re-tune"
    )
    assert len(compiled.planner._plans) == n_plans


def test_salvage_cache_text_partial_truncation():
    payload = {
        "chip": "test",
        "networks": {},
        "plans": {"a": {"x": 1}, "b": {"y": 2}, "c": {"z": 3}},
        "version": 5,
    }
    text = json.dumps(payload, indent=1, sort_keys=True)
    # Cut inside the last plans entry: a, b survive, c is lost.
    cut = text.index('"c":') + 6
    got = salvage_cache_text(text[:cut])
    assert got["plans"] == {"a": {"x": 1}, "b": {"y": 2}}
    assert got["chip"] == "test"
    assert "c" not in got["plans"]


def test_flock_merge_quarantines_corrupt_disk_state(tmp_path):
    cache = _tuned_cache(tmp_path)
    # A second planner holds tuned state in memory while the on-disk file
    # is corrupted by a crashed concurrent writer...
    planner_b = Planner(impl="jax", cache_path=cache, autosave=False)
    assert planner_b._plans, "planner B loaded the warm cache"
    corrupt_cache_file(cache, mode="garbage", seed=3)
    # ...so B's save must quarantine the corrupt bytes inside the flock
    # merge, then write a valid union of memory + salvage.
    with pytest.warns(RuntimeWarning, match="corrupt"):
        planner_b.save()
    merged = json.loads(open(cache).read())
    assert merged["version"] == PLAN_CACHE_VERSION
    assert set(merged["plans"]) >= set(planner_b._plans)
    quarantines = [
        f for f in os.listdir(tmp_path) if ".corrupt-" in f
    ]
    assert quarantines, "corrupt disk state was quarantined, not discarded"


def test_quarantine_warns_once_per_path(tmp_path):
    cache = _tuned_cache(tmp_path)
    corrupt_cache_file(cache, mode="truncate")
    with pytest.warns(RuntimeWarning):
        Planner(impl="jax", cache_path=cache)
    # Second corruption of the same path: quarantined again (fresh name)
    # but silently — the warning already fired for this path.
    with open(cache, "w") as f:
        f.write('{"version": 5, "plans": {broken')
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        Planner(impl="jax", cache_path=cache)
    assert os.path.exists(f"{cache}.corrupt-{os.getpid()}-1")


# ---------------------------------------------------------------------------
# No request lost or served twice under a seeded fault storm


def test_no_loss_no_double_serve_under_fault_storm():
    faults = FaultPlan.seeded(
        123, n_faults=6, steps=8, kinds=("exception", "nan", "inf"),
    )
    eng = _compiled(buckets=(1, 2)).serve(faults=faults)
    uids = [eng.submit(img) for img in _images(9, seed=9)]
    seen = {}
    for _ in range(50):
        if not eng.queue:
            break
        step = eng.step()
        dup = set(step) & set(seen)
        assert not dup, f"uids served twice: {dup}"
        seen.update(step)
    assert set(seen) == set(uids), "every submitted request gets a result"
