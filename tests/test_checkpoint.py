"""Checkpoint store: roundtrip, atomicity, GC, async writer, torn writes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointWriter, CheckpointStore
from repro.optim import AdamWConfig, adamw, constant


@pytest.fixture()
def store(tmp_path):
    return CheckpointStore(str(tmp_path / "ckpt"), keep_last=2)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layer": {"w": jax.random.normal(k, (8, 16)),
                  "b": jnp.zeros((16,), jnp.bfloat16)},
        "scale": jnp.float32(3.5),
    }


def test_roundtrip(store):
    t = _tree()
    store.save(7, {"params": t}, extra={"note": "hi"})
    step, out = store.restore({"params": jax.tree.map(jnp.zeros_like, t)})
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_roundtrip_with_opt_state(store):
    params = _tree(1)
    for mdt in ("float32", "int8"):
        cfg = AdamWConfig(lr=constant(1e-3), moment_dtype=mdt)
        opt = adamw.init(cfg, params)
        store.save(1, {"params": params, "opt": opt})
        _, out = store.restore({"params": params, "opt": opt})
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(out["opt"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(store):
    t = _tree()
    for s in (10, 20, 30):
        store.save(s, {"params": t})
    assert store.latest_step() == 30
    assert store.all_steps() == [20, 30]  # keep_last=2 pruned step 10


def test_torn_write_is_never_loaded(store):
    t = _tree()
    store.save(5, {"params": t})
    # Simulate a crash mid-write: tmp dir exists, no manifest rename.
    torn = os.path.join(store.dir, "tmp.step_6")
    os.makedirs(torn)
    open(os.path.join(torn, "arrays.npz"), "wb").write(b"garbage")
    assert store.latest_step() == 5
    # Simulate LATEST pointing at a missing step.
    with open(os.path.join(store.dir, "LATEST"), "w") as f:
        f.write("999")
    assert store.latest_step() == 5  # falls back to newest complete


def test_async_writer(store):
    w = AsyncCheckpointWriter(store)
    t = _tree(2)
    w.save(11, {"params": t})
    w.wait()
    step, out = store.restore({"params": t})
    assert step == 11
    np.testing.assert_array_equal(
        np.asarray(out["params"]["layer"]["w"]), np.asarray(t["layer"]["w"])
    )


def test_restore_shape_mismatch_raises(store):
    t = _tree()
    store.save(1, {"params": t})
    bad = {"params": {**t, "layer": {"w": jnp.zeros((9, 16)), "b": t["layer"]["b"]}}}
    with pytest.raises(AssertionError):
        store.restore(bad)
