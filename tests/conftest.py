"""Shared test utilities.

NOTE: no XLA_FLAGS here — tests see 1 CPU device by design.  Multi-device
behavior is exercised through subprocess helpers that force a device count
in a fresh process (see run_with_devices).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(n_devices: int, code: str, timeout: int = 480) -> str:
    """Run ``code`` in a fresh python with n forced host devices; returns
    stdout.  Raises on nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed ({proc.returncode}):\n{proc.stdout}\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    import numpy as np

    return np.random.default_rng(0)
