"""Per-arch smoke tests (reduced configs) + model-family numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeSpec
from repro.data import batch_for
from repro.models import transformer as tf

SEQ = 32
BATCH = 2


def _smoke_batch(cfg, kind="train"):
    shape = ShapeSpec("t", SEQ, BATCH, kind)
    return batch_for(cfg, shape, step=0)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_forward(arch):
    """One forward on the reduced config: output shapes + finite values."""
    cfg = configs.smoke_config(arch, seq_len=SEQ)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    logits, aux = tf.forward(cfg, params, batch)
    s_expect = SEQ if cfg.frontend != "vision_patches" else SEQ
    assert logits.shape == (BATCH, s_expect, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_train_step(arch):
    """One train step: loss finite, params move, no NaNs anywhere."""
    from repro.optim import AdamWConfig, adamw, constant
    from repro.train.step import make_train_step

    cfg = configs.smoke_config(arch, seq_len=SEQ)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=constant(1e-3))
    opt_state = adamw.init(opt_cfg, params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    new_params, new_opt, metrics = step(params, opt_state, _smoke_batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params,
    )
    assert max(jax.tree.leaves(moved)) > 0
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize(
    "arch", [a for a in configs.ARCHS
             if configs.get_config(a).supports_decode]
)
def test_arch_decode_step(arch):
    cfg = configs.smoke_config(arch, seq_len=SEQ)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    cache = tf.init_cache(cfg, BATCH, SEQ)
    toks = jnp.ones((BATCH, 1), jnp.int32)
    logits, new_cache = tf.decode_step(cfg, params, cache, toks, jnp.int32(0))
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-27b",
                                  "recurrentgemma-9b", "xlstm-125m",
                                  "granite-moe-1b-a400m"])
def test_prefill_matches_decode(arch):
    """prefill_with_cache == token-by-token decode (same logits, same cache
    effect on the next step).  MoE archs get a dropless capacity factor:
    capacity competition legitimately differs between joint-prefill and
    per-step routing, so only the no-drop regime is comparable."""
    import dataclasses

    cfg = configs.smoke_config(arch, seq_len=16)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(
        np.random.default_rng(2).integers(0, cfg.vocab_size, (BATCH, 8)),
        jnp.int32,
    )
    logits_pf, cache_pf = tf.prefill_with_cache(
        cfg, params, {"tokens": toks}, capacity=16
    )
    cache = tf.init_cache(cfg, BATCH, 16)
    for t in range(8):
        logits_dec, cache = tf.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                           jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_pf, np.float32),
                               np.asarray(logits_dec, np.float32),
                               rtol=2e-3, atol=2e-3)
    # Next decode step from both caches must agree too.
    nxt = jnp.ones((BATCH, 1), jnp.int32)
    l1, _ = tf.decode_step(cfg, params, cache_pf, nxt, jnp.int32(8))
    l2, _ = tf.decode_step(cfg, params, cache, nxt, jnp.int32(8))
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=2e-3, atol=2e-3)


def test_local_vs_global_attention_differ():
    """gemma2's alternating pattern must actually mask differently."""
    base = configs.smoke_config("gemma2-27b", seq_len=SEQ)
    import dataclasses

    g_all = dataclasses.replace(base, layer_pattern=("attn",), local_window=4)
    g_loc = dataclasses.replace(base, layer_pattern=("local",), local_window=4)
    # Same PRNG key -> identical weights despite differing param key names.
    p_all = tf.init_params(g_all, jax.random.PRNGKey(3))
    p_loc = tf.init_params(g_loc, jax.random.PRNGKey(3))
    for a, b in zip(jax.tree.leaves(p_all), jax.tree.leaves(p_loc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    batch = _smoke_batch(base)
    l1, _ = tf.forward(g_all, p_all, batch)
    l2, _ = tf.forward(g_loc, p_loc, batch)
    # Same params, different masking -> different logits beyond the window.
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3


def test_encoder_is_bidirectional():
    cfg = configs.smoke_config("hubert-xlarge", seq_len=SEQ)
    params = tf.init_params(cfg, jax.random.PRNGKey(4))
    batch = _smoke_batch(cfg)
    logits, _ = tf.forward(cfg, params, batch)
    # Perturb a LATE frame; an EARLY position's logits must change
    # (bidirectional attention), which causal models would forbid.
    frames2 = batch["frames"].at[:, -1, :].add(10.0)
    logits2, _ = tf.forward(cfg, params, {**batch, "frames": frames2})
    assert float(jnp.max(jnp.abs(logits[:, 0] - logits2[:, 0]))) > 1e-5


def test_causality():
    cfg = configs.smoke_config("llama3.2-1b", seq_len=SEQ)
    params = tf.init_params(cfg, jax.random.PRNGKey(5))
    toks = jnp.ones((1, SEQ), jnp.int32)
    logits, _ = tf.forward(cfg, params, {"tokens": toks})
    toks2 = toks.at[0, -1].set(5)
    logits2, _ = tf.forward(cfg, params, {"tokens": toks2})
    # changing the last token must not affect earlier positions
    np.testing.assert_allclose(
        np.asarray(logits[:, :-1], np.float32),
        np.asarray(logits2[:, :-1], np.float32), rtol=1e-5, atol=1e-5,
    )


def test_chunked_attention_matches_naive():
    import dataclasses

    base = configs.smoke_config("llama3.2-1b", seq_len=64)
    naive = dataclasses.replace(base, attn_chunked_threshold=100000)
    chunked = dataclasses.replace(base, attn_chunked_threshold=1)
    params = tf.init_params(naive, jax.random.PRNGKey(6))
    toks = jnp.asarray(
        np.random.default_rng(7).integers(0, base.vocab_size, (2, 64)), jnp.int32
    )
    l1, _ = tf.forward(naive, params, {"tokens": toks})
    l2, _ = tf.forward(chunked, params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), rtol=2e-3, atol=2e-3)


def test_mlstm_forms_agree():
    from repro.models.xlstm import (_init_mlstm_state, _mlstm_chunked,
                                    _mlstm_parallel, _mlstm_step)

    rng = np.random.default_rng(8)
    b, s, h, hd = 2, 24, 3, 8
    q, k, v = (jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
               for _ in range(3))
    log_f = jnp.asarray(np.log(rng.uniform(0.6, 0.99, (b, s, h))), jnp.float32)
    log_i = jnp.asarray(rng.normal(size=(b, s, h)), jnp.float32)
    out_p = _mlstm_parallel(q, k, v, log_f, log_i)
    out_c, _ = _mlstm_chunked(q, k, v, log_f, log_i,
                              _init_mlstm_state(b, h, hd), chunk=8)
    state = _init_mlstm_state(b, h, hd)
    outs = []
    for t in range(s):
        state, o = _mlstm_step(state, q[:, t], k[:, t], v[:, t],
                               log_f[:, t], log_i[:, t])
        outs.append(o)
    out_r = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(out_p, out_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out_c, out_r, rtol=1e-4, atol=1e-4)


def test_rglru_scan_matches_sequential():
    from repro.models.rglru import _rglru_scan

    rng = np.random.default_rng(9)
    b, s, d = 2, 16, 8
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 0.99, (b, s, d)), jnp.float32)
    hs, h_last = _rglru_scan(x, a, None)
    h = jnp.zeros((b, d))
    for t in range(s):
        h = a[:, t] * h + x[:, t]
        np.testing.assert_allclose(hs[:, t], h, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_last, h, rtol=1e-5, atol=1e-5)


def test_param_counts_match_known_sizes():
    """Analytic param counts land near the nominal model sizes."""
    expect = {
        "llama3.2-1b": (1.0e9, 1.5e9),
        "qwen1.5-0.5b": (0.4e9, 0.65e9),
        "gemma2-27b": (24e9, 29e9),
        "arctic-480b": (430e9, 520e9),
        "xlstm-125m": (0.07e9, 0.16e9),
        "hubert-xlarge": (0.8e9, 1.1e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
