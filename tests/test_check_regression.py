"""The benchmark regression gate (benchmarks/check_regression.py): compare
semantics on synthetic files, plus the committed-baseline contract — a fresh
predict-only regeneration must match benchmarks/baseline/BENCH_e2e.json.
"""
import json
import os


from benchmarks.check_regression import (
    DEFAULT_PATTERN,
    compare,
    load_rows,
    main,
    regenerate,
)

BASELINE = os.path.join(
    os.path.dirname(__file__), "..", "benchmarks", "baseline",
    "BENCH_e2e.json",
)


def _rows(**named):
    return {k: {"name": k, "seconds": v, "derived": ""}
            for k, v in named.items()}


def test_compare_passes_within_tolerance():
    base = _rows(e2e_m_L00=1.0, e2e_m_predicted_total=2.0, e2e_m_total=9.0)
    cand = _rows(e2e_m_L00=1.04, e2e_m_predicted_total=1.9, e2e_m_total=90.0)
    reg, notes = compare(base, cand)
    assert reg == []          # 4% slower is inside the 5% gate; wall-clock
    #                           row (no _L / _predicted suffix) is ungated


def test_compare_flags_regression_and_missing():
    base = _rows(e2e_m_L00=1.0, e2e_m_L01=1.0, e2e_m_predicted_total=2.0)
    cand = _rows(e2e_m_L00=1.2, e2e_m_predicted_total=2.0)
    reg, _ = compare(base, cand)
    assert len(reg) == 2
    assert any("L00" in r and "1.2" in r for r in reg)
    assert any("L01" in r and "missing" in r for r in reg)


def test_compare_improvement_is_notice_not_failure():
    base = _rows(e2e_m_L00=1.0)
    cand = _rows(e2e_m_L00=0.5)
    reg, notes = compare(base, cand)
    assert reg == []
    assert len(notes) == 1 and "refresh" in notes[0]


def test_compare_empty_gate_fails():
    reg, _ = compare(_rows(other=1.0), _rows(other=1.0))
    assert reg and "empty gate" in reg[0]


def test_main_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    cand = tmp_path / "cand.json"
    base.write_text(json.dumps({"rows": [
        {"name": "e2e_m_L00", "seconds": 1.0, "derived": ""}]}))
    cand.write_text(json.dumps({"rows": [
        {"name": "e2e_m_L00", "seconds": 1.0, "derived": ""}]}))
    assert main(["--baseline", str(base), "--candidate", str(cand)]) == 0
    cand.write_text(json.dumps({"rows": [
        {"name": "e2e_m_L00", "seconds": 2.0, "derived": ""}]}))
    assert main(["--baseline", str(base), "--candidate", str(cand)]) == 1


def test_committed_baseline_matches_regeneration(tmp_path):
    """The acceptance gate itself: regenerating the deterministic modeled
    rows (both paper networks, predict-only) reproduces the committed
    baseline within tolerance — so any cost-model or planner-policy change
    that shifts a prediction must refresh benchmarks/baseline/BENCH_e2e.json
    in the same commit, and CI fails when it does not."""
    assert os.path.exists(BASELINE), "committed baseline missing"
    cand_path = regenerate(str(tmp_path / "BENCH_e2e.json"),
                           cache_path=str(tmp_path / "plans.json"))
    reg, _ = compare(load_rows(BASELINE), load_rows(cand_path))
    assert reg == [], reg


def test_baseline_gates_int8_rows():
    """The committed baseline actually covers the int8 path: per-layer int8
    rows and the int8 predicted totals are present and matched by the
    default gate pattern."""
    import re

    rows = load_rows(BASELINE)
    rx = re.compile(DEFAULT_PATTERN)
    int8_gated = [n for n in rows if "_int8_" in n and rx.search(n)]
    assert len(int8_gated) >= 10, int8_gated
    totals = [n for n in rows if n.endswith("_int8_predicted_total")]
    assert len(totals) == 2  # vgg16 + yolov3-tiny
    # And the modeled int8 totals beat fp32 (the point of the path).
    for t in totals:
        fp32 = rows[t.replace("_int8", "")]
        assert rows[t]["seconds"] < fp32["seconds"]
