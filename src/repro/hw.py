"""Target-hardware constants (TPU v5e) used by the co-design model, the
roofline analysis, and the benchmarks.

This container is CPU-only; v5e is the *target*.  All performance reporting
derives from these constants + compiled-artifact statistics (see
roofline/analysis.py), playing the role gem5 plays in the paper.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip (given)
    peak_flops_fp32: float = 98.5e12     # MXU fp32 ~ half of bf16
    peak_flops_int8: float = 394e12      # int8 MAC rate ~ 2x bf16
    hbm_bandwidth: float = 819e9         # B/s per chip (given)
    hbm_bytes: int = 16 * 1024**3        # 16 GiB HBM
    ici_link_bandwidth: float = 50e9     # B/s per link (given)
    ici_links: int = 4                   # 2D torus on v5e: 4 links/chip
    vmem_bytes: int = 16 * 1024**2       # ~16 MiB VMEM per core (sweepable)
    mxu_dim: int = 128                   # systolic array is 128x128
    sublanes: int = 8                    # VREG second-minor granularity
    lane_width: int = 128                # VREG minor (lane) granularity
    grid_step_overhead_s: float = 0.3e-6 # per-grid-step issue/DMA overhead


V5E = ChipSpec()


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """A logical device mesh + its physical wiring for collective modeling."""

    shape: tuple
    axes: tuple

    @property
    def num_chips(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshSpec(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshSpec(shape=(2, 16, 16), axes=("pod", "data", "model"))
