"""repro: TPU-native co-design framework for CNN inference kernels +
multi-pod JAX training/serving substrate.

Reproduces and extends "Accelerating CNN inference on long vector
architectures via co-design" (Gupta et al., 2022).
"""
__version__ = "1.0.0"
