"""repro: TPU-native co-design framework for CNN inference kernels +
multi-pod JAX training/serving substrate.

Reproduces and extends "Accelerating CNN inference on long vector
architectures via co-design" (Gupta et al., 2022).

The public surface is the compile-and-run facade::

    import repro

    compiled = repro.compile(model, params, repro.ExecutionOptions(...))
    y = compiled.run(x)
    engine = compiled.serve()

plus the co-design building blocks it is made of (``ConvSpec``, ``Planner``,
``NetworkExecutor``, ...).  See docs/api.md for the lifecycle and the
migration table from the legacy entry points.
"""
__version__ = "1.1.0"

from repro.api import (
    CNNModel,
    CompiledModel,
    ExecutionOptions,
    Model,
    compile,
    load,
)
from repro.core import (
    ConvAlgorithm,
    ConvPlan,
    ConvSpec,
    Epilogue,
    Layout,
    NetworkExecutor,
    NetworkPlan,
    Planner,
    conv2d,
    conv2d_reference,
)

__all__ = [
    # the facade (the documented entry point)
    "CNNModel",
    "CompiledModel",
    "ExecutionOptions",
    "Model",
    "compile",
    "load",
    # co-design building blocks
    "ConvAlgorithm",
    "ConvPlan",
    "ConvSpec",
    "Epilogue",
    "Layout",
    "NetworkExecutor",
    "NetworkPlan",
    "Planner",
    "conv2d",
    "conv2d_reference",
    # lazy (heavy serving stack, loaded on first attribute access)
    "CNNServingEngine",
    "ServingEngine",
]


def __getattr__(name):
    # The serving engines pull in the LM stack; load them lazily so
    # ``import repro`` stays light and warning-free.
    if name in ("CNNServingEngine", "ServingEngine"):
        import repro.serving as _serving

        return getattr(_serving, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
