"""Analytical TPU memory-hierarchy model for blocked GEMM and Winograd.

This module is the repo's gem5 analogue.  The paper sweeps vector length,
vector lanes and L2 size in a cycle-accurate simulator; we sweep the TPU
equivalents — block *width* (lane dim), on-chip parallelism, and VMEM budget —
in a first-order analytical model grounded in the v5e constants (repro/hw.py).

Model for a Pallas GEMM with grid (N/bn, M/bm, K/bk), K-innermost
accumulation in a VMEM scratch (our kernels/gemm):

  VMEM working set = 2*(bm*bk + bk*bn)*dtype + bm*bn*4   (double-buffered
                     A/B blocks + fp32 accumulator)
  HBM traffic      = M*K*(N/bn) + K*N*(M/bm) + 2*M*N     (A re-read per
                     column-panel, B re-read per row-panel, C written once;
                     this is exactly the BLIS traffic equation the paper's
                     6-loop blocking minimizes)
  compute time     = 2*Mp*Np*Kp / peak    (padded to HW granularity — the
                     TPU analogue of partially-filled vectors)
  startup          = grid_steps * per-step overhead  (the paper's "vector
                     start-up time" analogue)
  time             = max(compute, memory) + startup
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, List, Optional, Tuple

from repro.hw import V5E, ChipSpec
from repro.util import ceil_to


@dataclasses.dataclass(frozen=True)
class GemmShape:
    m: int
    n: int
    k: int

    @property
    def flops(self) -> int:
        return 2 * self.m * self.n * self.k


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    bm: int
    bn: int
    bk: int

    def vmem_bytes(self, dtype_bytes: int = 4, double_buffer: bool = True) -> int:
        buf = 2 if double_buffer else 1
        return (
            buf * (self.bm * self.bk + self.bk * self.bn) * dtype_bytes
            + self.bm * self.bn * 4
        )


@dataclasses.dataclass(frozen=True)
class GemmEstimate:
    compute_s: float
    memory_s: float
    startup_s: float
    vmem_bytes: int
    hbm_bytes: int
    mxu_utilization: float

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s) + self.startup_s

    @property
    def bound(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"


def predict_gemm(
    shape: GemmShape,
    block: BlockConfig,
    hw: ChipSpec = V5E,
    dtype_bytes: int = 4,
    lanes: int = 1,
) -> GemmEstimate:
    """First-order time prediction for one blocked GEMM on one chip.

    ``lanes`` models extra on-chip parallelism (the paper's vector-lane
    sweep): peak compute scales, per-step overhead does not shrink — exactly
    the start-up-latency trade-off the paper observes (§VI.B.c).
    """
    mp = ceil_to(shape.m, max(block.bm, hw.sublanes))
    np_ = ceil_to(shape.n, max(block.bn, hw.lane_width))
    kp = ceil_to(shape.k, block.bk)
    peak = (hw.peak_flops_fp32 if dtype_bytes == 4 else hw.peak_flops_bf16) * lanes
    compute_s = 2.0 * mp * np_ * kp / peak
    grid = (mp // block.bm) * (np_ // block.bn) * (kp // block.bk)
    traffic = dtype_bytes * (
        shape.m * shape.k * (np_ // block.bn)
        + shape.k * shape.n * (mp // block.bm)
        + 2 * shape.m * shape.n
    )
    return GemmEstimate(
        compute_s=compute_s,
        memory_s=traffic / hw.hbm_bandwidth,
        startup_s=grid * hw.grid_step_overhead_s,
        vmem_bytes=block.vmem_bytes(dtype_bytes),
        hbm_bytes=traffic,
        mxu_utilization=shape.flops / (2.0 * mp * np_ * kp),
    )


def candidate_blocks(
    vmem_budget: int,
    hw: ChipSpec = V5E,
    dtype_bytes: int = 4,
    bms: Iterable[int] = (8, 16, 32, 64, 128, 256, 512),
    bns: Iterable[int] = (128, 256, 512, 1024, 2048),
    bks: Iterable[int] = (128, 256, 512, 1024, 2048),
) -> List[BlockConfig]:
    """HW-aligned block configs whose working set fits the VMEM budget."""
    out = []
    for bm, bn, bk in itertools.product(bms, bns, bks):
        cfg = BlockConfig(bm, bn, bk)
        if cfg.vmem_bytes(dtype_bytes) <= vmem_budget:
            out.append(cfg)
    return out


def autotune_gemm(
    shape: GemmShape,
    hw: ChipSpec = V5E,
    vmem_budget: Optional[int] = None,
    dtype_bytes: int = 4,
    lanes: int = 1,
) -> Tuple[BlockConfig, GemmEstimate]:
    """Pick the predicted-fastest block config under a VMEM budget.

    This is the BLIS 'block size tuning' step (paper Table II) with VMEM in
    the role of L2.
    """
    budget = vmem_budget if vmem_budget is not None else hw.vmem_bytes
    best: Tuple[Optional[BlockConfig], Optional[GemmEstimate]] = (None, None)
    for cfg in candidate_blocks(budget, hw, dtype_bytes):
        # Don't bother with blocks bigger than the (padded) problem.
        if cfg.bm > ceil_to(shape.m, hw.sublanes) * 2:
            continue
        if cfg.bn > ceil_to(shape.n, hw.lane_width) * 2:
            continue
        if cfg.bk > ceil_to(shape.k, 128) * 2:
            continue
        est = predict_gemm(shape, cfg, hw, dtype_bytes, lanes)
        if best[1] is None or est.total_s < best[1].total_s:
            best = (cfg, est)
    assert best[0] is not None, "no feasible block config under VMEM budget"
    return best  # type: ignore[return-value]


def winograd_traffic_bytes(
    oh: int, ow: int, cin: int, cout: int, batch: int = 1, dtype_bytes: int = 4
) -> int:
    """HBM traffic of the winograd pipeline (input/V/M/output + U once).

    Winograd's working set per stage is smaller than im2col's K-panel —
    the reason the paper finds it needs less cache (§VII.B).
    """
    nth, ntw = -(-oh // 6), -(-ow // 6)
    tiles = batch * nth * ntw
    x_bytes = tiles * 64 * cin            # overlapping 8x8 reads
    v_bytes = 2 * tiles * 64 * cin        # V write + read
    u_bytes = 64 * cin * cout             # pre-transformed weights, read once
    m_bytes = 2 * tiles * 64 * cout       # M write + read
    y_bytes = tiles * 36 * cout           # output write
    return dtype_bytes * (x_bytes + v_bytes + u_bytes + m_bytes + y_bytes)
