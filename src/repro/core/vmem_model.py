"""Analytical TPU memory-hierarchy model for blocked GEMM and Winograd.

This module is the repo's gem5 analogue.  The paper sweeps vector length,
vector lanes and L2 size in a cycle-accurate simulator; we sweep the TPU
equivalents — block *width* (lane dim), on-chip parallelism, and VMEM budget —
in a first-order analytical model grounded in the v5e constants (repro/hw.py).

Model for a Pallas GEMM with grid (N/bn, M/bm, K/bk), K-innermost
accumulation in a VMEM scratch (our kernels/gemm):

  VMEM working set = 2*(bm*bk + bk*bn)*dtype + bm*bn*4   (double-buffered
                     A/B blocks + fp32 accumulator)
  HBM traffic      = M*K*(N/bn) + K*N*(M/bm) + 2*M*N     (A re-read per
                     column-panel, B re-read per row-panel, C written once;
                     this is exactly the BLIS traffic equation the paper's
                     6-loop blocking minimizes)
  compute time     = 2*Mp*Np*Kp / peak    (padded to HW granularity — the
                     TPU analogue of partially-filled vectors)
  startup          = grid_steps * per-step overhead  (the paper's "vector
                     start-up time" analogue)
  time             = max(compute, memory) + startup
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, List, Optional, Tuple

from repro.hw import V5E, ChipSpec
from repro.util import ceil_to

# The single source of truth for element sizes in the model.  Keyed by dtype
# *name* so it accepts numpy/jnp dtypes, python types and plain strings — the
# same normalization the planner's dtype plumbing uses.  Unknown names model
# as 4 bytes (fp32), the conservative default.
_ITEMSIZE = {
    "float64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool": 1,
    "fp8": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def itemsize(dtype) -> int:
    """Bytes per element for a dtype given as a dtype object, type or name.

    Every byte count in this module routes through here — the accumulator,
    bias/scale-row and dequant-output terms use ``itemsize("float32")``
    explicitly instead of a bare ``4``, so the fp32-ness of those buffers is
    stated where it is assumed.
    """
    name = (
        getattr(dtype, "__name__", None)
        or getattr(dtype, "name", None)
        or str(dtype)
    )
    return _ITEMSIZE.get(name, 4)


# Accumulators, bias/scale epilogue rows and int8 dequant outputs are fp32 /
# int32 in every kernel family regardless of the operand itemsize.
ACC_BYTES = itemsize("float32")


@dataclasses.dataclass(frozen=True)
class GemmShape:
    m: int
    n: int
    k: int

    @property
    def flops(self) -> int:
        return 2 * self.m * self.n * self.k


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    bm: int
    bn: int
    bk: int

    def vmem_bytes(self, dtype_bytes: int = 4, double_buffer: bool = True) -> int:
        buf = 2 if double_buffer else 1
        return (
            buf * (self.bm * self.bk + self.bk * self.bn) * dtype_bytes
            + self.bm * self.bn * ACC_BYTES
        )


@dataclasses.dataclass(frozen=True)
class GemmEstimate:
    compute_s: float
    memory_s: float
    startup_s: float
    vmem_bytes: int
    hbm_bytes: int
    mxu_utilization: float

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s) + self.startup_s

    @property
    def bound(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"


def peak_flops(hw: ChipSpec, dtype_bytes: int) -> float:
    """MXU peak for a given element size: fp32 / bf16 / int8 ladder.

    The itemsize is the model's dtype proxy everywhere else, so it is here
    too: 4 → fp32, 2 → bf16, 1 → int8 (2x the bf16 rate on v5e-class MXUs).
    """
    if dtype_bytes >= 4:
        return hw.peak_flops_fp32
    if dtype_bytes == 1:
        return getattr(hw, "peak_flops_int8", 2 * hw.peak_flops_bf16)
    return hw.peak_flops_bf16


def predict_gemm(
    shape: GemmShape,
    block: BlockConfig,
    hw: ChipSpec = V5E,
    dtype_bytes: int = 4,
    lanes: int = 1,
) -> GemmEstimate:
    """First-order time prediction for one blocked GEMM on one chip.

    ``lanes`` models extra on-chip parallelism (the paper's vector-lane
    sweep): peak compute scales, per-step overhead does not shrink — exactly
    the start-up-latency trade-off the paper observes (§VI.B.c).
    """
    mp = ceil_to(shape.m, max(block.bm, hw.sublanes))
    np_ = ceil_to(shape.n, max(block.bn, hw.lane_width))
    kp = ceil_to(shape.k, block.bk)
    peak = peak_flops(hw, dtype_bytes) * lanes
    compute_s = 2.0 * mp * np_ * kp / peak
    grid = (mp // block.bm) * (np_ // block.bn) * (kp // block.bk)
    # int8 GEMMs accumulate in int32 and write fp32 (the fused dequant
    # epilogue), so the C term keeps the fp32 itemsize.
    out_bytes = ACC_BYTES if dtype_bytes == 1 else dtype_bytes
    traffic = dtype_bytes * (
        shape.m * shape.k * (np_ // block.bn)
        + shape.k * shape.n * (mp // block.bm)
    ) + out_bytes * 2 * shape.m * shape.n
    return GemmEstimate(
        compute_s=compute_s,
        memory_s=traffic / hw.hbm_bandwidth,
        startup_s=grid * hw.grid_step_overhead_s,
        vmem_bytes=block.vmem_bytes(dtype_bytes),
        hbm_bytes=traffic,
        mxu_utilization=shape.flops / (2.0 * mp * np_ * kp),
    )


def candidate_blocks(
    vmem_budget: int,
    hw: ChipSpec = V5E,
    dtype_bytes: int = 4,
    bms: Iterable[int] = (8, 16, 32, 64, 128, 256, 512),
    bns: Iterable[int] = (128, 256, 512, 1024, 2048),
    bks: Iterable[int] = (128, 256, 512, 1024, 2048),
) -> List[BlockConfig]:
    """HW-aligned block configs whose working set fits the VMEM budget."""
    out = []
    for bm, bn, bk in itertools.product(bms, bns, bks):
        cfg = BlockConfig(bm, bn, bk)
        if cfg.vmem_bytes(dtype_bytes) <= vmem_budget:
            out.append(cfg)
    return out


def autotune_gemm(
    shape: GemmShape,
    hw: ChipSpec = V5E,
    vmem_budget: Optional[int] = None,
    dtype_bytes: int = 4,
    lanes: int = 1,
) -> Tuple[BlockConfig, GemmEstimate]:
    """Pick the predicted-fastest block config under a VMEM budget.

    This is the BLIS 'block size tuning' step (paper Table II) with VMEM in
    the role of L2.
    """
    budget = vmem_budget if vmem_budget is not None else hw.vmem_bytes
    best: Tuple[Optional[BlockConfig], Optional[GemmEstimate]] = (None, None)
    for cfg in candidate_blocks(budget, hw, dtype_bytes):
        # Don't bother with blocks bigger than the (padded) problem.
        if cfg.bm > ceil_to(shape.m, hw.sublanes) * 2:
            continue
        if cfg.bn > ceil_to(shape.n, hw.lane_width) * 2:
            continue
        if cfg.bk > ceil_to(shape.k, 128) * 2:
            continue
        est = predict_gemm(shape, cfg, hw, dtype_bytes, lanes)
        if best[1] is None or est.total_s < best[1].total_s:
            best = (cfg, est)
    assert best[0] is not None, "no feasible block config under VMEM budget"
    return best  # type: ignore[return-value]


def gemm_kernel_vmem_bytes(
    bm: int, bn: int, bk: int, dtype_bytes: int = 4,
    out_dtype_bytes: Optional[int] = None, double_buffer: bool = True,
    epilogue_rows: int = 0, three_loop: bool = False,
) -> int:
    """Full per-program VMEM footprint of the blocked GEMM kernels.

    Unlike ``BlockConfig.vmem_bytes`` (the quantity the autotuner *budgets*:
    A/B blocks + accumulator), this is the complete footprint the compiled
    kernel actually holds — including the streamed output block and the
    fused epilogue's (1, bn) bias/scale rows — which is what the static
    verifier (repro.analysis) checks the jaxpr-recovered footprint against.

    ``epilogue_rows`` counts the (1, bn) fp32 rows the epilogue streams:
    one for a fused bias, two for int8's scale + bias.  ``three_loop``
    models the full-K-panel variant, which accumulates in its output block
    and has no separate scratch (pass ``bk`` = the full K for it).
    """
    if out_dtype_bytes is None:
        out_dtype_bytes = ACC_BYTES if dtype_bytes == 1 else dtype_bytes
    buf = 2 if double_buffer else 1
    total = buf * (bm * bk + bk * bn) * dtype_bytes      # A / B blocks
    total += buf * bm * bn * out_dtype_bytes             # output block
    total += buf * epilogue_rows * bn * ACC_BYTES        # bias / scale rows
    if not three_loop:
        total += bm * bn * ACC_BYTES                     # accumulator scratch
    return total


def winograd_traffic_bytes(
    oh: int, ow: int, cin: int, cout: int, batch: int = 1, dtype_bytes: int = 4,
    fused: bool = False,
) -> int:
    """HBM traffic of the winograd pipeline (input/V/M/output + U once).

    ``fused=False`` models the 3-pass realization (input transform, tuple
    multiply, output transform as separate kernels): the V and M
    intermediates, each (64, tiles, C) fp32, round-trip through HBM between
    kernels — ``2*tiles*64*(cin+cout)`` elements that dominate the layer.
    ``fused=True`` models the single-pass megakernel
    (kernels/winograd/kernel.py:fused_winograd_pallas): V lives in registers
    and M in a VMEM scratch accumulator, so both round-trips vanish and only
    the tile reads, the pre-transformed weights and the output remain.

    Winograd's working set per stage is smaller than im2col's K-panel —
    the reason the paper finds it needs less cache (§VII.B).
    """
    nth, ntw = -(-oh // 6), -(-ow // 6)
    tiles = batch * nth * ntw
    x_bytes = tiles * 64 * cin            # overlapping 8x8 reads
    u_bytes = 64 * cin * cout             # pre-transformed weights, read once
    y_bytes = tiles * 36 * cout           # output write
    if fused:
        return dtype_bytes * (x_bytes + u_bytes + y_bytes)
    v_bytes = 2 * tiles * 64 * cin        # V write + read
    m_bytes = 2 * tiles * 64 * cout       # M write + read
    return dtype_bytes * (x_bytes + v_bytes + u_bytes + m_bytes + y_bytes)


def im2col_gemm_traffic_bytes(
    oh: int, ow: int, cin: int, cout: int, kh: int = 3, kw: int = 3,
    batch: int = 1, dtype_bytes: int = 4, out_dtype_bytes: Optional[int] = None,
) -> int:
    """Ideal-reuse HBM traffic of one im2col+GEMM conv layer.

    The three terms of the paper's Table-IV GEMM, itemsize-aware: the
    logical patch matrix read (batch*oh*ow x kh*kw*cin), the weight read,
    and the output write.  Input/weight elements move at ``dtype_bytes``;
    the output moves at ``out_dtype_bytes`` (defaults to 4 for int8 inputs —
    the kernel's dequant epilogue writes fp32 — and to ``dtype_bytes``
    otherwise).  This is the quantity the int8 policy's ≤ 0.5x fp32 traffic
    gate compares (core/quant.py::int8_traffic_ratio).
    """
    if out_dtype_bytes is None:
        out_dtype_bytes = ACC_BYTES if dtype_bytes == 1 else dtype_bytes
    rows = batch * oh * ow
    taps = kh * kw
    return (
        dtype_bytes * (rows * taps * cin + taps * cin * cout)
        + out_dtype_bytes * rows * cout
    )


def im2col_kernel_vmem_bytes(
    hp: int, wp: int, toh: int, ow: int, bc: int, bo: int,
    kh: int = 3, kw: int = 3, dtype_bytes: int = 4,
    double_buffer: bool = True, bias: bool = True,
    out_dtype_bytes: Optional[int] = None,
) -> int:
    """Per-program VMEM footprint of the fused im2col+GEMM conv kernel.

    The kernel (kernels/im2col_gemm/kernel.py) keeps live at once: the
    (1, Hp, Wp, bc) input channel slab and the (kh, kw, bc, bo) weight block
    (both double-buffered across the in-channel grid axis), the optional
    (1, bo) bias row, the (1, toh, OW, bo) output block and the
    (toh, OW, bo) fp32 accumulator scratch.  The old pick_blocks heuristic
    budgeted only the input slab and the accumulator — the weight block
    (quadratic in the channel blocks) and the bias row silently overflowed
    the budget for deep layers, exactly the bug the Winograd pick_blocks
    had before PR 3.

    ``out_dtype_bytes`` sizes the output block separately from the operands:
    an int8 conv reads int8 slabs/weights but writes fp32 (dequant
    epilogue), and its bias/scale rows and accumulator scratch stay
    fp32/int32 (4-byte) regardless of the operand itemsize.
    """
    if out_dtype_bytes is None:
        out_dtype_bytes = ACC_BYTES if dtype_bytes == 1 else dtype_bytes
    buf = 2 if double_buffer else 1
    return (
        buf * hp * wp * bc * dtype_bytes            # input channel slab
        + buf * kh * kw * bc * bo * dtype_bytes     # weight block
        + (bo * ACC_BYTES if bias else 0)           # fp32 bias/scale row
        + buf * toh * ow * bo * out_dtype_bytes     # output block
        + toh * ow * bo * ACC_BYTES                 # fp32/int32 acc scratch
    )


def winograd_kernel_vmem_bytes(
    bt: int, bc: int, bo: int, fused: bool = True, dtype_bytes: int = 4,
    double_buffer: bool = True,
) -> int:
    """Per-program VMEM footprint of the Winograd Pallas kernels.

    ``fused=True``: the single-pass megakernel holds the (bt, 8, 8, bc) tile
    block and the (8, 8, bc, bo) weight block (both double-buffered across
    the Cin grid axis), the (8, 8, bt, bo) fp32 M accumulator scratch, and
    the (bt, 6, 6, bo) output block.

    ``fused=False``: the 3-pass pipeline's footprint is the max over its
    three kernels — each one's in/out blocks are live simultaneously (plus
    the tuple-multiply's fp32 accumulator scratch).
    """
    buf = 2 if double_buffer else 1
    if fused:
        return (
            buf * bt * 64 * bc * dtype_bytes        # input tile block
            + buf * 64 * bc * bo * dtype_bytes      # transformed weight block
            + 64 * bt * bo * ACC_BYTES              # M accumulator scratch
            + buf * bt * 36 * bo * dtype_bytes      # output block
        )
    input_tf = buf * bt * 64 * bc * dtype_bytes + buf * 64 * bt * bc * dtype_bytes
    tuple_mul = (
        buf * (bt * bc + bc * bo) * dtype_bytes
        + buf * bt * bo * dtype_bytes
        + bt * bo * ACC_BYTES
    )
    output_tf = buf * 64 * bt * bo * dtype_bytes + buf * bt * 36 * bo * dtype_bytes
    return max(input_tf, tuple_mul, output_tf)


# Candidate (bt, bc, bo) grids for the Winograd kernels: tiles on sublanes,
# channels on lanes — the same HW granularity the GEMM candidates use.
WINOGRAD_BTS = (8, 16, 32, 64, 128, 256)
WINOGRAD_BCS = (128, 256, 512)
WINOGRAD_BOS = (128, 256, 512)


def predict_winograd(
    tiles: int,
    cin: int,
    cout: int,
    blocks: Tuple[int, int, int],
    hw: ChipSpec = V5E,
    dtype_bytes: int = 4,
    fused: bool = True,
) -> GemmEstimate:
    """First-order time prediction for the Winograd kernels at one blocking.

    The traffic term is block-aware (BLIS-style panel re-reads: the tile
    panel per out-channel panel, the weight panel per tile panel), unlike
    ``winograd_traffic_bytes`` which reports the ideal-reuse totals; the
    3-pass variant additionally pays the V/M round trips and a 64x larger
    grid for the tuple-multiply stage.
    """
    bt, bc, bo = blocks
    tp = ceil_to(tiles, bt)
    cp = ceil_to(cin, bc)
    op = ceil_to(cout, bo)
    nt, nc, no = tp // bt, cp // bc, op // bo
    peak = peak_flops(hw, dtype_bytes)
    # The tuple multiply dominates compute: 64 GEMMs of (tp, cp) x (cp, op).
    compute_s = 2.0 * 64 * tp * cp * op / peak
    x_bytes = tiles * 64 * cin * dtype_bytes
    u_bytes = 64 * cin * cout * dtype_bytes
    y_bytes = tiles * 36 * cout * dtype_bytes
    if fused:
        grid = nt * no * nc
        traffic = x_bytes * no + u_bytes * nt + y_bytes
    else:
        v_bytes = tiles * 64 * cin * dtype_bytes
        m_bytes = tiles * 64 * cout * dtype_bytes
        grid = nt * nc + 64 * nt * no * nc + nt * no
        traffic = (
            (x_bytes + v_bytes)                       # input transform
            + (v_bytes * no + u_bytes * nt + m_bytes)  # tuple multiply
            + (m_bytes + y_bytes)                      # output transform
        )
    return GemmEstimate(
        compute_s=compute_s,
        memory_s=traffic / hw.hbm_bandwidth,
        startup_s=grid * hw.grid_step_overhead_s,
        vmem_bytes=winograd_kernel_vmem_bytes(bt, bc, bo, fused, dtype_bytes),
        hbm_bytes=traffic,
        mxu_utilization=(tiles * cin * cout) / float(tp * cp * op),
    )


def autotune_winograd_blocks(
    tiles: int,
    cin: int,
    cout: int,
    hw: ChipSpec = V5E,
    vmem_budget: Optional[int] = None,
    dtype_bytes: int = 4,
    fused: bool = True,
) -> Tuple[Tuple[int, int, int], GemmEstimate]:
    """Pick the predicted-fastest (bt, bc, bo) under a VMEM budget.

    The Winograd instance of the paper's Table-II block-size tuning: every
    HW-aligned candidate no bigger than the padded problem is scored with
    ``predict_winograd`` and checked against the *full* per-kernel footprint
    (``winograd_kernel_vmem_bytes``).  If even the granularity floor
    (8, 128, 128) overflows the budget it is returned anyway — block shapes
    cannot shrink below the (sublane, lane) tile.
    """
    budget = vmem_budget if vmem_budget is not None else hw.vmem_bytes
    bt_max = ceil_to(tiles, 8)
    bc_max = ceil_to(cin, 128)
    bo_max = ceil_to(cout, 128)
    candidates = [
        (bt, bc, bo)
        for bt in WINOGRAD_BTS
        for bc in WINOGRAD_BCS
        for bo in WINOGRAD_BOS
        if bt <= bt_max and bc <= bc_max and bo <= bo_max
        and winograd_kernel_vmem_bytes(bt, bc, bo, fused, dtype_bytes) <= budget
    ]
    if not candidates:
        candidates = [(8, 128, 128)]
    best = min(
        candidates,
        key=lambda b: predict_winograd(
            tiles, cin, cout, b, hw, dtype_bytes, fused
        ).total_s,
    )
    return best, predict_winograd(tiles, cin, cout, best, hw, dtype_bytes, fused)
