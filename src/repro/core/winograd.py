"""Winograd F(6x6, 3x3) convolution with inter-tile channel parallelism.

This is the paper's novel contribution (§IV.B): rather than growing the tile
beyond 8x8 (which destroys numerical accuracy), the transforms are vectorized
by packing one 8x8 tile from each of several channels along the vector.  On
TPU we realize the same scheme by keeping **channels as the minormost (lane)
axis** of every transform operand: an (..., tiles, channels) block fills the
128-wide lane axis with channels exactly as the paper fills a 512..2048-bit
vector with 4..16 channels.  The tuple multiplication (§IV.B last paragraph)
becomes a batched GEMM over the 64 transform positions:
    M[p] = V[p] @ U[p],  p in 0..63,  V[p]: (tiles, Cin), U[p]: (Cin, Cout)
which maps directly onto the MXU.

Transform matrices are the standard Lavin/Cook-Toom F(6,3) set with
interpolation points (0, ±1, ±2, ±1/2, ∞) — the same family NNPACK uses.
Their correctness is asserted against direct convolution in the test-suite.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.conv_spec import ConvSpec, Epilogue, apply_epilogue

TILE = 8          # input tile (paper's default 8x8)
OUT_TILE = 6      # output tile of F(6,3)
R = 3             # filter size

# B^T (8x8): input transform.  V = B^T d B.
BT = np.array(
    [
        [1, 0, -21 / 4, 0, 21 / 4, 0, -1, 0],
        [0, 1, 1, -17 / 4, -17 / 4, 1, 1, 0],
        [0, -1, 1, 17 / 4, -17 / 4, -1, 1, 0],
        [0, 1 / 2, 1 / 4, -5 / 2, -5 / 4, 2, 1, 0],
        [0, -1 / 2, 1 / 4, 5 / 2, -5 / 4, -2, 1, 0],
        [0, 2, 4, -5 / 2, -5, 1 / 2, 1, 0],
        [0, -2, 4, 5 / 2, -5, -1 / 2, 1, 0],
        [0, -1, 0, 21 / 4, 0, -21 / 4, 0, 1],
    ],
    dtype=np.float64,
)

# G (8x3): weight transform.  U = G g G^T.
G = np.array(
    [
        [1, 0, 0],
        [-2 / 9, -2 / 9, -2 / 9],
        [-2 / 9, 2 / 9, -2 / 9],
        [1 / 90, 1 / 45, 2 / 45],
        [1 / 90, -1 / 45, 2 / 45],
        [32 / 45, 16 / 45, 8 / 45],
        [32 / 45, -16 / 45, 8 / 45],
        [0, 0, 1],
    ],
    dtype=np.float64,
)

# A^T (6x8): output transform.  Y = A^T M A.
AT = np.array(
    [
        [1, 1, 1, 1, 1, 1, 1, 0],
        [0, 1, -1, 2, -2, 1 / 2, -1 / 2, 0],
        [0, 1, 1, 4, 4, 1 / 4, 1 / 4, 0],
        [0, 1, -1, 8, -8, 1 / 8, -1 / 8, 0],
        [0, 1, 1, 16, 16, 1 / 16, 1 / 16, 0],
        [0, 1, -1, 32, -32, 1 / 32, -1 / 32, 1],
    ],
    dtype=np.float64,
)


def transform_weights(w: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """U = G w G^T per (cin, cout) pair.

    Done **once, offline** for inference — the paper excludes the weight
    transform from timing for the same reason (§VII.A).

    Args:
      w: (3, 3, Cin, Cout).
    Returns:
      (8, 8, Cin, Cout) transformed weights.
    """
    g = jnp.asarray(G, dtype)
    # U[a,b,c,o] = sum_{i,j} G[a,i] w[i,j,c,o] G[b,j]
    return jnp.einsum("ai,bj,ijco->abco", g, g, w.astype(dtype))


def _tile_input(x: jnp.ndarray, oh: int, ow: int) -> Tuple[jnp.ndarray, int, int]:
    """Pad + extract overlapping 8x8 input tiles with stride 6.

    Args:
      x: (B, H, W, C) *already padded* with the conv's own padding.
    Returns:
      tiles (B, nTH, nTW, 8, 8, C), and the tile grid (nTH, nTW).
    """
    b, h, w, c = x.shape
    nth = -(-oh // OUT_TILE)  # ceil
    ntw = -(-ow // OUT_TILE)
    need_h = nth * OUT_TILE + R - 1
    need_w = ntw * OUT_TILE + R - 1
    x = jnp.pad(x, ((0, 0), (0, need_h - h), (0, need_w - w), (0, 0)))
    rows = (jnp.arange(nth) * OUT_TILE)[:, None] + jnp.arange(TILE)[None, :]
    cols = (jnp.arange(ntw) * OUT_TILE)[:, None] + jnp.arange(TILE)[None, :]
    tiles = x[:, rows[:, None, :, None], cols[None, :, None, :], :]
    return tiles, nth, ntw


def input_transform(tiles: jnp.ndarray) -> jnp.ndarray:
    """V = B^T d B, channels kept minormost (inter-tile channel packing).

    Args:
      tiles: (B, nTH, nTW, 8, 8, C).
    Returns:
      (8, 8, B*nTH*nTW, C) — position-major, (tiles, channels) trailing so the
      lane axis is the channel axis, as in the paper's Fig. 5 scheme.
    """
    bt = jnp.asarray(BT, tiles.dtype)
    b, nth, ntw = tiles.shape[:3]
    v = jnp.einsum("ai,bj,BtuijC->abBtuC", bt, bt, tiles)
    return v.reshape(TILE, TILE, b * nth * ntw, tiles.shape[-1])


def tuple_multiply(v: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Batched tuple multiplication over the 64 transform positions.

    M[a,b] = V[a,b] @ U[a,b]:  (8,8,T,Cin) x (8,8,Cin,Cout) -> (8,8,T,Cout).
    This is the paper's "increase the number of blocks for the GEMM kernel"
    (§IV.B): each position is an independent GEMM; on TPU all 64 run as one
    batched MXU matmul.
    """
    return jnp.einsum("abtc,abco->abto", v, u)


def output_transform(m: jnp.ndarray, b: int, nth: int, ntw: int) -> jnp.ndarray:
    """Y = A^T M A back to spatial tiles.

    Args:
      m: (8, 8, B*nTH*nTW, Cout).
    Returns:
      (B, nTH*6, nTW*6, Cout).
    """
    at = jnp.asarray(AT, m.dtype)
    cout = m.shape[-1]
    m = m.reshape(TILE, TILE, b, nth, ntw, cout)
    y = jnp.einsum("xa,yb,abBtuC->BtxuyC", at, at, m)
    return y.reshape(b, nth * OUT_TILE, ntw * OUT_TILE, cout)


def conv2d_winograd(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    pretransformed: bool = False,
    epilogue: Optional[Epilogue] = None,
) -> jnp.ndarray:
    """Full Winograd F(6,3) convolution, stride 1, 3x3 kernels.

    Args:
      x: (B, H, W, Cin).
      w: (3, 3, Cin, Cout) raw weights, or (8, 8, Cin, Cout) if
         ``pretransformed`` (offline weight transform, inference mode).
    Returns:
      (B, OH, OW, Cout).
    """
    assert spec.kernel_size == (3, 3) and spec.stride == (1, 1), (
        "Winograd F(6,3) requires 3x3 stride-1; the selector routes "
        "everything else to im2col+GEMM (paper §VII.A)."
    )
    bsz, h, ww, _ = x.shape
    oh, ow = spec.out_hw(h, ww)
    ph, pw = spec.padding
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    u = w if pretransformed else transform_weights(w, x.dtype)
    tiles, nth, ntw = _tile_input(x, oh, ow)
    v = input_transform(tiles)
    m = tuple_multiply(v, u.astype(x.dtype))
    y = output_transform(m, bsz, nth, ntw)
    # Epilogue on the transformed output (bias + activation are elementwise,
    # so applying before the crop is exact).
    return apply_epilogue(y, epilogue)[:, :oh, :ow, :]


def winograd_flops(oh: int, ow: int, cin: int, cout: int) -> dict:
    """Multiply counts for F(6,3) vs direct 3x3 — the paper's 2.4x source.

    Per 6x6 output tile: direct = 36*9*Cin*Cout MACs; winograd tuple mult =
    64*Cin*Cout MACs (5.06x fewer) + transform overhead.
    """
    nth, ntw = -(-oh // OUT_TILE), -(-ow // OUT_TILE)
    tiles = nth * ntw
    direct = 2 * oh * ow * 9 * cin * cout
    tuple_mult = 2 * tiles * 64 * cin * cout
    # B^T d B: two 8x8 @ 8x8 per tile-channel; A^T M A: 6x8 @ 8x8 + 6x8 @ 8x6.
    in_tf = tiles * cin * 2 * (8 * 8 * 8) * 2
    out_tf = tiles * cout * 2 * (6 * 8 * 8 + 6 * 8 * 6)
    return {
        "direct_flops": direct,
        "winograd_flops": tuple_mult + in_tf + out_tf,
        "tuple_flops": tuple_mult,
        "transform_flops": in_tf + out_tf,
        "mult_reduction": direct / tuple_mult,
    }
