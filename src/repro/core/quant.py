"""Int8 inference quantization: offline scale computation + policy.

The paper's co-design thesis is that shrinking the working set is the
dominant lever for CNN inference throughput; int8 is the same lever applied
to dtype — quantizing activations and weights halves-to-quarters HBM
traffic on the im2col+GEMM side.  This module holds everything that happens
*offline* (scales, weight quantization, calibration) plus the two planner
policies that decide *whether* a layer quantizes:

  - traffic benefit: a layer only quantizes when its modeled int8 GEMM
    bytes are at most ``INT8_TRAFFIC_THRESHOLD`` times its fp32 bytes
    (``int8_traffic_ratio``).  A cin=3 stem layer, whose fp32 output write
    dominates, fails this test and stays fp32 — the bytes win would not pay
    for the quantization noise.
  - Winograd error budget: the F(6, 3) input transform amplifies the data
    range by ``winograd_transform_amplification()`` (~36x for our B^T), so
    an int8 V-matrix loses ~20*log10(amp) dB of SQNR.  Unless the estimate
    clears the budget (it does not for F(6, 3)), Winograd layers fall back
    to fp32 — cf. Maji et al.'s transform-stage precision handling.

Quantization scheme (symmetric, round-to-nearest, [-127, 127]):

  activations  per-input-channel scales sx (C,), calibrated offline from a
               sample batch (max-abs over B, H, W).  The per-channel scales
               are *folded into the weights* before weight quantization, so
               the kernel-side dequant stays a single per-output-channel
               row — the only granularity that factors out of the K
               reduction.
  weights      per-output-channel scales sw (O,) on the activation-folded
               weights w * sx[c].
  kernel       int8 x int8 -> int32 accumulation; the fused epilogue
               dequantizes on the accumulator (y = acc * sw + bias, then
               activation) and writes fp32 — inter-layer activations stay
               fp32, each int8 layer re-quantizes at entry with its static
               calibrated scales (a cheap fused elementwise pass; the GEMM
               reads, which dominate by the kh*kw reuse factor, are int8).

The block-scaling idiom (max-abs / 127 with a clamp floor) is shared with
``optim/quantized_state.py``; here the block axis is a channel, there a
flat 256-element run.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

QMAX = 127.0
SCALE_FLOOR = 1e-12        # all-zero channels quantize to zeros, not NaNs
INT8_TRAFFIC_THRESHOLD = 0.5
WINOGRAD_SQNR_BUDGET_DB = 30.0


# ---------------------------------------------------------------------------
# Scale computation / (de)quantization primitives


def activation_scales(x, axis: Optional[Tuple[int, ...]] = None):
    """Per-channel symmetric scales for an NHWC activation: amax/127.

    ``axis`` defaults to all-but-last (per-channel over B, H, W).  Returns
    fp32 (C,) with the ``SCALE_FLOOR`` clamp so dead channels stay finite.
    """
    import jax.numpy as jnp

    if axis is None:
        axis = tuple(range(x.ndim - 1))
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    return jnp.maximum(amax / QMAX, SCALE_FLOOR)


def quantize_activation(x, scale):
    """x / scale, round-to-nearest, clip to [-127, 127], int8.

    ``scale`` is the per-channel (C,) calibration vector (broadcast over
    B, H, W).  Runs inside the jitted forward — XLA fuses it into a single
    elementwise pass feeding the int8 kernel.
    """
    import jax.numpy as jnp

    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)


def quantize_conv_weights(w, x_scale):
    """Per-output-channel int8 weights with the activation scales folded in.

    w (kh, kw, C, O) fp32, x_scale (C,) -> (wq int8 (kh, kw, C, O),
    w_scale fp32 (O,)).  The folded weights w' = w * x_scale[c] make the
    kernel's integer product xq * wq ≈ (x / sx) * (w * sx) = x * w, so the
    dequant epilogue is a single per-output-channel row:

        y[o] ≈ w_scale[o] * sum_k xq * wq    (int32 accumulation)

    Zero-padded output channels get scale SCALE_FLOOR and all-zero int8
    weights, preserving the layout-elision invariant act(0 + 0) = 0.
    """
    import jax.numpy as jnp

    wf = w.astype(jnp.float32) * x_scale[None, None, :, None]
    amax = jnp.max(jnp.abs(wf), axis=(0, 1, 2))
    w_scale = jnp.maximum(amax / QMAX, SCALE_FLOOR)
    wq = jnp.clip(jnp.round(wf / w_scale), -QMAX, QMAX).astype(jnp.int8)
    return wq, w_scale


def sqnr_db(ref, test) -> float:
    """Signal-to-quantization-noise ratio in dB (fp64, conformance gate)."""
    ref = np.asarray(ref, np.float64)
    err = np.asarray(test, np.float64) - ref
    sig = float(np.sum(ref * ref))
    noise = float(np.sum(err * err))
    if noise == 0.0:
        return float("inf")
    return 10.0 * np.log10(max(sig, 1e-300) / noise)


# ---------------------------------------------------------------------------
# Offline calibration (fp32 reference walk)


def default_calibration_batch(h: int, w: int, in_channels: int,
                              batch: int = 2, seed: int = 0):
    """Deterministic synthetic calibration batch (standard-normal).

    Used when ``repro.compile(..., ExecutionOptions(dtype='int8'))`` gets no
    calibration data — zero caller changes, documented accuracy caveat: real
    sample inputs calibrate the activation ranges better.
    """
    import jax

    return jax.random.normal(
        jax.random.PRNGKey(seed), (batch, h, w, in_channels), "float32"
    )


def calibrate_activation_scales(
    netplan, folded_params: Sequence[Dict], x,
) -> Dict[int, Any]:
    """Per-conv-step activation scales from an fp32 oracle walk.

    Walks the layer table exactly like ``netplan.run_network`` but on
    *logical* (unpadded) channels through ``conv2d_reference``, recording
    each conv input's per-channel max-abs.  Returns {step index: (C,) fp32
    scales} for every conv step.  Runs eagerly, offline — the scales become
    constants of the jitted int8 forward.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.conv2d import conv2d_reference
    from repro.core.conv_spec import Epilogue, apply_epilogue, apply_activation

    scales: Dict[int, Any] = {}
    outputs: List[Any] = []
    cur = jnp.asarray(x, jnp.float32)
    for s in netplan.steps:
        l = s.layer
        p = folded_params[s.index]
        if l.kind == "conv":
            scales[s.index] = activation_scales(cur)
            y = conv2d_reference(cur, p["w"].astype(jnp.float32), s.spec)
            cur = apply_epilogue(
                y, Epilogue(bias=p["b"], activation=l.activation)
            )
        elif l.kind == "maxpool":
            cur = jax.lax.reduce_window(
                cur, -jnp.inf, jax.lax.max,
                (1, l.size, l.size, 1), (1, l.stride, l.stride, 1), "SAME",
            )
        elif l.kind == "avgpool":
            cur = cur.mean(axis=(1, 2))
        elif l.kind == "upsample":
            cur = jnp.repeat(jnp.repeat(cur, l.size, axis=1), l.size, axis=2)
        elif l.kind == "shortcut":
            cur = cur + outputs[l.from_layers[0]]
        elif l.kind == "route":
            cur = jnp.concatenate([outputs[j] for j in l.from_layers], axis=-1)
        elif l.kind == "fc":
            if cur.ndim == 4:
                cur = cur.mean(axis=(1, 2))
            cur = apply_activation(cur @ p["w"] + p["b"], l.activation)
        outputs.append(cur)
    return scales


# ---------------------------------------------------------------------------
# Planner policies


def int8_traffic_ratio(spec, h: int, w: int, batch: int = 1) -> float:
    """Modeled int8 / fp32 HBM bytes of this layer's im2col+GEMM.

    int8 moves int8 activations + int8 weights but still writes an fp32
    output (inter-layer activations stay fp32); the ratio is what the
    quantization policy gates on.
    """
    from repro.core.vmem_model import im2col_gemm_traffic_bytes

    oh, ow = spec.out_hw(h, w)
    fp32 = im2col_gemm_traffic_bytes(
        oh, ow, spec.in_channels, spec.out_channels, spec.kh, spec.kw,
        batch=batch, dtype_bytes=4, out_dtype_bytes=4,
    )
    q8 = im2col_gemm_traffic_bytes(
        oh, ow, spec.in_channels, spec.out_channels, spec.kh, spec.kw,
        batch=batch, dtype_bytes=1, out_dtype_bytes=4,
    )
    return q8 / fp32


def int8_worthwhile(spec, h: int, w: int, batch: int = 1,
                    threshold: float = INT8_TRAFFIC_THRESHOLD) -> bool:
    """The quantization-benefit gate: bytes ratio must clear the threshold.

    Quantization noise is only paid for when the HBM-bytes win is
    substantial; a stem layer (cin=3) whose fp32 output write dominates
    stays fp32.
    """
    return int8_traffic_ratio(spec, h, w, batch) <= threshold


def winograd_transform_amplification() -> float:
    """Worst-case data-range growth of the F(6, 3) input transform.

    V = B^T d B, so max|V| <= (max row-sum |B^T|)^2 * max|d| — the factor an
    int8 quantization grid for V must stretch by relative to quantizing d
    directly.  Computed from the repo's actual B^T matrix (not a literature
    constant) so a transform change re-prices the policy automatically.
    """
    from repro.core.winograd import BT

    row_sum = float(np.max(np.sum(np.abs(BT), axis=1)))
    return row_sum * row_sum


def winograd_int8_sqnr_estimate_db() -> float:
    """Estimated SQNR of an int8 F(6, 3) transform stage.

    Uniform-quantizer baseline SQNR for a max-abs-calibrated int8 grid is
    20*log10(127*sqrt(12)/kappa) with kappa ~ amax/sigma ~ 4 for conv
    activations; the transform multiplies the grid step by the
    amplification factor, subtracting 20*log10(amp) dB.
    """
    kappa = 4.0
    base = 20.0 * np.log10(QMAX * np.sqrt(12.0) / kappa)
    return float(base - 20.0 * np.log10(winograd_transform_amplification()))


def winograd_int8_budget_ok(
    threshold_db: float = WINOGRAD_SQNR_BUDGET_DB,
) -> bool:
    """Whether int8 Winograd clears the transform-stage error budget.

    False for F(6, 3) (the ~36x amplification costs ~31 dB, leaving the
    estimate far below the 30 dB conformance gate), so the planner runs
    Winograd layers in fp32 — or re-routes them to int8 im2col+GEMM when
    the cost model prices that faster.  The policy is a function, not a
    constant: a smaller-tile transform (e.g. F(2, 3)) could pass.
    """
    return winograd_int8_sqnr_estimate_db() >= threshold_db
