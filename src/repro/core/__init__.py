"""Core library: the paper's contribution as composable JAX modules.

- conv_spec:  ConvSpec + the per-layer algorithm selector ("no one-size-fits-all")
- im2col:     im2col + conv-as-GEMM (paper §IV.A)
- winograd:   F(6x6,3x3) with inter-tile channel parallelism (paper §IV.B)
- conv2d:     public dispatching conv entry point
- vmem_model: analytical TPU memory-hierarchy model (the gem5 analogue)
- codesign:   vector-length / cache-size / lanes co-design sweeps (paper §V/§VI)
- planner:    per-layer ConvPlan resolution + persistent autotuning cache
- netplan:    whole-network planning: inter-layer layout persistence +
              the NetworkExecutor (sharded batch execution)
"""
from repro.core.conv_spec import (
    ConvAlgorithm,
    ConvSpec,
    Epilogue,
    apply_activation,
    apply_epilogue,
    select_algorithm,
)
from repro.core.conv2d import conv2d, conv2d_reference
from repro.core.im2col import conv2d_im2col, im2col
from repro.core.netplan import (
    Layout,
    NetworkExecutor,
    NetworkPlan,
    build_network_plan,
    plan_network,
)
from repro.core.planner import ConvPlan, Planner
from repro.core.winograd import conv2d_winograd, transform_weights

__all__ = [
    "Layout",
    "NetworkExecutor",
    "NetworkPlan",
    "build_network_plan",
    "plan_network",
    "ConvAlgorithm",
    "ConvSpec",
    "Epilogue",
    "apply_activation",
    "apply_epilogue",
    "select_algorithm",
    "conv2d",
    "conv2d_reference",
    "conv2d_im2col",
    "im2col",
    "ConvPlan",
    "Planner",
    "conv2d_winograd",
    "transform_weights",
]
