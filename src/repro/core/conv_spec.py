"""Convolution specification and per-layer algorithm selection.

This encodes the paper's central "no one-size-fits-all convolution" finding
(§II.c, §VII): 1x1 kernels run as a direct GEMM, 3x3 stride-1 kernels run
Winograd F(6x6,3x3), everything else falls back to im2col+GEMM.  The selector
is a first-class, overridable feature of the framework: every conv layer
carries a ConvSpec and the dispatcher in core/conv2d.py consults it.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional, Tuple


class ConvAlgorithm(enum.Enum):
    """Convolution algorithm choices studied by the paper."""

    AUTO = "auto"
    AUTO_COST = "auto_cost"      # roofline-model-driven selection (beyond
                                 # paper: v5e eligibility also requires the
                                 # layer be activation-dominated; see
                                 # EXPERIMENTS.md §Perf CNN section)
    DIRECT = "direct"            # 1x1 → plain GEMM (no patch expansion)
    IM2COL_GEMM = "im2col_gemm"  # generic path (paper §IV.A)
    WINOGRAD = "winograd"        # F(6x6,3x3), 8x8 tiles (paper §IV.B)


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Static description of one convolutional layer."""

    in_channels: int
    out_channels: int
    kernel_size: Tuple[int, int] = (3, 3)
    stride: Tuple[int, int] = (1, 1)
    padding: Tuple[int, int] = (1, 1)   # symmetric (ph, pw)
    dilation: Tuple[int, int] = (1, 1)
    algorithm: ConvAlgorithm = ConvAlgorithm.AUTO

    @property
    def kh(self) -> int:
        return self.kernel_size[0]

    @property
    def kw(self) -> int:
        return self.kernel_size[1]

    def out_hw(self, h: int, w: int) -> Tuple[int, int]:
        """Output spatial dims for an (h, w) input."""
        ph, pw = self.padding
        sh, sw = self.stride
        dh, dw = self.dilation
        eff_kh = (self.kh - 1) * dh + 1
        eff_kw = (self.kw - 1) * dw + 1
        oh = (h + 2 * ph - eff_kh) // sh + 1
        ow = (w + 2 * pw - eff_kw) // sw + 1
        return oh, ow

    def gemm_dims(self, h: int, w: int) -> Tuple[int, int, int]:
        """(M, N, K) of the im2col GEMM for an (h, w) input.

        Matches the paper's formulation: M = n_filters, K = kh*kw*c,
        N = oh*ow (Table IV uses exactly these).
        """
        oh, ow = self.out_hw(h, w)
        return self.out_channels, oh * ow, self.kh * self.kw * self.in_channels


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Per-layer conv epilogue fused into the kernel's output stage.

    The paper's BLIS lesson (§IV.A) applied to the layer pipeline: instead of
    bouncing the conv output through HBM three more times (add_bias →
    activation as separate elementwise passes), the bias add and activation
    run on the fp32 accumulator while it is still VMEM-resident.  Inference-
    mode batchnorm is first folded into the conv weights + this bias
    (``models/cnn.fold_batchnorm``), so every conv layer reduces to
    conv + bias + activation.

    ``bias`` is a traced (out_channels,) vector or None; ``activation`` is a
    static kind ('linear' | 'relu' | 'leaky') so jitted kernel wrappers can
    specialize on it.

    ``scale`` extends the same fused write-back to int8 dequantization: a
    per-output-channel (O,) vector multiplied into the raw accumulator
    *before* the bias add, so y = act(acc * scale + bias).  For int8 convs
    the accumulator is int32 and ``scale`` carries the folded
    activation x weight quantization scales (core/quant.py); for fp32 convs
    it stays None and the epilogue is unchanged.
    """

    bias: Optional[Any] = None      # (O,) jnp vector, traced through jit
    activation: str = "linear"      # linear | relu | leaky
    scale: Optional[Any] = None     # (O,) dequant row, traced through jit


def apply_activation(x, kind: str):
    """Darknet's activate_array, shared by kernels and reference paths."""
    import jax.numpy as jnp

    if kind == "leaky":
        return jnp.where(x > 0, x, 0.1 * x)
    if kind == "relu":
        return jnp.maximum(x, 0)
    if kind == "linear":
        return x
    raise ValueError(f"unknown activation {kind!r}")


def apply_epilogue(y, epilogue: Optional[Epilogue]):
    """Reference epilogue: y * scale + bias, then activation (pure jnp)."""
    if epilogue is None:
        return y
    if epilogue.scale is not None:
        import jax.numpy as jnp

        y = y.astype(jnp.float32) * epilogue.scale
    if epilogue.bias is not None:
        y = y + epilogue.bias
    return apply_activation(y, epilogue.activation)


def select_algorithm(spec: ConvSpec) -> ConvAlgorithm:
    """The paper's per-layer selection rule (§VII.A, §II.c).

    - 1x1, stride 1: the im2col matrix equals the input — run a direct GEMM.
    - 3x3, stride 1, no dilation: Winograd F(6,3) is 2.4x faster (paper §VII).
    - 3x3 stride 2: the paper measured Winograd 1.4x *slower* → im2col+GEMM.
    - everything else: im2col+GEMM.
    """
    if spec.algorithm is not ConvAlgorithm.AUTO:
        return spec.algorithm
    if spec.kernel_size == (1, 1) and spec.stride == (1, 1):
        return ConvAlgorithm.DIRECT
    if (
        spec.kernel_size == (3, 3)
        and spec.stride == (1, 1)
        and spec.dilation == (1, 1)
    ):
        return ConvAlgorithm.WINOGRAD
    return ConvAlgorithm.IM2COL_GEMM


def arithmetic_intensity(m: int, n: int, k: int, bytes_per_elem: int = 4) -> float:
    """AI of a GEMM as defined in the paper (§VI.C):

    AI = 2*M*N*K / (bytes * (M*N + K*N + M*K)).
    """
    return (2.0 * m * n * k) / (bytes_per_elem * (m * n + k * n + m * k))
