"""Public convolution API with per-layer algorithm dispatch.

``conv2d`` is the single entry point used by the model zoo (models/cnn.py)
and the examples.  Routing comes from, in priority order: an explicit
``ConvPlan`` (the planner's cached co-design decision — algorithm, impl and
block sizes resolved once per layer/shape/chip), a ``Planner`` to look one
up, or the per-call selectors in core/conv_spec.py / core/codesign.py.
Execution goes to direct-GEMM / im2col+GEMM / Winograd, optionally through
the Pallas kernels (kernels/) when the impl is 'pallas'.
"""
from __future__ import annotations

from typing import Optional, TYPE_CHECKING

import jax.numpy as jnp

from repro.core.conv_spec import (
    ConvAlgorithm,
    ConvSpec,
    Epilogue,
    select_algorithm,
)
from repro.core.im2col import conv2d_direct_1x1, conv2d_im2col
from repro.core.winograd import conv2d_winograd

if TYPE_CHECKING:  # import cycle: planner imports conv2d for measure mode
    from repro.core.netplan import Layout
    from repro.core.planner import ConvPlan, Planner


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    impl: str = "jax",
    interpret: Optional[bool] = None,
    plan: Optional["ConvPlan"] = None,
    planner: Optional["Planner"] = None,
    epilogue: Optional[Epilogue] = None,
    in_layout: Optional["Layout"] = None,
    out_layout: Optional["Layout"] = None,
    pretransformed: bool = False,
) -> jnp.ndarray:
    """Convolve ``x`` (B,H,W,C) with ``w`` (kh,kw,C,O) per ``spec``.

    impl: 'jax' (pure jnp, the reference path) or 'pallas' (TPU kernels;
    ``interpret=True`` executes them on CPU for validation).  When ``plan``
    is given (or resolved via ``planner``) it overrides both the algorithm
    choice and ``impl``, and its block sizes are forwarded to the Pallas
    kernels — no per-call re-selection happens.  ``epilogue`` (bias +
    activation) is fused into the output stage of whichever path runs.

    ``in_layout``/``out_layout`` (core/netplan.Layout) are the network
    executor's inter-layer layout contract: with a non-trivial ``in_layout``
    the input (and the offline-prepared ``w``/``epilogue.bias``) already
    carry block-padded channels and the kernel wrappers pad nothing; with a
    non-trivial ``out_layout`` the channel crop is deferred and the padded
    activation flows to the next planned layer (pallas impl only).

    ``pretransformed`` declares that ``w`` already carries the offline
    Winograd weight transform ((8, 8, C, O) from ``transform_weights`` /
    ``prepare_net_params(pretransform=True)``).  The flag is explicit by
    contract — it is never inferred from weight shapes, because the old
    sniff (``w.shape[0] != spec.kh``) was ambiguous for any kh == 8 kernel,
    whose raw weights are (8, 8, C, O) too.
    """
    if plan is None and planner is not None:
        plan = planner.plan(
            spec, x.shape[1], x.shape[2], batch=x.shape[0], dtype=x.dtype
        )
    if plan is not None:
        algo = plan.algorithm
        impl = plan.impl
    elif spec.algorithm is ConvAlgorithm.AUTO_COST:
        from repro.core.codesign import select_algorithm_by_cost

        algo = select_algorithm_by_cost(spec, x.shape[1], x.shape[2])
    else:
        algo = select_algorithm(spec)
    if impl == "pallas":
        # Imported lazily: kernels are optional at import time.
        from repro.kernels import conv_ops

        return conv_ops.conv2d_pallas(
            x, w, spec, algo, interpret=interpret, plan=plan,
            epilogue=epilogue, in_layout=in_layout, out_layout=out_layout,
            pretransformed=pretransformed,
        )
    if (in_layout is not None and in_layout.pad_c) or (
        out_layout is not None and out_layout.pad_c
    ):
        raise ValueError(
            "block-padded channel layouts require impl='pallas' — the pure "
            "jnp paths have no block padding to persist"
        )
    if algo is ConvAlgorithm.DIRECT:
        return conv2d_direct_1x1(x, w, spec, epilogue=epilogue)
    if algo is ConvAlgorithm.WINOGRAD:
        # Offline-prepared weights arrive pre-transformed as (8,8,C,O) —
        # declared by the caller, never sniffed from the shape.
        return conv2d_winograd(
            x, w, spec, pretransformed=pretransformed, epilogue=epilogue,
        )
    return conv2d_im2col(x, w, spec, epilogue=epilogue)


def conv2d_reference(x: jnp.ndarray, w: jnp.ndarray, spec: ConvSpec) -> jnp.ndarray:
    """XLA's own convolution — the oracle every algorithm is tested against."""
    import jax

    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=spec.stride,
        padding=[(spec.padding[0], spec.padding[0]), (spec.padding[1], spec.padding[1])],
        rhs_dilation=spec.dilation,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
