"""Network-level inference planning and execution.

The paper's co-design argument is end-to-end: the 5x win comes from tuning
the kernels *and* the memory system across the whole layer set, not one conv
at a time — and the follow-up RISC-V study makes the same point that
per-layer-optimal choices are not network-optimal.  Executing layer-by-layer
through ``core.conv2d`` leaves pure HBM elementwise traffic between
consecutive convs: every layer crops its block-padded kernel output back to
logical channels and the next layer immediately re-pads it to *its* block
multiple.  This module plans the network once and makes those boundaries a
planner decision:

  Layout        the physical channel layout an NHWC activation carries
                relative to its logical shape (trailing zero channels from
                block alignment).  Trailing *row* padding is never carried:
                the kernels' tail rows hold act(bias), not zeros, so the
                network plan instead snaps each im2col row tile ``toh`` to a
                divisor of OH — the row-block pad/crop pair vanishes
                identically instead of being elided.
  NetworkPlan   the whole network resolved ahead of time: per-layer
                ConvPlans (reusing the planner's persistent cache, keyed by
                batch), network-adjusted kernel blocks, and the inter-layer
                layout decisions — which crop+re-pad pairs are elided so the
                padded activation flows straight into the next pallas_call,
                with a single channel crop at network exit.
  NetworkExecutor  runs a NetworkPlan: offline parameter preparation
                (batchnorm folding, block padding, Winograd weight
                pre-transform), a jitted whole-network forward, and
                data-parallel batch execution via shard_map over a device
                mesh on the batch axis (single-device fallback).

Elision is legal exactly when the padded region stays zero and divisible:
the producer's weight/bias pads make its extra output channels
act(0 + 0) = 0 (relu/leaky/linear all fix 0), maxpool/upsample preserve
zero channels, and the consumer's zero weight pads ignore them — so a
producer's physical channel count that divides the consumer's channel block
can flow through unchanged.  Any consumer that needs logical channels
(route concat, shortcut add, fc, avgpool, or a layer referenced by one)
forces a crop back to logical.

Whole-network decisions persist as a "networks" entry in the planner's v4
cache (keyed by a layer-table digest + batch/chip/dtype/impl/policy), so a
warm process rebuilds the NetworkPlan with zero re-tunes.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.conv_spec import (
    ConvAlgorithm,
    ConvSpec,
    Epilogue,
    apply_activation,
    select_algorithm,
)
from repro.core.planner import ConvPlan, Planner
from repro.util import ceil_to


# ---------------------------------------------------------------------------
# Layout


@dataclasses.dataclass(frozen=True)
class Layout:
    """Physical channel layout of an NHWC activation.

    ``c`` logical channels plus ``pad_c`` trailing zero channels (block
    alignment).  The invariant every producer maintains — and every consumer
    may rely on — is that the ``pad_c`` tail is exactly zero.
    """

    c: int
    pad_c: int = 0

    @property
    def phys_c(self) -> int:
        return self.c + self.pad_c

    @property
    def trivial(self) -> bool:
        return self.pad_c == 0

    def to_json(self) -> List[int]:
        return [self.c, self.pad_c]

    @classmethod
    def from_json(cls, d: Sequence[int]) -> Layout:
        return cls(int(d[0]), int(d[1]))


# ---------------------------------------------------------------------------
# NetworkPlan


@dataclasses.dataclass(frozen=True)
class NetStep:
    """One planned layer: its spec/plan plus the layouts it consumes and
    produces.  ``in_layout``/``out_layout`` are only non-trivial for planned
    pallas convs (and the pools between them, which pass layouts through)."""

    index: int
    layer: Any                      # CNNLayer (duck-typed: .kind, ...)
    spec: Optional[ConvSpec]
    plan: Optional[ConvPlan]
    in_hw: Tuple[int, int]
    out_hw: Tuple[int, int]
    in_layout: Layout
    out_layout: Layout


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """A whole network resolved for one (input shape, batch, impl, dtype)."""

    steps: Tuple[NetStep, ...]
    input_hw: Tuple[int, int]
    in_channels: int
    batch: int
    impl: str
    dtype_name: str

    @property
    def layers(self) -> Tuple[Any, ...]:
        return tuple(s.layer for s in self.steps)

    @property
    def elided_boundaries(self) -> int:
        """Conv boundaries whose crop+re-pad pair was elided (padded
        channels flow straight into the next layer)."""
        return sum(
            1 for s in self.steps
            if s.layer.kind == "conv" and not s.out_layout.trivial
        )

    @property
    def exit_layout(self) -> Layout:
        return self.steps[-1].out_layout if self.steps else Layout(0)


# ---------------------------------------------------------------------------
# Algorithm / block helpers


def _conv_spec(layer, in_ch: int) -> ConvSpec:
    pad = layer.pad if layer.pad is not None else layer.kernel // 2
    return ConvSpec(
        in_channels=in_ch,
        out_channels=layer.out_channels,
        kernel_size=(layer.kernel, layer.kernel),
        stride=(layer.stride, layer.stride),
        padding=(pad, pad),
    )


def resolve_algorithm(
    spec: ConvSpec, plan: Optional[ConvPlan], h: int, w: int
) -> ConvAlgorithm:
    """The algorithm ``conv2d`` would route this layer to (same priority)."""
    if plan is not None:
        return plan.algorithm
    if spec.algorithm is ConvAlgorithm.AUTO_COST:
        from repro.core.codesign import select_algorithm_by_cost

        return select_algorithm_by_cost(spec, h, w)
    return select_algorithm(spec)


def _in_channel_multiple(plan: ConvPlan, algo: ConvAlgorithm) -> int:
    """The input-channel block the layer's Pallas kernel reduces over."""
    if algo is ConvAlgorithm.DIRECT:
        return plan.kernel_blocks[2]        # (bm, bn, bk) -> bk
    return plan.kernel_blocks[1]            # (toh|bt, bc, bo) -> bc


def _out_channel_multiple(plan: ConvPlan, algo: ConvAlgorithm) -> int:
    """The out-channel block the layer's kernel emits in multiples of."""
    if algo is ConvAlgorithm.DIRECT:
        return plan.kernel_blocks[1]        # bn
    return plan.kernel_blocks[2]            # bo


def _snap_row_tile(plan: ConvPlan, algo: ConvAlgorithm, oh: int) -> ConvPlan:
    """Network-level adjustment: make the im2col row tile divide OH.

    The kernel's row-tiled grid emits ceil(OH/toh)*toh rows; rows past OH
    hold act(bias), so they cannot flow to the next layer and the wrapper
    must crop them.  Snapping toh to the largest divisor of OH no bigger
    than the autotuned tile makes the row-block pad/crop pair vanish
    identically — a decision only visible at network scope.  The crop it
    saves is one cheap elementwise op, so the snap is only taken when the
    divisor keeps at least half the tuned tile: a prime OH (best divisor 1)
    must not explode the grid into one program per output row — the
    executor's im2col path crops the row tail exactly like the wrapper.
    """
    if algo is not ConvAlgorithm.IM2COL_GEMM:
        return plan
    toh, bc, bo = plan.kernel_blocks
    snapped = min(toh, oh)
    while oh % snapped:
        snapped -= 1
    if snapped < min(toh, oh) / 2 or (snapped, bc, bo) == plan.kernel_blocks:
        return plan
    return dataclasses.replace(plan, kernel_blocks=(snapped, bc, bo))


# ---------------------------------------------------------------------------
# Building the plan


def _propagate_shapes(
    layers: Tuple[Any, ...], h: int, w: int, in_channels: int
) -> List[Dict[str, Any]]:
    """Per-layer {'spec', 'in': (h,w,c), 'out': (h,w,c)} — the single shape
    walk shared by planning and layout resolution (mirrors
    models/cnn.cnn_forward)."""
    infos: List[Dict[str, Any]] = []
    shapes: List[Tuple[int, int, int]] = []
    cur_c, cur_h, cur_w = in_channels, h, w
    for i, l in enumerate(layers):
        in_shape = (cur_h, cur_w, cur_c)
        spec = None
        if l.kind == "conv":
            spec = _conv_spec(l, cur_c)
            cur_h, cur_w = spec.out_hw(cur_h, cur_w)
            cur_c = l.out_channels
        elif l.kind == "maxpool":
            cur_h, cur_w = -(-cur_h // l.stride), -(-cur_w // l.stride)
        elif l.kind == "upsample":
            cur_h, cur_w = cur_h * l.size, cur_w * l.size
        elif l.kind == "route":
            cur_c = sum(shapes[j][2] for j in l.from_layers)
            cur_h, cur_w = shapes[l.from_layers[0]][:2]
        elif l.kind == "avgpool":
            cur_h, cur_w = 1, 1
        elif l.kind == "fc":
            cur_h, cur_w = 1, 1
            cur_c = l.out_channels
        shapes.append((cur_h, cur_w, cur_c))
        infos.append({"spec": spec, "in": in_shape, "out": shapes[i]})
    return infos


def build_network_plan(
    layers: Sequence[Any],
    h: int,
    w: int,
    in_channels: int = 3,
    batch: int = 1,
    plans: Optional[Sequence[Optional[ConvPlan]]] = None,
    impl: str = "jax",
    dtype: Any = "float32",
    snap_rows: bool = True,
) -> NetworkPlan:
    """Pure layout resolution: layer table + per-layer plans -> NetworkPlan.

    No planner and no tuning — ``plan_network`` wraps this with plan
    resolution and the persistent network cache entry.  Deterministic given
    (layers, shapes, plans), so it can also run at trace time (cnn_infer).
    """
    layers = tuple(layers)
    n = len(layers)
    plans = tuple(plans) if plans is not None else (None,) * n
    assert len(plans) == n, (len(plans), n)
    referenced = {j for l in layers for j in getattr(l, "from_layers", ())}
    infos = _propagate_shapes(layers, h, w, in_channels)

    def next_conv(i: int):
        """Follow ``cur`` from layer i through layout-transparent layers.

        Returns ('conv', j) when the next consumer is conv j and no
        intermediate output is referenced by a route/shortcut (padded
        tensors must not land in the saved-outputs list of a logical
        consumer); ('exit',) when the padded activation runs straight off
        the network's end (single crop at exit); ('stop',) otherwise.
        """
        j = i + 1
        while j < n:
            kind = layers[j].kind
            if kind == "conv":
                if any(x in referenced for x in range(i, j)):
                    return ("stop",)
                return ("conv", j)
            if kind in ("maxpool", "upsample"):
                j += 1
                continue
            return ("stop",)
        if any(x in referenced for x in range(i, n)):
            return ("stop",)
        return ("exit",)

    # Pass 2: layout decisions along the ``cur`` chain.
    steps: List[NetStep] = []
    carry = Layout(in_channels)             # layout of `cur` entering layer i
    for i, l in enumerate(layers):
        info = infos[i]
        ih, iw, ic = info["in"]
        oh_, ow_, oc = info["out"]
        plan = plans[i]
        if l.kind == "conv":
            spec = info["spec"]
            algo = resolve_algorithm(spec, plan, ih, iw)
            eff_impl = plan.impl if plan is not None else impl
            planned_pallas = plan is not None and eff_impl == "pallas"
            if planned_pallas and snap_rows:
                plan = _snap_row_tile(plan, algo, oh_)
            if planned_pallas:
                in_mult = _in_channel_multiple(plan, algo)
                if carry.pad_c and carry.phys_c % in_mult == 0:
                    in_layout = carry       # producer elided into us
                else:
                    in_layout = Layout(ic, ceil_to(ic, in_mult) - ic)
                out_phys = ceil_to(oc, _out_channel_multiple(plan, algo))
                nxt = next_conv(i)
                elide = nxt[0] == "exit"
                if nxt[0] == "conv":
                    j = nxt[1]
                    pj = plans[j]
                    specj = infos[j]["spec"]
                    if pj is not None and pj.impl == "pallas":
                        algoj = resolve_algorithm(
                            specj, pj, *infos[j]["in"][:2]
                        )
                        elide = out_phys % _in_channel_multiple(pj, algoj) == 0
                out_layout = (
                    Layout(oc, out_phys - oc) if elide else Layout(oc)
                )
            else:
                if not carry.trivial:       # pragma: no cover - by invariant
                    raise AssertionError(
                        "padded activation reached an unplanned conv"
                    )
                in_layout = Layout(ic)
                out_layout = Layout(oc)
            carry = out_layout
        elif l.kind in ("maxpool", "upsample"):
            # Channel-preserving: zero pad channels stay zero (max over an
            # all-zero channel window is 0; repeat copies zeros).
            in_layout = carry
            out_layout = carry
        else:
            if not carry.trivial:           # pragma: no cover - by invariant
                raise AssertionError(
                    f"padded activation reached logical consumer {l.kind!r}"
                )
            in_layout = Layout(ic)
            out_layout = Layout(oc)
            carry = out_layout
        steps.append(
            NetStep(
                index=i,
                layer=l,
                spec=info["spec"],
                plan=plan,
                in_hw=(ih, iw),
                out_hw=(oh_, ow_),
                in_layout=in_layout,
                out_layout=out_layout,
            )
        )
    dtype_name = getattr(dtype, "__name__", None) or getattr(
        dtype, "name", None
    ) or str(dtype)
    return NetworkPlan(
        steps=tuple(steps),
        input_hw=(h, w),
        in_channels=in_channels,
        batch=batch,
        impl=impl,
        dtype_name=dtype_name,
    )


# ---------------------------------------------------------------------------
# Planner-backed entry point with the persistent network cache


def network_key(
    layers: Sequence[Any],
    h: int,
    w: int,
    in_channels: int,
    batch: int,
    planner: Planner,
    dtype: Any = "float32",
) -> str:
    """Cache key for a whole-network entry: a digest of the layer table plus
    every planner field that changes per-layer decisions (chip, dtype, impl,
    mode, VMEM budget, policies) and the batch — batch-keyed plans."""
    digest = hashlib.sha1(repr(tuple(layers)).encode()).hexdigest()[:16]
    dtype_name = getattr(dtype, "__name__", None) or getattr(
        dtype, "name", None
    ) or str(dtype)
    return "|".join(
        [
            "net", digest, f"h{h}w{w}", f"ci{in_channels}", f"b{batch}",
            planner.hw.name, dtype_name, planner.impl, planner.mode,
            f"e{int(planner.fuse_epilogue)}",
            "wf" + ("a" if planner.winograd_fused is None
                    else str(int(planner.winograd_fused))),
            f"v{planner.vmem_budget}",
        ]
    )


def plan_network(
    layers: Sequence[Any],
    h: int,
    w: int,
    planner: Planner,
    in_channels: int = 3,
    batch: int = 1,
    dtype: Any = "float32",
) -> NetworkPlan:
    """Resolve a NetworkPlan through a Planner, warm-cached at network scope.

    Cold: resolves every conv's ConvPlan (per-layer cache or tune), builds
    the layout decisions, and stores the whole record as a v4 "networks"
    cache entry.  Warm: reconstructs the NetworkPlan straight from the
    entry — zero per-layer lookups, zero tunes, the layout decisions exactly
    as first planned.
    """
    layers = tuple(layers)
    key = network_key(layers, h, w, in_channels, batch, planner, dtype)
    entry = planner.network_entry(key)
    if entry is not None:
        try:
            netplan = _netplan_from_entry(layers, entry)
        except (KeyError, ValueError, TypeError, IndexError):
            pass                            # corrupt entry -> replan
        else:
            planner.network_hits += 1       # counted only once validated
            return netplan
    plans: List[Optional[ConvPlan]] = [
        (planner.plan(info["spec"], info["in"][0], info["in"][1],
                      batch=batch, dtype=dtype)
         if l.kind == "conv" else None)
        for l, info in zip(layers, _propagate_shapes(layers, h, w,
                                                     in_channels))
    ]
    netplan = build_network_plan(
        layers, h, w, in_channels=in_channels, batch=batch, plans=plans,
        impl=planner.impl, dtype=dtype,
    )
    planner.put_network_entry(key, _entry_from_netplan(netplan))
    return netplan


def _entry_from_netplan(netplan: NetworkPlan) -> Dict[str, Any]:
    return {
        "input_hw": list(netplan.input_hw),
        "in_channels": netplan.in_channels,
        "batch": netplan.batch,
        "impl": netplan.impl,
        "dtype": netplan.dtype_name,
        "steps": [
            {
                "plan": s.plan.to_json() if s.plan is not None else None,
                "in_hw": list(s.in_hw),
                "out_hw": list(s.out_hw),
                "in_layout": s.in_layout.to_json(),
                "out_layout": s.out_layout.to_json(),
            }
            for s in netplan.steps
        ],
    }


def _netplan_from_entry(
    layers: Tuple[Any, ...], entry: Dict[str, Any]
) -> NetworkPlan:
    recs = entry["steps"]
    if len(recs) != len(layers):
        raise ValueError("network entry does not match the layer table")
    steps = []
    for i, (l, r) in enumerate(zip(layers, recs)):
        spec = None
        if l.kind == "conv":
            in_c = Layout.from_json(r["in_layout"]).c
            spec = _conv_spec(l, in_c)
        steps.append(
            NetStep(
                index=i,
                layer=l,
                spec=spec,
                plan=(ConvPlan.from_json(r["plan"])
                      if r["plan"] is not None else None),
                in_hw=tuple(r["in_hw"]),
                out_hw=tuple(r["out_hw"]),
                in_layout=Layout.from_json(r["in_layout"]),
                out_layout=Layout.from_json(r["out_layout"]),
            )
        )
    return NetworkPlan(
        steps=tuple(steps),
        input_hw=tuple(entry["input_hw"]),
        in_channels=entry["in_channels"],
        batch=entry["batch"],
        impl=entry["impl"],
        dtype_name=entry["dtype"],
    )


# ---------------------------------------------------------------------------
# Pipeline partitioning (layer-pipelined multi-chip execution)
#
# The multi-chip analogue of the paper's per-layer co-design: the network
# partition is *planned* from the same per-layer cost model that picked each
# layer's algorithm and blocks (predict_conv_time totals per stage), not
# guessed from layer counts.  A stage is a contiguous ``steps[start:stop]``
# slice; cuts are restricted to boundaries where the PR-4 layout-elision
# contract closes (trivial out_layout — padded channels never cross a chip
# boundary; the crop/re-pad pair materializes at the stage edge via the
# existing exit-crop/_align_channels machinery) and where no route/shortcut
# ``from_layers`` reference would reach back into an earlier stage.

#: Modeled per-tick schedule overhead (dispatch + ppermute launch), the term
#: that keeps the auto-``n_micro`` chooser from degenerating to "as many
#: microbatches as possible": more microbatches shrink the bubble but pay
#: this fixed cost every tick.  Sized well below a typical stage's modeled
#: seconds (~1e-5 for the paper's networks) so it breaks ties rather than
#: dominating the decision.
TICK_OVERHEAD_S = 2e-6


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A NetworkPlan split into contiguous, cost-balanced pipeline stages.

    ``stage_bounds[s] = (start, stop)`` — stage s runs ``steps[start:stop]``.
    ``stage_seconds[s]`` is the planner-predicted seconds for the stage at
    the plan's full batch (sum of its steps' ``predicted_s``).  ``n_micro``
    is the microbatch count the auto-chooser resolved (the executor may
    override it).
    """

    stage_bounds: Tuple[Tuple[int, int], ...]
    stage_seconds: Tuple[float, ...]
    n_micro: int

    @property
    def n_stages(self) -> int:
        return len(self.stage_bounds)

    def bubble_fraction(self, n_micro: Optional[int] = None) -> float:
        """GPipe fill/drain bubble: (S-1)/(m+S-1) of the schedule's ticks
        run fewer than S active stages."""
        m = self.n_micro if n_micro is None else n_micro
        s = self.n_stages
        return (s - 1) / (m + s - 1)

    def modeled_latency_s(self, n_micro: Optional[int] = None) -> float:
        """Modeled end-to-end seconds for one full batch through the
        pipeline: bubble + per-tick max-stage time (see
        ``modeled_pipeline_latency``)."""
        m = self.n_micro if n_micro is None else n_micro
        return modeled_pipeline_latency(self.stage_seconds, m)

    def to_json(self) -> Dict[str, Any]:
        return {
            "stage_bounds": [list(b) for b in self.stage_bounds],
            "stage_seconds": list(self.stage_seconds),
            "n_micro": self.n_micro,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> PipelinePlan:
        return cls(
            stage_bounds=tuple(
                (int(b[0]), int(b[1])) for b in d["stage_bounds"]
            ),
            stage_seconds=tuple(float(t) for t in d["stage_seconds"]),
            n_micro=int(d["n_micro"]),
        )


def step_seconds(netplan: NetworkPlan) -> Tuple[float, ...]:
    """Per-step planner-predicted seconds (0.0 for unplanned/free layers —
    pools, routes, fc: their cost is noise next to the convs the cost model
    prices, exactly as in plan_report)."""
    return tuple(
        s.plan.predicted_s if s.plan is not None else 0.0
        for s in netplan.steps
    )


def legal_cut_points(netplan: NetworkPlan) -> List[int]:
    """Boundary indices b where the network may be cut into stages
    (cut between ``steps[b-1]`` and ``steps[b]``).

    A cut at b is legal iff (1) ``steps[b-1].out_layout`` is trivial — the
    boundary activation is logically laid out, so no elision chain spans the
    chip edge and the PR-4 padded-channel contract holds entirely within a
    stage; and (2) no layer j >= b references a layer r < b via
    ``from_layers`` (route concat / shortcut add need the producer's output
    resident on the same stage).
    """
    from repro.models.cnn import layer_ref_spans

    n = len(netplan.steps)
    spans = layer_ref_spans([s.layer for s in netplan.steps])
    legal = []
    for b in range(1, n):
        if not netplan.steps[b - 1].out_layout.trivial:
            continue
        if any(r < b <= j for r, j in spans):
            continue
        legal.append(b)
    return legal


def _bounds_seconds(
    per_step: Sequence[float], bounds: Sequence[Tuple[int, int]]
) -> Tuple[float, ...]:
    return tuple(
        float(sum(per_step[a:z])) for a, z in bounds
    )


#: Exact-search budget: partition candidates up to this count are scored
#: directly on the modeled latency; past it the min-max DP approximation
#: takes over.  comb(20, 3) = 1140 for VGG-16 at 4 stages — the paper's
#: networks never leave the exact regime.
_EXACT_SEARCH_LIMIT = 200_000


def partition_network(
    netplan: NetworkPlan, n_stages: int, n_micro: Optional[int] = None
) -> PipelinePlan:
    """Cost-balanced contiguous partition into ``n_stages`` stages.

    Minimizes ``modeled_pipeline_latency`` — the tick-synchronous schedule
    model over the planner's own ``predict_conv_time`` totals — over the
    legal cut set.  At CNN depth the legal cut combinations number in the
    thousands, so the search is exact (each candidate scored at its own
    best microbatch count); a pathologically deep network falls back to
    the classic min-max linear-partition DP, which optimizes the
    steady-state term only.  Raises ValueError when fewer than
    ``n_stages - 1`` legal cuts exist (e.g. an elision chain covering the
    whole net).

    ``n_micro=None`` runs the auto-chooser over divisors of the plan's
    batch (``choose_n_micro``); a fixed ``n_micro`` scores candidates at
    that count.
    """
    import itertools
    import math

    n = len(netplan.steps)
    if not 1 <= n_stages <= n:
        raise ValueError(f"n_stages={n_stages} for a {n}-step network")
    per_step = step_seconds(netplan)
    cuts = legal_cut_points(netplan)
    if len(cuts) < n_stages - 1:
        raise ValueError(
            f"only {len(cuts)} legal cut points for n_stages={n_stages} "
            f"(elision chains / route spans forbid the rest)"
        )

    def finish(bounds: Tuple[Tuple[int, int], ...]) -> PipelinePlan:
        seconds = _bounds_seconds(per_step, bounds)
        m = (choose_n_micro(seconds, netplan.batch) if n_micro is None
             else n_micro)
        return PipelinePlan(
            stage_bounds=bounds, stage_seconds=seconds, n_micro=m
        )

    n_comb = math.comb(len(cuts), n_stages - 1)
    if n_comb <= _EXACT_SEARCH_LIMIT:
        best_plan: Optional[PipelinePlan] = None
        best_key: Tuple[float, float] = (float("inf"), float("inf"))
        for combo in itertools.combinations(cuts, n_stages - 1):
            edges = (0,) + combo + (n,)
            plan = finish(tuple(zip(edges[:-1], edges[1:])))
            # Tie-break on the steady-state max stage: at n_micro=1 the
            # tick sum is partition-independent (one active stage per
            # tick), and the balanced profile is what a larger batch or a
            # microbatch override will want.
            key = (plan.modeled_latency_s(), max(plan.stage_seconds))
            if key < best_key:
                best_plan, best_key = plan, key
        assert best_plan is not None
        return best_plan

    # DP fallback: minimize the max stage (the steady-state tick) over
    # boundary candidates.  best[(k, e)] = (max stage seconds, prev end).
    prefix = [0.0]
    for t in per_step:
        prefix.append(prefix[-1] + t)

    def seg(a: int, z: int) -> float:
        return prefix[z] - prefix[a]

    ends = cuts + [n]
    best: Dict[Tuple[int, int], Tuple[float, int]] = {(0, 0): (0.0, -1)}
    for k in range(1, n_stages + 1):
        allowed = ends if k < n_stages else [n]
        for e in allowed:
            cand: Optional[Tuple[float, int]] = None
            for (pk, pe), (pmax, _) in best.items():
                if pk != k - 1 or pe >= e:
                    continue
                m = max(pmax, seg(pe, e))
                if cand is None or m < cand[0]:
                    cand = (m, pe)
            if cand is not None:
                best[(k, e)] = cand
    if (n_stages, n) not in best:
        raise ValueError(
            f"no legal {n_stages}-stage partition (cut set {cuts})"
        )
    bounds_rev = []
    e = n
    for k in range(n_stages, 0, -1):
        _, pe = best[(k, e)]
        bounds_rev.append((pe, e))
        e = pe
    return finish(tuple(reversed(bounds_rev)))


def equal_count_partition(
    netplan: NetworkPlan, n_stages: int, n_micro: Optional[int] = None
) -> PipelinePlan:
    """The naive strawman: equal *layer-count* stages, costs ignored.

    Each cut targets ``round(s * n / n_stages)`` and snaps to the nearest
    legal cut point (so the partition is executable — a hand-rolled
    splitter still cannot cut through an elision chain or a route span),
    but per-layer costs are never consulted.  This is the baseline the
    cost-balanced partition must beat on modeled latency.
    """
    n = len(netplan.steps)
    if not 1 <= n_stages <= n:
        raise ValueError(f"n_stages={n_stages} for a {n}-step network")
    legal = legal_cut_points(netplan)
    if len(legal) < n_stages - 1:
        raise ValueError(
            f"only {len(legal)} legal cut points for n_stages={n_stages}"
        )
    cuts: List[int] = []
    for s in range(1, n_stages):
        target = round(s * n / n_stages)
        avail = [b for b in legal if b not in cuts and b > (cuts[-1] if cuts
                                                           else 0)]
        # Keep enough headroom for the remaining cuts to stay increasing.
        remaining = n_stages - 1 - s
        avail = avail[: len(avail) - remaining] if remaining else avail
        if not avail:
            raise ValueError("cannot place equal-count cuts legally")
        cuts.append(min(avail, key=lambda b: (abs(b - target), b)))
    edges = [0] + cuts + [n]
    bounds = tuple(zip(edges[:-1], edges[1:]))
    seconds = _bounds_seconds(step_seconds(netplan), bounds)
    if n_micro is None:
        n_micro = choose_n_micro(seconds, netplan.batch)
    return PipelinePlan(
        stage_bounds=bounds, stage_seconds=seconds, n_micro=n_micro
    )


def modeled_pipeline_latency(
    stage_seconds: Sequence[float],
    n_micro: int,
    tick_overhead_s: float = TICK_OVERHEAD_S,
) -> float:
    """Modeled seconds for one batch through the GPipe schedule.

    The executor's schedule is tick-synchronous — each of the
    ``n_micro + n_stages - 1`` ticks ends in a collective (ppermute), so a
    tick lasts as long as the slowest *active* stage's per-microbatch
    compute (stage seconds are predicted at full batch and scale down
    linearly with the microbatch split):

        latency(m) = sum_t max{T_s / m : stage s active at tick t}
                     + (m + S - 1) * overhead

    In steady state every tick is gated by the global max stage (the
    classic bubble identity); during fill/drain only a prefix/suffix of
    stages is active, which is why balancing the *whole* stage profile —
    not just its max — shows up in the model.  The fixed per-tick overhead
    penalizes over-splitting.
    """
    s = len(stage_seconds)
    per_mb = [t / n_micro for t in stage_seconds]
    total = 0.0
    for t in range(n_micro + s - 1):
        active = [per_mb[i] for i in range(s) if t >= i and t - i < n_micro]
        if active:
            total += max(active)
    return total + (n_micro + s - 1) * tick_overhead_s


def choose_n_micro(
    stage_seconds: Sequence[float],
    batch: int,
    tick_overhead_s: float = TICK_OVERHEAD_S,
) -> int:
    """The microbatch count minimizing modeled latency.

    Candidates are the divisors of ``batch`` (microbatches must tile the
    batch exactly — the executor reshapes to (m, batch//m, ...)); ties break
    to the smaller count (less overhead exposure for the same model).
    """
    if batch < 1:
        raise ValueError(f"batch={batch}")
    best_m, best_t = 1, float("inf")
    for m in range(1, batch + 1):
        if batch % m:
            continue
        t = modeled_pipeline_latency(stage_seconds, m, tick_overhead_s)
        if t < best_t:
            best_m, best_t = m, t
    return best_m


def pipeline_key(
    layers: Sequence[Any],
    h: int,
    w: int,
    in_channels: int,
    batch: int,
    n_stages: int,
    planner: Planner,
    dtype: Any = "float32",
) -> str:
    """Cache key for a stage-partition entry: the network digest key (which
    already folds in chip/dtype/impl/policies/batch) plus the stage count."""
    return (
        network_key(layers, h, w, in_channels, batch, planner, dtype)
        + f"|stages{n_stages}"
    )


def plan_pipeline(
    layers: Sequence[Any],
    h: int,
    w: int,
    planner: Planner,
    n_stages: int,
    in_channels: int = 3,
    batch: int = 1,
    dtype: Any = "float32",
    netplan: Optional[NetworkPlan] = None,
) -> PipelinePlan:
    """Resolve a PipelinePlan through a Planner, warm-cached at v6 scope.

    Cold: partitions the (possibly freshly planned) NetworkPlan and stores
    the record as a "pipelines" cache entry keyed by (network digest,
    n_stages, chip, dtype).  Warm: reconstructs the PipelinePlan straight
    from the entry — zero re-partitions (``planner.pipeline_hits``).
    """
    layers = tuple(layers)
    if netplan is None:
        netplan = plan_network(
            layers, h, w, planner, in_channels=in_channels, batch=batch,
            dtype=dtype,
        )
    key = pipeline_key(
        layers, h, w, in_channels, batch, n_stages, planner, dtype
    )
    entry = planner.pipeline_entry(key)
    if entry is not None:
        try:
            pipeplan = PipelinePlan.from_json(entry)
            _validate_pipeline_bounds(pipeplan, len(netplan.steps), n_stages)
        except (KeyError, ValueError, TypeError, IndexError):
            pass                            # corrupt entry -> repartition
        else:
            planner.pipeline_hits += 1      # counted only once validated
            return pipeplan
    pipeplan = partition_network(netplan, n_stages)
    planner.put_pipeline_entry(key, pipeplan.to_json())
    return pipeplan


def _validate_pipeline_bounds(
    pipeplan: PipelinePlan, n_steps: int, n_stages: int
) -> None:
    """Raise unless the bounds are a contiguous cover of [0, n_steps)."""
    bounds = pipeplan.stage_bounds
    if len(bounds) != n_stages:
        raise ValueError(f"{len(bounds)} stages, wanted {n_stages}")
    if bounds[0][0] != 0 or bounds[-1][1] != n_steps:
        raise ValueError(f"bounds {bounds} do not cover [0, {n_steps})")
    for (a0, z0), (a1, _) in zip(bounds, bounds[1:]):
        if z0 != a1 or a0 >= z0:
            raise ValueError(f"non-contiguous bounds {bounds}")
    if bounds[-1][0] >= bounds[-1][1]:
        raise ValueError(f"empty final stage in {bounds}")
    if pipeplan.n_micro < 1:
        raise ValueError(f"n_micro={pipeplan.n_micro}")
    if len(pipeplan.stage_seconds) != n_stages:
        raise ValueError("stage_seconds length mismatch")


# ---------------------------------------------------------------------------
# Parameter preparation (offline: folding, padding, weight pre-transform)


def pretransform_flags(
    netplan: NetworkPlan, pretransform: bool = True
) -> Tuple[bool, ...]:
    """Per-step "weights carry the offline Winograd transform" flags.

    Exactly the layers ``prepare_net_params(pretransform=True)`` transforms:
    conv steps whose resolved algorithm is Winograd.  The flag travels
    *explicitly* from preparation to execution (``run_network`` /
    ``NetworkExecutor`` / the api facade) — it is never sniffed from weight
    shapes, because a raw kh == 8 kernel is (8, 8, C, O) exactly like a
    pre-transformed 3x3 one.
    """
    if not pretransform:
        return (False,) * len(netplan.steps)
    return tuple(
        s.layer.kind == "conv"
        and resolve_algorithm(s.spec, s.plan, *s.in_hw)
        is ConvAlgorithm.WINOGRAD
        for s in netplan.steps
    )


def prepare_net_params(
    netplan: NetworkPlan,
    params: Sequence[Dict],
    pretransform: bool = False,
    calibration: Optional[jnp.ndarray] = None,
) -> List[Dict]:
    """Offline parameter preparation for a NetworkPlan.

    Folds inference batchnorm into conv weights + bias, pads every conv's
    weights/bias to the step's physical channel layouts (so no weight pads
    appear at layer boundaries in the jitted forward), and — with
    ``pretransform`` — applies the offline Winograd weight transform
    (paper §VII.A excludes it from timing for the same reason).  The layers
    transformed are exactly ``pretransform_flags(netplan, pretransform)``;
    pass those flags to ``run_network`` so execution routes the transformed
    weights explicitly.

    Under an int8 network plan the steps whose ConvPlan resolved to
    ``dtype == 'int8'`` are additionally quantized offline (core/quant.py):
    an fp32 oracle walk over ``calibration`` (a sample input batch; a
    deterministic synthetic batch when None) yields per-input-channel
    activation scales, which are folded into the weights before
    per-output-channel int8 weight quantization.  Such a step's prepared
    entry carries ``w`` (int8), ``b`` (fp32), ``w_scale`` (the fused dequant
    row) and ``x_scale`` (the entry quantization scales, padded with ones so
    zero-padded channels quantize to 0 and the layout-elision invariant
    act(0 * scale + 0) = 0 survives quantization).
    """
    from repro.models.cnn import fold_batchnorm

    flags = pretransform_flags(netplan, pretransform)
    params = fold_batchnorm(params, [s.layer for s in netplan.steps])
    int8_steps = {
        s.index
        for s in netplan.steps
        if s.layer.kind == "conv" and s.plan is not None
        and s.plan.dtype == "int8"
    }
    act_scales: Dict[int, jnp.ndarray] = {}
    if int8_steps:
        from repro.core.quant import (
            calibrate_activation_scales,
            default_calibration_batch,
        )

        if calibration is None:
            calibration = default_calibration_batch(
                *netplan.input_hw, netplan.in_channels
            )
        act_scales = calibrate_activation_scales(netplan, params, calibration)
    out: List[Dict] = []
    for s, p, pre in zip(netplan.steps, params, flags):
        if s.layer.kind != "conv":
            out.append(p)
            continue
        w, b = p["w"], p["b"]
        if s.index in int8_steps:
            from repro.core.quant import quantize_conv_weights

            assert not pre, "int8 steps never carry the Winograd transform"
            x_scale = act_scales[s.index]
            w, w_scale = quantize_conv_weights(w, x_scale)
            cin_pad = s.in_layout.phys_c - w.shape[2]
            o_pad = s.out_layout.phys_c - w.shape[3]
            if cin_pad or o_pad:
                w = jnp.pad(w, ((0, 0), (0, 0), (0, cin_pad), (0, o_pad)))
                b = jnp.pad(b, (0, o_pad))
                w_scale = jnp.pad(w_scale, (0, o_pad))
            if cin_pad:
                # Ones, not zeros: the entry quantization divides by these.
                x_scale = jnp.pad(x_scale, (0, cin_pad), constant_values=1.0)
            out.append({"w": w, "b": b, "w_scale": w_scale,
                        "x_scale": x_scale})
            continue
        cin_pad = s.in_layout.phys_c - w.shape[2]
        o_pad = s.out_layout.phys_c - w.shape[3]
        if cin_pad or o_pad:
            w = jnp.pad(w, ((0, 0), (0, 0), (0, cin_pad), (0, o_pad)))
            b = jnp.pad(b, (0, o_pad))
        if pre:
            from repro.core.winograd import transform_weights

            w = transform_weights(w, w.dtype)           # (8, 8, Cp, Op)
        out.append({"w": w, "b": b})
    return out


# ---------------------------------------------------------------------------
# Execution


def _align_channels(x: jnp.ndarray, want_phys: int) -> jnp.ndarray:
    have = x.shape[-1]
    if have == want_phys:
        return x
    if have < want_phys:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, want_phys - have)]
        return jnp.pad(x, pad)
    return x[..., :want_phys]


def run_network(
    netplan: NetworkPlan,
    params: Sequence[Dict],
    x: jnp.ndarray,
    interpret: Optional[bool] = None,
    pretransformed: Optional[Sequence[bool]] = None,
    start: int = 0,
    stop: Optional[int] = None,
) -> jnp.ndarray:
    """The planned whole-network forward on prepared params.

    Pads once at entry (the first conv's input layout), flows block-padded
    activations across every elided boundary, crops once at exit.  Pure
    function of (params, x) given the static NetworkPlan — jit it, or let
    NetworkExecutor do so.

    ``pretransformed`` is the per-step flag tuple from
    ``pretransform_flags`` saying which conv weights already carry the
    offline Winograd transform.  ``None`` is accepted for legacy callers
    and falls back to a *guarded* shape check (8x8 leading dims AND a 3x3
    spec — a raw kh == 8 kernel is never misread as transformed); new code
    should always pass the explicit flags.

    ``start``/``stop`` run the ``steps[start:stop]`` slice only — one
    pipeline stage.  ``params`` is then the slice-aligned parameter list
    (``params[j - start]`` for layer j) while ``pretransformed`` stays
    full-network length (flag lookup is by absolute index).  Legal slices
    begin at a stage boundary from ``legal_cut_points``: the incoming
    activation is logically laid out (trivial layout — the partitioner
    forbids cuts inside an elision chain) and no ``from_layers`` reference
    reaches back before ``start``.  The exit crop runs only when the slice
    includes the final step; interior stages hand their boundary activation
    off as produced.
    """
    from repro.core.conv2d import conv2d

    n_steps = len(netplan.steps)
    stop = n_steps if stop is None else stop
    assert 0 <= start <= stop <= n_steps, (start, stop, n_steps)
    outputs: List[jnp.ndarray] = []
    cur = x
    for s in netplan.steps[start:stop]:
        l = s.layer
        if l.kind == "conv":
            p = params[s.index - start]
            cur = _align_channels(cur, s.in_layout.phys_c)
            quantized = "w_scale" in p
            if quantized:
                # int8 step (prepare_net_params quantized it offline): the
                # activation re-quantizes at entry with the static
                # calibrated scales, the kernel accumulates int8 x int8 in
                # int32, and the fused epilogue dequantizes via w_scale —
                # inter-layer activations stay fp32.
                from repro.core.quant import quantize_activation

                cur = quantize_activation(cur, p["x_scale"])
                epi = Epilogue(bias=p["b"], activation=l.activation,
                               scale=p["w_scale"])
            else:
                epi = Epilogue(bias=p["b"], activation=l.activation)
            eff_impl = s.plan.impl if s.plan is not None else netplan.impl
            if pretransformed is not None:
                pre = bool(pretransformed[s.index])
            else:                           # legacy guard, not a sniff: a
                pre = (                     # 3x3 spec can't have raw (8,8)
                    s.spec.kernel_size == (3, 3)
                    and p["w"].ndim == 4
                    and p["w"].shape[0] == 8
                    and p["w"].shape[1] == 8
                )
            if s.plan is not None and eff_impl == "pallas":
                # The executor owns the boundary: channels arrive block-
                # padded per in_layout, the crop defers per out_layout.
                cur = conv2d(
                    cur, p["w"], s.spec, impl=eff_impl, interpret=interpret,
                    plan=s.plan, epilogue=epi,
                    in_layout=s.in_layout, out_layout=s.out_layout,
                    pretransformed=pre,
                )
            elif quantized:
                # Pure-jnp int8 reference: the same integer products in
                # fp32 (exact for int8 operands; accumulated rounding is
                # orders below the quantization noise), dequantized by the
                # shared epilogue.
                cur = conv2d(
                    cur.astype(jnp.float32), p["w"].astype(jnp.float32),
                    s.spec, impl=eff_impl, interpret=interpret,
                    plan=s.plan, epilogue=epi, pretransformed=pre,
                )
            else:
                cur = conv2d(
                    cur, p["w"], s.spec, impl=eff_impl, interpret=interpret,
                    plan=s.plan, epilogue=epi, pretransformed=pre,
                )
        elif l.kind == "maxpool":
            cur = jax.lax.reduce_window(
                cur, -jnp.inf, jax.lax.max,
                (1, l.size, l.size, 1),
                (1, l.stride, l.stride, 1), "SAME",
            )
        elif l.kind == "avgpool":
            cur = cur.mean(axis=(1, 2))
        elif l.kind == "upsample":
            cur = jnp.repeat(jnp.repeat(cur, l.size, axis=1), l.size, axis=2)
        elif l.kind == "shortcut":
            cur = cur + outputs[l.from_layers[0] - start]
        elif l.kind == "route":
            cur = jnp.concatenate(
                [outputs[j - start] for j in l.from_layers], axis=-1
            )
        elif l.kind == "fc":
            p = params[s.index - start]
            if cur.ndim == 4:
                cur = cur.mean(axis=(1, 2))
            cur = apply_activation(cur @ p["w"] + p["b"], l.activation)
        outputs.append(cur)
    exit_layout = netplan.exit_layout
    if stop == n_steps and exit_layout.pad_c:
        cur = cur[..., :exit_layout.c]      # the single crop at network exit
    return cur


def expected_channel_ops(netplan: NetworkPlan) -> List[Dict[str, Any]]:
    """The channel-axis pads/crops ``run_network`` will emit, predicted
    statically from the plan.

    Mirrors the executor walk: the entry/per-conv ``_align_channels`` when
    the carried physical channel count differs from the step's ``in_layout``,
    the kernel wrappers' deferred channel crop when the kernel's out-channel
    grid (``ceil_to(phys, block)``) overshoots the layout's keep count, the
    direct GEMM's K-axis pad when the incoming channels don't divide ``bk``,
    and the single exit crop.  ``repro.analysis``'s elision pass census
    (taint-tracked pad/slice ops on the traced jaxpr's minor axis) must
    match this list exactly — any extra op is executor drift from the plan,
    any missing op means the plan promised movement that can't happen.

    Row-tile tails, tile-count alignment and spatial padding are intra-layer
    movement on non-minor axes and deliberately outside this contract.
    """
    ops: List[Dict[str, Any]] = []
    outputs_phys: List[int] = []
    cur_phys = netplan.in_channels
    for s in netplan.steps:
        l = s.layer
        if l.kind == "conv":
            planned = s.plan is not None and (
                s.plan.impl if s.plan is not None else netplan.impl
            ) == "pallas"
            if planned:
                want = s.in_layout.phys_c
                if cur_phys != want:
                    ops.append({
                        "step": s.index,
                        "kind": "pad" if cur_phys < want else "crop",
                    })
                algo = resolve_algorithm(s.spec, s.plan, *s.in_hw)
                o_phys = s.out_layout.phys_c
                o_keep = (
                    s.out_layout.phys_c if s.out_layout.pad_c
                    else s.spec.out_channels
                )
                if algo is ConvAlgorithm.DIRECT:
                    bm, bn, bk = s.plan.kernel_blocks
                    if ceil_to(want, bk) != want:
                        ops.append({"step": s.index, "kind": "pad"})
                    emitted = ceil_to(o_phys, bn)
                else:
                    emitted = ceil_to(o_phys, s.plan.kernel_blocks[2])
                if emitted != o_keep:
                    ops.append({"step": s.index, "kind": "crop"})
                cur_phys = o_keep
            else:
                cur_phys = s.spec.out_channels
        elif l.kind == "route":
            cur_phys = sum(outputs_phys[j] for j in l.from_layers)
        elif l.kind == "fc":
            cur_phys = l.out_channels
        # maxpool / upsample / shortcut / avgpool preserve channels
        outputs_phys.append(cur_phys)
    if netplan.exit_layout.pad_c:
        ops.append({"step": len(netplan.steps) - 1, "kind": "crop"})
    return ops


class NetworkExecutor:
    """Jitted whole-network inference over a NetworkPlan.

    Prepares parameters offline (fold + pad + optional Winograd
    pre-transform), compiles one forward for the plan's batch shape, and —
    when more than one device is visible and the batch divides — runs
    data-parallel over a 1-D device mesh on the batch axis via shard_map
    (params replicated, activations batch-sharded; single-device fallback
    is a plain jit).
    """

    def __init__(
        self,
        netplan: NetworkPlan,
        params: Sequence[Dict],
        interpret: Optional[bool] = None,
        devices: Optional[Sequence[Any]] = None,
        pretransform: bool = True,
        prepared: bool = False,
        calibration: Optional[jnp.ndarray] = None,
    ):
        self.netplan = netplan
        self.params = (
            list(params) if prepared
            else prepare_net_params(netplan, params, pretransform=pretransform,
                                    calibration=calibration)
        )
        # The explicit flag contract: which conv weights carry the offline
        # Winograd transform.  With ``prepared=True`` the caller vouches the
        # params were prepared with the same ``pretransform`` policy — and
        # because the old shape sniff tolerated a mismatch here, we verify
        # the claim against the weights instead of failing deep in a kernel.
        self.pretransformed = pretransform_flags(netplan, pretransform)
        if prepared:
            for s, p, pre in zip(netplan.steps, self.params,
                                 self.pretransformed):
                if s.layer.kind != "conv":
                    continue
                looks_transformed = (
                    s.spec.kernel_size == (3, 3) and p["w"].shape[0] == 8
                )
                if pre != looks_transformed:
                    raise ValueError(
                        f"step {s.index}: prepared params "
                        f"{'lack' if pre else 'carry'} the offline Winograd "
                        f"weight transform (w {tuple(p['w'].shape)}) but the "
                        f"executor was built with pretransform={pretransform}"
                        f" — pass the same pretransform= that "
                        f"prepare_net_params ran with"
                    )
        if devices is None:
            devices = jax.devices()
        self.mesh = None

        def fwd(prms, xx):
            return run_network(netplan, prms, xx, interpret=interpret,
                               pretransformed=self.pretransformed)

        if len(devices) > 1 and netplan.batch % len(devices) == 0:
            import numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh, PartitionSpec as P

            self.mesh = Mesh(np.array(devices), ("batch",))
            fwd = shard_map(
                fwd, mesh=self.mesh,
                in_specs=(P(), P("batch")), out_specs=P("batch"),
                check_rep=False,
            )
        self._fn = jax.jit(fwd)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        b, h, w = x.shape[0], x.shape[1], x.shape[2]
        assert (h, w) == self.netplan.input_hw and b == self.netplan.batch, (
            f"executor planned for batch {self.netplan.batch} at "
            f"{self.netplan.input_hw}, got {x.shape}"
        )
        return self._fn(self.params, x)
