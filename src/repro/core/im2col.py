"""im2col and conv-as-GEMM, in pure JAX (NHWC layout).

The paper's im2col+GEMM pipeline (§IV.A): lower the convolution to a GEMM
with A = weights (M x K), B = im2col(input) (K x N), C = output (M x N),
M = out_channels, K = kh*kw*in_channels, N = oh*ow.

On TPU we keep everything channels-last so the innermost (lane) axis is the
channel axis — the same layout decision the paper makes when it packs
channels along the vector (§IV.B).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.conv_spec import ConvSpec, Epilogue, apply_epilogue


def im2col(
    x: jnp.ndarray,
    kernel_size: Tuple[int, int],
    stride: Tuple[int, int] = (1, 1),
    padding: Tuple[int, int] = (0, 0),
    dilation: Tuple[int, int] = (1, 1),
) -> jnp.ndarray:
    """Extract convolution patches.

    Args:
      x: (B, H, W, C) input.
    Returns:
      (B, OH, OW, kh*kw*C) patches, K ordered as (kh, kw, C) to match a
      weight reshaped from (kh, kw, C, O).
    """
    b, h, w, c = x.shape
    kh, kw = kernel_size
    sh, sw = stride
    ph, pw = padding
    dh, dw = dilation
    eff_kh = (kh - 1) * dh + 1
    eff_kw = (kw - 1) * dw + 1
    oh = (h + 2 * ph - eff_kh) // sh + 1
    ow = (w + 2 * pw - eff_kw) // sw + 1

    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))

    # Row/col gather indices; broadcasting builds the (OH, OW, kh, kw) grid.
    rows = (jnp.arange(oh) * sh)[:, None] + (jnp.arange(kh) * dh)[None, :]  # (OH, kh)
    cols = (jnp.arange(ow) * sw)[:, None] + (jnp.arange(kw) * dw)[None, :]  # (OW, kw)
    # patches: (B, OH, OW, kh, kw, C)
    patches = x[:, rows[:, None, :, None], cols[None, :, None, :], :]
    return patches.reshape(b, oh, ow, kh * kw * c)


def conv2d_im2col(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    epilogue: Optional[Epilogue] = None,
) -> jnp.ndarray:
    """Convolution via im2col + GEMM, with an optional fused epilogue.

    Args:
      x: (B, H, W, C); w: (kh, kw, C, O).
    Returns:
      (B, OH, OW, O).
    """
    b, h, _w, c = x.shape
    kh, kw, wc, o = w.shape
    assert (kh, kw) == spec.kernel_size and wc == c and o == spec.out_channels
    oh, ow = spec.out_hw(h, _w)
    patches = im2col(x, spec.kernel_size, spec.stride, spec.padding, spec.dilation)
    k = kh * kw * c
    # (B*OH*OW, K) @ (K, O): N-major output, channels-last (lane axis = O).
    out = patches.reshape(b * oh * ow, k) @ w.reshape(k, o)
    return apply_epilogue(out, epilogue).reshape(b, oh, ow, o)


def conv2d_direct_1x1(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    epilogue: Optional[Epilogue] = None,
) -> jnp.ndarray:
    """1x1 convolution as a plain GEMM (the paper's Direct path for 1x1)."""
    b, h, ww, c = x.shape
    assert spec.kernel_size == (1, 1)
    sh, sw = spec.stride
    ph, pw = spec.padding
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
        h, ww = h + 2 * ph, ww + 2 * pw
    if (sh, sw) != (1, 1):
        x = x[:, ::sh, ::sw, :]
    oh, ow = x.shape[1], x.shape[2]
    out = x.reshape(b * oh * ow, c) @ w.reshape(c, spec.out_channels)
    return apply_epilogue(out, epilogue).reshape(b, oh, ow, spec.out_channels)
