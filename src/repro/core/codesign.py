"""Hardware/software co-design sweeps (the paper's §V/§VI study, TPU-ized).

Sweeps the three TPU analogues of the paper's knobs against the optimized
kernels, using the analytical model in vmem_model.py:

  vector length  ->  block width bn (lane-dim elements per block)
  L2 cache size  ->  VMEM budget available for blocking
  vector lanes   ->  on-chip parallel compute (``lanes`` peak multiplier)

Outputs feed benchmarks/table2_blocksizes.py, table3_veclen.py and
fig_cache_sweep.py, which mirror Table II / Fig 6 / Figs 7-8 of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.conv_spec import ConvSpec, arithmetic_intensity
from repro.core.vmem_model import (
    BlockConfig,
    GemmEstimate,
    GemmShape,
    autotune_gemm,
    predict_gemm,
)
from repro.hw import V5E, ChipSpec

MB = 1024 * 1024

# Default sweep ranges: VMEM budgets stand in for the 1MB..256MB L2 sweep;
# block widths stand in for 512-bit..16384-bit vectors (16..512 fp32 elems,
# scaled x8 to TPU lane granularity).
VMEM_BUDGETS = (1 * MB, 2 * MB, 4 * MB, 8 * MB, 16 * MB, 32 * MB, 64 * MB)
BLOCK_WIDTHS = (128, 256, 512, 1024, 2048)
LANES = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    vmem_budget: int
    bn: int
    lanes: int
    block: BlockConfig
    estimate: GemmEstimate


def sweep_vector_length(
    shape: GemmShape,
    vmem_budget: int = 16 * MB,
    lanes: int = 1,
    widths: Sequence[int] = BLOCK_WIDTHS,
    hw: ChipSpec = V5E,
    dtype_bytes: int = 4,
) -> List[SweepPoint]:
    """Fig 6 analogue: fixed cache (VMEM), sweep the vector (lane) width."""
    points = []
    for bn in widths:
        best: Tuple[Optional[BlockConfig], Optional[GemmEstimate]] = (None, None)
        for bm in (8, 16, 32, 64, 128, 256):
            for bk in (128, 256, 512, 1024, 2048):
                cfg = BlockConfig(bm, bn, bk)
                if cfg.vmem_bytes(dtype_bytes) > vmem_budget:
                    continue
                est = predict_gemm(shape, cfg, hw, dtype_bytes, lanes)
                if best[1] is None or est.total_s < best[1].total_s:
                    best = (cfg, est)
        if best[0] is not None:
            points.append(SweepPoint(vmem_budget, bn, lanes, best[0], best[1]))
    return points


def sweep_cache_size(
    shape: GemmShape,
    budgets: Sequence[int] = VMEM_BUDGETS,
    lanes: int = 1,
    hw: ChipSpec = V5E,
    dtype_bytes: int = 4,
) -> Dict[int, List[SweepPoint]]:
    """Fig 7/8 analogue: per VMEM budget, the best config at each width."""
    return {
        budget: sweep_vector_length(shape, budget, lanes, hw=hw, dtype_bytes=dtype_bytes)
        for budget in budgets
    }


def sweep_lanes(
    shape: GemmShape,
    vmem_budget: int = 16 * MB,
    lanes: Sequence[int] = LANES,
    hw: ChipSpec = V5E,
    dtype_bytes: int = 4,
) -> List[SweepPoint]:
    """§VI.B.c analogue: on-chip parallelism vs block width trade-off."""
    out = []
    for ln in lanes:
        cfg, est = autotune_gemm(shape, hw, vmem_budget, dtype_bytes, ln)
        out.append(SweepPoint(vmem_budget, cfg.bn, ln, cfg, est))
    return out


def predict_conv_time(
    spec: ConvSpec,
    h: int,
    w: int,
    algorithm,
    hw: ChipSpec = V5E,
    dtype_bytes: int = 4,
    batch: int = 1,
    winograd_fused: bool = True,
) -> float:
    """Modeled seconds for one conv layer executed with ``algorithm``.

    Roofline time max(compute, HBM traffic) at this layer's dims.  GEMM-family
    algorithms (direct / im2col) move the patch matrix, the weights and the
    output; Winograd moves the tile/transform pipeline — by default the
    single-pass megakernel's traffic (transforms and M accumulation fused in
    VMEM, ``winograd_fused=True``), or the 3-pass pipeline's traffic with the
    V/M HBM round-trips (``winograd_fused=False``).  Activation terms scale
    with ``batch``; weight terms do not.

    Itemsize-aware: ``dtype_bytes`` prices the operand traffic and picks the
    fp32/bf16/int8 MXU peak; the output write is priced separately because
    the int8 kernels dequantize in the epilogue and write fp32.
    """
    from repro.core.conv_spec import ConvAlgorithm
    from repro.core.vmem_model import im2col_gemm_traffic_bytes, peak_flops
    from repro.core.winograd import winograd_flops

    oh, ow = spec.out_hw(h, w)
    cin, cout = spec.in_channels, spec.out_channels
    kh, kw = spec.kernel_size
    peak = peak_flops(hw, dtype_bytes)
    bw = hw.hbm_bandwidth
    if algorithm is ConvAlgorithm.WINOGRAD:
        from repro.core.vmem_model import winograd_traffic_bytes

        fl = winograd_flops(oh, ow, cin, cout)
        wino_bytes = winograd_traffic_bytes(
            oh, ow, cin, cout, batch, dtype_bytes, fused=winograd_fused
        )
        return max(batch * fl["winograd_flops"] / peak, wino_bytes / bw)
    # direct-1x1 and im2col share the GEMM roofline; direct just has K = Cin.
    gemm_bytes = im2col_gemm_traffic_bytes(
        oh, ow, cin, cout, kh, kw, batch=batch, dtype_bytes=dtype_bytes
    )
    flops = 2.0 * batch * oh * ow * kh * kw * cin * cout
    return max(flops / peak, gemm_bytes / bw)


def select_algorithm_by_cost(
    spec: ConvSpec, h: int, w: int, hw: ChipSpec = V5E, dtype_bytes: int = 4,
    winograd_fused: bool = True, batch: int = 1,
):
    """Roofline-model-driven per-layer algorithm choice (beyond paper).

    The paper selects Winograd for every 3x3/stride-1 layer.  On v5e
    (critical AI ~120 fp32) that rule over-triggers: Winograd's 64/9x
    weight-traffic inflation loses for deep low-resolution layers.  This
    selector compares modeled times of im2col+GEMM vs the Winograd
    realization that would actually run (``winograd_fused``: the single-pass
    megakernel by default, the 3-pass pipeline when a planner forces it)
    and picks the winner.
    """
    from repro.core.conv_spec import ConvAlgorithm, select_algorithm

    base = select_algorithm(dataclasses.replace(spec, algorithm=ConvAlgorithm.AUTO))
    if base is not ConvAlgorithm.WINOGRAD:
        return base
    t_wino = predict_conv_time(
        spec, h, w, ConvAlgorithm.WINOGRAD, hw, dtype_bytes, batch,
        winograd_fused=winograd_fused,
    )
    t_im2col = predict_conv_time(
        spec, h, w, ConvAlgorithm.IM2COL_GEMM, hw, dtype_bytes, batch
    )
    return ConvAlgorithm.WINOGRAD if t_wino < t_im2col else ConvAlgorithm.IM2COL_GEMM


def layer_roofline(
    spec: ConvSpec, h: int, w: int, hw: ChipSpec = V5E, dtype_bytes: int = 4
) -> Dict[str, float]:
    """Table IV analogue: AI + % of single-chip peak for one conv layer."""
    m, n, k = spec.gemm_dims(h, w)
    ai = arithmetic_intensity(m, n, k, dtype_bytes)
    peak = hw.peak_flops_fp32 if dtype_bytes == 4 else hw.peak_flops_bf16
    ai_critical = peak / hw.hbm_bandwidth
    # Attainable fraction under the roofline, degraded by MXU padding waste.
    _, est = autotune_gemm(GemmShape(m, n, k), hw, dtype_bytes=dtype_bytes)
    attainable = min(1.0, ai / ai_critical)
    sustained = est.compute_s / est.total_s * est.mxu_utilization
    return {
        "M": m,
        "N": n,
        "K": k,
        "AI": ai,
        "ai_critical": ai_critical,
        "roofline_frac": attainable,
        "pct_of_peak": 100.0 * min(attainable, sustained),
    }
