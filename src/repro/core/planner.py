"""Per-layer convolution planner with a persistent autotuning cache.

The paper's central finding is that the convolution algorithm *and* its
blocking are per-layer, per-chip decisions (§VII + the follow-up co-design
paper): the same 3x3 layer wants Winograd at high resolution and im2col+GEMM
deep in the network, and the best BLIS-style block sizes shift with the
layer's GEMM dims and the chip's cache budget.  The repo's ingredients — the
selector (conv_spec/codesign), the VMEM cost model (vmem_model) and the
Pallas kernels — used to re-derive that decision on every ``conv2d`` call.

This module makes the co-design decision **once per (layer, shape, chip,
dtype)** and caches it:

  ConvPlan   frozen record of one decision: algorithm, impl, the GEMM-level
             ``BlockConfig`` the autotuner chose, the kernel-level block
             tuple the Pallas wrappers consume, and the predicted (or
             measured) seconds.
  Planner    resolves plans.  ``mode='cost'`` drives the vmem_model
             autotuner + roofline (fast, deterministic, no hardware);
             ``mode='measure'`` times candidate algorithms on the current
             backend and keeps the winner (the paper's empirical per-layer
             selection, §VII.A).  Plans persist in a JSON cache keyed by
             (spec, input shape, chip, dtype, impl, mode, VMEM budget) so a
             warm process — or the next process — re-tunes nothing.

Every downstream consumer threads through here: ``core.conv2d`` accepts a
plan (or a planner to look one up), ``kernels/conv_ops`` forwards the plan's
block sizes to the Pallas kernels, and the api facade (``repro.compile``)
resolves whole networks ahead of time (see benchmarks/e2e_cnn.py).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import tempfile
import warnings
from typing import Any, Dict, List, Optional, Tuple

from repro.core.conv_spec import ConvAlgorithm, ConvSpec, select_algorithm
from repro.core.vmem_model import BlockConfig, GemmShape, autotune_gemm
from repro.hw import V5E, ChipSpec
from repro.util import ceil_to

# v6 adds the "pipelines" section — stage partitions for layer-pipelined
# multi-chip execution (written by core/netplan.plan_pipeline), keyed by
# (network digest, n_stages, chip, dtype) so a warm process re-partitions
# nothing.  The "plans"/"networks" schemas are unchanged from v5, but the
# version gates the whole file, so v5 caches re-tune once.
# v5: plans carry a per-layer ``dtype`` — the *execution* precision the
# tuner resolved, which under an int8 request can legitimately be float32
# (the quantization policy keeps a layer fp32 when the modeled traffic win
# is below threshold or the Winograd error budget fails).  Traffic/footprint
# accounting became itemsize-aware (fp32 output writes under int8 operands),
# shifting modeled times and block tuples, so v4 caches are invalidated.
# v4 added the "networks" section — whole-network entries (written by
# core/netplan.plan_network) recording per-layer plans after network-level
# adjustment plus the inter-layer layout-elision decisions, so a warm
# process rebuilds a NetworkPlan with zero re-tunes.
PLAN_CACHE_VERSION = 6

# Default on-disk location (overridable per Planner and via environment).
DEFAULT_CACHE_PATH = os.environ.get(
    "REPRO_PLAN_CACHE", os.path.join(".cache", "conv_plans.json")
)

# ---------------------------------------------------------------------------
# Cache corruption recovery
#
# A cache file that fails ``json.load`` used to be silently treated as a
# cold start — and then *clobbered* by the next save, destroying the one
# artifact that could explain what went wrong (and every salvageable tune
# in it).  Instead: quarantine the corrupt bytes (rename to
# ``<path>.corrupt-<pid>``, never overwritten), warn once per path per
# process, and salvage every top-level "plans"/"networks" entry that still
# parses — a truncated tail loses the last few entries, not the whole tune
# history.

# Paths already warned about in this process (warn once, not per Planner).
_QUARANTINE_WARNED: set = set()


def _salvage_section(text: str, name: str) -> Dict[str, Any]:
    """Best-effort recovery of one top-level ``"name": {...}`` JSON section.

    The cache is written with ``indent=1, sort_keys=True``, so a top-level
    section opens as ``\\n "name": {`` — the indent-anchored pattern cannot
    collide with same-named keys nested inside opaque network entries.  From
    the opening brace, ``raw_decode`` walks ``"key": value`` pairs one at a
    time and keeps everything that parses; the first undecodable span (the
    truncation/garbage point) ends the walk.
    """
    anchor = f'\n "{name}": {{'
    start = text.find(anchor)
    if start >= 0:
        pos = start + len(anchor)
    else:
        # Fallback for caches not written by us (compact or re-indented).
        import re

        m = re.search(r'"%s"\s*:\s*\{' % re.escape(name), text)
        if m is None:
            return {}
        pos = m.end()
    decoder = json.JSONDecoder()
    out: Dict[str, Any] = {}
    n = len(text)
    while pos < n:
        while pos < n and text[pos] in " \t\r\n,":
            pos += 1
        if pos >= n or text[pos] == "}":
            break
        if text[pos] != '"':
            break
        try:
            key, end = decoder.raw_decode(text, pos)
            pos = end
            while pos < n and text[pos] in " \t\r\n":
                pos += 1
            if pos >= n or text[pos] != ":":
                break
            pos += 1
            while pos < n and text[pos] in " \t\r\n":
                pos += 1
            value, end = decoder.raw_decode(text, pos)
            pos = end
        except (json.JSONDecodeError, ValueError):
            break
        out[str(key)] = value
    return out


def salvage_cache_text(text: str) -> Dict[str, Any]:
    """Recover whatever top-level structure still parses from corrupt cache
    bytes: the version/chip scalars plus every intact "plans"/"networks"
    entry before the corruption point."""
    data: Dict[str, Any] = {}
    for scalar in ("version", "chip"):
        sec = _salvage_section_scalar(text, scalar)
        if sec is not None:
            data[scalar] = sec
    data["plans"] = _salvage_section(text, "plans")
    data["networks"] = _salvage_section(text, "networks")
    data["pipelines"] = _salvage_section(text, "pipelines")
    return data


def _salvage_section_scalar(text: str, name: str) -> Optional[Any]:
    import re

    m = re.search(r'"%s"\s*:\s*' % re.escape(name), text)
    if m is None:
        return None
    try:
        value, _ = json.JSONDecoder().raw_decode(text, m.end())
    except (json.JSONDecodeError, ValueError):
        return None
    return value


def _quarantine_cache(path: str, text: Optional[str]) -> Dict[str, Any]:
    """Move a corrupt cache aside and salvage what parses.

    The quarantined copy is never overwritten: if ``<path>.corrupt-<pid>``
    already exists (two corruption events in one process lifetime), a
    ``-N`` counter suffix picks a fresh name.  Returns the salvaged data
    (possibly empty) for the caller to merge.
    """
    dest = f"{path}.corrupt-{os.getpid()}"
    n = 1
    while os.path.exists(dest):
        dest = f"{path}.corrupt-{os.getpid()}-{n}"
        n += 1
    try:
        os.replace(path, dest)
    except OSError:
        dest = None     # the file vanished or is unmovable; still salvage
    salvaged = salvage_cache_text(text) if text else {}
    if (
        salvaged.get("plans")
        or salvaged.get("networks")
        or salvaged.get("pipelines")
    ):
        # sort_keys writes "version" last, so truncation usually eats it.
        # Entries still go through per-entry validation on load
        # (ConvPlan.from_json try/except; network records validate in
        # netplan) — a wrong-version survivor is dropped there, not here.
        salvaged.setdefault("version", PLAN_CACHE_VERSION)
    n_entries = (
        len(salvaged.get("plans", {}))
        + len(salvaged.get("networks", {}))
        + len(salvaged.get("pipelines", {}))
    )
    if path not in _QUARANTINE_WARNED:
        _QUARANTINE_WARNED.add(path)
        warnings.warn(
            f"plan cache {path!r} is corrupt"
            + (f"; quarantined to {dest!r}" if dest else "")
            + f"; salvaged {n_entries} entr{'y' if n_entries == 1 else 'ies'}"
            f" (cold re-tune covers the rest)",
            RuntimeWarning,
            stacklevel=3,
        )
    return salvaged


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """One resolved co-design decision for one conv layer at one shape.

    ``block`` is the autotuned GEMM-level BlockConfig (the paper's Table II
    block sizes, VMEM in the role of L2).  ``kernel_blocks`` is what the
    Pallas wrappers actually consume — (bm, bn, bk) for the direct GEMM,
    (toh, bc, bo) for the fused im2col kernel, (bt, bc, bo) for the Winograd
    pipeline.  ``predicted_s`` is modeled seconds in cost mode and measured
    wall seconds in measure mode (``source`` says which).
    """

    algorithm: ConvAlgorithm
    impl: str
    block: BlockConfig
    kernel_blocks: Tuple[int, int, int]
    predicted_s: float
    source: str = "cost_model"          # cost_model | measured
    fused_epilogue: bool = False        # bias+activation fused in the kernel
    winograd_fused: bool = False        # single-pass Winograd megakernel
                                        # (vs the 3-pass V/M-via-HBM pipeline)
    dtype: str = "float32"              # resolved execution precision; under
                                        # an int8 request this may stay
                                        # 'float32' (quantization policy)

    def to_json(self) -> Dict[str, Any]:
        return {
            "algorithm": self.algorithm.value,
            "impl": self.impl,
            "block": [self.block.bm, self.block.bn, self.block.bk],
            "kernel_blocks": list(self.kernel_blocks),
            "predicted_s": self.predicted_s,
            "source": self.source,
            "fused_epilogue": self.fused_epilogue,
            "winograd_fused": self.winograd_fused,
            "dtype": self.dtype,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> ConvPlan:
        return cls(
            algorithm=ConvAlgorithm(d["algorithm"]),
            impl=d["impl"],
            block=BlockConfig(*d["block"]),
            kernel_blocks=tuple(d["kernel_blocks"]),
            predicted_s=float(d["predicted_s"]),
            source=d.get("source", "cost_model"),
            fused_epilogue=bool(d.get("fused_epilogue", False)),
            winograd_fused=bool(d.get("winograd_fused", False)),
            dtype=d.get("dtype", "float32"),
        )


def plan_key(
    spec: ConvSpec,
    h: int,
    w: int,
    batch: int,
    chip: str,
    dtype: str,
    impl: str,
    mode: str = "cost",
    vmem_budget: Optional[int] = None,
    fuse_epilogue: bool = False,
    winograd_fused: Optional[bool] = None,
) -> str:
    """Canonical cache key: every field that changes the decision.

    ``winograd_fused`` is the planner's *policy* (None = auto: the tuner
    picks fused vs 3-pass; True/False = forced), not the resolved decision —
    an auto planner must never reuse a plan tuned under a forced policy.
    """
    return "|".join(
        [
            chip,
            dtype,
            impl,
            mode,
            f"e{int(fuse_epilogue)}",
            f"wf{'a' if winograd_fused is None else int(winograd_fused)}",
            f"v{vmem_budget if vmem_budget is not None else 0}",
            f"b{batch}",
            f"h{h}w{w}",
            f"ci{spec.in_channels}co{spec.out_channels}",
            f"k{spec.kh}x{spec.kw}",
            f"s{spec.stride[0]}x{spec.stride[1]}",
            f"p{spec.padding[0]}x{spec.padding[1]}",
            f"d{spec.dilation[0]}x{spec.dilation[1]}",
            spec.algorithm.value,
        ]
    )


def _dtype_name(dtype) -> str:
    """'float32' from jnp.float32 / np.dtype / str alike (no jax import)."""
    name = getattr(dtype, "__name__", None) or getattr(dtype, "name", None)
    return name if name is not None else str(dtype)


def _dtype_bytes(dtype) -> int:
    """Element size for planning, via the cost model's single itemsize map."""
    from repro.core.vmem_model import itemsize

    return itemsize(_dtype_name(dtype))


def _eligible_algorithms(spec: ConvSpec) -> List[ConvAlgorithm]:
    """Candidate set for measure mode (forced specs collapse to one)."""
    if spec.algorithm not in (ConvAlgorithm.AUTO, ConvAlgorithm.AUTO_COST):
        return [spec.algorithm]
    if spec.kernel_size == (1, 1) and spec.stride == (1, 1):
        return [ConvAlgorithm.DIRECT, ConvAlgorithm.IM2COL_GEMM]
    if (
        spec.kernel_size == (3, 3)
        and spec.stride == (1, 1)
        and spec.dilation == (1, 1)
    ):
        return [ConvAlgorithm.WINOGRAD, ConvAlgorithm.IM2COL_GEMM]
    return [ConvAlgorithm.IM2COL_GEMM]


class Planner:
    """Resolves and caches ConvPlans.

    Lookup order: in-memory dict -> persistent JSON cache -> tune (cost model
    or microbenchmark) and write back.  ``stats`` counts ``hits`` (memory or
    disk) and ``tunes`` (cache misses that ran the autotuner); a warm cache
    means ``tunes == 0``.
    """

    def __init__(
        self,
        hw: ChipSpec = V5E,
        mode: str = "cost",
        impl: str = "jax",
        cache_path: Optional[str] = DEFAULT_CACHE_PATH,
        vmem_budget: Optional[int] = None,
        measure_reps: int = 3,
        autosave: bool = True,
        fuse_epilogue: bool = False,
        winograd_fused: Optional[bool] = None,
    ):
        if mode not in ("cost", "measure"):
            raise ValueError(f"mode must be 'cost' or 'measure', got {mode!r}")
        self.hw = hw
        self.mode = mode
        self.impl = impl
        # Plans record the fusion decision so consumers (cnn_forward) apply
        # the epilogue inside the kernel exactly when the plan was tuned
        # that way; keyed separately in the cache.
        self.fuse_epilogue = fuse_epilogue
        # Winograd realization policy: None lets the tuner choose between
        # the single-pass fused megakernel and the 3-pass pipeline (cost
        # mode compares modeled traffic; measure mode on the pallas impl
        # times both); True/False forces one realization.
        self.winograd_fused = winograd_fused
        self.cache_path = cache_path
        self.vmem_budget = vmem_budget if vmem_budget is not None else hw.vmem_bytes
        self.measure_reps = measure_reps
        # autosave=False defers persistence to an explicit save() — use for
        # bulk planning (plan_layers over a deep net) to avoid a locked
        # read-merge-rewrite of the cache file on every miss.
        self.autosave = autosave
        self._dirty = False
        self._plans: Dict[str, ConvPlan] = {}
        # Whole-network entries (core/netplan.plan_network): opaque JSON
        # records keyed by the caller's network key.  Persisted alongside
        # the per-layer plans in the same versioned cache file.
        self._networks: Dict[str, Any] = {}
        # Stage-partition entries (core/netplan.plan_pipeline): opaque JSON
        # records keyed by (network digest, n_stages, chip, dtype) — a warm
        # load re-partitions nothing.
        self._pipelines: Dict[str, Any] = {}
        self.network_hits = 0
        self.pipeline_hits = 0
        self.stats = {"hits": 0, "tunes": 0}
        if cache_path and os.path.exists(cache_path):
            self._load()

    # -- persistence ---------------------------------------------------------

    def _load(self) -> None:
        try:
            # errors="replace": corrupt bytes may not even be UTF-8; decode
            # what we can and let the JSON layer (or salvage) sort it out.
            with open(self.cache_path, errors="replace") as f:
                text = f.read()
        except OSError:
            return  # unreadable cache is a cold start, not an error
        try:
            data = json.loads(text)
            if not isinstance(data, dict):
                raise json.JSONDecodeError("top level is not an object",
                                           text, 0)
        except json.JSONDecodeError:
            # Corrupt cache: quarantine the bytes (never clobber them on
            # the next save) and salvage every entry that still parses.
            data = _quarantine_cache(self.cache_path, text)
        if data.get("version") != PLAN_CACHE_VERSION:
            return
        for key, d in data.get("plans", {}).items():
            try:
                self._plans[key] = ConvPlan.from_json(d)
            except (KeyError, ValueError, TypeError):
                continue
        nets = data.get("networks", {})
        if isinstance(nets, dict):
            self._networks.update(nets)
        pipes = data.get("pipelines", {})
        if isinstance(pipes, dict):
            self._pipelines.update(pipes)

    def save(self) -> None:
        """Atomically write the cache (tmp file + rename).

        Merges with whatever is on disk first (ours wins on key collision) so
        concurrent planners tuning different layers converge to the union
        instead of clobbering each other's entries; a sidecar flock makes the
        read-merge-write sequence race-free where flock exists.
        """
        if not self.cache_path:
            return
        d = os.path.dirname(self.cache_path) or "."
        os.makedirs(d, exist_ok=True)
        lock = open(self.cache_path + ".lock", "w")  # noqa: SIM115  (closed in finally)
        try:
            with contextlib.suppress(ImportError):
                # non-POSIX: best-effort, merge still helps
                import fcntl

                fcntl.flock(lock, fcntl.LOCK_EX)
            plans: Dict[str, Any] = {}
            networks: Dict[str, Any] = {}
            pipelines: Dict[str, Any] = {}
            if os.path.exists(self.cache_path):
                disk: Dict[str, Any] = {}
                with contextlib.suppress(OSError):
                    with open(self.cache_path, errors="replace") as f:
                        disk_text = f.read()
                    try:
                        disk = json.loads(disk_text)
                        if not isinstance(disk, dict):
                            raise json.JSONDecodeError(
                                "top level is not an object", disk_text, 0
                            )
                    except json.JSONDecodeError:
                        # A concurrent writer crashed mid-save (or the file
                        # rotted): quarantine + salvage, same as _load —
                        # the merge keeps every entry that still parses
                        # instead of silently discarding the disk state.
                        disk = _quarantine_cache(self.cache_path, disk_text)
                if disk.get("version") == PLAN_CACHE_VERSION:
                    p = disk.get("plans", {})
                    nw = disk.get("networks", {})
                    pp = disk.get("pipelines", {})
                    if isinstance(p, dict):
                        plans.update(p)
                    if isinstance(nw, dict):
                        networks.update(nw)
                    if isinstance(pp, dict):
                        pipelines.update(pp)
            plans.update({k: p.to_json() for k, p in self._plans.items()})
            networks.update(self._networks)
            pipelines.update(self._pipelines)
            payload = {
                "version": PLAN_CACHE_VERSION,
                "chip": self.hw.name,
                "plans": plans,
                "networks": networks,
                "pipelines": pipelines,
            }
            fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.cache_path)
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise
        finally:
            lock.close()
        self._dirty = False

    def __len__(self) -> int:
        return len(self._plans)

    # -- network-level entries (consumed by core/netplan) --------------------

    def network_entry(self, key: str) -> Optional[Dict[str, Any]]:
        """The stored whole-network record for ``key``, or None (cold).

        ``network_hits`` is NOT counted here: the consumer
        (core/netplan.plan_network) increments it only after the entry
        validates and reconstructs — a corrupt record that falls back to
        replanning must not report warm persistence.
        """
        return self._networks.get(key)

    def put_network_entry(self, key: str, entry: Dict[str, Any]) -> None:
        """Store a whole-network record (must be plain JSON-able data)."""
        self._networks[key] = entry
        if self.autosave:
            self.save()
        else:
            self._dirty = True

    # -- pipeline-partition entries (consumed by core/netplan) ---------------

    def pipeline_entry(self, key: str) -> Optional[Dict[str, Any]]:
        """The stored stage-partition record for ``key``, or None (cold).

        Like ``network_entry``, ``pipeline_hits`` is incremented by the
        consumer (core/netplan.plan_pipeline) only after the entry validates
        — a corrupt record that falls back to re-partitioning must not
        report warm persistence.
        """
        return self._pipelines.get(key)

    def put_pipeline_entry(self, key: str, entry: Dict[str, Any]) -> None:
        """Store a stage-partition record (must be plain JSON-able data)."""
        self._pipelines[key] = entry
        if self.autosave:
            self.save()
        else:
            self._dirty = True

    # -- planning ------------------------------------------------------------

    def plan(
        self,
        spec: ConvSpec,
        h: int,
        w: int,
        batch: int = 1,
        dtype: Any = "float32",
    ) -> ConvPlan:
        """The plan for one layer at one input shape; tunes on first miss."""
        key = plan_key(
            spec, h, w, batch, self.hw.name, _dtype_name(dtype), self.impl,
            self.mode, self.vmem_budget, self.fuse_epilogue,
            self.winograd_fused,
        )
        cached = self._plans.get(key)
        if cached is not None:
            self.stats["hits"] += 1
            return cached
        self.stats["tunes"] += 1
        if _dtype_name(dtype) == "int8":
            # Quantization is a *policy* decision, not a measurement: the
            # accuracy budget and the traffic threshold come from the model
            # either way, so measure mode delegates too.
            plan = self._tune_int8(spec, h, w, batch)
        elif self.mode == "measure":
            plan = self._tune_measured(spec, h, w, batch, dtype)
        else:
            plan = self._tune_cost_model(spec, h, w, batch, dtype)
        self._plans[key] = plan
        if self.autosave:
            self.save()
        else:
            self._dirty = True
        return plan

    def _resolve_blocks(
        self,
        spec: ConvSpec,
        algo: ConvAlgorithm,
        h: int,
        w: int,
        batch: int,
        dtype_bytes: int,
        winograd_fused: bool = True,
    ) -> Tuple[BlockConfig, Tuple[int, int, int]]:
        """(GEMM BlockConfig, kernel block tuple) for one algorithm choice.

        The BlockConfig is autotuned on the GEMM exactly as the kernel runs
        it (direct: (B*OH*OW, O, C); im2col: K = kh*kw*C; winograd: the
        per-position tuple multiply (tiles, O, C)).  Winograd kernel blocks
        (bt, bc, bo) are autotuned per realization — the fused megakernel's
        M-accumulator scratch (8*8*bt*bo*4 bytes) is budgeted alongside the
        tile and weight blocks, so the fused and 3-pass variants can land on
        different tuples.
        """
        oh, ow = spec.out_hw(h, w)
        cin, cout = spec.in_channels, spec.out_channels
        if algo is ConvAlgorithm.WINOGRAD:
            tiles = batch * -(-oh // 6) * -(-ow // 6)
            shape = GemmShape(tiles, cout, cin)
        elif algo is ConvAlgorithm.DIRECT:
            shape = GemmShape(batch * oh * ow, cout, cin)
        else:
            shape = GemmShape(batch * oh * ow, cout, spec.kh * spec.kw * cin)
        cfg, _ = autotune_gemm(shape, self.hw, self.vmem_budget, dtype_bytes)
        # Clamp to the padded problem so tiny layers don't over-pad.
        cfg = BlockConfig(
            min(cfg.bm, ceil_to(shape.m, self.hw.sublanes)),
            min(cfg.bn, ceil_to(shape.n, self.hw.lane_width)),
            min(cfg.bk, ceil_to(shape.k, self.hw.lane_width)),
        )
        if algo is ConvAlgorithm.WINOGRAD:
            from repro.core.vmem_model import autotune_winograd_blocks

            kernel_blocks, _ = autotune_winograd_blocks(
                shape.m, cin, cout, self.hw, self.vmem_budget, dtype_bytes,
                fused=winograd_fused,
            )
        elif algo is ConvAlgorithm.IM2COL_GEMM:
            from repro.kernels.im2col_gemm.ops import pick_blocks

            ph, pw = spec.padding
            kernel_blocks = pick_blocks(
                h + 2 * ph, w + 2 * pw, cin, cout, oh, ow, dtype_bytes,
                vmem_budget=self.vmem_budget, kh=spec.kh, kw=spec.kw,
            )
        else:
            kernel_blocks = (cfg.bm, cfg.bn, cfg.bk)
        return cfg, kernel_blocks

    def _tune_cost_model(
        self, spec: ConvSpec, h: int, w: int, batch: int, dtype
    ) -> ConvPlan:
        """Analytic decision: codesign routing + vmem_model block autotune."""
        from repro.core.codesign import predict_conv_time, select_algorithm_by_cost

        dtype_bytes = _dtype_bytes(dtype)
        if spec.algorithm in (ConvAlgorithm.AUTO, ConvAlgorithm.AUTO_COST):
            # Selection must model the Winograd realization this planner's
            # policy would actually run: a forced-3-pass planner competes
            # im2col against the 3-pass pipeline, not the megakernel.
            # Batch matters too: the im2col-vs-winograd crossover shifts as
            # activation traffic amortizes the weight term.
            algo = select_algorithm_by_cost(
                spec, h, w, self.hw, dtype_bytes,
                winograd_fused=(self.winograd_fused
                                if self.winograd_fused is not None else True),
                batch=batch,
            )
        else:
            algo = select_algorithm(spec)
        wf = False
        if algo is ConvAlgorithm.WINOGRAD:
            if self.winograd_fused is None:
                # Auto: the megakernel wins whenever its eliminated V/M
                # round-trips beat the 3-pass pipeline's modeled time.
                wf = predict_conv_time(
                    spec, h, w, algo, self.hw, dtype_bytes, batch,
                    winograd_fused=True,
                ) <= predict_conv_time(
                    spec, h, w, algo, self.hw, dtype_bytes, batch,
                    winograd_fused=False,
                )
            else:
                wf = self.winograd_fused
        cfg, kernel_blocks = self._resolve_blocks(
            spec, algo, h, w, batch, dtype_bytes, winograd_fused=wf
        )
        t = predict_conv_time(
            spec, h, w, algo, self.hw, dtype_bytes, batch, winograd_fused=wf
        )
        return ConvPlan(
            algorithm=algo,
            impl=self.impl,
            block=cfg,
            kernel_blocks=kernel_blocks,
            predicted_s=t,
            source="cost_model",
            fused_epilogue=self.fuse_epilogue,
            winograd_fused=wf,
            dtype=_dtype_name(dtype),
        )

    def _tune_int8(self, spec: ConvSpec, h: int, w: int, batch: int) -> ConvPlan:
        """Per-layer int8-vs-fp32 decision under an int8 request.

        A layer quantizes only when both policy gates pass (core/quant.py):

          1. the modeled int8 im2col/direct GEMM HBM bytes are at most half
             its fp32 bytes (``int8_worthwhile``) — otherwise the bytes win
             does not pay for the quantization noise (e.g. the cin=3 stem);
          2. the int8 candidate's modeled time actually beats the fp32 plan
             that would otherwise run — an fp32 Winograd layer genuinely
             competes with int8 im2col (the 64/9x weight-traffic inflation
             vs the 4x operand shrink), so the roofline decides.

        Winograd itself is never an int8 candidate unless the F(6, 3)
        transform-stage error budget holds (``winograd_int8_budget_ok`` —
        it does not), so an int8 3x3 layer runs im2col+GEMM.  The returned
        plan's ``dtype`` records the resolved precision; the executor
        quantizes exactly the layers whose plan says 'int8'.
        """
        from repro.core.codesign import predict_conv_time
        from repro.core.quant import int8_worthwhile, winograd_int8_budget_ok

        fp32_plan = self._tune_cost_model(spec, h, w, batch, "float32")
        if not int8_worthwhile(spec, h, w, batch):
            return fp32_plan
        if spec.kernel_size == (1, 1) and spec.stride == (1, 1):
            algo = ConvAlgorithm.DIRECT
        elif (
            fp32_plan.algorithm is ConvAlgorithm.WINOGRAD
            and winograd_int8_budget_ok()
        ):
            algo = ConvAlgorithm.WINOGRAD
        else:
            algo = ConvAlgorithm.IM2COL_GEMM
        wf = fp32_plan.winograd_fused if algo is ConvAlgorithm.WINOGRAD else False
        t_int8 = predict_conv_time(
            spec, h, w, algo, self.hw, 1, batch, winograd_fused=wf
        )
        if t_int8 >= fp32_plan.predicted_s:
            return fp32_plan
        cfg, kernel_blocks = self._resolve_blocks(
            spec, algo, h, w, batch, 1, winograd_fused=wf
        )
        return ConvPlan(
            algorithm=algo,
            impl=self.impl,
            block=cfg,
            kernel_blocks=kernel_blocks,
            predicted_s=t_int8,
            source="cost_model",
            fused_epilogue=self.fuse_epilogue,
            winograd_fused=wf,
            dtype="int8",
        )

    def _tune_measured(
        self, spec: ConvSpec, h: int, w: int, batch: int, dtype
    ) -> ConvPlan:
        """Empirical decision: time each eligible algorithm, keep the winner.

        This is the paper's §VII.A methodology (measure both, pick per layer)
        run on whatever backend is active; on CPU it times the jitted pure-JAX
        paths, on TPU the Pallas kernels when ``impl='pallas'``.
        """
        import time

        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.core.conv2d import conv2d

        dtype_bytes = _dtype_bytes(dtype)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(batch, h, w, spec.in_channels)), dtype)
        wts = jnp.asarray(
            rng.normal(size=(spec.kh, spec.kw, spec.in_channels, spec.out_channels))
            * 0.05,
            dtype,
        )
        # A fuse_epilogue planner stamps plans that will replay with the
        # bias+activation kernel variants — time those same variants, not
        # the bias-less ones (the costs differ per output-stage shape).
        epi = None
        if self.fuse_epilogue:
            from repro.core.conv_spec import Epilogue

            epi = Epilogue(
                bias=jnp.asarray(
                    rng.normal(size=(spec.out_channels,)), dtype
                ),
                activation="relu",
            )
        best: Tuple[Optional[ConvPlan], float] = (None, float("inf"))
        candidates = []
        for algo in _eligible_algorithms(spec):
            if algo is ConvAlgorithm.WINOGRAD:
                if self.winograd_fused is not None:
                    candidates.append((algo, self.winograd_fused))
                elif self.impl == "pallas":
                    # Both realizations exist only on the Pallas path: time
                    # the fused megakernel against the 3-pass pipeline.
                    candidates += [(algo, True), (algo, False)]
                else:
                    candidates.append((algo, True))
            else:
                candidates.append((algo, False))
        for algo, wf in candidates:
            cfg, kernel_blocks = self._resolve_blocks(
                spec, algo, h, w, batch, dtype_bytes, winograd_fused=wf
            )
            candidate = ConvPlan(
                algorithm=algo,
                impl=self.impl,
                block=cfg,
                kernel_blocks=kernel_blocks,
                predicted_s=0.0,
                source="measured",
                fused_epilogue=self.fuse_epilogue,
                winograd_fused=wf,
                dtype=_dtype_name(dtype),
            )
            fn = jax.jit(
                lambda a, b, p=candidate: conv2d(a, b, spec, plan=p,
                                                 epilogue=epi)
            )
            try:
                jax.block_until_ready(fn(x, wts))  # compile + warm
                times = []
                for _ in range(self.measure_reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(x, wts))
                    times.append(time.perf_counter() - t0)
                t = float(np.median(times))
            except Exception:
                continue  # an algorithm that fails to run is never the plan
            if t < best[1]:
                best = (dataclasses.replace(candidate, predicted_s=t), t)
        if best[0] is None:
            # Every candidate failed (e.g. no backend): fall back to the model.
            return self._tune_cost_model(spec, h, w, batch, dtype)
        return best[0]
