from repro.checkpoint.store import AsyncCheckpointWriter, CheckpointStore
