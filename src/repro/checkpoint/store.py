"""Fault-tolerant checkpointing: atomic npz shards + JSON manifest.

Write protocol (crash-safe at every point):
  1. serialize pytrees to   <dir>/tmp.step_N/arrays.npz + manifest.json
  2. fsync, then atomic rename to <dir>/step_N
  3. update <dir>/LATEST (write tmp + rename)
Restore scans LATEST, falls back to the newest complete step dir, and
verifies the manifest before loading — a torn write can never be loaded.
``keep_last`` old steps are garbage-collected after a successful write.
"""
from __future__ import annotations

import contextlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"


_NPZ_NATIVE = set("biufc")  # numpy kinds npz can serialize directly


def _flatten(tree) -> Dict[str, Tuple[np.ndarray, str]]:
    """Returns key -> (array-as-saved, original dtype string).  Dtypes numpy
    can't serialize (bfloat16, float8 from ml_dtypes) are stored as uint8
    views and reconstructed from the manifest on restore."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        raw = np.asarray(leaf)
        if raw.ndim:  # ascontiguousarray promotes 0-d to (1,): skip scalars
            raw = np.ascontiguousarray(raw)
        dtype_str = str(raw.dtype)
        if raw.dtype.kind not in _NPZ_NATIVE:
            raw = raw.reshape(-1).view(np.uint8)
        flat[key] = (raw, dtype_str)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray], dtypes: Dict[str, str]):
    import ml_dtypes  # noqa: F401  (registers bfloat16 et al. with numpy)

    paths_leaves, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        dtype = np.dtype(dtypes.get(key, str(arr.dtype)))
        if arr.dtype == np.uint8 and dtype.kind not in _NPZ_NATIVE:
            arr = arr.view(dtype)
        assert arr.size == int(np.prod(leaf.shape) or 1), (
            f"{key}: {arr.shape} vs {leaf.shape}"
        )
        leaves.append(arr.reshape(tuple(leaf.shape)).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(tdef, leaves)


class CheckpointStore:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------

    def save(self, step: int, trees: Dict[str, Any],
             extra: Optional[Dict] = None) -> str:
        """trees: named pytrees, e.g. {'params': ..., 'opt_state': ...}."""
        tmp = os.path.join(self.dir, f"tmp.step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        arrays = {}
        manifest = {"step": step, "trees": {}, "dtypes": {}, "extra": extra or {}}
        for name, tree in trees.items():
            flat = _flatten(tree)
            manifest["trees"][name] = sorted(flat)
            for k, (v, dtype_str) in flat.items():
                arrays[f"{name}::{k}"] = v
                manifest["dtypes"][f"{name}::{k}"] = dtype_str
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, MANIFEST)
            ):
                out.append(int(name.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if os.path.exists(path):
            with contextlib.suppress(ValueError):
                step = int(open(path).read().strip())
                if os.path.exists(os.path.join(self.dir, f"step_{step}", MANIFEST)):
                    return step
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, templates: Dict[str, Any],
                step: Optional[int] = None) -> Tuple[int, Dict[str, Any]]:
        """Restore named pytrees into the given abstract/concrete templates."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        manifest = json.load(open(os.path.join(d, MANIFEST)))
        data = np.load(os.path.join(d, "arrays.npz"))
        dtypes = manifest.get("dtypes", {})
        out = {}
        for name, template in templates.items():
            flat = {k: data[f"{name}::{k}"] for k in manifest["trees"][name]}
            dts = {k: dtypes.get(f"{name}::{k}", "") for k in flat}
            out[name] = _unflatten(template, flat, dts)
        return step, out


class AsyncCheckpointWriter:
    """Snapshot-to-host then write on a background thread; ``wait()`` joins.

    The training loop never blocks on disk: device->host transfer happens
    synchronously (cheap, required for consistency), serialization +
    fsync + rename run off-thread.
    """

    def __init__(self, store: CheckpointStore):
        self.store = store
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, trees: Dict[str, Any], extra=None):
        self.wait()
        host_trees = jax.tree.map(lambda x: np.asarray(x), trees)

        def _write():
            try:
                self.store.save(step, host_trees, extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
