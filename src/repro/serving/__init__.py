from repro.serving.engine import ServingEngine, Request
from repro.serving.cnn_engine import CNNServingEngine, ImageRequest
from repro.serving.resilience import (
    Backpressure,
    CircuitBreaker,
    DeadlineExceeded,
    FallbackExhausted,
    InvalidRequest,
    QueueNotDrained,
    RequestFailed,
    ResilientEngine,
    ServingError,
    cnn_fallback_ladder,
    is_failure,
    lm_fallback_ladder,
)
from repro.serving.faults import (
    FakeClock,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    corrupt_cache_file,
)

__all__ = [
    "ServingEngine", "Request", "CNNServingEngine", "ImageRequest",
    "Backpressure", "CircuitBreaker", "DeadlineExceeded",
    "FallbackExhausted", "InvalidRequest", "QueueNotDrained",
    "RequestFailed", "ResilientEngine", "ServingError",
    "cnn_fallback_ladder", "is_failure", "lm_fallback_ladder",
    "FakeClock", "FaultPlan", "FaultSpec", "InjectedFault",
    "corrupt_cache_file",
]
