from repro.serving.engine import ServingEngine, Request
from repro.serving.cnn_engine import CNNServingEngine, ImageRequest
