"""Deterministic fault injection for the serving resilience machinery.

Every degradation path in `serving/resilience.py` must be provable in CI,
the way PR 7's mutation suite proves each verifier pass catches exactly its
injected plan corruption.  A ``FaultPlan`` is a seeded, finite script of
faults — executor exceptions, NaN/Inf output rows, synthetic latency
spikes, plan-cache corruption — matched against (step, bucket, rung) at
each executor call, so a test can say "step 3, bucket 4, rung 'primary'
raises" and then assert the interpret fallback served that exact batch.

Nothing here runs in production: engines take ``faults=None`` by default
and the draw hook short-circuits.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """The exception raised by an ``exception``-kind fault."""


VALID_KINDS = ("exception", "nan", "inf", "latency", "corrupt_cache")


@dataclasses.dataclass
class FaultSpec:
    """One scripted fault.

    ``step``/``bucket``/``rung`` select where it fires (``None`` = wildcard);
    ``times`` bounds how many matching calls it poisons (faults are finite
    by construction — an unbounded fault would mask recovery).  ``rows``
    limits nan/inf poisoning to specific batch rows (``None`` = all rows).
    """

    kind: str
    step: Optional[int] = None
    bucket: Optional[Any] = None
    rung: Optional[str] = None
    times: int = 1
    rows: Optional[Tuple[int, ...]] = None
    latency_s: float = 0.0
    path: Optional[str] = None
    note: str = ""

    def __post_init__(self):
        if self.kind not in VALID_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{VALID_KINDS}"
            )
        if self.times < 1:
            raise ValueError(f"times must be >= 1, got {self.times}")
        if self.rows is not None:
            self.rows = tuple(int(r) for r in self.rows)

    def matches(self, step: int, bucket: Any, rung: str) -> bool:
        if self.step is not None and self.step != step:
            return False
        if self.bucket is not None and self.bucket != bucket:
            return False
        if self.rung is not None and self.rung != rung:
            return False
        return True


class FaultPlan:
    """A finite, ordered script of faults drawn against (step, bucket, rung).

    ``draw`` returns the first matching non-exhausted spec (decrementing its
    budget) or ``None``; every draw outcome is appended to ``self.log`` so
    tests can assert exactly which calls were poisoned.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self._arms: List[List] = [[s, s.times] for s in specs]
        self.log: List[Tuple[int, Any, str, Optional[FaultSpec]]] = []

    @property
    def specs(self) -> List[FaultSpec]:
        return [arm[0] for arm in self._arms]

    @property
    def exhausted(self) -> bool:
        """True once every scripted fault has fired its full budget."""
        return all(left == 0 for _, left in self._arms)

    @property
    def injected(self) -> int:
        """Number of draws that actually returned a fault."""
        return sum(1 for *_k, spec in self.log if spec is not None)

    def draw(self, step: int, bucket: Any, rung: str) -> Optional[FaultSpec]:
        for arm in self._arms:
            spec, left = arm
            if left > 0 and spec.matches(step, bucket, rung):
                arm[1] = left - 1
                self.log.append((step, bucket, rung, spec))
                return spec
        self.log.append((step, bucket, rung, None))
        return None

    @classmethod
    def seeded(
        cls,
        seed: int,
        n_faults: int,
        steps: int,
        kinds: Sequence[str] = ("exception", "nan", "inf", "latency"),
        buckets: Sequence[Any] = (None,),
        rung: Optional[str] = "primary",
    ) -> FaultPlan:
        """A reproducible random plan: same seed → same fault script.

        Faults land only on the named ``rung`` (default the fast path) so a
        seeded storm exercises the ladder without also poisoning the rungs
        meant to absorb it.
        """
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(int(n_faults)):
            kind = str(rng.choice(list(kinds)))
            bucket = buckets[int(rng.integers(len(buckets)))]
            specs.append(
                FaultSpec(
                    kind=kind,
                    step=int(rng.integers(1, max(2, steps + 1))),
                    bucket=bucket,
                    rung=rung,
                    latency_s=float(rng.uniform(0.01, 0.2))
                    if kind == "latency"
                    else 0.0,
                    note=f"seeded(seed={seed})",
                )
            )
        return cls(specs)


class FakeClock:
    """Injectable monotonic clock: tests advance time explicitly, so
    deadline expiry and latency spikes are deterministic."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, seconds: float) -> float:
        self.now += float(seconds)
        return self.now


def corrupt_cache_file(path: str, mode: str = "truncate", seed: int = 0) -> None:
    """Deterministically corrupt a plan-cache file on disk.

    ``truncate`` cuts the file mid-JSON (the classic crashed-writer shape);
    ``garbage`` overwrites a byte span with seeded noise.
    """
    with open(path, "rb") as f:
        data = f.read()
    if mode == "truncate":
        corrupted = data[: max(1, int(len(data) * 0.6))]
    elif mode == "garbage":
        rng = np.random.default_rng(seed)
        buf = bytearray(data)
        n = max(1, len(buf) // 8)
        start = len(buf) // 3
        for i in range(start, min(len(buf), start + n)):
            buf[i] = int(rng.integers(0, 256))
        corrupted = bytes(buf)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    tmp = f"{path}.tmp-corrupt-{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(corrupted)
    os.replace(tmp, path)


def _poison(out: Any, value: float, rows: Optional[Tuple[int, ...]]) -> Any:
    """Poison an executor output with ``value`` (NaN or Inf).

    Handles both engine output shapes: a bare array (CNN logits) and a
    ``(logits, cache)`` tuple (LM decode) — the cache is left intact so the
    fault models a bad compute result, not corrupted state.
    """
    if isinstance(out, tuple):
        return (_poison(out[0], value, rows),) + tuple(out[1:])
    arr = np.array(out, dtype=np.float32, copy=True)
    if rows is None:
        arr[...] = value
    else:
        for r in rows:
            if 0 <= r < arr.shape[0]:
                arr[r, ...] = value
    return arr


def apply_fault(
    spec: FaultSpec,
    fn: Callable,
    args: Tuple,
    clock: Optional[Callable[[], float]] = None,
) -> Any:
    """Execute one guarded call under ``spec``.

    exception      raise InjectedFault instead of calling ``fn``
    nan / inf      call ``fn``, poison the selected output rows
    latency        advance the injectable clock (or sleep briefly on a real
                   one), then call ``fn`` normally
    corrupt_cache  corrupt ``spec.path`` on disk, then call ``fn`` — models
                   a concurrent writer crashing mid-save
    """
    if spec.kind == "exception":
        raise InjectedFault(
            f"injected executor exception ({spec.note or 'scripted'})"
        )
    if spec.kind == "latency":
        if hasattr(clock, "advance"):
            clock.advance(spec.latency_s)
        elif spec.latency_s > 0:
            time.sleep(min(spec.latency_s, 0.05))
        return fn(*args)
    if spec.kind == "corrupt_cache":
        if spec.path:
            corrupt_cache_file(spec.path)
        return fn(*args)
    out = fn(*args)
    value = np.nan if spec.kind == "nan" else np.inf
    return _poison(out, value, spec.rows)
