"""Batched serving engine: prefill + decode with continuous batching.

The engine keeps a fixed-capacity decode batch; finished sequences free
their slot, queued requests prefill into it.  Decode steps are one jitted
``serve_step`` over the whole batch regardless of occupancy (standard TPU
serving shape discipline: no recompiles as requests come and go).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 capacity: int, temperature: float = 0.0, seed: int = 0):
        assert cfg.supports_decode, f"{cfg.name} is encoder-only"
        # Continuous batching is only correct for attention (KV ring) caches:
        # per-row positions make every ring-slot write overwrite-before-read.
        # Recurrent state (rglru/mlstm/slstm) is updated unconditionally per
        # decode step, so batched slot-local prefill would feed garbage
        # tokens into other rows' states with no way to undo it.
        recurrent = {b for b in cfg.pattern_layers
                     if b not in ("attn", "local")}
        if recurrent and batch_size > 1:
            raise ValueError(
                f"{cfg.name} has recurrent blocks {sorted(recurrent)}: "
                "continuous batching would corrupt their per-row state; "
                "use batch_size=1 (or the global-batch prefill in "
                "launch/serve.py)"
            )
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.capacity = capacity
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)

        self.cache = tf.init_cache(cfg, batch_size, capacity)
        self.pos = np.zeros(batch_size, np.int64)      # per-slot next position
        self.slot_req: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self._uid = 0

        self._decode = jax.jit(lambda p, c, t, pos: tf.decode_step(cfg, p, c, t, pos))

    # -- public api -----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError(
                "empty prompt: decode needs at least one token to condition on"
            )
        self._uid += 1
        self.queue.append(Request(self._uid, prompt, max_new_tokens))
        return self._uid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until all submitted requests finish.  Returns uid->tokens."""
        results: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            self._admit()
            live = [i for i, r in enumerate(self.slot_req) if r is not None]
            if not live and not self.queue:
                break
            self._decode_one_step()
            for i, r in enumerate(self.slot_req):
                if r is not None and r.done:
                    results[r.uid] = r.out_tokens
                    self.slot_req[i] = None
        return results

    # -- internals --------------------------------------------------------

    def _admit(self):
        """Prefill queued requests into free slots, one token at a time via
        the decode path (slot-local; the global-batch prefill path is used
        by launch/serve.py where all slots start together)."""
        for i in range(self.batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                self.pos[i] = 0
                # Feed the prompt through decode steps for this slot.
                for t in req.prompt[:-1]:
                    self._step_slot(i, int(t))
                req._last_token = int(req.prompt[-1])

    def _step_slot(self, slot: int, token: int):
        """Advance one lagging slot (prompt prefill) through the batched
        decode.  Every row passes its *own* position, so other live rows'
        KV ring slots are written at positions they will legitimately
        overwrite on their next real decode step — never at a foreign
        slot's position (which is what corrupted mid-flight admissions
        before).  This overwrite-before-read argument only holds for
        attention caches; recurrent blocks are rejected at __init__ for
        batch_size > 1."""
        tokens = np.zeros((self.batch, 1), np.int32)
        tokens[slot, 0] = token
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos, jnp.int32),
        )
        self.pos[slot] += 1
        return np.asarray(logits[slot])

    def _sample(self, logits: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(logits.argmax())
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, jnp.asarray(logits) / self.temperature))

    def _decode_one_step(self):
        tokens = np.zeros((self.batch, 1), np.int32)
        any_live = False
        for i, r in enumerate(self.slot_req):
            if r is not None:
                tokens[i, 0] = getattr(r, "_last_token", 0)
                any_live = True
        if not any_live:
            return
        # Per-slot positions: sequences admitted mid-flight with shorter
        # prompts decode at their own position (a shared max() position
        # desynced their KV cache — wrote every row at the longest
        # sequence's slot and skipped the intermediate positions).
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos, jnp.int32),
        )
        logits_np = np.asarray(logits)
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            nxt = self._sample(logits_np[i])
            r.out_tokens.append(nxt)
            r._last_token = nxt
            self.pos[i] += 1
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
