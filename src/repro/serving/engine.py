"""Batched serving engine: prefill + decode with continuous batching.

The engine keeps a fixed-capacity decode batch; finished sequences free
their slot, queued requests prefill into it.  Decode steps are one jitted
``serve_step`` over the whole batch regardless of occupancy (standard TPU
serving shape discipline: no recompiles as requests come and go).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    @classmethod
    def from_compiled(cls, compiled, batch_size: Optional[int] = None,
                      capacity: int = 256, **kw) -> "ServingEngine":
        """Consume a facade compilation (``repro.compile(cfg, params,
        options).serve()`` routes here): model config, params, and the
        default batch (the largest option bucket) come from it."""
        return cls(
            compiled.model, compiled.params,
            batch_size=batch_size or max(compiled.options.buckets),
            capacity=capacity, **kw,
        )

    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 capacity: int, temperature: float = 0.0, seed: int = 0):
        assert cfg.supports_decode, f"{cfg.name} is encoder-only"
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.capacity = capacity
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)

        self.cache = tf.init_cache(cfg, batch_size, capacity)
        # Batch-1 pristine cache: admission resets a freed slot's rows from
        # its row 0 (recurrent state must not leak between occupants) at
        # 1/batch of the memory a full pristine copy would pin.
        self._fresh_cache = tf.init_cache(cfg, 1, capacity)
        self.pos = np.zeros(batch_size, np.int64)      # per-slot next position
        self.slot_req: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self._uid = 0

        # Every decode passes a live-slot mask: rows not decoding this step
        # keep their state (jnp.where around every state write).  KV ring
        # caches tolerated garbage writes via overwrite-before-read, but
        # recurrent state (rglru/mlstm/slstm) does not — the mask is what
        # makes continuous batching correct for recurrent stacks too.
        self._decode = jax.jit(
            lambda p, c, t, pos, live: tf.decode_step(cfg, p, c, t, pos,
                                                      live=live)
        )

    # -- public api -----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError(
                "empty prompt: decode needs at least one token to condition on"
            )
        self._uid += 1
        self.queue.append(Request(self._uid, prompt, max_new_tokens))
        return self._uid

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive until all submitted requests finish.  Returns uid->tokens."""
        results: Dict[int, List[int]] = {}
        for _ in range(max_steps):
            self._admit()
            live = [i for i, r in enumerate(self.slot_req) if r is not None]
            if not live and not self.queue:
                break
            self._decode_one_step()
            for i, r in enumerate(self.slot_req):
                if r is not None and r.done:
                    results[r.uid] = r.out_tokens
                    self.slot_req[i] = None
        return results

    # -- internals --------------------------------------------------------

    def _admit(self):
        """Prefill queued requests into free slots, one token at a time via
        the decode path (slot-local; the global-batch prefill path is used
        by launch/serve.py where all slots start together)."""
        for i in range(self.batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                self.pos[i] = 0
                # The freed slot's recurrent state (rglru/mlstm/slstm) and
                # ring slots start from init — no leakage from the slot's
                # previous occupant.
                self.cache = tf.reset_cache_rows(
                    self.cache, self._fresh_cache, i
                )
                # Feed the prompt through decode steps for this slot.
                for t in req.prompt[:-1]:
                    self._step_slot(i, int(t))
                req._last_token = int(req.prompt[-1])

    def _step_slot(self, slot: int, token: int):
        """Advance one lagging slot (prompt prefill) through the batched
        decode.  Only ``slot`` is live: every other row's state — KV ring
        *and* recurrent (rglru/mlstm/slstm) — is masked out of the update,
        so the garbage token this step feeds them never touches their
        caches.  (Before the mask, correctness leaned on the KV ring's
        overwrite-before-read property, which recurrent state lacks; the
        engine rejected batch_size > 1 for recurrent stacks outright.)"""
        tokens = np.zeros((self.batch, 1), np.int32)
        tokens[slot, 0] = token
        live = np.zeros(self.batch, bool)
        live[slot] = True
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos, jnp.int32), jnp.asarray(live),
        )
        self.pos[slot] += 1
        return np.asarray(logits[slot])

    def _sample(self, logits: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(logits.argmax())
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, jnp.asarray(logits) / self.temperature))

    def _decode_one_step(self):
        tokens = np.zeros((self.batch, 1), np.int32)
        any_live = False
        for i, r in enumerate(self.slot_req):
            if r is not None:
                tokens[i, 0] = getattr(r, "_last_token", 0)
                any_live = True
        if not any_live:
            return
        # Per-slot positions: sequences admitted mid-flight with shorter
        # prompts decode at their own position (a shared max() position
        # desynced their KV cache — wrote every row at the longest
        # sequence's slot and skipped the intermediate positions).  The
        # live mask keeps empty slots' state frozen.
        live = np.array([r is not None for r in self.slot_req], bool)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos, jnp.int32), jnp.asarray(live),
        )
        logits_np = np.asarray(logits)
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            nxt = self._sample(logits_np[i])
            r.out_tokens.append(nxt)
            r._last_token = nxt
            self.pos[i] += 1
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True
