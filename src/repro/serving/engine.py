"""Batched serving engine: prefill + decode with continuous batching.

The engine keeps a fixed-capacity decode batch; finished sequences free
their slot, queued requests prefill into it.  Decode steps are one jitted
``serve_step`` over the whole batch regardless of occupancy (standard TPU
serving shape discipline: no recompiles as requests come and go).

The ``ResilientEngine`` machinery (serving/resilience.py) is threaded
through: ``submit`` validates prompts and applies backpressure/deadlines,
the decode call runs through a jit → eager fallback ladder behind a
circuit breaker (the eager rung survives XLA compilation bugs), expired
requests — queued *or* mid-decode — are evicted with ``DeadlineExceeded``
results, and ``health()`` reports the degradation state.  With default
options and no faults all of it is inert: rung 0 is the pre-existing
jitted decode and outputs are bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serving.resilience import (
    DEFAULT_PROBE_AFTER,
    DeadlineExceeded,
    FallbackExhausted,
    QueueNotDrained,
    RequestFailed,
    ResilientEngine,
    lm_fallback_ladder,
    validate_prompt,
)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    deadline: Optional[float] = None    # absolute, engine-clock seconds
    priority: int = 0                   # higher admits first


class ServingEngine(ResilientEngine):
    @classmethod
    def from_compiled(cls, compiled, batch_size: Optional[int] = None,
                      capacity: int = 256, **kw) -> ServingEngine:
        """Consume a facade compilation (``repro.compile(cfg, params,
        options).serve()`` routes here): model config, params, the default
        batch (the largest option bucket), and the resilience policy
        (``max_queue``/``default_deadline_s``/``fallback``/``retries``)
        come from it; ``kw`` overrides win."""
        opts = compiled.options
        kw.setdefault("max_queue", getattr(opts, "max_queue", None))
        kw.setdefault(
            "default_deadline_s", getattr(opts, "default_deadline_s", None)
        )
        kw.setdefault("retries", getattr(opts, "retries", 1))
        kw.setdefault("fallback", getattr(opts, "fallback", "ladder"))
        return cls(
            compiled.model, compiled.params,
            batch_size=batch_size or max(compiled.options.buckets),
            capacity=capacity, **kw,
        )

    def __init__(self, cfg: ModelConfig, params, batch_size: int,
                 capacity: int, temperature: float = 0.0, seed: int = 0,
                 *,
                 max_queue: Optional[int] = None,
                 default_deadline_s: Optional[float] = None,
                 retries: int = 1,
                 fallback: str = "ladder",
                 probe_after: int = DEFAULT_PROBE_AFTER,
                 clock=None,
                 faults=None):
        assert cfg.supports_decode, f"{cfg.name} is encoder-only"
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.capacity = capacity
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)

        self.cache = tf.init_cache(cfg, batch_size, capacity)
        # Batch-1 pristine cache: admission resets a freed slot's rows from
        # its row 0 (recurrent state must not leak between occupants) at
        # 1/batch of the memory a full pristine copy would pin.
        self._fresh_cache = tf.init_cache(cfg, 1, capacity)
        self.pos = np.zeros(batch_size, np.int64)      # per-slot next position
        self.slot_req: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self._uid = 0

        # Every decode passes a live-slot mask: rows not decoding this step
        # keep their state (jnp.where around every state write).  KV ring
        # caches tolerated garbage writes via overwrite-before-read, but
        # recurrent state (rglru/mlstm/slstm) does not — the mask is what
        # makes continuous batching correct for recurrent stacks too.
        self._decode = jax.jit(
            lambda p, c, t, pos, live: tf.decode_step(cfg, p, c, t, pos,
                                                      live=live)
        )
        self._resilience_init(
            ladder=lm_fallback_ladder(),
            max_queue=max_queue,
            default_deadline_s=default_deadline_s,
            retries=retries,
            fallback=fallback,
            probe_after=probe_after,
            clock=clock,
            faults=faults,
        )
        # The eager rung is built lazily on first failure; request-level
        # failures raised mid-decode accumulate here (``_decode_one_step``
        # keeps its no-argument signature for subclasses) and ``run``
        # drains them into its results.
        self._eager_decode = None
        self._failures: Dict[int, Any] = {}

    # -- public api -----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               deadline_s: Optional[float] = None, priority: int = 0) -> int:
        """Enqueue one prompt; returns its uid.

        Raises ``Backpressure`` when the queue is at ``max_queue`` and
        ``InvalidRequest`` (a ValueError) for empty/float/out-of-vocab
        prompts — a bad token array must not corrupt the batched embedding
        lookup for its co-batched neighbours.
        """
        self._check_admission(len(self.queue))
        prompt = validate_prompt(prompt, self.cfg.vocab_size)
        deadline = self._absolute_deadline(deadline_s)
        self._uid += 1
        self.queue.append(
            Request(self._uid, prompt, max_new_tokens, deadline=deadline,
                    priority=int(priority))
        )
        return self._uid

    def run(self, max_steps: int = 10_000) -> Dict[int, Any]:
        """Drive until all submitted requests finish.  Returns uid->tokens
        (or a typed ``DeadlineExceeded``/``RequestFailed`` marker).

        Raises ``QueueNotDrained`` (partial results + remaining uids
        attached) when ``max_steps`` is exhausted with work still live.
        """
        results: Dict[int, Any] = {}
        for _ in range(max_steps):
            self._evict_expired(results)
            self._admit()
            if self._failures:
                results.update(self._failures)
                self._failures.clear()
            live = [i for i, r in enumerate(self.slot_req) if r is not None]
            if not live and not self.queue:
                break
            self._decode_one_step()
            if self._failures:
                results.update(self._failures)
                self._failures.clear()
            for i, r in enumerate(self.slot_req):
                if r is not None and r.done:
                    results[r.uid] = r.out_tokens
                    self.slot_req[i] = None
        else:
            remaining = [r.uid for r in self.queue] + [
                r.uid for r in self.slot_req if r is not None
            ]
            if remaining:
                raise QueueNotDrained(results, remaining, max_steps)
        return results

    # -- internals --------------------------------------------------------

    def _evict_expired(self, results: Dict[int, Any]) -> None:
        """Evict expired requests — queued *and* mid-decode (a stale slot
        frees immediately so waiting work can admit)."""
        now = self._now()
        live, evicted = self._split_expired(self.queue, now)
        self.queue = live
        results.update(evicted)
        for i, r in enumerate(self.slot_req):
            if r is not None and r.deadline is not None and now >= r.deadline:
                results[r.uid] = DeadlineExceeded(
                    uid=r.uid, deadline=r.deadline, now=now
                )
                self._res_stats["evictions"] += 1
                self.slot_req[i] = None

    def _admit(self):
        """Prefill queued requests into free slots, one token at a time via
        the decode path (slot-local; the global-batch prefill path is used
        by launch/serve.py where all slots start together)."""
        if self.queue:
            # Priority order, FIFO within a class (identity permutation for
            # all-default priority=0 — stable sort on (-priority, uid)).
            self.queue.sort(key=lambda r: (-r.priority, r.uid))
        for i in range(self.batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                self.pos[i] = 0
                # The freed slot's recurrent state (rglru/mlstm/slstm) and
                # ring slots start from init — no leakage from the slot's
                # previous occupant.
                self.cache = tf.reset_cache_rows(
                    self.cache, self._fresh_cache, i
                )
                # Feed the prompt through decode steps for this slot.
                try:
                    for t in req.prompt[:-1]:
                        self._step_slot(i, int(t))
                except FallbackExhausted as e:
                    self._res_stats["request_failures"] += 1
                    self._failures[req.uid] = RequestFailed(
                        uid=req.uid, reason=str(e),
                        rung=self._ladder[-1].name,
                    )
                    self.slot_req[i] = None
                    continue
                req._last_token = int(req.prompt[-1])

    def _step_slot(self, slot: int, token: int):
        """Advance one lagging slot (prompt prefill) through the batched
        decode.  Only ``slot`` is live: every other row's state — KV ring
        *and* recurrent (rglru/mlstm/slstm) — is masked out of the update,
        so the garbage token this step feeds them never touches their
        caches.  (Before the mask, correctness leaned on the KV ring's
        overwrite-before-read property, which recurrent state lacks; the
        engine rejected batch_size > 1 for recurrent stacks outright.)"""
        tokens = np.zeros((self.batch, 1), np.int32)
        tokens[slot, 0] = token
        live = np.zeros(self.batch, bool)
        live[slot] = True
        self._step_index += 1
        out, _rung, _bad = self._guarded_call(
            "decode",
            (self.params, self.cache, jnp.asarray(tokens),
             jnp.asarray(self.pos, jnp.int32), jnp.asarray(live)),
            live=live,
        )
        logits, self.cache = out
        self.pos[slot] += 1
        return np.asarray(logits[slot])

    def _sample(self, logits: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(logits.argmax())
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, jnp.asarray(logits) / self.temperature))

    def _decode_one_step(self):
        tokens = np.zeros((self.batch, 1), np.int32)
        any_live = False
        for i, r in enumerate(self.slot_req):
            if r is not None:
                tokens[i, 0] = getattr(r, "_last_token", 0)
                any_live = True
        if not any_live:
            return
        # Per-slot positions: sequences admitted mid-flight with shorter
        # prompts decode at their own position (a shared max() position
        # desynced their KV cache — wrote every row at the longest
        # sequence's slot and skipped the intermediate positions).  The
        # live mask keeps empty slots' state frozen.
        live = np.array([r is not None for r in self.slot_req], bool)
        self._step_index += 1
        try:
            out, rung, bad = self._guarded_call(
                "decode",
                (self.params, self.cache, jnp.asarray(tokens),
                 jnp.asarray(self.pos, jnp.int32), jnp.asarray(live)),
                live=live,
            )
        except FallbackExhausted as e:
            # Every live request fails at request level; the engine itself
            # survives and the next dispatch starts a fresh probe.
            for i, r in enumerate(self.slot_req):
                if r is not None:
                    self._res_stats["request_failures"] += 1
                    self._failures[r.uid] = RequestFailed(
                        uid=r.uid, reason=str(e),
                        rung=self._ladder[-1].name,
                    )
                    self.slot_req[i] = None
            return
        logits, self.cache = out
        logits_np = np.asarray(logits)
        rung_name = self._ladder[rung].name
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            if bad is not None and bad[i]:
                # Row-level poison with healthy neighbours: request-level
                # failure — the rest of the batch keeps decoding.
                self._res_stats["request_failures"] += 1
                self._failures[r.uid] = RequestFailed(
                    uid=r.uid,
                    reason="non-finite logits row survived retries",
                    rung=rung_name,
                )
                self.slot_req[i] = None
                continue
            nxt = self._sample(logits_np[i])
            r.out_tokens.append(nxt)
            r._last_token = nxt
            self.pos[i] += 1
            if len(r.out_tokens) >= r.max_new_tokens:
                r.done = True

    # -- resilience hooks ---------------------------------------------------

    def _rung_fn(self, key, rung_index: int):
        """Rung 0 is the jitted decode untouched; rung 1 runs the same
        ``decode_step`` eagerly (op by op) — the path that survives XLA
        compilation bugs, built lazily on first failure."""
        if rung_index == 0:
            return self._decode
        if self._eager_decode is None:
            cfg = self.cfg
            self._eager_decode = lambda p, c, t, pos, live: tf.decode_step(
                cfg, p, c, t, pos, live=live
            )
        return self._eager_decode

    def _rows_nonfinite(self, out, live):
        logits = np.asarray(out[0])
        flat = logits.reshape(logits.shape[0], -1)
        return ~np.isfinite(flat).all(axis=1)
