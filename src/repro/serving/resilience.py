"""Serving-layer resilience: deadlines, backpressure, and a fallback ladder.

The paper's co-design premise is that the *selector* picks the best viable
algorithm per layer; resilience is the same idea applied to failure.  When
the fast path dies — a transient XLA OOM, a poisoned kernel, a non-finite
batch — the serving layer must degrade to the next-best plan instead of
dying with it.  This module is the shared machinery both serving engines
(`serving/cnn_engine.py`, `serving/engine.py`) thread through:

  admission     ``submit(deadline_s=, priority=)`` rejects with a typed
                ``Backpressure`` error once the queue holds
                ``ExecutionOptions.max_queue`` requests, and validates the
                payload (shape, dtype, finiteness) *before* it can poison a
                whole co-batched padded batch.
  deadlines     every request may carry an absolute deadline (per-request
                ``deadline_s`` or ``ExecutionOptions.default_deadline_s``);
                ``step()`` evicts expired requests with a
                ``DeadlineExceeded`` result instead of serving stale work.
                The clock is injectable (``FakeClock`` in serving/faults.py)
                so expiry is deterministic under test.
  fallback      executor calls run through a per-bucket **ladder** of
                degraded realizations (pallas → pallas-interpret → pure-XLA
                reference forward; int8 → fp32).  On exception or a fully
                non-finite output the call retries ``retries`` times, then
                descends one rung; rows that stay non-finite while the rest
                of the batch is healthy become *request-level*
                ``RequestFailed`` results (one poisoned image must not take
                its co-batched neighbours down).
  breaker       each bucket owns a CLOSED/OPEN/HALF_OPEN circuit breaker
                with deterministic probe-after-N-steps recovery: a trip
                pins the bucket at the deeper rung, ``probe_after``
                dispatches later one batch probes the rung above, and a
                successful probe climbs back — one poisoned bucket degrades
                alone while the rest of the ladder stays fast.
  health        ``engine.health()`` reports per-bucket breaker state,
                fallback depth, evictions, rejections, and retry counts.

Resilience is zero-cost on the happy path: rung 0 is the engine's existing
executor (bit-identical outputs, identical plan-cache contents), fallback
rungs are built lazily on first failure, and the default options
(``max_queue=None``, ``default_deadline_s=None``) disable every gate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Typed errors and per-request failure results


class ServingError(Exception):
    """Base of every typed serving-layer error."""


class Backpressure(ServingError, RuntimeError):
    """``submit`` rejected: the admission queue is at ``max_queue``."""

    def __init__(self, queue_len: int, max_queue: int):
        self.queue_len = queue_len
        self.max_queue = max_queue
        super().__init__(
            f"admission queue full ({queue_len}/{max_queue}); retry later "
            f"or raise ExecutionOptions.max_queue"
        )


class InvalidRequest(ServingError, ValueError):
    """``submit`` rejected the payload before it could poison a batch."""


class QueueNotDrained(ServingError, RuntimeError):
    """``run(max_steps)`` exhausted its step budget with work still queued.

    Carries the partial results and the remaining uids so no request is
    silently lost (callers used to KeyError on the missing uids instead).
    """

    def __init__(self, results: Dict[int, Any], remaining: Sequence[int],
                 max_steps: int):
        self.results = dict(results)
        self.remaining = list(remaining)
        super().__init__(
            f"queue not drained after {max_steps} steps: "
            f"{len(self.remaining)} request(s) remaining "
            f"(uids {self.remaining[:8]}{'...' if len(self.remaining) > 8 else ''}); "
            f"partial results for {len(self.results)} request(s) are on "
            f".results"
        )


class FallbackExhausted(ServingError, RuntimeError):
    """Every ladder rung failed for one batch (internal; surfaces to the
    caller as per-request ``RequestFailed`` results, never an engine crash)."""


class _NonFiniteOutput(Exception):
    """Internal marker: an otherwise-successful rung produced a fully
    non-finite output (treated exactly like an executor exception)."""


@dataclasses.dataclass(frozen=True)
class DeadlineExceeded:
    """Result marker: the request expired in the queue and was evicted."""

    uid: int
    deadline: float
    now: float

    @property
    def ok(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class RequestFailed:
    """Result marker: this request failed at request level (non-finite
    output row, or every ladder rung exhausted)."""

    uid: int
    reason: str
    rung: Optional[str] = None

    @property
    def ok(self) -> bool:
        return False


def is_failure(result: Any) -> bool:
    """True for the typed failure results (DeadlineExceeded/RequestFailed)."""
    return isinstance(result, (DeadlineExceeded, RequestFailed))


# ---------------------------------------------------------------------------
# Fallback ladder


@dataclasses.dataclass(frozen=True)
class Rung:
    """One realization on the fallback ladder.

    ``impl``/``interpret``/``dtype`` describe how the rung executes; the
    engine's ``_build_rung`` maps them to a concrete callable.  Rung 0 is
    always the engine's configured fast path.
    """

    name: str
    impl: str
    interpret: Optional[bool] = None
    dtype: str = "float32"


def cnn_fallback_ladder(options) -> Tuple[Rung, ...]:
    """The degradation ladder an option set implies, fast rung first.

    pallas → pallas-interpret → pure-XLA reference forward; an int8 request
    additionally ends at the fp32 reference (``int8 → fp32``).  The final
    rung is always the per-layer pure-XLA fp32 reference — the one path
    with no Pallas kernels, no plans, and no quantization to go wrong.
    """
    impl = options.impl
    interpret = options.interpret
    dtype = options.dtype
    rungs = [Rung("primary", impl, interpret, dtype)]
    if impl == "pallas" and interpret is not True:
        rungs.append(Rung("pallas-interpret", "pallas", True, dtype))
    rungs.append(Rung("xla-ref", "xla", None, "float32"))
    return tuple(rungs)


def lm_fallback_ladder() -> Tuple[Rung, ...]:
    """LM decode ladder: the jitted decode step, then the same step run
    eagerly (op-by-op) — the rung that survives XLA compilation bugs."""
    return (
        Rung("jit-decode", "jax", None, "float32"),
        Rung("eager-decode", "jax", None, "float32"),
    )


# ---------------------------------------------------------------------------
# Per-bucket circuit breaker

CLOSED = "CLOSED"
OPEN = "OPEN"
HALF_OPEN = "HALF_OPEN"

DEFAULT_PROBE_AFTER = 4


class CircuitBreaker:
    """CLOSED/HALF_OPEN/OPEN state machine for one bucket's ladder position.

    ``depth`` is the rung currently serving the bucket (0 = fast path).
    CLOSED means healthy at depth 0.  A trip moves ``depth`` down the
    ladder and opens the breaker; after ``probe_after`` dispatches the
    breaker half-opens and the next batch probes the rung above.  A
    successful probe climbs one rung (re-opening the countdown until the
    bucket is back at depth 0); a failed probe re-opens at the current
    depth.  Everything is counted in dispatches, never wall time, so
    recovery is deterministic and provable under fault injection.
    """

    def __init__(self, n_rungs: int, probe_after: int = DEFAULT_PROBE_AFTER):
        self.n_rungs = max(1, int(n_rungs))
        self.probe_after = max(1, int(probe_after))
        self.depth = 0
        self.state = CLOSED
        self.steps_until_probe = 0
        self.trips = 0
        self.recoveries = 0
        self.probes = 0

    def start_rung(self) -> int:
        """The rung this dispatch should attempt first.  Advances the
        OPEN→HALF_OPEN countdown; call exactly once per dispatched batch."""
        if self.state == OPEN and self.depth > 0:
            self.steps_until_probe -= 1
            if self.steps_until_probe <= 0:
                self.state = HALF_OPEN
        if self.state == HALF_OPEN and self.depth > 0:
            self.probes += 1
            return self.depth - 1
        return self.depth

    def settle(self, rung: int) -> None:
        """Record the rung that actually served the batch."""
        if rung < self.depth:
            # Successful probe: climb one rung; keep probing until depth 0.
            self.depth = rung
            self.recoveries += 1
            if self.depth == 0:
                self.state = CLOSED
            else:
                self.state = OPEN
                self.steps_until_probe = self.probe_after
        elif rung > self.depth:
            # Trip: the active rung failed, a deeper one served the batch.
            self.depth = rung
            self.trips += 1
            self.state = OPEN
            self.steps_until_probe = self.probe_after
        elif self.state == HALF_OPEN:
            # Probe failed; the current depth served.  Re-arm the countdown.
            self.state = OPEN
            self.steps_until_probe = self.probe_after
        # rung == depth while CLOSED/OPEN: steady state, nothing to record.

    def exhaust(self) -> None:
        """Every rung failed: pin at the deepest rung and re-arm a probe."""
        self.depth = self.n_rungs - 1
        self.trips += 1
        if self.depth > 0:
            self.state = OPEN
            self.steps_until_probe = self.probe_after
        else:
            self.state = CLOSED

    def snapshot(self) -> Dict[str, Any]:
        return {
            "state": self.state,
            "depth": self.depth,
            "trips": self.trips,
            "recoveries": self.recoveries,
            "probes": self.probes,
            "steps_until_probe": self.steps_until_probe,
        }


# ---------------------------------------------------------------------------
# The mixin both engines thread through


class ResilientEngine:
    """Deadline/backpressure/ladder/breaker machinery shared by the CNN
    bucket-ladder engine and the LM prefill-decode engine.

    The host engine calls ``_resilience_init`` once, implements
    ``_rung_fn(bucket_key, rung_index) -> callable`` (rung 0 must be its
    existing fast path; deeper rungs may build lazily), and routes every
    executor call through ``_guarded_call``.
    """

    def _resilience_init(
        self,
        *,
        ladder: Sequence[Rung],
        max_queue: Optional[int] = None,
        default_deadline_s: Optional[float] = None,
        retries: int = 1,
        fallback: str = "ladder",
        probe_after: int = DEFAULT_PROBE_AFTER,
        clock: Optional[Callable[[], float]] = None,
        faults=None,
    ) -> None:
        ladder = tuple(ladder)
        # fallback="off" keeps only the fast rung: failures surface as
        # request-level results immediately instead of degrading.
        self._ladder = ladder[:1] if fallback == "off" else ladder
        self._max_queue = None if max_queue is None else int(max_queue)
        self._default_deadline_s = (
            None if default_deadline_s is None else float(default_deadline_s)
        )
        self._retries = max(0, int(retries))
        self._probe_after = int(probe_after)
        self._clock = clock if clock is not None else time.monotonic
        self.faults = faults
        self._breakers: Dict[Any, CircuitBreaker] = {}
        self._step_index = 0
        self._res_stats = {
            "evictions": 0,
            "rejections": 0,
            "retries": 0,
            "request_failures": 0,
            "fallback_batches": 0,
            "faults_injected": 0,
        }

    # -- admission / deadlines ------------------------------------------------

    def _now(self) -> float:
        return float(self._clock())

    def _check_admission(self, queue_len: int) -> None:
        if self._max_queue is not None and queue_len >= self._max_queue:
            self._res_stats["rejections"] += 1
            raise Backpressure(queue_len, self._max_queue)

    def _absolute_deadline(
        self, deadline_s: Optional[float]
    ) -> Optional[float]:
        d = deadline_s if deadline_s is not None else self._default_deadline_s
        if d is None:
            return None
        if d <= 0:
            raise InvalidRequest(f"deadline_s must be > 0, got {d}")
        return self._now() + float(d)

    def _split_expired(self, requests, now: float):
        """(live, {uid: DeadlineExceeded}) partition of ``requests``."""
        live, evicted = [], {}
        for r in requests:
            if r.deadline is not None and now >= r.deadline:
                evicted[r.uid] = DeadlineExceeded(
                    uid=r.uid, deadline=r.deadline, now=now
                )
                self._res_stats["evictions"] += 1
            else:
                live.append(r)
        return live, evicted

    # -- the guarded executor call -------------------------------------------

    def _breaker(self, key) -> CircuitBreaker:
        br = self._breakers.get(key)
        if br is None:
            br = CircuitBreaker(len(self._ladder), self._probe_after)
            self._breakers[key] = br
        return br

    def _rung_fn(self, key, rung_index: int) -> Callable:
        raise NotImplementedError       # engine-specific

    def _rows_nonfinite(
        self, out: Any, live: Optional[np.ndarray]
    ) -> Optional[np.ndarray]:
        """Per-row non-finite mask of an executor output (None = no check)."""
        raise NotImplementedError       # engine-specific

    def _invoke(self, key, rung_index: int, fn: Callable, args: Tuple):
        """One executor call, with the fault-injection hook applied."""
        if self.faults is not None:
            from repro.serving.faults import apply_fault

            fault = self.faults.draw(
                step=self._step_index, bucket=key,
                rung=self._ladder[rung_index].name,
            )
            if fault is not None:
                self._res_stats["faults_injected"] += 1
                return apply_fault(fault, fn, args, clock=self._clock)
        return fn(*args)

    def _guarded_call(
        self, key, args: Tuple, live: Optional[np.ndarray] = None
    ) -> Tuple[Any, int, Optional[np.ndarray]]:
        """Run one batch through the ladder: ``(out, rung_index, bad_rows)``.

        Attempts the breaker's rung, retrying ``retries`` times on exception
        or fully-non-finite output, then descends.  Rows that stay
        non-finite while the rest of the batch is healthy are returned as
        ``bad_rows`` for request-level failure — they do not trip the
        breaker.  Raises ``FallbackExhausted`` when every rung failed.
        """
        br = self._breaker(key)
        start = br.start_rung()
        last_err: Optional[BaseException] = None
        for rung in range(start, len(self._ladder)):
            fn = self._rung_fn(key, rung)
            partial: Optional[Tuple[Any, np.ndarray]] = None
            for attempt in range(self._retries + 1):
                if attempt:
                    self._res_stats["retries"] += 1
                try:
                    out = self._invoke(key, rung, fn, args)
                    bad = self._rows_nonfinite(out, live)
                except Exception as e:      # noqa: BLE001 - the whole point
                    last_err = e
                    continue
                if bad is not None and live is not None:
                    # Padded/dead rows hold garbage by design: only live
                    # rows count as poisoned.
                    bad = bad & np.asarray(live, bool)
                if bad is not None and bad.any():
                    live_bad = bad[live] if live is not None else bad
                    if live_bad.size and live_bad.all():
                        # The whole batch is poisoned: rung-level failure.
                        last_err = _NonFiniteOutput(
                            f"rung {self._ladder[rung].name!r} produced a "
                            f"fully non-finite output"
                        )
                        continue
                    # Some rows healthy: request-level, not batch-level.
                    partial = (out, bad)
                    continue
                if rung > 0:
                    self._res_stats["fallback_batches"] += 1
                br.settle(rung)
                return out, rung, None
            if partial is not None:
                # Retries exhausted but most of the batch is fine: serve the
                # healthy rows, fail the poisoned ones at request level.
                if rung > 0:
                    self._res_stats["fallback_batches"] += 1
                br.settle(rung)
                return partial[0], rung, partial[1]
        br.exhaust()
        raise FallbackExhausted(
            f"every fallback rung failed for bucket {key!r} "
            f"(ladder {[r.name for r in self._ladder]}): {last_err!r}"
        ) from last_err

    # -- health ---------------------------------------------------------------

    def health(self) -> Dict[str, Any]:
        """Per-bucket breaker state + engine-wide resilience counters."""
        buckets = {
            str(key): {
                **br.snapshot(),
                "rung": self._ladder[
                    min(br.depth, len(self._ladder) - 1)
                ].name,
            }
            for key, br in sorted(self._breakers.items(), key=lambda kv: str(kv[0]))
        }
        depths = [br.depth for br in self._breakers.values()]
        return {
            "ladder": [r.name for r in self._ladder],
            "buckets": buckets,
            "fallback_depth": max(depths) if depths else 0,
            "queue_len": len(getattr(self, "queue", ())),
            "steps": self._step_index,
            "max_queue": self._max_queue,
            "default_deadline_s": self._default_deadline_s,
            "retries_allowed": self._retries,
            **self._res_stats,
        }


def validate_image(
    image: np.ndarray, want_shape: Tuple[int, ...]
) -> np.ndarray:
    """Admission-time payload validation for image requests.

    One NaN image used to poison every co-batched request's epilogue; the
    cheap check runs once at submit, against the single image, instead of
    per dispatched batch.
    """
    image = np.asarray(image)
    if image.shape != tuple(want_shape):
        raise InvalidRequest(
            f"expected image shape {tuple(want_shape)}, got {image.shape}"
        )
    if image.dtype.kind not in "fiub":
        raise InvalidRequest(
            f"expected a real numeric image dtype, got {image.dtype}"
        )
    if image.dtype.kind == "f" and not np.isfinite(image).all():
        raise InvalidRequest(
            "image payload contains non-finite values (NaN/Inf) — rejected "
            "at submit so it cannot poison a co-batched padded batch"
        )
    return image


def validate_prompt(prompt: np.ndarray, vocab_size: int) -> np.ndarray:
    """Admission-time payload validation for LM prompt requests."""
    arr = np.asarray(prompt)
    if arr.dtype.kind == "f":
        raise InvalidRequest(
            f"prompt must be an integer token array, got {arr.dtype} "
            f"(non-finite or fractional values would corrupt the embedding "
            f"lookup)"
        )
    if arr.dtype.kind not in "iu":
        raise InvalidRequest(
            f"prompt must be an integer token array, got {arr.dtype}"
        )
    if arr.size == 0:
        raise InvalidRequest(
            "empty prompt: decode needs at least one token to condition on"
        )
    arr = arr.astype(np.int32)
    if (arr < 0).any() or (arr >= vocab_size).any():
        raise InvalidRequest(
            f"prompt tokens out of range [0, {vocab_size}): "
            f"min={int(arr.min())} max={int(arr.max())}"
        )
    return arr
