"""CNN serving engine: dynamic batching into planner-known batch buckets.

The LM engine (serving/engine.py) keeps one fixed decode batch; image
serving has the opposite shape problem — requests are independent
single-image forwards, and the efficient batch size is a *planner* decision
(plans are batch-keyed: the im2col-vs-Winograd crossover and the block
tuples shift as activation traffic amortizes the weight terms).  This
engine bridges the two:

  buckets      a small ladder of batch sizes (default 1/4/8).  Each bucket
               gets its own NetworkPlan (warm v4 network cache entry) and
               its own jitted executor.  No shape outside the ladder is
               ever compiled — the standard serving discipline of bounded
               compilation.
  dispatch     ``submit`` enqueues; ``step`` drains the queue through the
               **largest bucket that fills completely**, falling back to
               the smallest bucket that covers the remainder (padded with
               zero images whose outputs are dropped).  ``run`` loops
               ``step`` until the queue is empty; ``infer`` is the
               synchronous whole-array convenience wrapper.

Since the `repro.api` facade landed, the engine is a thin *consumer* of a
``CompiledModel``: planner, cache, per-bucket plans, and the device mesh
all come from one compilation instead of being re-plumbed here.  Build it
as ``repro.compile(model, params, options).serve()``; direct construction
is a deprecation shim that compiles on your behalf.

The engine threads the ``ResilientEngine`` machinery (serving/resilience.py):
``submit`` validates payloads and applies backpressure/deadlines, ``step``
evicts expired requests and routes the executor call through a per-bucket
fallback ladder (pallas → pallas-interpret → pure-XLA fp32 reference) with
a circuit breaker, and ``health()`` reports the degradation state.  With
default options and no faults, all of it is inert: rung 0 *is* the
pre-existing executor and outputs are bit-identical.

Stats record per-bucket batch counts and padded slots, so a deployment can
check its bucket ladder against its real arrival distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import DEFAULT_CACHE_PATH
from repro.serving.resilience import (
    DEFAULT_PROBE_AFTER,
    FallbackExhausted,
    QueueNotDrained,
    RequestFailed,
    ResilientEngine,
    ServingError,
    cnn_fallback_ladder,
    is_failure,
    validate_image,
)


@dataclasses.dataclass
class ImageRequest:
    uid: int
    image: np.ndarray               # (H, W, C) float32
    deadline: Optional[float] = None    # absolute, engine-clock seconds
    priority: int = 0                   # higher dispatches first


class CNNServingEngine(ResilientEngine):
    """Batched CNN inference over a fixed bucket ladder of batch sizes."""

    def __init__(
        self,
        layers: Sequence[Any],
        params: Sequence[Dict],
        input_hw: Tuple[int, int],
        in_channels: int = 3,
        buckets: Sequence[int] = (1, 4, 8),
        impl: str = "jax",
        mode: str = "cost",
        cache_path: Optional[str] = DEFAULT_CACHE_PATH,
        interpret: Optional[bool] = None,
        dtype: Any = "float32",
        planner=None,
        devices: Optional[Sequence[Any]] = None,
        _compiled=None,
        *,
        clock=None,
        faults=None,
        probe_after: int = DEFAULT_PROBE_AFTER,
    ):
        if not buckets or any(int(b) <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        buckets = tuple(sorted({int(b) for b in buckets}))
        if _compiled is None:
            # Direct construction was a deprecated shim for one release
            # (PR 5) and is gone: the engine always consumes a compilation.
            raise TypeError(
                "CNNServingEngine is constructed from a compilation: use "
                "repro.compile(model, params, options).serve() or "
                "CNNServingEngine.from_compiled(compiled)"
            )
        self.compiled = _compiled
        self.planner = _compiled.planner
        self.layers = _compiled.model.layers
        self.input_hw = tuple(_compiled.model.input_hw)
        self.in_channels = _compiled.model.in_channels
        self.buckets = buckets
        self.dtype = _compiled.options.dtype
        # The dtype batches are cast to before entering the executor: under
        # int8 the images stay fp32 (quantization happens per layer inside
        # the jitted network against calibrated scales).
        self.input_dtype = getattr(
            _compiled.options, "input_dtype", self.dtype
        )
        # One executor per bucket, all from the same compilation — plans
        # are batch-keyed, so each bucket resolves its own NetworkPlan and
        # network entry; a warm cache file makes a fresh engine re-tune
        # nothing.  With ``pipeline_stages`` set the buckets are
        # pipeline-backed (each bucket gets its own cost-balanced stage
        # partition from the v6 cache).  Persistence is the compilation's
        # concern: it saves when (and only when) new tunes land and it owns
        # the planner, so the trailing save is a no-op on a warm cache or a
        # shared planner.
        self._executors = {
            b: _compiled._executor_for(b) for b in self.buckets
        }
        self.compiled.save_plans()
        self.queue: List[ImageRequest] = []
        self._uid = 0
        self.stats = {
            "batches": {b: 0 for b in self.buckets},
            "padded_slots": 0,
            "requests": 0,
        }
        opts = _compiled.options
        self._resilience_init(
            ladder=cnn_fallback_ladder(opts),
            max_queue=getattr(opts, "max_queue", None),
            default_deadline_s=getattr(opts, "default_deadline_s", None),
            retries=getattr(opts, "retries", 1),
            fallback=getattr(opts, "fallback", "ladder"),
            probe_after=probe_after,
            clock=clock,
            faults=faults,
        )
        # Fallback rungs are built lazily on first failure: the happy path
        # creates no extra executors, triggers no extra planning, and
        # leaves the plan cache byte-identical to pre-resilience behavior.
        self._fallback_fns: Dict[Tuple[int, int], Any] = {}

    @classmethod
    def from_compiled(cls, compiled, buckets: Optional[Sequence[int]] = None,
                      **kw) -> CNNServingEngine:
        """The facade path (``CompiledModel.serve()``): consume an existing
        compilation — its planner, cache, options, and device mesh.
        Resilience test hooks (``clock=``, ``faults=``, ``probe_after=``)
        pass through."""
        return cls(
            compiled.model.layers, compiled.params, compiled.model.input_hw,
            in_channels=compiled.model.in_channels,
            buckets=tuple(buckets) if buckets else compiled.options.buckets,
            _compiled=compiled, **kw,
        )

    # -- public api ---------------------------------------------------------

    def submit(self, image: np.ndarray, deadline_s: Optional[float] = None,
               priority: int = 0) -> int:
        """Enqueue one (H, W, C) image; returns its uid.

        ``deadline_s`` is a relative budget (None = the options' default);
        an expired request is evicted with a ``DeadlineExceeded`` result.
        Raises ``Backpressure`` when the queue is at ``max_queue`` and
        ``InvalidRequest`` (a ValueError) for bad shape/dtype/non-finite
        payloads — one NaN image must not poison a co-batched padded batch.
        """
        self._check_admission(len(self.queue))
        image = validate_image(
            image, (*self.input_hw, self.in_channels)
        )
        deadline = self._absolute_deadline(deadline_s)
        self._uid += 1
        self.stats["requests"] += 1
        self.queue.append(
            ImageRequest(self._uid, image, deadline=deadline,
                         priority=int(priority))
        )
        return self._uid

    def step(self) -> Dict[int, Any]:
        """Serve one batch from the queue.  Returns uid -> output row (or a
        typed ``DeadlineExceeded``/``RequestFailed`` failure marker).

        Bucket policy: the largest bucket that fills completely from the
        queue; when even the smallest bucket cannot fill, the smallest
        bucket that covers what is pending runs padded (zero images, their
        rows dropped) — latency over utilization at the tail.  Expired
        requests are evicted before dispatch (never served stale); the
        executor call runs through the per-bucket fallback ladder.
        """
        if not self.queue:
            return {}
        # Evict expired work first: a stale result is worse than none.
        live_reqs, results = self._split_expired(self.queue, self._now())
        # Priority order, FIFO within a class: the key is the identity
        # permutation for default priority=0 submissions (stable sort).
        live_reqs.sort(key=lambda r: (-r.priority, r.uid))
        self.queue = live_reqs
        if not self.queue:
            return results
        self._step_index += 1
        pending = len(self.queue)
        full = [b for b in self.buckets if b <= pending]
        bucket = max(full) if full else min(
            b for b in self.buckets if b >= pending
        )
        reqs = self.queue[:bucket]
        del self.queue[:len(reqs)]
        pad = bucket - len(reqs)
        batch = np.stack([r.image for r in reqs])
        if pad:
            batch = np.concatenate(
                [batch, np.zeros((pad, *batch.shape[1:]), batch.dtype)]
            )
            self.stats["padded_slots"] += pad
        live = np.zeros(bucket, bool)
        live[: len(reqs)] = True
        try:
            out, rung, bad_rows = self._guarded_call(
                bucket, (jnp.asarray(batch, self.input_dtype),), live=live
            )
        except FallbackExhausted as e:
            # Batch-level loss surfaces as per-request typed failures: the
            # engine itself survives and the next step starts a fresh probe.
            self._res_stats["request_failures"] += len(reqs)
            for r in reqs:
                results[r.uid] = RequestFailed(
                    uid=r.uid, reason=str(e),
                    rung=self._ladder[-1].name,
                )
            return results
        out = np.asarray(jax.block_until_ready(out))
        self.stats["batches"][bucket] += 1
        rung_name = self._ladder[rung].name
        for i, r in enumerate(reqs):
            if bad_rows is not None and bad_rows[i]:
                # Row-level poison with healthy neighbours: request-level
                # failure, not batch-level — the rest of the batch serves.
                self._res_stats["request_failures"] += 1
                results[r.uid] = RequestFailed(
                    uid=r.uid,
                    reason="non-finite output row survived retries",
                    rung=rung_name,
                )
            else:
                results[r.uid] = out[i]
        return results

    def run(self, max_steps: int = 10_000) -> Dict[int, Any]:
        """Drain the queue.  Returns uid -> output for every request.

        Raises ``QueueNotDrained`` (carrying the partial results and the
        remaining uids) when ``max_steps`` is exhausted with work still
        queued — an incomplete dict silently missing uids made ``infer``
        callers KeyError far from the cause.
        """
        results: Dict[int, Any] = {}
        for _ in range(max_steps):
            if not self.queue:
                break
            results.update(self.step())
        if self.queue:
            raise QueueNotDrained(
                results, [r.uid for r in self.queue], max_steps
            )
        return results

    def infer(self, images: np.ndarray) -> np.ndarray:
        """Synchronous convenience: submit a (N, H, W, C) stack, run, and
        return outputs in submission order.  Raises ``ServingError`` if any
        request came back as a typed failure instead of an output row."""
        uids = [self.submit(img) for img in np.asarray(images)]
        results = self.run()
        failed = {u: results[u] for u in uids if is_failure(results[u])}
        if failed:
            raise ServingError(
                f"{len(failed)}/{len(uids)} request(s) failed: "
                f"{list(failed.values())[:3]}"
            )
        return np.stack([results[u] for u in uids])

    @property
    def warm(self) -> bool:
        """True when every bucket planned from the cache (zero tunes)."""
        return self.planner.stats["tunes"] == 0

    # -- resilience hooks ---------------------------------------------------

    def _rung_fn(self, bucket: int, rung_index: int):
        """The executor for one (bucket, rung).  Rung 0 is the compiled
        fast path untouched; deeper rungs build lazily on first failure."""
        if rung_index == 0:
            return self._executors[bucket]
        key = (bucket, rung_index)
        fn = self._fallback_fns.get(key)
        if fn is None:
            fn = self._build_rung(bucket, self._ladder[rung_index])
            self._fallback_fns[key] = fn
        return fn

    def _build_rung(self, bucket: int, rung):
        compiled = self.compiled
        if rung.name == "pallas-interpret":
            # Same NetworkPlan, same params, interpret-mode kernels: the
            # rung that survives a miscompiled/poisoned lowered kernel
            # while staying bit-compatible with the plan's semantics.
            from repro.core.netplan import NetworkExecutor

            return NetworkExecutor(
                compiled.network_plan(bucket), compiled.params,
                interpret=True,
                devices=getattr(compiled, "_devices", None),
                pretransform=compiled.options.pretransform,
                calibration=getattr(compiled, "calibration", None),
            )
        # "xla-ref": the per-layer pure-XLA fp32 reference forward — no
        # Pallas kernels, no plans, no quantization (int8 degrades to fp32).
        from repro.models.cnn import cnn_forward, fold_batchnorm

        layers = list(self.layers)
        folded = fold_batchnorm(list(compiled.params), layers)
        return jax.jit(
            lambda x: cnn_forward(folded, layers, x, impl="xla")
        )

    def _rows_nonfinite(self, out, live):
        arr = np.asarray(out)
        if arr.dtype.kind != "f":
            return None
        flat = arr.reshape(arr.shape[0], -1)
        return ~np.isfinite(flat).all(axis=1)
