"""CNN serving engine: dynamic batching into planner-known batch buckets.

The LM engine (serving/engine.py) keeps one fixed decode batch; image
serving has the opposite shape problem — requests are independent
single-image forwards, and the efficient batch size is a *planner* decision
(plans are batch-keyed: the im2col-vs-Winograd crossover and the block
tuples shift as activation traffic amortizes the weight terms).  This
engine bridges the two:

  buckets      a small ladder of batch sizes (default 1/4/8).  Each bucket
               gets its own NetworkPlan (warm v4 network cache entry) and
               its own jitted executor.  No shape outside the ladder is
               ever compiled — the standard serving discipline of bounded
               compilation.
  dispatch     ``submit`` enqueues; ``step`` drains the queue through the
               **largest bucket that fills completely**, falling back to
               the smallest bucket that covers the remainder (padded with
               zero images whose outputs are dropped).  ``run`` loops
               ``step`` until the queue is empty; ``infer`` is the
               synchronous whole-array convenience wrapper.

Since the `repro.api` facade landed, the engine is a thin *consumer* of a
``CompiledModel``: planner, cache, per-bucket plans, and the device mesh
all come from one compilation instead of being re-plumbed here.  Build it
as ``repro.compile(model, params, options).serve()``; direct construction
is a deprecation shim that compiles on your behalf.

Stats record per-bucket batch counts and padded slots, so a deployment can
check its bucket ladder against its real arrival distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.planner import DEFAULT_CACHE_PATH


@dataclasses.dataclass
class ImageRequest:
    uid: int
    image: np.ndarray               # (H, W, C) float32


class CNNServingEngine:
    """Batched CNN inference over a fixed bucket ladder of batch sizes."""

    def __init__(
        self,
        layers: Sequence[Any],
        params: Sequence[Dict],
        input_hw: Tuple[int, int],
        in_channels: int = 3,
        buckets: Sequence[int] = (1, 4, 8),
        impl: str = "jax",
        mode: str = "cost",
        cache_path: Optional[str] = DEFAULT_CACHE_PATH,
        interpret: Optional[bool] = None,
        dtype: Any = "float32",
        planner=None,
        devices: Optional[Sequence[Any]] = None,
        _compiled=None,
    ):
        if not buckets or any(int(b) <= 0 for b in buckets):
            raise ValueError(f"buckets must be positive, got {buckets!r}")
        buckets = tuple(sorted({int(b) for b in buckets}))
        if _compiled is None:
            # Legacy direct construction: compile on the caller's behalf.
            from repro._deprecation import warn_once
            from repro.api import CNNModel, ExecutionOptions
            from repro.api import compile as api_compile
            from repro.core.planner import _dtype_name

            warn_once(
                "serving.CNNServingEngine(layers, params, ...)",
                "repro.compile(model, params, options).serve()",
            )
            model = CNNModel(tuple(layers), tuple(input_hw),
                             in_channels=in_channels, name="cnn-serving")
            options = ExecutionOptions(
                impl=impl, mode=mode, cache_path=cache_path,
                interpret=interpret, dtype=_dtype_name(dtype),
                batch=buckets[0], buckets=buckets,
            )
            _compiled = api_compile(model, params, options, planner=planner,
                                    devices=devices)
        self.compiled = _compiled
        self.planner = _compiled.planner
        self.layers = _compiled.model.layers
        self.input_hw = tuple(_compiled.model.input_hw)
        self.in_channels = _compiled.model.in_channels
        self.buckets = buckets
        self.dtype = _compiled.options.dtype
        # The dtype batches are cast to before entering the executor: under
        # int8 the images stay fp32 (quantization happens per layer inside
        # the jitted network against calibrated scales).
        self.input_dtype = getattr(
            _compiled.options, "input_dtype", self.dtype
        )
        # One executor per bucket, all from the same compilation — plans
        # are batch-keyed, so each bucket resolves its own NetworkPlan and
        # network entry; a warm cache file makes a fresh engine re-tune
        # nothing.  Persistence is the compilation's concern: it saves when
        # (and only when) new tunes land and it owns the planner, so the
        # trailing save is a no-op on a warm cache or a shared planner.
        self._executors = {b: _compiled.executor(b) for b in self.buckets}
        self.compiled.save_plans()
        self.queue: List[ImageRequest] = []
        self._uid = 0
        self.stats = {
            "batches": {b: 0 for b in self.buckets},
            "padded_slots": 0,
            "requests": 0,
        }

    @classmethod
    def from_compiled(cls, compiled, buckets: Optional[Sequence[int]] = None,
                      ) -> "CNNServingEngine":
        """The facade path (``CompiledModel.serve()``): consume an existing
        compilation — its planner, cache, options, and device mesh."""
        return cls(
            compiled.model.layers, compiled.params, compiled.model.input_hw,
            in_channels=compiled.model.in_channels,
            buckets=tuple(buckets) if buckets else compiled.options.buckets,
            _compiled=compiled,
        )

    # -- public api ---------------------------------------------------------

    def submit(self, image: np.ndarray) -> int:
        """Enqueue one (H, W, C) image; returns its uid."""
        image = np.asarray(image)
        want = (*self.input_hw, self.in_channels)
        if image.shape != want:
            raise ValueError(f"expected image shape {want}, got {image.shape}")
        self._uid += 1
        self.stats["requests"] += 1
        self.queue.append(ImageRequest(self._uid, image))
        return self._uid

    def step(self) -> Dict[int, np.ndarray]:
        """Serve one batch from the queue.  Returns uid -> output row.

        Bucket policy: the largest bucket that fills completely from the
        queue; when even the smallest bucket cannot fill, the smallest
        bucket that covers what is pending runs padded (zero images, their
        rows dropped) — latency over utilization at the tail.
        """
        if not self.queue:
            return {}
        pending = len(self.queue)
        full = [b for b in self.buckets if b <= pending]
        bucket = max(full) if full else min(
            b for b in self.buckets if b >= pending
        )
        reqs = self.queue[:bucket]
        del self.queue[:len(reqs)]
        pad = bucket - len(reqs)
        batch = np.stack([r.image for r in reqs])
        if pad:
            batch = np.concatenate(
                [batch, np.zeros((pad, *batch.shape[1:]), batch.dtype)]
            )
            self.stats["padded_slots"] += pad
        self.stats["batches"][bucket] += 1
        out = np.asarray(
            jax.block_until_ready(
                self._executors[bucket](jnp.asarray(batch, self.input_dtype))
            )
        )
        return {r.uid: out[i] for i, r in enumerate(reqs)}

    def run(self, max_steps: int = 10_000) -> Dict[int, np.ndarray]:
        """Drain the queue.  Returns uid -> output for every request."""
        results: Dict[int, np.ndarray] = {}
        for _ in range(max_steps):
            if not self.queue:
                break
            results.update(self.step())
        return results

    def infer(self, images: np.ndarray) -> np.ndarray:
        """Synchronous convenience: submit a (N, H, W, C) stack, run, and
        return outputs in submission order."""
        uids = [self.submit(img) for img in np.asarray(images)]
        results = self.run()
        return np.stack([results[u] for u in uids])

    @property
    def warm(self) -> bool:
        """True when every bucket planned from the cache (zero tunes)."""
        return self.planner.stats["tunes"] == 0
