"""Process-wide mesh context for mesh-agnostic models.

Models annotate activations with *named* axis hints via ``shard_hint``;
the hints only take effect when a launcher (dryrun/train/serve) has
installed a mesh with ``use_mesh``.  Axis names absent from the installed
mesh are dropped, so the same model code runs on 1 CPU device, a
single-pod (data, model) mesh, or the multi-pod (pod, data, model) mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(prev)


AxisHint = Union[None, str, Sequence[str]]


def _resolve(axis: AxisHint, names) -> Union[None, str, tuple]:
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in names else None
    present = tuple(a for a in axis if a in names)
    return present if len(present) > 1 else (present[0] if present else None)


def set_axis_mode(mode: str) -> None:
    """'default', 'dp_only', or 'dp_seq'.

    dp_only: pure data parallelism — the TP axis joins the batch axes and
    model-dim hints are dropped (small archs, batch >= device count).
    dp_seq: data x sequence (context) parallelism — batch over the DP axes,
    the sequence dim over the freed 'model' axis (small-arch prefill, where
    batch < device count would leave the model axis idle)."""
    _state.axis_mode = mode


def get_axis_mode() -> str:
    return getattr(_state, "axis_mode", "default")


def largest_divisible_subset(dim: int, axes, sizes) -> tuple:
    """Longest prefix-preferring subset of ``axes`` whose size product
    divides ``dim`` (greedy: keep an axis if divisibility still holds)."""
    kept = []
    prod = 1
    for a in axes:
        if dim % (prod * sizes[a]) == 0:
            kept.append(a)
            prod *= sizes[a]
    return tuple(kept)


def shard_hint(x: jax.Array, *axes: AxisHint) -> jax.Array:
    """Constrain ``x``'s sharding if a mesh is installed; no-op otherwise.

    Each positional arg names the mesh axis (or tuple of axes) for the
    corresponding array dim; trailing dims default to unsharded.  Axis
    groups shrink to their largest subset that divides the dim (so batch=32
    over 256 devices still shards 16-way instead of replicating).
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    mode = get_axis_mode()
    if mode == "dp_only":
        axes = tuple(
            (("pod", "data", "model") if (a == BATCH or a == ("pod", "data"))
             else None if a == MODEL else a)
            for a in axes
        )
    elif mode == "dp_seq":
        axes = tuple(None if a == MODEL else a for a in axes)
        # Sequence dim (dim 1 of activation hints) rides the model axis.
        if len(axes) >= 3 and axes[1] is None:
            axes = axes[:1] + ("model",) + axes[2:]
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, entry in zip(x.shape, axes):
        entry = _resolve(entry, names)
        if entry is None:
            fixed.append(None)
            continue
        ax = (entry,) if isinstance(entry, str) else tuple(entry)
        kept = largest_divisible_subset(dim, ax, sizes)
        fixed.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    spec = P(*fixed)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# Conventional axis groupings used across the model zoo.
BATCH = ("pod", "data")   # DP axes
MODEL = "model"           # TP axis
