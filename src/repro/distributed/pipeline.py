"""Pipeline parallelism: GPipe-style microbatch schedule via shard_map +
lax.ppermute over a 'stage' mesh axis.

Two layers live here:

  gpipe_spmd / pipeline_forward
      the generic schedule: stage_fn(stage_params, x) replicated over a
      1-D stage mesh, activations flowing by collective-permute with the
      classic (n_micro + n_stages - 1)-tick bubble.  Drain ticks skip the
      stage body entirely (lax.cond) instead of recomputing a clamped
      duplicate microbatch, so ``stage_fn`` must be collective-free — its
      compute is data-parallel per microbatch, which every CNN stage body
      is.

  PipelineExecutor
      the planned CNN instantiation: a ``NetworkPlan`` split by a
      ``PipelinePlan`` (core/netplan.partition_network) into contiguous
      stages, each stage's *prepared* params resident only on its device
      (stacked dtype-grouped buffers sharded over the stage axis), and the
      per-stage compute still running the planned Pallas kernels via
      ``run_network(start=, stop=)``.  CNN stages have heterogeneous
      activation shapes, so boundary activations travel as fixed-size
      zero-padded flat buffers and each device selects its static-shaped
      stage body with ``lax.switch`` on the device-varying stage index.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

# jax >= 0.5 requires carries that differ per device to be marked
# device-varying over the mesh axis (vma tracking); older versions have no
# pvary and no tracking — the identity is exactly right there.
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def gpipe_spmd(stage_fn: Callable, axis_name: str, n_stages: int,
               n_micro: int) -> Callable:
    """Build the per-device SPMD body running inside shard_map.

    stage_fn(stage_params, x) -> y: one stage's compute on one microbatch.
    The wrapped fn takes (stage_params, microbatches (n_micro, mb, ...)) and
    returns the pipeline output (n_micro, mb, ...), valid on the LAST stage
    (earlier stages return zeros — callers read the last stage's shard).

    A stage is *active* at tick t iff t >= stage and t - stage < n_micro;
    outside that window (fill on late stages, drain on early ones) the body
    is skipped via ``lax.cond`` — stage 0 no longer burns FLOPs recomputing
    the last microbatch for ``n_stages - 1`` drain ticks.  The skip requires
    ``stage_fn`` to be collective-free (the ppermute stays outside the
    cond, unconditional, so the SPMD program keeps identical collectives on
    every device).
    """

    def run(stage_params, micro):
        stage = jax.lax.axis_index(axis_name)
        mb_shape = micro.shape[1:]
        total = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            recv, outs = carry
            # Stage 0 injects microbatch t while t is in range; drain ticks
            # (t >= n_micro) feed zeros and the cond below skips the body.
            in_range = t < n_micro
            idx = jnp.where(in_range, t, 0)
            x0 = jnp.where(
                in_range,
                jax.lax.dynamic_index_in_dim(micro, idx, 0, keepdims=False),
                jnp.zeros(mb_shape, micro.dtype),
            )
            x_in = jnp.where(stage == 0, x0, recv)
            active = (t >= stage) & (t - stage < n_micro)
            y = jax.lax.cond(
                active,
                lambda b: stage_fn(stage_params, b),
                jnp.zeros_like,
                x_in,
            )
            # Collect at the last stage: output for microbatch t-(S-1).
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid = (stage == n_stages - 1) & (t - (n_stages - 1) >= 0)
            cur = jax.lax.dynamic_index_in_dim(outs, out_idx, 0,
                                               keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(valid, y, cur), out_idx, 0
            )
            recv_next = jax.lax.ppermute(y, axis_name, perm)
            return (recv_next, outs), None

        # Mark the carries as device-varying over the stage axis (each stage
        # holds different values), required under shard_map's vma tracking.
        outs0 = _pvary(
            jnp.zeros((n_micro,) + mb_shape, micro.dtype), (axis_name,)
        )
        recv0 = _pvary(jnp.zeros(mb_shape, micro.dtype), (axis_name,))
        (_, outs), _ = jax.lax.scan(tick, (recv0, outs0), jnp.arange(total))
        return outs

    return run


def pipeline_forward(
    mesh: Mesh,
    stage_fn: Callable,
    stacked_params,
    x: jnp.ndarray,
    n_micro: int,
    stage_axis: str = "stage",
) -> jnp.ndarray:
    """Run x (batch, ...) through n_stages pipeline stages on ``mesh``.

    stacked_params: pytree with leading dim n_stages (stage s's params live
    on stage s's devices via sharding on ``stage_axis``).
    """
    n_stages = mesh.shape[stage_axis]
    assert x.shape[0] % n_micro == 0
    micro = x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

    def spmd(params, mb):
        # Inside shard_map the stacked dim is 1 per device; drop it.
        local = jax.tree.map(lambda p: p[0], params)
        run = gpipe_spmd(stage_fn, stage_axis, n_stages, n_micro)
        out = run(local, mb)
        # Broadcast the last stage's result to all stages so the output
        # spec can be replicated over the stage axis.  zeros_like, not 0.0:
        # a float literal would upcast (and for int8 outputs break) the
        # psum's operand dtype.
        last = jax.lax.psum(
            jnp.where(
                jax.lax.axis_index(stage_axis) == n_stages - 1,
                out,
                jnp.zeros_like(out),
            ),
            stage_axis,
        )
        return last

    fn = shard_map(
        spmd,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    out = fn(stacked_params, micro)
    return out.reshape(x.shape[0], *out.shape[2:])


# ---------------------------------------------------------------------------
# Planned CNN pipeline executor


def _flatten_stage_params(
    stage_params: Sequence[Any],
) -> Tuple[Any, List[Tuple[Tuple[int, ...], str, int]], Dict[str, int]]:
    """(treedef, per-leaf (shape, dtype name, offset-within-dtype-buffer),
    per-dtype total sizes) for one stage's prepared param slice."""
    leaves, treedef = jax.tree_util.tree_flatten(list(stage_params))
    meta: List[Tuple[Tuple[int, ...], str, int]] = []
    sizes: Dict[str, int] = {}
    for leaf in leaves:
        arr = jnp.asarray(leaf)
        dt = str(arr.dtype)
        meta.append((tuple(arr.shape), dt, sizes.get(dt, 0)))
        sizes[dt] = sizes.get(dt, 0) + arr.size
    return treedef, meta, sizes


def _pack_stage_params(
    per_stage: Sequence[Sequence[Any]],
) -> Tuple[Dict[str, jnp.ndarray], List[Any], List[Any]]:
    """Stack every stage's prepared params into dtype-grouped buffers.

    Stages hold structurally different parameter slices (different layer
    counts, int8 vs fp32 leaves, Winograd-pretransformed shapes), but
    shard_map needs one pytree with a uniform ``n_stages`` leading dim.
    Each stage's leaves are flattened and concatenated per dtype, padded to
    the max across stages: ``{dtype: (n_stages, Pmax_dtype)}``.  Returns
    (buffers, per-stage treedefs, per-stage leaf metadata) — the metadata
    lets each ``lax.switch`` branch statically slice its own leaves back
    out of the local row.
    """
    treedefs, metas, sizes = [], [], []
    for sp in per_stage:
        td, meta, sz = _flatten_stage_params(sp)
        treedefs.append(td)
        metas.append(meta)
        sizes.append(sz)
    dtypes = sorted({dt for sz in sizes for dt in sz})
    buffers: Dict[str, jnp.ndarray] = {}
    for dt in dtypes:
        pmax = max(sz.get(dt, 0) for sz in sizes)
        rows = []
        for sp, _sz in zip(per_stage, sizes):
            leaves, _ = jax.tree_util.tree_flatten(list(sp))
            flat = [
                jnp.asarray(leaf).reshape(-1)
                for leaf in leaves
                if str(jnp.asarray(leaf).dtype) == dt
            ]
            row = (
                jnp.concatenate(flat)
                if flat else jnp.zeros((0,), dtype=dt)
            )
            rows.append(jnp.pad(row, (0, pmax - row.size)))
        buffers[dt] = jnp.stack(rows)
    return buffers, treedefs, metas


def _unpack_stage_params(
    local: Dict[str, jnp.ndarray], treedef, meta
) -> List[Any]:
    """Rebuild one stage's prepared param list from its local buffer row."""
    leaves = [
        jax.lax.dynamic_slice_in_dim(local[dt], off, _size(shape)).reshape(
            shape
        )
        for shape, dt, off in meta
    ]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _size(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


class PipelineExecutor:
    """Layer-pipelined inference: a NetworkPlan split across a stage mesh.

    Mirrors ``NetworkExecutor``'s contract (prepare offline, jit once,
    ``__call__(x)`` at the planned batch) but runs the ``PipelinePlan``'s
    stages on distinct devices with GPipe microbatching: each device holds
    only its stage's prepared params, boundary activations (always
    logically laid out — the partitioner forbids cuts inside an elision
    chain) flow by ppermute as zero-padded flat buffers, and every stage
    body is the planned ``run_network`` slice, Pallas kernels included.
    """

    def __init__(
        self,
        netplan,
        pipeplan,
        params: Sequence[Dict],
        interpret: Optional[bool] = None,
        devices: Optional[Sequence[Any]] = None,
        pretransform: bool = True,
        prepared: bool = False,
        calibration: Optional[jnp.ndarray] = None,
        n_micro: Optional[int] = None,
    ):
        from repro.core.netplan import (
            prepare_net_params,
            pretransform_flags,
            run_network,
        )
        from repro.launch.mesh import make_stage_mesh

        self.netplan = netplan
        self.pipeplan = pipeplan
        n_stages = pipeplan.n_stages
        self.n_micro = int(n_micro if n_micro is not None else
                           pipeplan.n_micro)
        if netplan.batch % self.n_micro:
            raise ValueError(
                f"n_micro={self.n_micro} does not divide batch "
                f"{netplan.batch}"
            )
        mb = netplan.batch // self.n_micro
        self.params = (
            list(params) if prepared
            else prepare_net_params(netplan, params,
                                    pretransform=pretransform,
                                    calibration=calibration)
        )
        self.pretransformed = pretransform_flags(netplan, pretransform)
        self.mesh = make_stage_mesh(n_stages, devices=devices)

        # int8 networks still pipe fp32 activations (quantization happens
        # per layer inside the stage body, core/netplan.run_network).
        act_dtype = (
            "float32" if netplan.dtype_name == "int8" else netplan.dtype_name
        )

        # Stage-boundary shapes at microbatch size, by abstract evaluation
        # of each stage slice in order (robust to avgpool/fc rank changes).
        flags = self.pretransformed
        bounds = pipeplan.stage_bounds
        per_stage = [
            self.params[a:z] for a, z in bounds
        ]
        in_shapes: List[Tuple[int, ...]] = []
        cur = jax.ShapeDtypeStruct(
            (mb, *netplan.input_hw, netplan.in_channels), act_dtype
        )
        for (a, z), sp in zip(bounds, per_stage):
            in_shapes.append(tuple(cur.shape))
            cur = jax.eval_shape(
                lambda xx, sp=sp, a=a, z=z: run_network(
                    netplan, sp, xx, interpret=interpret,
                    pretransformed=flags, start=a, stop=z,
                ),
                cur,
            )
        out_shape = tuple(cur.shape)
        self._out_shape = out_shape
        sizes = [_size(s) for s in in_shapes] + [_size(out_shape)]
        amax = max(sizes)

        pbufs, treedefs, metas = _pack_stage_params(per_stage)
        self._pbufs = pbufs

        def make_branch(s: int):
            a, z = bounds[s]
            in_shape, sp_meta, td = in_shapes[s], metas[s], treedefs[s]

            def branch(local, xbuf):
                sp = _unpack_stage_params(local, td, sp_meta)
                x = jax.lax.dynamic_slice_in_dim(
                    xbuf, 0, _size(in_shape)
                ).reshape(in_shape)
                y = run_network(
                    netplan, sp, x, interpret=interpret,
                    pretransformed=flags, start=a, stop=z,
                )
                flat = y.reshape(-1).astype(xbuf.dtype)
                return jnp.pad(flat, (0, amax - flat.size))

            return branch

        branches = [make_branch(s) for s in range(n_stages)]

        def spmd(bufs, micro):
            local = {k: v[0] for k, v in bufs.items()}
            stage = jax.lax.axis_index("stage")

            def stage_fn(loc, xbuf):
                return jax.lax.switch(
                    stage, [lambda b, s=s: branches[s](loc, b)
                            for s in range(n_stages)], xbuf
                )

            run = gpipe_spmd(stage_fn, "stage", n_stages, self.n_micro)
            outs = run(local, micro)
            return jax.lax.psum(
                jnp.where(stage == n_stages - 1, outs,
                          jnp.zeros_like(outs)),
                "stage",
            )

        sharded = shard_map(
            spmd,
            mesh=self.mesh,
            in_specs=(P("stage"), P()),
            out_specs=P(),
            check_rep=False,
        )
        n_micro_, batch = self.n_micro, netplan.batch

        def fwd(bufs, x):
            micro = x.astype(act_dtype).reshape(n_micro_, -1)
            micro = jnp.pad(micro, ((0, 0), (0, amax - micro.shape[1])))
            out = sharded(bufs, micro)          # (n_micro, amax)
            out = out[:, :_size(out_shape)]
            return out.reshape(batch, *out_shape[1:])

        self._fn = jax.jit(fwd)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        b, h, w = x.shape[0], x.shape[1], x.shape[2]
        assert (h, w) == self.netplan.input_hw and b == self.netplan.batch, (
            f"pipeline executor planned for batch {self.netplan.batch} at "
            f"{self.netplan.input_hw}, got {x.shape}"
        )
        return self._fn(self._pbufs, x)
