"""Pipeline parallelism: GPipe-style microbatch schedule via shard_map +
lax.ppermute over a 'stage' mesh axis.

Opt-in layer: the default dry-run mesh uses (pod, data, model), but the
launcher can dedicate an axis (typically 'pod' or part of 'data') as the
stage axis for deep models.  Each stage holds its slice of the stacked
layer params; activations flow stage->stage by collective-permute, with
the classic (n_micro + n_stages - 1)-tick bubble schedule.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe_spmd(stage_fn: Callable, axis_name: str, n_stages: int,
               n_micro: int) -> Callable:
    """Build the per-device SPMD body running inside shard_map.

    stage_fn(stage_params, x) -> y: one stage's compute on one microbatch.
    The wrapped fn takes (stage_params, microbatches (n_micro, mb, ...)) and
    returns the pipeline output (n_micro, mb, ...), valid on the LAST stage
    (earlier stages return zeros — callers read the last stage's shard).
    """

    def run(stage_params, micro):
        stage = jax.lax.axis_index(axis_name)
        mb_shape = micro.shape[1:]
        total = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            recv, outs = carry
            # Stage 0 injects microbatch t (when in range); others consume recv.
            idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage == 0, micro[idx], recv)
            y = stage_fn(stage_params, x_in)
            # Collect at the last stage: output for microbatch t-(S-1).
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid = (stage == n_stages - 1) & (t - (n_stages - 1) >= 0)
            cur = jax.lax.dynamic_index_in_dim(outs, out_idx, 0,
                                               keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(valid, y, cur), out_idx, 0
            )
            recv_next = jax.lax.ppermute(y, axis_name, perm)
            return (recv_next, outs), None

        # Mark the carries as device-varying over the stage axis (each stage
        # holds different values), required under shard_map's vma tracking.
        outs0 = jax.lax.pvary(
            jnp.zeros((n_micro,) + mb_shape, micro.dtype), (axis_name,)
        )
        recv0 = jax.lax.pvary(jnp.zeros(mb_shape, micro.dtype), (axis_name,))
        (_, outs), _ = jax.lax.scan(tick, (recv0, outs0), jnp.arange(total))
        return outs

    return run


def pipeline_forward(
    mesh: Mesh,
    stage_fn: Callable,
    stacked_params,
    x: jnp.ndarray,
    n_micro: int,
    stage_axis: str = "stage",
) -> jnp.ndarray:
    """Run x (batch, ...) through n_stages pipeline stages on ``mesh``.

    stacked_params: pytree with leading dim n_stages (stage s's params live
    on stage s's devices via sharding on ``stage_axis``).
    """
    n_stages = mesh.shape[stage_axis]
    assert x.shape[0] % n_micro == 0
    micro = x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

    def spmd(params, mb):
        # Inside shard_map the stacked dim is 1 per device; drop it.
        local = jax.tree.map(lambda p: p[0], params)
        run = gpipe_spmd(stage_fn, stage_axis, n_stages, n_micro)
        out = run(local, mb)
        # Broadcast the last stage's result to all stages so the output
        # spec can be replicated over the stage axis.
        last = jax.lax.psum(
            jnp.where(jax.lax.axis_index(stage_axis) == n_stages - 1, out, 0.0),
            stage_axis,
        )
        return last

    fn = shard_map(
        spmd,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
    )
    out = fn(stacked_params, micro)
    return out.reshape(x.shape[0], *out.shape[2:])
