"""Distribution layer: mesh context, partition rules, pipeline parallelism,
gradient compression, fault tolerance / elastic re-mesh."""
