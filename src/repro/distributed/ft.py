"""Fault-tolerance machinery: heartbeats, failure detection, straggler
monitoring, and the elastic re-mesh planner.

On a real cluster each host runs this against a shared filesystem (or a
KV store with the same protocol).  All logic is deterministic and
unit-tested; the training loop (train/loop.py) drives the single-host
instance of the same state machine.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple


class Heartbeat:
    """Rank-R liveness file: {'rank', 'step', 'time'} rewritten atomically."""

    def __init__(self, directory: str, rank: int):
        self.dir = directory
        self.rank = rank
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, f"rank_{rank}.json")

    def beat(self, step: int, now: Optional[float] = None) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"rank": self.rank, "step": step,
                       "time": now if now is not None else time.time()}, f)
        os.replace(tmp, self.path)


class FailureDetector:
    """Declares ranks dead after ``timeout`` seconds without a heartbeat."""

    def __init__(self, directory: str, world_size: int, timeout: float = 60.0):
        self.dir = directory
        self.world_size = world_size
        self.timeout = timeout

    def read(self) -> Dict[int, dict]:
        beats = {}
        for r in range(self.world_size):
            path = os.path.join(self.dir, f"rank_{r}.json")
            with contextlib.suppress(FileNotFoundError, json.JSONDecodeError):
                beats[r] = json.load(open(path))
        return beats

    def dead_ranks(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        beats = self.read()
        dead = []
        for r in range(self.world_size):
            b = beats.get(r)
            if b is None or now - b["time"] > self.timeout:
                dead.append(r)
        return dead


class StragglerMonitor:
    """Flags steps slower than ``threshold`` x the rolling-median step time.

    On a real deployment the flag feeds the coordinator, which can evict a
    persistently slow host into the spare pool (see ElasticPlanner).
    """

    def __init__(self, window: int = 20, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: List[float] = []
        self.slow_count = 0

    def record(self, step_time: float) -> bool:
        self.times.append(step_time)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = sorted(self.times)[len(self.times) // 2]
        is_slow = len(self.times) >= 5 and step_time > self.threshold * med
        if is_slow:
            self.slow_count += 1
        return is_slow


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Outcome of a re-mesh decision after failures."""

    healthy_hosts: Tuple[int, ...]
    new_mesh_shape: Tuple[int, ...]
    restart_from_checkpoint: bool
    dropped_hosts: Tuple[int, ...]


class ElasticPlanner:
    """Re-mesh policy: shrink the DP axis to the largest feasible size that
    keeps the model (TP) axis intact.

    Mesh (data, model): TP is wired intra-host/pod (fixed), so failures
    remove whole DP rows.  Training restarts from the last checkpoint with
    the per-host batch rebalanced (global batch is preserved by raising
    grad-accum; see plan.grad_accum_factor).
    """

    def __init__(self, mesh_shape: Sequence[int], hosts_per_dp_row: int = 1,
                 min_dp: int = 1):
        self.mesh_shape = tuple(mesh_shape)  # (..., data, model)
        self.hosts_per_dp_row = hosts_per_dp_row
        self.min_dp = min_dp

    def plan(self, world_size: int, dead: Sequence[int]) -> ElasticPlan:
        healthy = tuple(r for r in range(world_size) if r not in set(dead))
        *lead, dp, tp = self.mesh_shape
        rows_lost = set()
        for r in dead:
            rows_lost.add(r // self.hosts_per_dp_row)
        new_dp = dp - len({row for row in rows_lost if row < dp})
        # Keep DP a power-of-two divisor of the original (collective-friendly).
        while new_dp >= self.min_dp and dp % new_dp != 0:
            new_dp -= 1
        new_dp = max(new_dp, self.min_dp)
        return ElasticPlan(
            healthy_hosts=healthy,
            new_mesh_shape=tuple(lead) + (new_dp, tp),
            restart_from_checkpoint=bool(dead),
            dropped_hosts=tuple(sorted(dead)),
        )

    def grad_accum_factor(self, plan: ElasticPlan) -> int:
        """Multiplier that preserves global batch after the DP shrink."""
        old_dp = self.mesh_shape[-2]
        new_dp = plan.new_mesh_shape[-2]
        return max(1, old_dp // max(new_dp, 1))
