"""Partition rules: param-path regex -> PartitionSpec, plus ZeRO sharding
of optimizer state across the DP axes.

Megatron-style TP on the 'model' axis:
  - column-parallel up-projections (wq/wk/wv, w_gate, w_up) shard the output
    feature dim; row-parallel down-projections (wo, w_down) shard the input
    dim -> one psum per block.
  - vocab-parallel embeddings/head shard the vocab dim.
  - MoE expert banks shard experts over the DP axes (EP) x features over
    'model' (TP) — the arctic-480b memory plan (DESIGN.md §5).
Optimizer moments additionally shard over ('pod','data') where divisible
(ZeRO): see ``zero_spec``.
"""
from __future__ import annotations

import re
from typing import Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP = ("pod", "data")
TP = "model"

# (regex over the flattened param path, spec builder).  Paths look like
# 'period/0:attn/mixer/wq' or 'tail/1:local/mlp/w_down'.
_RULES: Sequence[Tuple[str, Tuple]] = (
    (r"embed/table$",            (TP, None)),        # vocab-parallel
    (r"head$",                   (None, TP)),
    (r"frontend_proj$",          (None, TP)),
    (r"mixer/w[qkv]$",           (None, TP)),        # column-parallel
    (r"mixer/b[qkv]$",           (TP,)),
    (r"mixer/wo$",               (TP, None)),        # row-parallel
    (r"(mlp|dense_mlp)/w_(gate|up)$", (None, TP)),
    (r"(mlp|dense_mlp)/b_up$",   (TP,)),
    (r"(mlp|dense_mlp)/w_down$", (TP, None)),
    (r"(mlp|dense_mlp)/b_down$", (None,)),
    (r"moe/router$",             (None, None)),
    (r"moe/w_(gate|up)$",        (DP, None, TP)),    # EP x TP
    (r"moe/w_down$",             (DP, TP, None)),
    (r"mixer/w_(y|gate)$",       (None, TP)),        # rglru branches
    (r"mixer/w_out$",            (TP, None)),
    (r"mixer/conv_w$",           (None, TP)),
    (r"mixer/conv_b$",           (TP,)),
    (r"mixer/w_[ax]$",           (None, TP)),
    (r"mixer/b_[ax]$",           (TP,)),
    (r"mixer/lam$",              (TP,)),
    (r"mixer/w_up$",             (None, TP)),        # mlstm up (d, 2d)
    (r"mixer/w_down$",           (TP, None)),
    (r"mixer/w_[if]$",           (None, None)),      # tiny per-head gates
    (r"mixer/b_[if]$",           (None,)),
    (r"mixer/w_in$",             (None, TP)),        # slstm
    (r"mixer/b_in$",             (TP,)),
    (r"mixer/r$",                (None, None, None)),
    (r"mixer/out_norm$",         (None,)),
    (r"(norm1|norm2|post_norm1|post_norm2|final_norm)$", (None,)),
)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def spec_for_path(path_str: str, stacked: bool) -> P:
    """PartitionSpec for one param; ``stacked`` prepends the scan dim."""
    for pat, spec in _RULES:
        if re.search(pat, path_str):
            full = ((None,) + tuple(spec)) if stacked else tuple(spec)
            return P(*full)
    return P()  # replicate by default (scalars, unmatched leaves)


def _filter_axes(spec: P, mesh: Mesh) -> P:
    names = set(mesh.axis_names)

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in names else None
        kept = tuple(a for a in entry if a in names)
        return kept if len(kept) > 1 else (kept[0] if kept else None)

    return P(*(fix(e) for e in spec))


def _divisible(shape, spec: P, mesh: Mesh) -> bool:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        need = int(np.prod([sizes[a] for a in axes]))
        if dim % need != 0:
            return False
    return True


def param_sharding(params, mesh: Mesh):
    """NamedSharding pytree for a param pytree (stacked 'period' subtrees
    get the leading scan dim unsharded)."""

    def one(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith("period/")
        spec = _filter_axes(spec_for_path(ps, stacked), mesh)
        if not _divisible(leaf.shape, spec, mesh):
            spec = P()  # fall back to replication rather than mis-shard
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def zero_spec(shape, spec: P, mesh: Mesh, dp_axes=DP) -> P:
    """Add ZeRO: shard the first free, divisible dim of an optimizer-moment
    tensor over the DP axes (on top of its param's TP sharding)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in dp_axes if a in sizes)
    if not dp:
        return spec
    dp_size = int(np.prod([sizes[a] for a in dp]))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # Already DP-sharded somewhere (e.g. MoE expert banks)?  Nothing to add.
    used = set()
    for e in entries:
        for a in ((e,) if isinstance(e, str) else (e or ())):
            used.add(a)
    if used & set(dp):
        return spec
    for i, (dim, entry) in enumerate(zip(shape, entries)):
        if entry is None and dim % dp_size == 0:
            entries[i] = dp if len(dp) > 1 else dp[0]
            return P(*entries)
        if entry is not None:
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            tp_size = int(np.prod([sizes[a] for a in axes]))
            if dim % (tp_size * dp_size) == 0:
                entries[i] = tuple(dp) + axes
                return P(*entries)
    return spec  # nothing divisible: leave as the param spec


def opt_state_sharding(opt_state, params, mesh: Mesh, dp_axes=DP, psh=None):
    """Sharding for AdamWState: step replicated; moments = param spec + ZeRO
    over ``dp_axes``.

    int8 QTensor moments are always (-1, 256)-blocked, so their block dim
    shards across DP x TP uniformly.
    """
    from repro.optim.quantized_state import QTensor

    psh = psh if psh is not None else param_sharding(params, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    all_ax = tuple(a for a in ("pod", "data", "model") if a in sizes)
    total = int(np.prod([sizes[a] for a in all_ax]))

    def build(m_leaf, sh_leaf):
        if isinstance(m_leaf, QTensor):
            nblocks = m_leaf.q.shape[0]
            ax = all_ax if (total and nblocks % total == 0) else ()
            entry = ax if len(ax) > 1 else (ax[0] if ax else None)
            return QTensor(
                NamedSharding(mesh, P(entry, None)),
                NamedSharding(mesh, P(entry)),
                m_leaf.shape,
            )
        spec = zero_spec(m_leaf.shape, sh_leaf.spec, mesh, dp_axes=dp_axes)
        if not _divisible(m_leaf.shape, spec, mesh):
            spec = sh_leaf.spec
        return NamedSharding(mesh, spec)

    from repro.optim.adamw import AdamWState

    is_q = lambda x: isinstance(x, QTensor)
    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=jax.tree_util.tree_map(build, opt_state.m, psh, is_leaf=is_q),
        v=jax.tree_util.tree_map(build, opt_state.v, psh, is_leaf=is_q),
    )


def batch_sharding(batch, mesh: Mesh):
    """Inputs shard their leading (batch) dim over the largest subset of
    the DP axes that divides it."""
    from repro.distributed.context import largest_divisible_subset

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in DP if a in sizes)

    def one(leaf):
        if leaf.ndim < 1 or not dp:
            return NamedSharding(mesh, P())
        kept = largest_divisible_subset(leaf.shape[0], dp, sizes)
        if not kept:
            return NamedSharding(mesh, P())
        entry = kept if len(kept) > 1 else kept[0]
        return NamedSharding(mesh, P(*((entry,) + (None,) * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(one, batch)


def cache_sharding(cache, mesh: Mesh):
    """KV/state caches shard batch over DP; kv-heads over model when divisible."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = tuple(a for a in DP if a in sizes)
    spec_dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    dp_size = int(np.prod([sizes[a] for a in dp])) if dp else 1
    tp = sizes.get(TP, 1)

    def one(path, leaf):
        ps = _path_str(path)
        stacked = ps.startswith("period/")
        shape = leaf.shape
        core = shape[1:] if stacked else shape
        spec = [None] * len(core)
        # batch dim first; kv-head dim for 4D kv tensors.
        if len(core) >= 1 and core[0] % max(dp_size, 1) == 0 and dp and core[0] > 1:
            spec[0] = spec_dp
        if len(core) == 4 and core[2] % tp == 0:
            spec[2] = TP  # (B, S, KV, hd)
        if len(core) == 4 and "c" in ps.rsplit("/", 1)[-1] and core[1] % tp == 0:
            spec = [spec[0], TP, None, None]  # mlstm C (B,H,hd,hd)
        full = ([None] + spec) if stacked else spec
        return NamedSharding(mesh, P(*full))

    return jax.tree_util.tree_map_with_path(one, cache)
