"""int8 error-feedback gradient compression for the DP all-reduce.

The classic bandwidth trick for data-parallel training over slow links
(here: the cross-pod DCN hop of the multi-pod mesh): quantize grads to
int8 (per-tensor block scales), exchange the int8 payload + scales
(all_gather — 4x less wire traffic than fp32 ring all-reduce), sum the
dequantized shards locally, and carry the quantization residual into the
next step (error feedback keeps the scheme unbiased over time).

Used inside shard_map over the DP axis; convergence is validated in
tests/test_distributed.py on a toy problem.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, block: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)[:, None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compressed_allreduce_mean(
    grad: jnp.ndarray,
    error: jnp.ndarray,
    axis_name: str,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 mean-all-reduce over ``axis_name``.

    Returns (averaged_grad, new_error).  Call inside shard_map/psum scope.
    """
    n = jax.lax.psum(1, axis_name)
    corrected = grad + error
    q, scale = quantize_int8(corrected)
    local_deq = dequantize_int8(q, scale, grad.shape)
    new_error = corrected - local_deq
    # The wire payload is the int8 tensor + fp32 block scales.
    q_all = jax.lax.all_gather(q, axis_name)          # (n, blocks, 256) int8
    s_all = jax.lax.all_gather(scale, axis_name)      # (n, blocks) fp32
    summed = jnp.einsum(
        "nbk,nb->bk", q_all.astype(jnp.float32), s_all
    ).reshape(-1)
    size = 1
    for s in grad.shape:
        size *= s
    mean = summed[:size].reshape(grad.shape) / n
    return mean, new_error


def compression_ratio(shape, block: int = 256) -> float:
    """Wire bytes fp32 / wire bytes (int8 + scales)."""
    n = 1
    for s in shape:
        n *= s
    blocks = -(-n // block)
    return (4.0 * n) / (1.0 * blocks * block + 4.0 * blocks)
