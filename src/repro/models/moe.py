"""Mixture-of-Experts layer: top-k routing, capacity-bounded dispatch,
expert-parallel GEMMs (+ optional dense residual branch, for arctic).

Expert weights are sharded E-over-data x f-over-model (see
distributed/sharding.py): the capacity-bounded scatter/gather is the token
redistribution across the data axis (the all-to-all analogue under XLA
SPMD), and each expert's GEMM is the paper's blocked-GEMM co-design target.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import BATCH, MODEL, shard_hint
from repro.models.layers import normal_init


def init_moe(rng, d_model: int, d_ff: int, num_experts: int, dtype):
    ks = jax.random.split(rng, 4)
    return {
        "router": normal_init(ks[0], (d_model, num_experts), dtype=jnp.float32),
        "w_gate": normal_init(ks[1], (num_experts, d_model, d_ff), dtype=dtype),
        "w_up": normal_init(ks[2], (num_experts, d_model, d_ff), dtype=dtype),
        "w_down": normal_init(ks[3], (num_experts, d_ff, d_model), dtype=dtype),
    }


def apply_moe(
    params: Dict,
    x: jnp.ndarray,
    top_k: int,
    capacity_factor: float = 1.25,
    sharded_dispatch: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x (B, S, d) -> (y (B, S, d), aux losses).

    Capacity-bounded dispatch: token copies beyond an expert's capacity
    C = ceil(T * k * cf / E) are dropped (their combine weight contributes
    nothing), matching GShard/Switch semantics.

    ``sharded_dispatch``: scatter-add dispatch with explicit DP sharding
    hints on the dispatch/combine buffers — keeps the (E, C, d) buffers
    expert-sharded over the DP axes instead of letting SPMD replicate them
    (the arctic-480b memory fix; see EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    e = params["router"].shape[-1]
    t = b * s
    cap = max(int(t * top_k * capacity_factor / e), top_k)

    tokens = x.reshape(t, d)
    logits = (tokens.astype(jnp.float32) @ params["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Rank of each routed copy within its expert (GShard cumsum trick).
    flat_idx = gate_idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)  # (T*k, E)
    rank = (jnp.cumsum(onehot, axis=0) - 1)
    rank = jnp.take_along_axis(rank, flat_idx[:, None], axis=1)[:, 0]  # (T*k,)
    keep = rank < cap

    src = jnp.repeat(tokens, top_k, axis=0)  # (T*k, d)
    if sharded_dispatch:
        # Masked scatter-add: dropped copies contribute zeros to slot 0, so
        # no waste row is needed and E*C stays DP-divisible and shardable.
        slot = jnp.where(keep, flat_idx * cap + rank, 0)
        src = src * keep[:, None].astype(src.dtype)
        src = shard_hint(src, BATCH, None)
        buf = jnp.zeros((e * cap, d), tokens.dtype).at[slot].add(src)
        expert_in = buf.reshape(e, cap, d)
    else:
        slot = jnp.where(keep, flat_idx * cap + rank, e * cap)
        buf = jnp.zeros((e * cap + 1, d), tokens.dtype).at[slot].set(src)
        expert_in = buf[: e * cap].reshape(e, cap, d)
    expert_in = shard_hint(expert_in, BATCH, None, None)

    # Expert GEMMs (SwiGLU), f sharded on the model axis.
    gate = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"])
    )
    up = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    hidden = shard_hint(gate * up, BATCH, None, MODEL)
    expert_out = jnp.einsum("ecf,efd->ecd", hidden, params["w_down"])

    # Combine: gather copies back, weight, and sum over the k choices.
    out_flat = expert_out.reshape(e * cap, d)
    if not sharded_dispatch:
        out_flat = jnp.concatenate(
            [out_flat, jnp.zeros((1, d), out_flat.dtype)]
        )
    gathered = out_flat[slot]  # (T*k, d); dropped copies masked below
    gathered = gathered * (gate_w.reshape(-1, 1) * keep[:, None]).astype(gathered.dtype)
    if sharded_dispatch:
        gathered = shard_hint(gathered, BATCH, None)
    y = gathered.reshape(t, top_k, d).sum(axis=1).reshape(b, s, d)

    # Switch-style load-balancing aux loss + router z-loss.
    density = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0
    )
    mean_prob = jnp.mean(probs, axis=0)
    aux = {
        "load_balance": e * jnp.sum(density * mean_prob),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux
