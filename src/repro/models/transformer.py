"""The model assembly: heterogeneous block stacks for all assigned archs.

A model is a cycled ``layer_pattern`` of mixer blocks ('attn', 'local',
'rglru', 'mlstm', 'slstm'), each followed by an MLP or MoE when the config
says so.  Full periods of the pattern are stacked and driven by
``jax.lax.scan`` (compile-time sanity for 88-layer configs); any remainder
layers are unrolled.  Params are plain pytrees; caches mirror the param
tree structure for decode.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.context import BATCH, MODEL, shard_hint
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (
    embed,
    init_embedding,
    normal_init,
    rms_norm,
    softcap,
    unembed,
)

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Per-layer init / apply


def _init_layer(cfg: ModelConfig, rng, btype: str) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 4)
    p: Params = {"norm1": jnp.zeros((cfg.d_model,), jnp.float32)}
    if btype in ("attn", "local"):
        p["mixer"] = attn_lib.init_attention(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, cfg.qkv_bias, dt,
        )
    elif btype == "rglru":
        p["mixer"] = rglru_lib.init_rglru_block(
            ks[0], cfg.d_model, cfg.resolved_d_rnn, cfg.conv_width, dt
        )
    elif btype == "mlstm":
        p["mixer"] = xlstm_lib.init_mlstm_block(ks[0], cfg.d_model, cfg.num_heads, dt)
    elif btype == "slstm":
        p["mixer"] = xlstm_lib.init_slstm_block(ks[0], cfg.d_model, cfg.num_heads, dt)
    else:
        raise ValueError(f"unknown block type {btype}")
    if cfg.use_post_norm:
        p["post_norm1"] = jnp.zeros((cfg.d_model,), jnp.float32)

    if cfg.num_experts:
        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["moe"] = moe_lib.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts, dt)
        if cfg.moe_dense_ff:
            from repro.models.layers import init_mlp

            p["dense_mlp"] = init_mlp(ks[2], cfg.d_model, cfg.moe_dense_ff, "swiglu", dt)
        if cfg.use_post_norm:
            p["post_norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    elif cfg.d_ff > 0 and cfg.mlp_type != "none":
        from repro.models.layers import init_mlp

        p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dt)
        if cfg.use_post_norm:
            p["post_norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def _mask_state_update(
    new_cache: Params, old_cache: Params, live: jnp.ndarray
) -> Params:
    """Per-row state write mask: rows where ``live`` is False keep their old
    state.  This is what makes continuous batching legal for *recurrent*
    blocks (rglru/mlstm/slstm): their state update is not
    overwrite-before-read like a KV ring slot, so a slot-local prefill step
    would otherwise fold garbage tokens into every other row's state with
    no way to undo it.  Applied uniformly to attention caches too — a
    masked row's ring slot is simply written one step later, at the same
    per-row position it would have been overwritten at anyway."""
    def mask(new, old):
        m = live.reshape(live.shape + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)

    return jax.tree_util.tree_map(mask, new_cache, old_cache)


def _apply_layer(
    cfg: ModelConfig,
    p: Params,
    x: jnp.ndarray,
    btype: str,
    positions: Optional[jnp.ndarray],
    cache: Optional[Params],
    cache_pos: Optional[jnp.ndarray],
    fill_capacity: Optional[int] = None,
    live: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Params], Dict[str, jnp.ndarray]]:
    aux: Dict[str, jnp.ndarray] = {}
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    new_cache = None
    fill = fill_capacity is not None
    if btype in ("attn", "local"):
        out, new_cache = attn_lib.attention_block(
            p["mixer"], h,
            num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim,
            causal=cfg.causal and not cfg.encoder_only,
            window=cfg.local_window if btype == "local" else 0,
            logit_cap=cfg.attn_logit_softcap,
            rope_theta=cfg.rope_theta,
            positions=positions,
            chunked_threshold=cfg.attn_chunked_threshold,
            cache=cache,
            cache_pos=cache_pos,
            fill_capacity=fill_capacity,
        )
    elif btype == "rglru":
        out, new_cache = rglru_lib.apply_rglru_block(
            p["mixer"], h, cache=cache, fill_state=fill
        )
    elif btype == "mlstm":
        out, new_cache = xlstm_lib.apply_mlstm_block(
            p["mixer"], h, cfg.num_heads, cache=cache, fill_state=fill
        )
    else:  # slstm
        out, new_cache = xlstm_lib.apply_slstm_block(
            p["mixer"], h, cfg.num_heads, cache=cache, fill_state=fill
        )
    if live is not None and cache is not None and new_cache is not None:
        new_cache = _mask_state_update(new_cache, cache, live)
    if cfg.use_post_norm:
        out = rms_norm(out, p["post_norm1"], cfg.norm_eps)
    x = x + out
    x = shard_hint(x, BATCH, None, None)

    if "moe" in p:
        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        out2, aux = moe_lib.apply_moe(
            p["moe"], h2, cfg.top_k, cfg.capacity_factor,
            sharded_dispatch=cfg.moe_sharded_dispatch,
        )
        if "dense_mlp" in p:
            from repro.models.layers import apply_mlp

            out2 = out2 + apply_mlp(p["dense_mlp"], h2, "swiglu")
        if cfg.use_post_norm:
            out2 = rms_norm(out2, p["post_norm2"], cfg.norm_eps)
        x = x + out2
    elif "mlp" in p:
        from repro.models.layers import apply_mlp

        h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
        out2 = apply_mlp(p["mlp"], h2, cfg.mlp_type)
        if cfg.use_post_norm:
            out2 = rms_norm(out2, p["post_norm2"], cfg.norm_eps)
        x = x + out2
    x = shard_hint(x, BATCH, None, None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack organization: scanned periods + unrolled tail


def _period_split(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...], Tuple[str, ...]]:
    pat = cfg.layer_pattern
    if not cfg.scan_layers:
        return 0, (), cfg.pattern_layers
    n_periods = cfg.num_layers // len(pat)
    if n_periods < 2:
        return 0, (), cfg.pattern_layers
    tail = cfg.pattern_layers[n_periods * len(pat):]
    return n_periods, pat, tail


def init_params(cfg: ModelConfig, rng) -> Params:
    dt = _dtype(cfg)
    n_periods, pat, tail = _period_split(cfg)
    k_embed, k_head, k_body, k_tail, k_front = jax.random.split(rng, 5)

    params: Params = {}
    if cfg.frontend == "audio_frames":
        params["frontend_proj"] = normal_init(
            k_front, (cfg.frontend_dim, cfg.d_model), dtype=dt
        )
        params["head"] = normal_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=dt)
    else:
        params["embed"] = init_embedding(k_embed, cfg.vocab_size, cfg.d_model, dt)
        if cfg.frontend == "vision_patches":
            params["frontend_proj"] = normal_init(
                k_front, (cfg.frontend_dim, cfg.d_model), dtype=dt
            )
        if not cfg.tie_embeddings:
            params["head"] = normal_init(
                k_head, (cfg.d_model, cfg.vocab_size), dtype=dt
            )

    if n_periods:
        def init_period(key):
            kk = jax.random.split(key, len(pat))
            return {
                f"{j}:{bt}": _init_layer(cfg, kk[j], bt) for j, bt in enumerate(pat)
            }

        params["period"] = jax.vmap(init_period)(jax.random.split(k_body, n_periods))
    if tail:
        kk = jax.random.split(k_tail, len(tail))
        params["tail"] = {
            f"{j}:{bt}": _init_layer(cfg, kk[j], bt) for j, bt in enumerate(tail)
        }
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _embed_inputs(cfg: ModelConfig, params: Params, batch: Dict) -> jnp.ndarray:
    dt = _dtype(cfg)
    if cfg.frontend == "audio_frames":
        x = batch["frames"].astype(dt) @ params["frontend_proj"]
    else:
        x = embed(params["embed"], batch["tokens"], scale_by_dim=cfg.embed_scale)
        if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
            patches = batch["patch_embeds"].astype(dt) @ params["frontend_proj"]
            x = jnp.concatenate([patches, x], axis=1)
    return shard_hint(x.astype(dt), BATCH, None, None)


def forward_hidden(
    cfg: ModelConfig, params: Params, batch: Dict
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Trunk forward: final-norm hidden states (B, S, d) + aux losses."""
    x = _embed_inputs(cfg, params, batch)
    s = x.shape[1]
    positions = jnp.arange(s)
    n_periods, pat, tail = _period_split(cfg)
    aux_total = {"load_balance": jnp.float32(0), "router_z": jnp.float32(0),
                 "dropped_frac": jnp.float32(0)}

    if n_periods:
        def period_fn(carry, period_params):
            xx, aux = carry
            for j, bt in enumerate(pat):
                xx, _, a = _apply_layer(
                    cfg, period_params[f"{j}:{bt}"], xx, bt, positions, None, None
                )
                for k in a:
                    aux = dict(aux, **{k: aux[k] + a[k]})
            return (xx, aux), None

        (x, aux_total), _ = jax.lax.scan(
            _remat_wrap(cfg, period_fn), (x, aux_total), params["period"]
        )
    for j, bt in enumerate(tail):
        x, _, a = _apply_layer(cfg, params["tail"][f"{j}:{bt}"], x, bt,
                               positions, None, None)
        for k in a:
            aux_total[k] = aux_total[k] + a[k]

    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux_total


def apply_head(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "head" in params:
        logits = x @ params["head"]
    else:
        logits = unembed(params["embed"], x)
    return softcap(logits, cfg.final_logit_softcap)


def forward(
    cfg: ModelConfig, params: Params, batch: Dict
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward (training / prefill).  Returns (logits, aux)."""
    x, aux_total = forward_hidden(cfg, params, batch)
    logits = apply_head(cfg, params, x)
    logits = shard_hint(logits, BATCH, None, MODEL)
    return logits, aux_total


def prefill_with_cache(
    cfg: ModelConfig, params: Params, batch: Dict, capacity: int
) -> Tuple[jnp.ndarray, Params]:
    """Prefill: forward over the prompt, returning (last-token logits, a
    decode-ready cache of the given capacity)."""
    x = _embed_inputs(cfg, params, batch)
    s = x.shape[1]
    positions = jnp.arange(s)
    n_periods, pat, tail = _period_split(cfg)
    new_cache: Params = {}

    if n_periods:
        def period_fn(xx, period_params):
            ncc = {}
            for j, bt in enumerate(pat):
                key = f"{j}:{bt}"
                xx, nc, _ = _apply_layer(
                    cfg, period_params[key], xx, bt, positions, None, None,
                    fill_capacity=capacity,
                )
                ncc[key] = nc
            return xx, ncc

        x, new_cache["period"] = jax.lax.scan(period_fn, x, params["period"])
    if tail:
        new_cache["tail"] = {}
        for j, bt in enumerate(tail):
            key = f"{j}:{bt}"
            x, nc, _ = _apply_layer(
                cfg, params["tail"][key], x, bt, positions, None, None,
                fill_capacity=capacity,
            )
            new_cache["tail"][key] = nc

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = apply_head(cfg, params, x[:, -1:, :])
    return logits[:, 0, :], new_cache


# ---------------------------------------------------------------------------
# Decode


def _init_layer_cache(cfg: ModelConfig, btype: str, batch: int, capacity: int):
    dt = _dtype(cfg)
    if btype == "attn":
        return attn_lib.init_kv_cache(
            batch, capacity, cfg.num_kv_heads, cfg.resolved_head_dim, dt
        )
    if btype == "local":
        return attn_lib.init_kv_cache(
            batch, min(cfg.local_window, capacity), cfg.num_kv_heads,
            cfg.resolved_head_dim, dt,
        )
    if btype == "rglru":
        return rglru_lib.init_rglru_cache(batch, cfg.resolved_d_rnn, cfg.conv_width, dt)
    if btype == "mlstm":
        return xlstm_lib.init_mlstm_cache(
            batch, cfg.num_heads, cfg.d_model // cfg.num_heads
        )
    return xlstm_lib.init_slstm_cache(
        batch, cfg.num_heads, cfg.d_model // cfg.num_heads
    )


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> Params:
    n_periods, pat, tail = _period_split(cfg)
    cache: Params = {}
    if n_periods:
        def one(_):
            return {
                f"{j}:{bt}": _init_layer_cache(cfg, bt, batch, capacity)
                for j, bt in enumerate(pat)
            }

        cache["period"] = jax.vmap(one)(jnp.arange(n_periods))
    if tail:
        cache["tail"] = {
            f"{j}:{bt}": _init_layer_cache(cfg, bt, batch, capacity)
            for j, bt in enumerate(tail)
        }
    return cache


def reset_cache_rows(cache: Params, fresh: Params, row) -> Params:
    """Reinitialize batch row(s) of a decode cache from a fresh one.

    A freshly admitted request must not inherit the previous occupant's
    *recurrent* state: KV ring slots tolerate staleness (per-row positions
    mask unwritten slots out of every read), but rglru/mlstm/slstm state is
    read unconditionally, so the slot has to start from the init state.
    ``fresh`` may be a **batch-1** cache (rows are identical at init, so
    its row 0 serves every slot) — callers should prefer that over pinning
    a full-batch pristine copy alive.  ``cache['period']`` leaves are
    stacked (n_periods, B, ...) by ``init_cache``'s vmap while
    ``cache['tail']`` leaves lead with B — hence the two index patterns.
    """
    out: Params = {}
    if "period" in cache:
        out["period"] = jax.tree_util.tree_map(
            lambda c, z: c.at[:, row].set(z[:, 0]),
            cache["period"], fresh["period"],
        )
    if "tail" in cache:
        out["tail"] = jax.tree_util.tree_map(
            lambda c, z: c.at[row].set(z[0]), cache["tail"], fresh["tail"]
        )
    return out


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Params,
    tokens: jnp.ndarray,   # (B, 1) int32
    pos: jnp.ndarray,      # scalar or (B,) int32: absolute position of the
                           # new token (per-row for continuous batching)
    live: Optional[jnp.ndarray] = None,  # (B,) bool: rows whose state may
                           # advance this step (continuous batching); None =
                           # every row is live (single-stream decode)
) -> Tuple[jnp.ndarray, Params]:
    """One-token decode with cache update.  Returns (logits (B,V), cache')."""
    x = embed(params["embed"], tokens, scale_by_dim=cfg.embed_scale)
    x = x.astype(_dtype(cfg))
    n_periods, pat, tail = _period_split(cfg)
    new_cache: Params = {}

    if n_periods:
        def period_fn(xx, scanned):
            pp, cc = scanned
            ncc = {}
            for j, bt in enumerate(pat):
                key = f"{j}:{bt}"
                xx, nc, _ = _apply_layer(
                    cfg, pp[key], xx, bt, None, cc[key], pos, live=live
                )
                ncc[key] = nc
            return xx, ncc

        x, new_period = jax.lax.scan(
            period_fn, x, (params["period"], cache["period"])
        )
        new_cache["period"] = new_period
    if tail:
        new_cache["tail"] = {}
        for j, bt in enumerate(tail):
            key = f"{j}:{bt}"
            x, nc, _ = _apply_layer(
                cfg, params["tail"][key], x, bt, None, cache["tail"][key],
                pos, live=live,
            )
            new_cache["tail"][key] = nc

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if "head" in params:
        logits = x @ params["head"]
    else:
        logits = unembed(params["embed"], x)
    logits = softcap(logits, cfg.final_logit_softcap)
    return logits[:, 0, :], new_cache
