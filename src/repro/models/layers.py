"""Shared neural-net building blocks (pure JAX, params = pytrees)."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np


from repro.distributed.context import BATCH, MODEL, shard_hint as maybe_shard


# ---------------------------------------------------------------------------
# Initializers


def normal_init(rng, shape, scale: float = 0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(rng, shape)).astype(dtype)


def fanin_init(rng, shape, dtype=jnp.float32):
    scale = 1.0 / np.sqrt(max(shape[0], 1))
    return (scale * jax.random.normal(rng, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs


def init_mlp(rng, d_model: int, d_ff: int, mlp_type: str, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    if mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": normal_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": normal_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": normal_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {
        "w_up": normal_init(ks[0], (d_model, d_ff), dtype=dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": normal_init(ks[1], (d_ff, d_model), dtype=dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def apply_mlp(params, x: jnp.ndarray, mlp_type: str, act: str = "gelu") -> jnp.ndarray:
    """Feed-forward block.  The up/down projections are the LM-side targets
    of the paper's blocked-GEMM co-design (they dominate HLO FLOPs)."""
    if mlp_type in ("swiglu", "geglu"):
        act_fn = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
        gate = act_fn(x @ params["w_gate"])
        up = x @ params["w_up"]
        h = maybe_shard(gate * up, BATCH, None, MODEL)
        return h @ params["w_down"]
    h = x @ params["w_up"] + params["b_up"]
    h = jax.nn.gelu(h) if act == "gelu" else jax.nn.relu(h)
    h = maybe_shard(h, BATCH, None, MODEL)
    return h @ params["w_down"] + params["b_down"]


# ---------------------------------------------------------------------------
# Embeddings / head


def init_embedding(rng, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": normal_init(rng, (vocab, d_model), dtype=dtype)}


def embed(params, tokens: jnp.ndarray, scale_by_dim: bool = False) -> jnp.ndarray:
    x = params["table"][tokens]
    if scale_by_dim:
        x = x * jnp.asarray(np.sqrt(params["table"].shape[-1]), x.dtype)
    return x


def unembed(params, x: jnp.ndarray, softcap: float = 0.0) -> jnp.ndarray:
    logits = x @ params["table"].T.astype(x.dtype)
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return jnp.tanh(x / cap) * cap if cap > 0 else x
