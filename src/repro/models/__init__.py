"""Model zoo: heterogeneous transformer stacks (all 10 assigned archs) and
Darknet-style CNNs built on the core conv dispatcher."""
