"""Darknet-style CNNs (the paper's evaluation vehicle) on the core conv
dispatcher.

Re-implements the convolutional-layer kernel set the paper vectorizes
(§II.B): im2col+GEMM / Winograd (via core/conv2d.py), plus fill_cpu,
copy_cpu, normalize_cpu, add_bias, scale_bias, activate_array — here as
fused jnp ops.  Layer tables for VGG16 / YOLOv3(-tiny) live in configs/.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.conv_spec import ConvSpec, Epilogue, apply_activation
from repro.core.conv2d import conv2d
from repro.models.layers import normal_init


@dataclasses.dataclass(frozen=True)
class CNNLayer:
    kind: str                      # conv | maxpool | upsample | shortcut | route | avgpool | fc
    out_channels: int = 0
    kernel: int = 3
    stride: int = 1
    pad: Optional[int] = None      # None -> same-ish (kernel//2)
    batch_norm: bool = True
    activation: str = "leaky"      # leaky | relu | linear
    from_layers: Tuple[int, ...] = ()  # shortcut/route sources (indices)
    size: int = 2                  # pool size / upsample factor


def _conv_spec(layer: CNNLayer, in_ch: int) -> ConvSpec:
    pad = layer.pad if layer.pad is not None else layer.kernel // 2
    return ConvSpec(
        in_channels=in_ch,
        out_channels=layer.out_channels,
        kernel_size=(layer.kernel, layer.kernel),
        stride=(layer.stride, layer.stride),
        padding=(pad, pad),
    )


def layer_ref_spans(layers: Sequence[CNNLayer]) -> Tuple[Tuple[int, int], ...]:
    """Every (source, consumer) ``from_layers`` dependency span.

    A route/shortcut at index j consuming layer r needs r's output resident
    wherever j runs; a pipeline-stage cut between them (r < cut <= j) is
    illegal.  Returned sorted by consumer for stable downstream iteration.
    """
    return tuple(
        (r, j)
        for j, l in enumerate(layers)
        for r in getattr(l, "from_layers", ())
    )


# --- The Darknet per-layer kernels (paper §II.B), vectorized -----------------


def activate_array(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind in ("leaky", "relu", "linear"):
        return apply_activation(x, kind)
    return x


def normalize(x, mean, var, eps=1e-5):
    return (x - mean) * jax.lax.rsqrt(var + eps)


def scale_bias(x, scales):
    return x * scales


def add_bias(x, bias):
    return x + bias


def batchnorm_inference(x, p):
    """normalize + scale_bias + add_bias, exactly Darknet's inference path."""
    return add_bias(scale_bias(normalize(x, p["mean"], p["var"]), p["gamma"]), p["beta"])


def fold_batchnorm(params: Sequence[Dict], layers: Sequence[CNNLayer],
                   eps: float = 1e-5) -> List[Dict]:
    """Fold inference-mode batchnorm into conv weights + bias.

    bn(conv(x, w)) = conv(x, w * s) + (beta - mean * s) with
    s = gamma / sqrt(var + eps), so every conv layer reduces to
    conv + bias (+ activation) — the precondition for fusing the whole
    epilogue into the conv kernel's output stage.  Layers without bn pass
    through unchanged; the returned params drop the ``bn`` dict in favor of
    a plain ``b`` bias and plug into ``cnn_forward`` / ``_cnn_infer``.
    """
    folded: List[Dict] = []
    for p, l in zip(params, layers):
        if l.kind == "conv" and "bn" in p:
            bn = p["bn"]
            s = bn["gamma"] * jax.lax.rsqrt(bn["var"] + eps)      # (O,)
            folded.append({
                "w": p["w"] * s,                                  # (kh,kw,C,O)
                "b": bn["beta"] - bn["mean"] * s,
            })
        else:
            folded.append(p)
    return folded


# --- Model init / forward ----------------------------------------------------


def init_cnn(rng, layers: Sequence[CNNLayer], in_channels: int = 3,
             dtype=jnp.float32, num_classes: int = 0) -> List[Dict]:
    params: List[Dict] = []
    ch: List[int] = []
    cur = in_channels
    keys = jax.random.split(rng, len(layers) + 1)
    for i, l in enumerate(layers):
        p: Dict = {}
        if l.kind == "conv":
            p["w"] = normal_init(
                keys[i], (l.kernel, l.kernel, cur, l.out_channels),
                scale=1.0 / (l.kernel * max(cur, 1) ** 0.5), dtype=dtype,
            )
            if l.batch_norm:
                p["bn"] = {
                    "gamma": jnp.ones((l.out_channels,), dtype),
                    "beta": jnp.zeros((l.out_channels,), dtype),
                    "mean": jnp.zeros((l.out_channels,), dtype),
                    "var": jnp.ones((l.out_channels,), dtype),
                }
            else:
                p["b"] = jnp.zeros((l.out_channels,), dtype)
            cur = l.out_channels
        elif l.kind == "route":
            cur = sum(ch[j] for j in l.from_layers)
        elif l.kind == "fc":
            p["w"] = normal_init(keys[i], (cur, l.out_channels),
                                 scale=1.0 / cur ** 0.5, dtype=dtype)
            p["b"] = jnp.zeros((l.out_channels,), dtype)
            cur = l.out_channels
        params.append(p)
        ch.append(cur)
    return params


def _plan_layers(
    layers: Sequence[CNNLayer],
    h: int,
    w: int,
    planner,
    in_channels: int = 3,
    batch: int = 1,
    dtype="float32",
) -> List[Optional[object]]:
    """Resolve a ConvPlan for every conv layer of a network ahead of time.

    Walks the layer table exactly like ``cnn_forward`` does (same shape
    propagation) and asks ``planner`` for each conv's plan at its actual
    input resolution.  Returns a list aligned with ``layers`` (None for
    non-conv layers) that plugs straight into ``cnn_forward(plans=...)``.
    """
    plans: List[Optional[object]] = []
    ch: List[Tuple[int, int, int]] = []
    cur_ch, cur_h, cur_w = in_channels, h, w
    for l in layers:
        plan = None
        if l.kind == "conv":
            spec = _conv_spec(l, cur_ch)
            plan = planner.plan(spec, cur_h, cur_w, batch=batch, dtype=dtype)
            cur_h, cur_w = spec.out_hw(cur_h, cur_w)
            cur_ch = l.out_channels
        elif l.kind == "maxpool":
            cur_h, cur_w = -(-cur_h // l.stride), -(-cur_w // l.stride)
        elif l.kind == "upsample":
            cur_h, cur_w = cur_h * l.size, cur_w * l.size
        elif l.kind == "route":
            cur_ch = sum(ch[j][0] for j in l.from_layers)
            cur_h, cur_w = ch[l.from_layers[0]][1], ch[l.from_layers[0]][2]
        elif l.kind == "fc":
            cur_ch = l.out_channels
        plans.append(plan)
        ch.append((cur_ch, cur_h, cur_w))
    return plans


def cnn_forward(
    params: Sequence[Dict],
    layers: Sequence[CNNLayer],
    x: jnp.ndarray,
    impl: str = "jax",
    interpret: Optional[bool] = None,
    planner=None,
    plans: Optional[Sequence[Optional[object]]] = None,
    fuse_epilogue: bool = False,
) -> jnp.ndarray:
    """x (B,H,W,C) NHWC.  ``impl``: 'jax' | 'pallas' | 'xla' (lax.conv).

    ``plans`` (from ``plan_layers``) or ``planner`` routes every conv through
    its cached co-design plan instead of per-call selection.  With
    ``fuse_epilogue`` every conv whose batchnorm has been folded (params
    carry a plain ``b`` bias — see ``fold_batchnorm``) runs bias +
    activation inside the conv kernel's output stage instead of as separate
    elementwise passes; a plan that records ``fused_epilogue`` opts its
    layer in as well.
    """
    outputs: List[jnp.ndarray] = []
    cur = x
    for i, l in enumerate(layers):
        p = params[i]
        if l.kind == "conv":
            spec = _conv_spec(l, cur.shape[-1])
            plan = plans[i] if plans is not None else None
            # bn-folded params carry "b" instead of "bn", regardless of the
            # layer table's batch_norm flag.
            has_bn = "bn" in p
            fuse = (fuse_epilogue or getattr(plan, "fused_epilogue", False))
            fuse = fuse and not has_bn and impl != "xla"
            if impl == "xla":
                from repro.core.conv2d import conv2d_reference

                cur = conv2d_reference(cur, p["w"], spec)
            else:
                epi = (
                    Epilogue(bias=p["b"], activation=l.activation)
                    if fuse else None
                )
                cur = conv2d(
                    cur, p["w"], spec, impl=impl, interpret=interpret,
                    plan=plan, planner=planner, epilogue=epi,
                )
            if fuse:
                outputs.append(cur)
                continue
            if has_bn:
                cur = batchnorm_inference(cur, p["bn"])
            else:
                cur = add_bias(cur, p["b"])
            cur = activate_array(cur, l.activation)
        elif l.kind == "maxpool":
            cur = jax.lax.reduce_window(
                cur, -jnp.inf, jax.lax.max,
                (1, l.size, l.size, 1),
                (1, l.stride, l.stride, 1), "SAME",
            )
        elif l.kind == "avgpool":
            cur = cur.mean(axis=(1, 2))
        elif l.kind == "upsample":
            cur = jnp.repeat(jnp.repeat(cur, l.size, axis=1), l.size, axis=2)
        elif l.kind == "shortcut":
            cur = cur + outputs[l.from_layers[0]]
        elif l.kind == "route":
            cur = jnp.concatenate([outputs[j] for j in l.from_layers], axis=-1)
        elif l.kind == "fc":
            if cur.ndim == 4:
                # Global-average pool into the classifier (keeps FC weights
                # input-resolution independent, as Darknet's avgpool does).
                cur = cur.mean(axis=(1, 2))
            cur = activate_array(cur @ p["w"] + p["b"], l.activation)
        outputs.append(cur)
    return cur


@functools.partial(
    jax.jit,
    static_argnames=("layers", "impl", "interpret", "plans", "fuse_epilogue",
                     "fold_bn"),
)
def _cnn_infer(
    params,
    layers: Tuple[CNNLayer, ...],
    x: jnp.ndarray,
    impl: str = "jax",
    interpret: Optional[bool] = None,
    plans: Optional[Tuple[Optional[object], ...]] = None,
    fuse_epilogue: bool = True,
    fold_bn: bool = True,
) -> jnp.ndarray:
    """Jitted whole-network inference (the pre-facade deployment path).

    Rides the network executor (core/netplan.py): one compilation covers
    batchnorm folding (``fold_bn``), the whole-network layout resolution
    (inter-layer channel-padding persistence for planned pallas convs, row
    tiles snapped to divisors of OH), and every conv with its fused bias +
    activation epilogue.  ``layers`` and ``plans`` must be tuples (static,
    hashable; the configs' layer tables already are).  With
    ``fuse_epilogue=False`` — or unfolded batchnorm params, which the
    executor cannot fuse — it falls back to the per-layer ``cnn_forward``
    path.  Standing-process serving should prefer the facade
    (``repro.compile``): it additionally prepares parameters offline (block
    padding + Winograd weight pre-transform) and shards the batch over a
    device mesh.
    """
    if fold_bn:
        params = fold_batchnorm(params, layers)
    if not fuse_epilogue or any(
        l.kind == "conv" and "bn" in p for l, p in zip(layers, params)
    ):
        return cnn_forward(
            params, layers, x, impl=impl, interpret=interpret, plans=plans,
            fuse_epilogue=fuse_epilogue,
        )
    from repro.core.netplan import (
        build_network_plan,
        prepare_net_params,
        run_network,
    )

    netplan = build_network_plan(
        layers, x.shape[1], x.shape[2], in_channels=x.shape[3],
        batch=x.shape[0], plans=plans, impl=impl, dtype=x.dtype,
    )
    prepared = prepare_net_params(netplan, params)      # pretransform=False
    return run_network(netplan, prepared, x, interpret=interpret,
                       pretransformed=(False,) * len(netplan.steps))


def conv_layer_dims(layers: Sequence[CNNLayer], h: int, w: int, in_ch: int = 3):
    """Per-conv-layer (M, N, K) GEMM dims — drives the Table IV benchmark."""
    dims = []
    ch: List[int] = []
    cur_ch, cur_h, cur_w = in_ch, h, w
    for l in layers:
        if l.kind == "conv":
            spec = _conv_spec(l, cur_ch)
            m, n, k = spec.gemm_dims(cur_h, cur_w)
            oh, ow = spec.out_hw(cur_h, cur_w)
            dims.append({
                "layer": len(ch), "M": m, "N": n, "K": k,
                "kernel": l.kernel, "stride": l.stride,
                "h": cur_h, "w": cur_w, "cin": cur_ch, "cout": l.out_channels,
            })
            cur_ch, cur_h, cur_w = l.out_channels, oh, ow
        elif l.kind == "maxpool":
            cur_h, cur_w = -(-cur_h // l.stride), -(-cur_w // l.stride)
        elif l.kind == "upsample":
            cur_h, cur_w = cur_h * l.size, cur_w * l.size
        elif l.kind == "route":
            cur_ch = sum(ch[j][0] for j in l.from_layers)
            cur_h, cur_w = ch[l.from_layers[0]][1], ch[l.from_layers[0]][2]
        elif l.kind == "shortcut":
            pass
        ch.append((cur_ch, cur_h, cur_w))
    return dims
