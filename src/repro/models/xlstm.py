"""xLSTM blocks: mLSTM (matrix memory, linear-attention form) and sLSTM
(scalar memory, sequential exponential-gating recurrence).

mLSTM runs in three regimes:
  - parallel (quadratic, decay-masked attention) for short train/prefill;
  - chunkwise recurrent (parallel within chunk, state across chunks) for
    long sequences — sub-quadratic, the reason xlstm runs long_500k;
  - single-step recurrent for decode, with (C, n, m) state per head.
All three are tested for agreement on small shapes.

sLSTM is inherently sequential (non-linear state dependence) and runs as a
``lax.scan`` over time with block-diagonal (per-head) recurrent weights.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, rms_norm


# ---------------------------------------------------------------------------
# mLSTM


def init_mlstm_block(rng, d_model: int, num_heads: int, dtype):
    ks = jax.random.split(rng, 8)
    hd = d_model // num_heads
    return {
        "w_up": normal_init(ks[0], (d_model, 2 * d_model), dtype=dtype),
        "w_q": normal_init(ks[1], (d_model, d_model), dtype=dtype),
        "w_k": normal_init(ks[2], (d_model, d_model), dtype=dtype),
        "w_v": normal_init(ks[3], (d_model, d_model), dtype=dtype),
        "w_i": normal_init(ks[4], (d_model, num_heads), dtype=jnp.float32),
        "b_i": jnp.zeros((num_heads,), jnp.float32),
        "w_f": normal_init(ks[5], (d_model, num_heads), dtype=jnp.float32),
        "b_f": jnp.full((num_heads,), 3.0, jnp.float32),  # open forget gates
        "w_down": normal_init(ks[6], (d_model, d_model), dtype=dtype),
        "out_norm": jnp.zeros((d_model,), jnp.float32),
        "_hd": jnp.zeros((hd,), jnp.float32),  # shape marker
    }


def _mlstm_parallel(q, k, v, log_f, log_i):
    """Stabilized quadratic form.  q,k,v: (B,S,H,hd); gates (B,S,H) fp32."""
    b, s, h, hd = q.shape
    lf_cum = jnp.cumsum(log_f, axis=1)  # (B,S,H)
    # dtilde_ij = lf_cum_i - lf_cum_j + log_i_j  for j <= i
    dt = lf_cum[:, :, None, :] - lf_cum[:, None, :, :] + log_i[:, None, :, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    dt = jnp.where(causal[None, :, :, None], dt, -jnp.inf)
    m = dt.max(axis=2)  # (B,S,H) stabilizer
    d = jnp.exp(dt - m[:, :, None, :])  # (B,Si,Sj,H)
    scores = jnp.einsum("bihd,bjhd->bijh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    w = scores * d
    norm = jnp.maximum(jnp.abs(w.sum(axis=2)), jnp.exp(-m))  # (B,S,H)
    out = jnp.einsum("bijh,bjhd->bihd", w, v.astype(jnp.float32))
    return (out / norm[..., None]).astype(q.dtype)


def _mlstm_step(state, q, k, v, log_f, log_i):
    """One recurrent step.  state = (C (B,H,hd,hd), n (B,H,hd), m (B,H));
    q,k,v (B,H,hd); gates (B,H) fp32."""
    c_prev, n_prev, m_prev = state
    hd = q.shape[-1]
    m_new = jnp.maximum(log_f + m_prev, log_i)
    f_sc = jnp.exp(log_f + m_prev - m_new)[..., None]
    i_sc = jnp.exp(log_i - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c_new = f_sc[..., None] * c_prev + i_sc[..., None] * (
        vf[..., :, None] * kf[..., None, :]
    )  # (B,H,hd_v,hd_k)
    n_new = f_sc * n_prev + i_sc * kf
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))
    num = jnp.einsum("bhvk,bhk->bhv", c_new, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qf)),
                      jnp.exp(-m_new))
    out = (num / den[..., None]).astype(q.dtype)
    return (c_new, n_new, m_new), out


def _mlstm_chunked(q, k, v, log_f, log_i, state, chunk: int):
    """Chunkwise recurrent: scan over S/chunk chunks, quadratic within.

    Cross-chunk contributions flow through the (C, n, m) state exactly as in
    the stabilized recurrent form; within-chunk uses the parallel form
    extended with the carried state.
    """
    b, s, h, hd = q.shape
    nc = s // chunk

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).transpose(1, 0, 2, *range(3, x.ndim + 1))

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    lfs, lis = to_chunks(log_f), to_chunks(log_i)

    def chunk_step(carry, inp):
        c_prev, n_prev, m_prev = carry  # (B,H,hd,hd),(B,H,hd),(B,H)
        qc, kc, vc, lf, li = inp  # (B,chunk,H,*)
        lf_cum = jnp.cumsum(lf, axis=1)  # (B,c,H)
        # Intra-chunk decay matrix.
        dt = lf_cum[:, :, None, :] - lf_cum[:, None, :, :] + li[:, None, :, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        dt = jnp.where(causal[None, :, :, None], dt, -jnp.inf)
        # Inter: position i sees state with weight lf_cum_i + m_prev.
        inter_logw = lf_cum + m_prev[:, None, :]  # (B,c,H)
        m = jnp.maximum(dt.max(axis=2), inter_logw)  # (B,c,H)
        d = jnp.exp(dt - m[:, :, None, :])
        qf = qc.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))
        kf = kc.astype(jnp.float32)
        vf = vc.astype(jnp.float32)
        scores = jnp.einsum("bihd,bjhd->bijh", qf, kf) * d
        inter_w = jnp.exp(inter_logw - m)  # (B,c,H)
        num = jnp.einsum("bijh,bjhd->bihd", scores, vf) + inter_w[..., None] * \
            jnp.einsum("bhvk,bihk->bihv", c_prev, qf)
        den_intra = scores.sum(axis=2)  # (B,c,H)
        den_inter = inter_w * jnp.einsum("bhk,bihk->bih", n_prev, qf)
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m))
        out = (num / den[..., None]).astype(qc.dtype)

        # State update to end of chunk.
        lf_tot = lf_cum[:, -1]  # (B,H)
        m_new = jnp.maximum(lf_tot + m_prev, (lf_tot[:, None] - lf_cum + li).max(axis=1))
        w_state = jnp.exp(lf_tot + m_prev - m_new)  # (B,H)
        w_in = jnp.exp(lf_tot[:, None] - lf_cum + li - m_new[:, None])  # (B,c,H)
        c_new = w_state[..., None, None] * c_prev + jnp.einsum(
            "bjh,bjhv,bjhk->bhvk", w_in, vf, kf
        )
        n_new = w_state[..., None] * n_prev + jnp.einsum("bjh,bjhk->bhk", w_in, kf)
        return (c_new, n_new, m_new), out

    state, outs = jax.lax.scan(chunk_step, state, (qs, ks, vs, lfs, lis))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return out, state


def apply_mlstm_block(
    params: Dict,
    x: jnp.ndarray,
    num_heads: int,
    cache: Optional[Dict] = None,
    chunk_threshold: int = 4096,
    chunk: int = 256,
    fill_state: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x (B,S,d) -> (out, cache').  cache = {'c','n','m'} for decode;
    ``fill_state`` returns the end-of-sequence state (prefill)."""
    b, s, d = x.shape
    hd = d // num_heads
    up = x @ params["w_up"]
    u, g = jnp.split(up, 2, axis=-1)
    q = (u @ params["w_q"]).reshape(b, s, num_heads, hd)
    k = (u @ params["w_k"]).reshape(b, s, num_heads, hd)
    v = (u @ params["w_v"]).reshape(b, s, num_heads, hd)
    uf = u.astype(jnp.float32)
    log_i = uf @ params["w_i"] + params["b_i"]  # (B,S,H)
    log_f = jax.nn.log_sigmoid(uf @ params["w_f"] + params["b_f"])

    new_cache = None
    if cache is not None and s == 1:
        state = (cache["c"], cache["n"], cache["m"])
        state, out = _mlstm_step(
            state, q[:, 0], k[:, 0], v[:, 0], log_f[:, 0], log_i[:, 0]
        )
        out = out[:, None]
        new_cache = {"c": state[0], "n": state[1], "m": state[2]}
    elif fill_state or (s > chunk_threshold and s % chunk == 0):
        state = _init_mlstm_state(b, num_heads, hd)
        ck = chunk if s % chunk == 0 else s
        out, state = _mlstm_chunked(q, k, v, log_f, log_i, state, ck)
        if fill_state:
            new_cache = {"c": state[0], "n": state[1], "m": state[2]}
    else:
        out = _mlstm_parallel(q, k, v, log_f, log_i)

    out = out.reshape(b, s, d)
    out = rms_norm(out, params["out_norm"])
    out = out * jax.nn.silu(g)
    return out @ params["w_down"], new_cache


def _init_mlstm_state(b, h, hd):
    return (
        jnp.zeros((b, h, hd, hd), jnp.float32),
        jnp.zeros((b, h, hd), jnp.float32),
        jnp.full((b, h), 0.0, jnp.float32),
    )


def init_mlstm_cache(batch, num_heads, head_dim, dtype=None):
    c, n, m = _init_mlstm_state(batch, num_heads, head_dim)
    return {"c": c, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM


def init_slstm_block(rng, d_model: int, num_heads: int, dtype):
    ks = jax.random.split(rng, 3)
    hd = d_model // num_heads
    return {
        "w_in": normal_init(ks[0], (d_model, 4 * d_model), dtype=dtype),
        "b_in": jnp.zeros((4 * d_model,), jnp.float32),
        # Block-diagonal recurrent weights: per head (hd -> 4*hd).
        "r": normal_init(ks[1], (num_heads, hd, 4 * hd), dtype=dtype),
        "w_out": normal_init(ks[2], (d_model, d_model), dtype=dtype),
        "out_norm": jnp.zeros((d_model,), jnp.float32),
    }


def apply_slstm_block(
    params: Dict,
    x: jnp.ndarray,
    num_heads: int,
    cache: Optional[Dict] = None,
    fill_state: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Sequential sLSTM.  x (B,S,d); cache = {'c','n','m','h'} for decode."""
    b, s, d = x.shape
    hd = d // num_heads
    zin = (x @ params["w_in"]).astype(jnp.float32) + params["b_in"]  # (B,S,4d)
    zin = zin.reshape(b, s, 4, num_heads, hd)

    if cache is not None:
        state0 = (cache["c"], cache["n"], cache["m"], cache["h"])
    else:
        zero = jnp.zeros((b, num_heads, hd), jnp.float32)
        state0 = (zero, zero, zero - 10.0, zero)

    r = params["r"].astype(jnp.float32)

    def step(state, z_t):
        c, n, m, h = state  # (B,H,hd) each
        rec = jnp.einsum("bhk,hkf->bhf", h, r).reshape(b, num_heads, 4, hd)
        zz = z_t.transpose(1, 0, 2, 3) + rec.transpose(2, 0, 1, 3)  # (4,B,H,hd)
        z_g, i_g, f_g, o_g = zz[0], zz[1], zz[2], zz[3]
        z_g = jnp.tanh(z_g)
        o_g = jax.nn.sigmoid(o_g)
        log_f = jax.nn.log_sigmoid(f_g)
        m_new = jnp.maximum(log_f + m, i_g)
        i_sc = jnp.exp(i_g - m_new)
        f_sc = jnp.exp(log_f + m - m_new)
        c_new = f_sc * c + i_sc * z_g
        n_new = f_sc * n + i_sc
        h_new = o_g * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    state, hs = jax.lax.scan(step, state0, zin.transpose(1, 0, 2, 3, 4))
    out = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    out = rms_norm(out, params["out_norm"])
    out = out @ params["w_out"]
    new_cache = None
    if cache is not None or fill_state:
        new_cache = {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}
    return out, new_cache


def init_slstm_cache(batch, num_heads, head_dim, dtype=None):
    zero = jnp.zeros((batch, num_heads, head_dim), jnp.float32)
    return {"c": zero, "n": zero, "m": zero - 10.0, "h": zero}
