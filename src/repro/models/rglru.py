"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Linear diagonal recurrence with input-dependent gates:
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  per-channel decay
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Because the recurrence is linear and diagonal it parallelizes with
``jax.lax.associative_scan`` over the sequence — the reason this family
runs the long_500k cells that quadratic attention cannot.

The block wraps the LRU Griffin-style: conv1d(4) temporal mixing on the
recurrent branch, GeLU gate branch, elementwise merge, output projection.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init

_C = 8.0  # Griffin's fixed decay temperature


def init_rglru_block(rng, d_model: int, d_rnn: int, conv_width: int, dtype):
    ks = jax.random.split(rng, 7)
    return {
        "w_y": normal_init(ks[0], (d_model, d_rnn), dtype=dtype),      # recurrent branch in
        "w_gate": normal_init(ks[1], (d_model, d_rnn), dtype=dtype),   # gate branch in
        "w_out": normal_init(ks[2], (d_rnn, d_model), dtype=dtype),
        "conv_w": normal_init(ks[3], (conv_width, d_rnn), dtype=dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "w_a": normal_init(ks[4], (d_rnn, d_rnn), dtype=dtype),
        "b_a": jnp.zeros((d_rnn,), dtype),
        "w_x": normal_init(ks[5], (d_rnn, d_rnn), dtype=dtype),
        "b_x": jnp.zeros((d_rnn,), dtype),
        # Lambda init so decay a ~ U[0.9, 0.999] at r=1 (Griffin's init).
        "lam": jax.random.uniform(ks[6], (d_rnn,), jnp.float32, 0.0, 1.0),
    }


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                   state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time.  x (B,S,D), w (W,D).

    Training: state None, left-pad with zeros.  Decode: x is (B,1,D) and
    ``state`` holds the last W-1 inputs (B, W-1, D).
    """
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
        new_state = xp[:, -(width - 1):, :] if width > 1 else None
    else:
        xp = jnp.concatenate([state, x], axis=1)
        new_state = xp[:, -(width - 1):, :]
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return out + b, new_state


def _rglru_scan(x: jnp.ndarray, a: jnp.ndarray,
                h0: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t*h_{t-1} + b_t via associative scan.  x,a: (B,S,D) fp32."""
    b_in = x
    if h0 is not None:
        # Fold the carried state in as a virtual step 0.
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b_in = jnp.concatenate([h0[:, None], b_in], axis=1)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b_in), axis=1)
    del aa
    if h0 is not None:
        hh = hh[:, 1:]
    return hh, hh[:, -1]


def apply_rglru_block(
    params: Dict,
    x: jnp.ndarray,
    cache: Optional[Dict] = None,
    fill_state: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x (B, S, d_model) -> (out, new_cache).

    cache = {'h': (B, d_rnn) fp32, 'conv': (B, W-1, d_rnn)} for decode.
    ``fill_state``: prefill mode — return the end-of-sequence state as a
    fresh cache.
    """
    y = x @ params["w_y"]
    gate = jax.nn.gelu(x @ params["w_gate"])

    conv_state = cache["conv"] if cache is not None else None
    y, new_conv = _causal_conv1d(y, params["conv_w"], params["conv_b"], conv_state)

    yf = y.astype(jnp.float32)
    r = jax.nn.sigmoid(yf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(yf @ params["w_x"].astype(jnp.float32) + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * yf)

    h0 = cache["h"] if cache is not None else None
    if x.shape[1] == 1 and h0 is not None:
        h = a[:, 0] * h0 + gated_in[:, 0]
        hs, h_last = h[:, None], h
    else:
        hs, h_last = _rglru_scan(gated_in, a, h0)

    out = (hs.astype(x.dtype) * gate) @ params["w_out"]
    new_cache = None
    if cache is not None or fill_state:
        new_cache = {"h": h_last, "conv": new_conv}
    return out, new_cache


def init_rglru_cache(batch: int, d_rnn: int, conv_width: int, dtype):
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_rnn), dtype),
    }
