"""Grouped-query attention: naive, chunked (online-softmax), and decode paths.

Supports the assigned-arch feature matrix: GQA/MQA (any kv<=heads), RoPE,
QKV bias (qwen1.5), attention logit softcap (gemma2), local sliding windows
(gemma2 alternating, recurrentgemma), encoder (bidirectional) mode (hubert),
and ring-buffer KV caches for decode.

The chunked path is the sub-quadratic-memory prefill implementation: an
online-softmax double scan over (q-chunk, kv-chunk) — the pure-JAX analogue
of flash attention, required for prefill_32k cells.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import BATCH, MODEL
from repro.models.layers import apply_rope, maybe_shard, normal_init, softcap

NEG_INF = -2.3819763e38  # matches XLA's finite mask value


def init_attention(rng, d_model, num_heads, num_kv_heads, head_dim, qkv_bias, dtype):
    ks = jax.random.split(rng, 4)
    p = {
        "wq": normal_init(ks[0], (d_model, num_heads * head_dim), dtype=dtype),
        "wk": normal_init(ks[1], (d_model, num_kv_heads * head_dim), dtype=dtype),
        "wv": normal_init(ks[2], (d_model, num_kv_heads * head_dim), dtype=dtype),
        "wo": normal_init(ks[3], (num_heads * head_dim, d_model), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def _qkv(params, x, num_heads, num_kv_heads, head_dim):
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, num_heads, head_dim)
    k = k.reshape(b, s, num_kv_heads, head_dim)
    v = v.reshape(b, s, num_kv_heads, head_dim)
    return q, k, v


def _mask(q_pos, k_pos, causal: bool, window: int):
    """(..., Sq, Sk) boolean validity mask from absolute positions."""
    m = jnp.ones(jnp.broadcast_shapes(q_pos[..., :, None].shape,
                                      k_pos[..., None, :].shape), bool)
    if causal:
        m &= k_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def _sdpa(q, k, v, mask, logit_cap: float):
    """q (B,Sq,KV,G,hd), k/v (B,Sk,KV,hd), mask (B?,Sq,Sk) -> (B,Sq,KV,G,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(jnp.float32(hd))
    scores = softcap(scores, logit_cap)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum(
        "bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)


def attention_naive(q, k, v, q_pos, k_pos, causal, window, logit_cap):
    mask = _mask(q_pos, k_pos, causal, window)
    return _sdpa(q, k, v, mask, logit_cap)


def _largest_divisor(s: int, cap: int) -> int:
    d = min(cap, s)
    while s % d:
        d -= 1
    return d


def attention_chunked(
    q, k, v, q_pos, k_pos, causal, window, logit_cap,
    q_chunk: int = 512, kv_chunk: int = 1024,
):
    """Online-softmax double scan; O(Sq*kv_chunk) live memory.

    q (B,S,KV,G,hd): S must divide by q_chunk; Sk by kv_chunk (callers pad).
    """
    b, sq, kv_h, g, hd = q.shape
    sk = k.shape[1]
    nq, nk = sq // q_chunk, sk // kv_chunk

    qc = q.reshape(b, nq, q_chunk, kv_h, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(nq, q_chunk) if q_pos.ndim == 1 else None
    kc = k.reshape(b, nk, kv_chunk, kv_h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, kv_chunk, kv_h, hd).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(nk, kv_chunk)

    def q_step(_, qi):
        q_blk, qpos_blk = qi  # (B,qc,KV,G,hd), (qc,)

        def kv_step(carry, ki):
            acc, m_prev, l_prev = carry
            k_blk, v_blk, kpos_blk = ki
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) / jnp.sqrt(jnp.float32(hd))
            s = softcap(s, logit_cap)
            msk = _mask(qpos_blk, kpos_blk, causal, window)  # (qc, kc)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, kv_h, g, q_chunk, hd), jnp.float32)
        m0 = jnp.full((b, kv_h, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_h, g, q_chunk), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kc, vc, kp))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qc, qp))  # (nq,B,qc,KV,G,hd)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, kv_h, g, hd)


def attention_block(
    params: Dict,
    x: jnp.ndarray,
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    causal: bool,
    window: int,
    logit_cap: float,
    rope_theta: float,
    positions: Optional[jnp.ndarray] = None,
    chunked_threshold: int = 8192,
    cache: Optional[Dict] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    fill_capacity: Optional[int] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full attention sub-block: qkv -> rope -> sdpa -> out-proj.

    Training/prefill: cache is None; decode: x is (B, 1, d) and ``cache``
    holds {'k','v','slot_pos'} ring buffers, ``cache_pos`` the absolute
    position of the new token — a scalar (all rows at the same position) or
    a (B,) vector (continuous batching: every row decodes at its own
    position, writing its own ring slot).  ``fill_capacity``: prefill mode —
    also return a cache of the given capacity filled with this call's K/V.
    """
    b, s, _ = x.shape
    g = num_heads // num_kv_heads
    q, k, v = _qkv(params, x, num_heads, num_kv_heads, head_dim)

    if cache is not None:
        # Per-row positions: scalar cache_pos broadcasts to (B,).
        pos = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32).reshape(-1), (b,)
        )
        q = apply_rope(q, pos[:, None], rope_theta)
        k = apply_rope(k, pos[:, None], rope_theta)
        cap = cache["k"].shape[1]
        slot = pos % cap  # (B,)
        rows = jnp.arange(b)
        new_cache = {
            "k": cache["k"].at[rows, slot].set(k[:, 0]),
            "v": cache["v"].at[rows, slot].set(v[:, 0]),
            "slot_pos": cache["slot_pos"].at[rows, slot].set(pos),
        }
        qh = q.reshape(b, 1, num_kv_heads, g, head_dim)
        k_pos = new_cache["slot_pos"]  # (B, Sk)
        valid = (k_pos >= 0) & (k_pos <= pos[:, None])
        if window > 0:
            valid &= k_pos > (pos - window)[:, None]
        mask = valid[:, None, :]  # (B, Sq=1, Sk)
        out = _sdpa(qh, new_cache["k"], new_cache["v"], mask, logit_cap)
        out = out.reshape(b, 1, num_heads * head_dim)
        return out @ params["wo"], new_cache

    if positions is None:
        positions = jnp.arange(s)
    q = apply_rope(q, positions[None].repeat(b, 0), rope_theta)
    k = apply_rope(k, positions[None].repeat(b, 0), rope_theta)
    qh = q.reshape(b, s, num_kv_heads, g, head_dim)
    qh = _shard_heads(qh, num_kv_heads, g)
    if s >= chunked_threshold:
        qc = _largest_divisor(s, 512)
        kc = _largest_divisor(s, 1024)
        out = attention_chunked(qh, k, v, positions, positions, causal,
                                window, logit_cap, q_chunk=qc, kv_chunk=kc)
    else:
        out = attention_naive(qh, k, v, positions, positions, causal, window, logit_cap)
    out = out.reshape(b, s, num_heads * head_dim)

    new_cache = None
    if fill_capacity is not None:
        cap = fill_capacity if window <= 0 else min(window, fill_capacity)
        if s >= cap:
            # Keep the last ``cap`` positions (ring layout: slot = pos % cap).
            keep_k, keep_v = k[:, s - cap:], v[:, s - cap:]
            keep_pos = positions[s - cap:]
        else:
            pad = cap - s
            keep_k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            keep_v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            keep_pos = jnp.pad(positions, (0, pad), constant_values=-1)
        slots = jnp.where(keep_pos >= 0, keep_pos % cap, jnp.arange(cap) % cap)
        slot_pos = jnp.full((cap,), -1, jnp.int32).at[slots].set(
            keep_pos.astype(jnp.int32)
        )
        new_cache = {
            "k": jnp.zeros_like(keep_k).at[:, slots].set(keep_k),
            "v": jnp.zeros_like(keep_v).at[:, slots].set(keep_v),
            # Per-row (B, cap) so continuous-batching decode can track each
            # row's own positions; prefill fills all rows identically.
            "slot_pos": jnp.broadcast_to(slot_pos, (b, cap)),
        }
    return out @ params["wo"], new_cache


def _shard_heads(qh, num_kv_heads: int, g: int):
    """TP hint for (B,S,KV,G,hd): shard whichever of KV / G divides the
    model axis — MQA archs (kv=1) shard query groups instead of kv heads,
    avoiding SPMD involuntary full rematerialization."""
    from repro.distributed.context import get_mesh

    mesh = get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return qh
    tp = mesh.shape["model"]
    if num_kv_heads % tp == 0:
        return maybe_shard(qh, BATCH, None, MODEL, None, None)
    if g % tp == 0:
        return maybe_shard(qh, BATCH, None, None, MODEL, None)
    return maybe_shard(qh, BATCH, None, None, None, None)


def init_kv_cache(batch, capacity, num_kv_heads, head_dim, dtype):
    return {
        "k": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, num_kv_heads, head_dim), dtype),
        "slot_pos": jnp.full((batch, capacity), -1, jnp.int32),
    }
