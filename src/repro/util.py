"""Tiny shared helpers used across core, kernels and benchmarks."""
from __future__ import annotations


def ceil_to(x: int, q: int) -> int:
    """Round ``x`` up to the next multiple of ``q``."""
    return -(-x // q) * q
