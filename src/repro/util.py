"""Tiny shared helpers used across core, kernels and benchmarks."""
from __future__ import annotations


def ceil_to(x: int, q: int) -> int:
    """Round ``x`` up to the next multiple of ``q``."""
    return -(-x // q) * q


def pad_bias_row(bias, n_padded: int):
    """(O,) bias -> (1, n_padded) kernel bias row, zero-padded on the tail.

    The single definition of the fused-epilogue bias layout contract, shared
    by the gemm / im2col / winograd wrappers and the layout-aware conv
    dispatch.  The pad is conditional on purpose: a zero-width jnp.pad still
    emits a pad eqn, which would break the network executor's
    no-interior-pad jaxpr guarantee (tests/test_netplan.py).
    """
    if bias is None:
        return None
    import jax.numpy as jnp

    n = bias.shape[0]
    return (jnp.pad(bias, (0, n_padded - n)) if n_padded != n
            else bias).reshape(1, n_padded)
