"""Jitted end-to-end Winograd conv on the Pallas kernels.

Pipeline (paper §IV.B):  tile -> input transform -> tuple multiply ->
output transform -> untile.  The overlapping 8x8 tile extraction and the
offline weight transform are plain XLA data-movement ops; the three
compute stages run as Pallas kernels with channels-on-lanes blocking.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.conv_spec import ConvSpec
from repro.core.winograd import OUT_TILE, TILE, _tile_input, transform_weights
from repro.hw import V5E
from repro.util import ceil_to


def pick_blocks(
    t: int, c: int, o: int, vmem_budget: Optional[int] = None
) -> Tuple[int, int, int]:
    """(bt, bc, bo) aligned to (sublane, lane) granularity, VMEM-bounded."""
    budget = vmem_budget if vmem_budget is not None else V5E.vmem_bytes
    bt = min(ceil_to(t, 8), 256)
    bc = min(ceil_to(c, 128), 512)
    bo = min(ceil_to(o, 128), 512)
    # input-transform block: bt*8*8*bc*4 bytes x2 buffers must fit VMEM.
    while bt > 8 and 2 * bt * 64 * bc * 4 > budget // 2:
        bt //= 2
    return bt, bc, bo


@functools.partial(
    jax.jit,
    static_argnames=("spec", "blocks", "interpret", "pretransformed",
                     "activation"),
)
def conv2d_winograd_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    blocks: Optional[Tuple[int, int, int]] = None,
    pretransformed: bool = False,
    interpret: bool = False,
    bias: Optional[jnp.ndarray] = None,
    activation: str = "linear",
) -> jnp.ndarray:
    """x (B,H,W,C), w (3,3,C,O) [or (8,8,C,O) pretransformed] -> (B,OH,OW,O).

    ``bias`` (O,) and ``activation`` form the fused epilogue, applied in the
    output-transform kernel on the fp32 accumulator before the store."""
    from repro.kernels.winograd.kernel import (
        input_transform_pallas,
        output_transform_pallas,
        tuple_multiply_pallas,
    )

    assert spec.kernel_size == (3, 3) and spec.stride == (1, 1)
    b, h, ww, c = x.shape
    o = w.shape[-1]
    oh, ow = spec.out_hw(h, ww)
    ph, pw = spec.padding
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))

    tiles, nth, ntw = _tile_input(x, oh, ow)  # (B, nTH, nTW, 8, 8, C)
    t = b * nth * ntw
    tiles = tiles.reshape(t, TILE, TILE, c)

    bt, bc, bo = blocks or pick_blocks(t, c, o)
    tp, cp, op = ceil_to(t, bt), ceil_to(c, bc), ceil_to(o, bo)
    tiles = jnp.pad(tiles, ((0, tp - t), (0, 0), (0, 0), (0, cp - c)))

    u = w if pretransformed else transform_weights(w, x.dtype)  # (8,8,C,O)
    u = jnp.pad(u, ((0, 0), (0, 0), (0, cp - c), (0, op - o)))

    v = input_transform_pallas(tiles, bt, bc, interpret=interpret)
    v = v.reshape(TILE * TILE, tp, cp)
    m = tuple_multiply_pallas(
        v, u.reshape(TILE * TILE, cp, op), bt, bc, bo, interpret=interpret
    )
    bias_p = None
    if bias is not None:
        bias_p = jnp.pad(bias, (0, op - o)).reshape(1, op)
    y = output_transform_pallas(
        m.reshape(TILE, TILE, tp, op), bt, bo, interpret=interpret,
        bias=bias_p, activation=activation,
    )  # (tp, 6, 6, op)

    y = y[:t, :, :, :o].reshape(b, nth, ntw, OUT_TILE, OUT_TILE, o)
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(b, nth * OUT_TILE, ntw * OUT_TILE, o)
    return y[:, :oh, :ow, :]
