"""Jitted end-to-end Winograd conv on the Pallas kernels.

Pipeline (paper §IV.B):  tile -> input transform -> tuple multiply ->
output transform -> untile.  The overlapping 8x8 tile extraction and the
offline weight transform are plain XLA data-movement ops.  The compute
stages run either as the single-pass fused megakernel (``fused=True``, the
default: transforms and M accumulation never leave VMEM) or as the 3-pass
kernel pipeline whose V/M intermediates round-trip through HBM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.conv_spec import ConvSpec
from repro.core.vmem_model import winograd_kernel_vmem_bytes
from repro.core.winograd import OUT_TILE, TILE, _tile_input, transform_weights
from repro.hw import V5E
from repro.util import ceil_to, pad_bias_row


def pick_blocks(
    t: int, c: int, o: int, vmem_budget: Optional[int] = None,
    fused: bool = True, dtype_bytes: int = 4,
) -> Tuple[int, int, int]:
    """(bt, bc, bo) aligned to (sublane, lane) granularity, VMEM-bounded.

    Budgets the **full** per-kernel footprint via
    ``vmem_model.winograd_kernel_vmem_bytes`` — for the fused megakernel the
    double-buffered tile + weight blocks, the (8, 8, bt, bo) fp32 M
    accumulator scratch and the output block; for the 3-pass pipeline the
    max footprint across its three kernels.  (The old heuristic budgeted
    only the input-transform block, 2*bt*64*bc*4 bytes, and silently
    overflowed VMEM through the weight block and tuple-multiply scratch.)
    The channel blocks shrink first (they are what the weight block is
    quadratic in), then the tile block; nothing shrinks below the
    (sublane, lane) granularity floor (8, 128, 128).
    """
    budget = vmem_budget if vmem_budget is not None else V5E.vmem_bytes
    bt = min(ceil_to(t, 8), 256)
    bc = min(ceil_to(c, 128), 512)
    bo = min(ceil_to(o, 128), 512)

    def fits() -> bool:
        return winograd_kernel_vmem_bytes(
            bt, bc, bo, fused=fused, dtype_bytes=dtype_bytes
        ) <= budget

    # Shrink in granularity multiples: halving a non-power-of-two start
    # (e.g. bc = ceil_to(384, 128)) must land back on a 128-lane multiple,
    # never below the (8, 128, 128) floor.
    while not fits() and (bc > 128 or bo > 128):
        if bc >= bo and bc > 128:
            bc = max(128, ceil_to(bc // 2, 128))
        else:
            bo = max(128, ceil_to(bo // 2, 128))
    while not fits() and bt > 8:
        bt = max(8, ceil_to(bt // 2, 8))
    return bt, bc, bo


def conv2d_winograd_padded_call(
    x_sp: jnp.ndarray,
    u_p: jnp.ndarray,
    oh: int,
    ow: int,
    blocks: Tuple[int, int, int],
    interpret: bool = False,
    bias_p: Optional[jnp.ndarray] = None,
    activation: str = "linear",
    fused: bool = True,
) -> jnp.ndarray:
    """The Winograd compute stages on channel-pre-padded operands.

    ``x_sp`` (B, H+2ph, W+2pw, Cp) already carries the conv's spatial
    padding and channels padded to the bc multiple; ``u_p`` (8, 8, Cp, Op)
    is the pre-transformed weight padded to the same channel blocks, and
    ``bias_p`` (1, Op) or None.  The overlapping-tile extraction and the
    tile-count padding to the bt multiple are intra-layer data movement and
    stay here; the *channel* pad/crop pair is what the network executor
    (core/netplan.py) elides between consecutive layers.  Returns
    (B, OH, OW, Op): rows/cols cropped to logical (the 6-multiple tail rows
    carry act(bias), never zeros, so they must not flow on), channels kept
    padded for the caller to crop — or to hand straight to the next layer.
    """
    from repro.kernels.winograd.kernel import (
        fused_winograd_pallas,
        input_transform_pallas,
        output_transform_pallas,
        tuple_multiply_pallas,
    )

    b = x_sp.shape[0]
    cp = x_sp.shape[-1]
    op = u_p.shape[-1]
    bt, bc, bo = blocks
    assert cp % bc == 0 and op % bo == 0, (cp, bc, op, bo)

    tiles, nth, ntw = _tile_input(x_sp, oh, ow)  # (B, nTH, nTW, 8, 8, Cp)
    t = b * nth * ntw
    tiles = tiles.reshape(t, TILE, TILE, cp)
    tp = ceil_to(t, bt)
    if tp != t:
        tiles = jnp.pad(tiles, ((0, tp - t), (0, 0), (0, 0), (0, 0)))

    if fused:
        y = fused_winograd_pallas(
            tiles, u_p, bt, bc, bo, interpret=interpret,
            bias=bias_p, activation=activation,
        )  # (tp, 6, 6, op)
    else:
        v = input_transform_pallas(tiles, bt, bc, interpret=interpret)
        v = v.reshape(TILE * TILE, tp, cp)
        m = tuple_multiply_pallas(
            v, u_p.reshape(TILE * TILE, cp, op), bt, bc, bo,
            interpret=interpret,
        )
        y = output_transform_pallas(
            m.reshape(TILE, TILE, tp, op), bt, bo, interpret=interpret,
            bias=bias_p, activation=activation,
        )  # (tp, 6, 6, op)

    y = y[:t].reshape(b, nth, ntw, OUT_TILE, OUT_TILE, op)
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(
        b, nth * OUT_TILE, ntw * OUT_TILE, op
    )
    return y[:, :oh, :ow, :]


@functools.partial(
    jax.jit,
    static_argnames=("spec", "blocks", "interpret", "pretransformed",
                     "activation", "fused"),
)
def conv2d_winograd_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    blocks: Optional[Tuple[int, int, int]] = None,
    pretransformed: bool = False,
    interpret: bool = False,
    bias: Optional[jnp.ndarray] = None,
    activation: str = "linear",
    fused: bool = True,
) -> jnp.ndarray:
    """x (B,H,W,C), w (3,3,C,O) [or (8,8,C,O) pretransformed] -> (B,OH,OW,O).

    ``fused=True`` (default) runs the single-pass megakernel: one
    pallas_call whose grid is (T/bt, O/bo, C/bc) and whose V and M
    intermediates stay in VMEM.  ``fused=False`` runs the 3-pass pipeline
    (input transform -> tuple multiply -> output transform), each stage a
    separate kernel with (64, T, C)-shaped HBM intermediates — kept for
    measure-mode comparison and as the reference realization of the paper's
    decomposition.

    ``bias`` (O,) and ``activation`` form the fused epilogue, applied on the
    fp32 accumulator after the inverse transform, before the store."""
    assert spec.kernel_size == (3, 3) and spec.stride == (1, 1)
    b, h, ww, c = x.shape
    o = w.shape[-1]
    oh, ow = spec.out_hw(h, ww)
    ph, pw = spec.padding
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))

    nth, ntw = -(-oh // OUT_TILE), -(-ow // OUT_TILE)
    t = b * nth * ntw
    bt, bc, bo = blocks or pick_blocks(
        t, c, o, fused=fused, dtype_bytes=jnp.dtype(x.dtype).itemsize
    )
    cp, op = ceil_to(c, bc), ceil_to(o, bo)
    if cp != c:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, cp - c)))

    u = w if pretransformed else transform_weights(w, x.dtype)  # (8,8,C,O)
    u = jnp.pad(u, ((0, 0), (0, 0), (0, cp - c), (0, op - o)))

    bias_p = pad_bias_row(bias, op)

    y = conv2d_winograd_padded_call(
        x, u, oh, ow, (bt, bc, bo), interpret=interpret,
        bias_p=bias_p, activation=activation, fused=fused,
    )
    return y[:, :, :, :o]


def winograd_call_descriptors(
    t: int, cp: int, op: int, blocks: Tuple[int, int, int],
    bias: bool = True, fused: bool = True, dtype_bytes: int = 4,
) -> list:
    """Static description of the pallas_call(s) ``conv2d_winograd_padded_call``
    emits for ``t`` logical tiles on (cp, op)-channel-padded operands.

    One descriptor for the fused megakernel, three (input transform, tuple
    multiply, output transform) for the 3-pass pipeline.  Traffic follows
    the verifier's fetch algebra (an operand re-fetches once per step of the
    grid prefix its index map depends on; the constant BT/AT matrices fetch
    exactly once).  ``model_vmem_bytes`` is ``winograd_kernel_vmem_bytes``,
    which for the 3-pass pipeline is the *max* over stages — per-stage
    actuals are compared one-sided (``vmem_one_sided``).
    """
    from repro.core.vmem_model import ACC_BYTES, winograd_kernel_vmem_bytes

    bt, bc, bo = blocks
    tp = ceil_to(t, bt)
    nt, nc, no = tp // bt, cp // bc, op // bo
    model = winograd_kernel_vmem_bytes(
        bt, bc, bo, fused=fused, dtype_bytes=dtype_bytes
    )
    if fused:
        traffic = (
            dtype_bytes * nt * no * nc * 64 * bc * (bt + bo)  # tiles + U
            + (ACC_BYTES * nt * no * bo if bias else 0)       # bias rows
            + dtype_bytes * tp * 36 * op                      # output write
            + dtype_bytes * (64 + 48)                         # BT + AT, once
        )
        name = "_fused_winograd_bias_kernel" if bias else "_fused_winograd_kernel"
        return [{
            "family": "winograd",
            "name": name,
            "grid": (nt, no, nc),
            "model_vmem_bytes": model,
            "traffic_bytes": traffic,
            "vmem_one_sided": False,
            # Kernel-interior contract: the Cin grid axis (innermost) is the
            # reduction, accumulated in the (8, 8, bt, bo) fp32 M scratch.
            # Winograd never runs int8 (quantization policy), so no k_elems.
            "reduction_axes": (2,),
            "k_elems": None,
        }]
    input_tf = {
        "family": "winograd",
        "name": "_input_transform_kernel",
        "grid": (nt, nc),
        "model_vmem_bytes": model,
        "traffic_bytes": dtype_bytes * (2 * nt * nc * 64 * bt * bc + 64),
        "vmem_one_sided": True,
        "reduction_axes": (),
        "k_elems": None,
    }
    tuple_mul = {
        "family": "winograd",
        "name": "_tuple_multiply_kernel",
        "grid": (64, nt, no, nc),
        "model_vmem_bytes": model,
        "traffic_bytes": dtype_bytes * 64 * nt * no * nc * bc * (bt + bo)
        + dtype_bytes * 64 * nt * no * bt * bo,
        "vmem_one_sided": True,
        # The per-position GEMM reduces over the in-channel grid axis
        # (innermost) into the (bt, bo) fp32 scratch.
        "reduction_axes": (3,),
        "k_elems": None,
    }
    output_tf = {
        "family": "winograd",
        "name": (
            "_output_transform_bias_kernel" if bias
            else "_output_transform_kernel"
        ),
        "grid": (nt, no),
        "model_vmem_bytes": model,
        "traffic_bytes": dtype_bytes * nt * no * (64 + 36) * bt * bo
        + (ACC_BYTES * nt * no * bo if bias else 0)
        + dtype_bytes * 48,
        "vmem_one_sided": True,
        "reduction_axes": (),
        "k_elems": None,
    }
    return [input_tf, tuple_mul, output_tf]
