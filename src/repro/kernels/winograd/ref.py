"""Pure-jnp oracles for the Winograd kernels (reuse core/winograd.py)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.winograd import AT, BT, conv2d_winograd


def input_transform_ref(tiles: jnp.ndarray) -> jnp.ndarray:
    """(T, 8, 8, C) -> (8, 8, T, C)."""
    bt = jnp.asarray(BT, tiles.dtype)
    return jnp.einsum("ai,bj,tijc->abtc", bt, bt, tiles)


def tuple_multiply_ref(v: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """(64, T, C) x (64, C, O) -> (64, T, O), fp32 accumulation."""
    return jnp.matmul(v, u, preferred_element_type=jnp.float32).astype(v.dtype)


def output_transform_ref(m: jnp.ndarray) -> jnp.ndarray:
    """(8, 8, T, O) -> (T, 6, 6, O)."""
    at = jnp.asarray(AT, m.dtype)
    return jnp.einsum("xa,yb,abto->txyo", at, at, m)


winograd_conv_ref = conv2d_winograd
