"""Winograd F(6x6,3x3) Pallas kernels with inter-tile channel parallelism.

TPU realization of the paper's §IV.B scheme.  The paper packs one 8x8 tile
from each of VL/16 channels along the vector register; here every transform
operand keeps a trailing (tiles, channels) block so the 128-lane axis is
filled by channels and the 8 sublanes by tiles — the same inter-tile
parallelization, expressed through BlockSpec tiling instead of `svcntw`.

Two realizations of the same pipeline:

The 3-pass decomposition (one kernel per stage, V and M via HBM):
  input_transform:   V = B^T d B     (per tile x channel)
  tuple_multiply:    M[p] = V[p] @ U[p]  batched GEMM over the 64 positions
                     (the paper's "increase the number of blocks for GEMM")
  output_transform:  Y = A^T M A     (per tile x out-channel)

The single-pass megakernel (``fused_winograd_pallas``): one grid
(T/bt, O/bo, C/bc) where each program transforms its tile block in
registers, runs the 64 per-position GEMMs, accumulates M in an
(8, 8, bt, bo) fp32 VMEM scratch across the Cin (reduction) grid axis, and
on the last Cin step applies Y = A^T M A plus the fused bias+activation
epilogue — V and M never touch HBM, which is where Winograd's FLOP
advantage is won or lost (cf. the follow-up co-design paper).

The weight transform U = G g G^T runs offline (ops.py), as in the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_spec import apply_activation
from repro.kernels.compat import CompilerParams

from repro.core.winograd import AT, BT


def _input_transform_kernel(bt_ref, d_ref, v_ref):
    """d (bt, 8, 8, bc) -> V (8, 8, bt, bc): channels stay minormost."""
    bt_mat = bt_ref[...]
    d = d_ref[...].astype(jnp.float32)
    # V[a,b,t,c] = sum_ij BT[a,i] d[t,i,j,c] BT[b,j]
    v = jnp.einsum("ai,bj,tijc->abtc", bt_mat, bt_mat, d)
    v_ref[...] = v.astype(v_ref.dtype)


def _tuple_multiply_kernel(v_ref, u_ref, m_ref, acc_ref):
    """Grid (64, nt, no, nc): per-position GEMM with K(=cin) accumulation."""

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        v_ref[0], u_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        m_ref[...] = acc_ref[...].astype(m_ref.dtype)[None]


def _output_transform_kernel(at_ref, m_ref, y_ref, *, activation: str = "linear"):
    """M (8, 8, bt, bo) -> Y (bt, 6, 6, bo)."""
    at_mat = at_ref[...]
    m = m_ref[...].astype(jnp.float32)
    y = jnp.einsum("xa,yb,abto->txyo", at_mat, at_mat, m)
    y_ref[...] = apply_activation(y, activation).astype(y_ref.dtype)


def _output_transform_bias_kernel(at_ref, m_ref, bias_ref, y_ref, *,
                                  activation: str):
    """Output transform with the fused epilogue: bias (1, bo) + activation
    applied to the fp32 transform result before the store."""
    at_mat = at_ref[...]
    m = m_ref[...].astype(jnp.float32)
    y = jnp.einsum("xa,yb,abto->txyo", at_mat, at_mat, m)
    y = y + bias_ref[...].astype(jnp.float32)
    y_ref[...] = apply_activation(y, activation).astype(y_ref.dtype)


def _fused_accumulate(cstep, bt_ref, d_ref, u_ref, acc_ref):
    """Shared megakernel reduction step: V in registers, M into scratch."""

    @pl.when(cstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bt_mat = bt_ref[...]
    d = d_ref[...].astype(jnp.float32)
    # V[a,b,t,c] = sum_ij BT[a,i] d[t,i,j,c] BT[b,j]   (never stored to HBM)
    v = jnp.einsum("ai,bj,tijc->abtc", bt_mat, bt_mat, d)
    u = u_ref[...].astype(jnp.float32)
    # 64 per-position GEMMs, batched over (a, b): M[a,b] += V[a,b] @ U[a,b].
    acc_ref[...] += jax.lax.dot_general(
        v, u,
        dimension_numbers=(((3,), (2,)), ((0, 1), (0, 1))),
        preferred_element_type=jnp.float32,
    )


def _fused_inverse_transform(at_ref, acc_ref):
    """Shared megakernel finish: Y = A^T M A on the fp32 accumulator."""
    at_mat = at_ref[...]
    return jnp.einsum("xa,yb,abto->txyo", at_mat, at_mat, acc_ref[...])


def _fused_winograd_kernel(bt_ref, at_ref, d_ref, u_ref, y_ref, acc_ref, *,
                           activation: str = "linear"):
    """Single-pass megakernel body: transform, tuple-GEMM, inverse transform.

    Grid (T/bt, O/bo, C/bc) with Cin innermost (the reduction axis).  The M
    accumulator scratch (8, 8, bt, bo) fp32 persists across the Cin steps;
    V exists only as a register-resident einsum result.
    """
    cstep = pl.program_id(2)
    _fused_accumulate(cstep, bt_ref, d_ref, u_ref, acc_ref)

    @pl.when(cstep == pl.num_programs(2) - 1)
    def _done():
        y = _fused_inverse_transform(at_ref, acc_ref)
        y_ref[...] = apply_activation(y, activation).astype(y_ref.dtype)


def _fused_winograd_bias_kernel(bt_ref, at_ref, d_ref, u_ref, bias_ref,
                                y_ref, acc_ref, *, activation: str):
    """Fused megakernel with the bias (1, bo) + activation epilogue applied
    to the fp32 inverse-transform result before the store."""
    cstep = pl.program_id(2)
    _fused_accumulate(cstep, bt_ref, d_ref, u_ref, acc_ref)

    @pl.when(cstep == pl.num_programs(2) - 1)
    def _done():
        y = _fused_inverse_transform(at_ref, acc_ref)
        y = y + bias_ref[...].astype(jnp.float32)
        y_ref[...] = apply_activation(y, activation).astype(y_ref.dtype)


def fused_winograd_pallas(
    tiles: jnp.ndarray,  # (T, 8, 8, C)
    u: jnp.ndarray,      # (8, 8, C, O) pre-transformed weights
    bt: int,
    bc: int,
    bo: int,
    interpret: bool = False,
    bias=None,           # (1, O) or None
    activation: str = "linear",
) -> jnp.ndarray:
    """(T, 8, 8, C) x (8, 8, C, O) -> (T, 6, 6, O) in one pallas_call.

    T % bt == 0, C % bc == 0, O % bo == 0 (ops.py pads).  Cin is the
    innermost ('arbitrary') grid axis so the per-(tile, out-channel) block's
    M accumulator survives in scratch between reduction steps; the tile and
    weight blocks stream through VMEM double-buffered.
    """
    t, _, _, c = tiles.shape
    o = u.shape[-1]
    assert bias is None or bias.shape == (1, o), (o, getattr(bias, "shape", None))
    in_specs = [
        pl.BlockSpec((8, 8), lambda i, j, k: (0, 0)),
        pl.BlockSpec((6, 8), lambda i, j, k: (0, 0)),
        pl.BlockSpec((bt, 8, 8, bc), lambda i, j, k: (i, 0, 0, k)),
        pl.BlockSpec((8, 8, bc, bo), lambda i, j, k: (0, 0, k, j)),
    ]
    inputs = [jnp.asarray(BT, jnp.float32), jnp.asarray(AT, jnp.float32),
              tiles, u]
    if bias is not None:
        kernel = functools.partial(
            _fused_winograd_bias_kernel, activation=activation
        )
        in_specs.append(pl.BlockSpec((1, bo), lambda i, j, k: (0, j)))
        inputs.append(bias)
    else:
        kernel = functools.partial(
            _fused_winograd_kernel, activation=activation
        )
    return pl.pallas_call(
        kernel,
        grid=(t // bt, o // bo, c // bc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, 6, 6, bo), lambda i, j, k: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((t, 6, 6, o), tiles.dtype),
        scratch_shapes=[pltpu.VMEM((8, 8, bt, bo), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*inputs)


def input_transform_pallas(
    tiles: jnp.ndarray, bt: int, bc: int, interpret: bool = False
) -> jnp.ndarray:
    """(T, 8, 8, C) -> (8, 8, T, C); T % bt == 0, C % bc == 0."""
    t, _, _, c = tiles.shape
    return pl.pallas_call(
        _input_transform_kernel,
        grid=(t // bt, c // bc),
        in_specs=[
            pl.BlockSpec((8, 8), lambda i, j: (0, 0)),
            pl.BlockSpec((bt, 8, 8, bc), lambda i, j: (i, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((8, 8, bt, bc), lambda i, j: (0, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((8, 8, t, c), tiles.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(jnp.asarray(BT, jnp.float32), tiles)


def tuple_multiply_pallas(
    v: jnp.ndarray,  # (64, T, C)
    u: jnp.ndarray,  # (64, C, O)
    bt: int,
    bc: int,
    bo: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched per-position GEMM -> M (64, T, O)."""
    p, t, c = v.shape
    _, _, o = u.shape
    return pl.pallas_call(
        _tuple_multiply_kernel,
        grid=(p, t // bt, o // bo, c // bc),
        in_specs=[
            pl.BlockSpec((1, bt, bc), lambda pp, i, j, k: (pp, i, k)),
            pl.BlockSpec((1, bc, bo), lambda pp, i, j, k: (pp, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bt, bo), lambda pp, i, j, k: (pp, i, j)),
        out_shape=jax.ShapeDtypeStruct((p, t, o), v.dtype),
        scratch_shapes=[pltpu.VMEM((bt, bo), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(v, u)


def output_transform_pallas(
    m: jnp.ndarray, bt: int, bo: int, interpret: bool = False,
    bias=None, activation: str = "linear",
) -> jnp.ndarray:
    """(8, 8, T, O) -> (T, 6, 6, O), with an optional fused bias (1, O) +
    activation epilogue applied to the fp32 transform output."""
    _, _, t, o = m.shape
    assert bias is None or bias.shape == (1, o), (o, getattr(bias, "shape", None))
    in_specs = [
        pl.BlockSpec((6, 8), lambda i, j: (0, 0)),
        pl.BlockSpec((8, 8, bt, bo), lambda i, j: (0, 0, i, j)),
    ]
    if bias is not None:
        kernel = functools.partial(
            _output_transform_bias_kernel, activation=activation
        )
        in_specs.append(pl.BlockSpec((1, bo), lambda i, j: (0, j)))
    else:
        kernel = functools.partial(
            _output_transform_kernel, activation=activation
        )
    return pl.pallas_call(
        kernel,
        grid=(t // bt, o // bo),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, 6, 6, bo), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((t, 6, 6, o), m.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(jnp.asarray(AT, jnp.float32), m, *(() if bias is None else (bias,)))
