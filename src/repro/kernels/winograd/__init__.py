from repro.kernels.winograd.ops import conv2d_winograd_pallas, pick_blocks

__all__ = ["conv2d_winograd_pallas", "pick_blocks"]
