"""Pallas TPU kernels for the paper's compute hot-spots.

- gemm/:        BLIS-like blocked GEMM (3-loop and 6-loop analogues)
- im2col_gemm/: fused patch-gather + GEMM convolution
- winograd/:    F(6,3) transforms + batched tuple GEMM
Each has ops.py (jitted wrapper) and ref.py (pure-jnp oracle); all are
validated in interpret mode on CPU and lower for TPU.
"""
