"""Flash-attention Pallas kernel (forward): online-softmax attention with
BlockSpec VMEM tiling.

Beyond-paper kernel targeting the LM cells' attention memory term (see
EXPERIMENTS.md §Perf, gemma2 next-levers): never materializes the (S, S)
score matrix.  Grid (batch*heads, q-blocks, kv-blocks), kv innermost
('arbitrary') with fp32 running max / sum / accumulator in VMEM scratch —
the same schedule as the pure-JAX `attention_chunked`, which doubles as its
oracle.  Supports causal masking, sliding windows, and logit softcap
(gemma2), so every attention arch in the zoo can route through it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -2.3819763e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, window: int,
                  logit_cap: float, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if logit_cap > 0:
        s = jnp.tanh(s / logit_cap) * logit_cap

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + p.sum(axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(kj == pl.num_programs(2) - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-37)[:, None]
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)[None]


def flash_attention_pallas(
    q: jnp.ndarray,  # (BH, S, hd)
    k: jnp.ndarray,  # (BH, Sk, hd)
    v: jnp.ndarray,
    bq: int = 256,
    bk: int = 256,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, s, hd = q.shape
    sk = k.shape[1]
    assert s % bq == 0 and sk % bk == 0
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        logit_cap=logit_cap, scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, s // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
