"""Jitted wrapper: pads sequence to block multiples, flattens heads."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.util import ceil_to


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_cap", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # (B, S, H, hd)
    k: jnp.ndarray,  # (B, Sk, H, hd)  (kv heads already broadcast)
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, s, h, hd = q.shape
    sk = k.shape[1]
    bq = min(bq, ceil_to(s, 8))
    bk = min(bk, ceil_to(sk, 8))
    sp, skp = ceil_to(s, bq), ceil_to(sk, bk)
    # Padding: query pad rows produce garbage rows we slice off; key pad
    # columns are masked out because their positions exceed every real
    # query position under the causal mask, or are handled by -inf rows
    # having zero weight after the window mask.  For the non-causal,
    # no-window case we mask pads via a window the size of the real Sk.
    if not causal and window <= 0 and skp != sk:
        window = sk + s  # wide enough to keep all real keys, drop none
    qf = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, skp - sk), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, skp - sk), (0, 0), (0, 0)))
    qf = qf.transpose(0, 2, 1, 3).reshape(b * h, sp, hd)
    kf = kf.transpose(0, 2, 1, 3).reshape(b * h, skp, hd)
    vf = vf.transpose(0, 2, 1, 3).reshape(b * h, skp, hd)
    out = flash_attention_pallas(
        qf, kf, vf, bq=bq, bk=bk, causal=causal, window=window,
        logit_cap=logit_cap, interpret=interpret,
    )
    out = out.reshape(b, h, sp, hd).transpose(0, 2, 1, 3)
    return out[:, :s]
