"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def attention_ref(q, k, v, causal=True, window=0, logit_cap=0.0):
    """q (BH, S, hd), k/v (BH, Sk, hd) -> (BH, S, hd), fp32 softmax."""
    bh, s, hd = q.shape
    sk = k.shape[1]
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    if logit_cap > 0:
        scores = jnp.tanh(scores / logit_cap) * logit_cap
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    scores = jnp.where(mask[None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)
