from repro.kernels.im2col_gemm.ops import conv2d_pallas_im2col, pick_blocks
from repro.kernels.im2col_gemm.ref import conv2d_ref

__all__ = ["conv2d_pallas_im2col", "pick_blocks", "conv2d_ref"]
