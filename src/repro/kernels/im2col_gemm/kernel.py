"""Fused im2col+GEMM convolution Pallas kernel.

TPU adaptation of the paper's im2col+GEMM pipeline (§IV.A): instead of
materializing the (K x N) im2col matrix in HBM (what Darknet does), the
patch gather happens *inside* the kernel on the VMEM-resident input block —
i.e. im2col is fused into the GEMM the way the paper fuses packing into the
6-loop blocked GEMM.  Data layout is NHWC so channels ride the lane axis.

Grid: (batch, output-row tiles, out-channel blocks, in-channel blocks);
the in-channel grid axis is the K-reduction, accumulated in a VMEM fp32
scratch.  For each of the kh*kw taps (static unroll — the paper's loop
unrolling) the kernel slices a shifted window out of the resident input
block with `pl.ds` and issues one MXU matmul per tap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_spec import apply_activation
from repro.kernels.compat import CompilerParams


def _accumulate_taps(x_ref, w_ref, o_ref, acc_ref, *, kh, kw, sh, sw, toh, ow):
    """Shared K-reduction body: init the accumulator on the first in-channel
    block, then statically unroll over the kh*kw taps (paper's loop
    unrolling) — each tap is a shifted strided window -> one
    (toh*OW, bc) x (bc, bo) MXU matmul."""
    r = pl.program_id(1)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bc = x_ref.shape[-1]
    bo = o_ref.shape[-1]
    row0 = r * toh * sh
    acc = acc_ref[...].reshape(toh * ow, bo)
    for di in range(kh):
        for dj in range(kw):
            slab = x_ref[
                0,
                pl.ds(row0 + di, (toh - 1) * sh + 1),
                pl.ds(dj, (ow - 1) * sw + 1),
                :,
            ]
            patch = slab[::sh, ::sw, :].reshape(toh * ow, bc)
            acc += jnp.dot(
                patch, w_ref[di, dj], preferred_element_type=jnp.float32
            )
    acc_ref[...] = acc.reshape(toh, ow, bo)


def _conv_kernel(
    x_ref,  # (1, Hp, Wp, bc) VMEM-resident input block (one channel slab)
    w_ref,  # (kh, kw, bc, bo)
    o_ref,  # (1, toh, OW, bo)
    acc_ref,  # (toh, OW, bo) fp32 scratch
    *,
    kh: int,
    kw: int,
    sh: int,
    sw: int,
    toh: int,
    ow: int,
    activation: str = "linear",
):
    _accumulate_taps(x_ref, w_ref, o_ref, acc_ref,
                     kh=kh, kw=kw, sh=sh, sw=sw, toh=toh, ow=ow)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        o_ref[...] = apply_activation(acc_ref[...], activation).astype(
            o_ref.dtype
        )[None]


def _conv_bias_kernel(
    x_ref, w_ref, bias_ref, o_ref, acc_ref, *,
    kh: int, kw: int, sh: int, sw: int, toh: int, ow: int, activation: str,
):
    """_conv_kernel plus a fused (1, bo) bias row applied in the output
    stage, on the fp32 accumulator, after the full K reduction."""
    _accumulate_taps(x_ref, w_ref, o_ref, acc_ref,
                     kh=kh, kw=kw, sh=sh, sw=sw, toh=toh, ow=ow)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        out = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        o_ref[...] = apply_activation(out, activation).astype(o_ref.dtype)[None]


def _accumulate_taps_q8(x_ref, w_ref, o_ref, acc_ref, *, kh, kw, sh, sw,
                        toh, ow):
    """int8 K-reduction body: same tap unroll as ``_accumulate_taps`` but
    int8 patch x int8 weight block products accumulate in an int32 VMEM
    scratch (the MXU's native quantized accumulation width)."""
    r = pl.program_id(1)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bc = x_ref.shape[-1]
    bo = o_ref.shape[-1]
    row0 = r * toh * sh
    acc = acc_ref[...].reshape(toh * ow, bo)
    for di in range(kh):
        for dj in range(kw):
            slab = x_ref[
                0,
                pl.ds(row0 + di, (toh - 1) * sh + 1),
                pl.ds(dj, (ow - 1) * sw + 1),
                :,
            ]
            patch = slab[::sh, ::sw, :].reshape(toh * ow, bc)
            acc += jnp.dot(
                patch, w_ref[di, dj], preferred_element_type=jnp.int32
            )
    acc_ref[...] = acc.reshape(toh, ow, bo)


def _conv_q8_kernel(
    x_ref, w_ref, scale_ref, o_ref, acc_ref, *,
    kh: int, kw: int, sh: int, sw: int, toh: int, ow: int, activation: str,
):
    """int8 conv: fused dequant epilogue act(acc * scale) on the int32
    accumulator; ``scale_ref`` is the (1, bo) per-out-channel row of folded
    activation x weight quantization scales (core/quant.py)."""
    _accumulate_taps_q8(x_ref, w_ref, o_ref, acc_ref,
                        kh=kh, kw=kw, sh=sh, sw=sw, toh=toh, ow=ow)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        out = acc_ref[...].astype(jnp.float32) * scale_ref[...].astype(
            jnp.float32
        )
        o_ref[...] = apply_activation(out, activation).astype(o_ref.dtype)[None]


def _conv_q8_bias_kernel(
    x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
    kh: int, kw: int, sh: int, sw: int, toh: int, ow: int, activation: str,
):
    """int8 conv with the full fused epilogue: act(acc * scale + bias)."""
    _accumulate_taps_q8(x_ref, w_ref, o_ref, acc_ref,
                        kh=kh, kw=kw, sh=sh, sw=sw, toh=toh, ow=ow)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _done():
        out = acc_ref[...].astype(jnp.float32) * scale_ref[...].astype(
            jnp.float32
        )
        out = out + bias_ref[...].astype(jnp.float32)
        o_ref[...] = apply_activation(out, activation).astype(o_ref.dtype)[None]


def conv2d_im2col_gemm_pallas(
    x: jnp.ndarray,  # (B, Hp, Wp, C) already conv-padded, C % bc == 0
    w: jnp.ndarray,  # (kh, kw, C, O), O % bo == 0
    sh: int,
    sw: int,
    oh: int,
    ow: int,
    toh: int,
    bc: int,
    bo: int,
    out_dtype=None,
    interpret: bool = False,
    bias=None,
    activation: str = "linear",
    scale=None,
) -> jnp.ndarray:
    """Run the fused conv kernel.  Returns (B, OHp, OW, O); caller crops.

    The input must be pre-padded so that every row tile's window is in
    bounds:  Hp >= (OHp-1)*sh + kh with OHp = ceil(oh/toh)*toh, and
    Wp >= (OW-1)*sw + kw.  ``bias`` (1, O) and ``activation`` are the fused
    epilogue, applied once after the full in-channel reduction.

    Passing ``scale`` (1, O) selects the int8 path: ``x``/``w`` must be
    int8, the accumulator scratch is int32, and the epilogue dequantizes —
    act(acc * scale + bias) — writing ``out_dtype`` (defaults to fp32).
    """
    b, hp, wp, c = x.shape
    kh, kw, _, o = w.shape
    ohp = -(-oh // toh) * toh
    assert hp >= (ohp - 1) * sh + kh, (hp, ohp, sh, kh)
    assert wp >= (ow - 1) * sw + kw, (wp, ow, sw, kw)
    assert c % bc == 0 and o % bo == 0
    assert bias is None or bias.shape == (1, o), (o, getattr(bias, "shape", None))
    quantized = scale is not None
    if quantized:
        assert x.dtype == jnp.int8 and w.dtype == jnp.int8, (x.dtype, w.dtype)
        assert scale.shape == (1, o), (o, scale.shape)
        out_dtype = out_dtype or jnp.float32
    else:
        out_dtype = out_dtype or x.dtype

    in_specs = [
        pl.BlockSpec((1, hp, wp, bc), lambda bi, r, oc, ic: (bi, 0, 0, ic)),
        pl.BlockSpec((kh, kw, bc, bo), lambda bi, r, oc, ic: (0, 0, ic, oc)),
    ]
    if quantized:
        body = _conv_q8_bias_kernel if bias is not None else _conv_q8_kernel
    else:
        body = _conv_bias_kernel if bias is not None else _conv_kernel
    kernel = functools.partial(
        body, kh=kh, kw=kw, sh=sh, sw=sw, toh=toh, ow=ow,
        activation=activation,
    )
    extras = (() if scale is None else (scale,)) + (
        () if bias is None else (bias,)
    )
    for _ in extras:
        in_specs.append(pl.BlockSpec((1, bo), lambda bi, r, oc, ic: (0, oc)))
    return pl.pallas_call(
        kernel,
        grid=(b, ohp // toh, o // bo, c // bc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, toh, ow, bo), lambda bi, r, oc, ic: (bi, r, 0, oc)
        ),
        out_shape=jax.ShapeDtypeStruct((b, ohp, ow, o), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((toh, ow, bo), jnp.int32 if quantized else jnp.float32)
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, w, *extras)
