"""Jitted wrapper for the fused im2col+GEMM conv kernel.

Pads input/weights to HW-aligned block multiples, picks block sizes from the
co-design model (channel blocks sized so the input slab + accumulator fit
the VMEM budget), runs the kernel, crops the output.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.conv_spec import ConvSpec
from repro.hw import V5E
from repro.kernels.im2col_gemm.kernel import conv2d_im2col_gemm_pallas
from repro.util import ceil_to


def pick_blocks(
    hp: int, wp: int, c: int, o: int, oh: int, ow: int, dtype_bytes: int = 4,
    vmem_budget: Optional[int] = None,
) -> Tuple[int, int, int]:
    """(toh, bc, bo): biggest channel slab + row tile fitting the VMEM budget.

    This is the conv-kernel instance of the paper's block-size tuning
    (Table II): the input slab (Hp*Wp*bc) plays the role of the packed B
    panel, the accumulator (toh*OW*bo) the role of the C block.
    """
    budget = vmem_budget if vmem_budget is not None else V5E.vmem_bytes
    bc = min(ceil_to(c, 8), 128)
    # Shrink the channel slab until it takes at most ~2/3 of VMEM (x2 for
    # double buffering).
    while bc > 8 and 2 * hp * wp * bc * dtype_bytes > 2 * budget // 3:
        bc //= 2
    bo = min(ceil_to(o, 128), 256)
    toh = min(oh, 64)
    while toh > 8 and toh * ow * bo * 4 > budget // 3:
        toh //= 2
    return max(toh, 1), max(bc, 8), bo


@functools.partial(
    jax.jit,
    static_argnames=("spec", "blocks", "interpret", "out_dtype", "activation"),
)
def conv2d_pallas_im2col(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    blocks: Optional[Tuple[int, int, int]] = None,
    out_dtype=None,
    interpret: bool = False,
    bias: Optional[jnp.ndarray] = None,
    activation: str = "linear",
) -> jnp.ndarray:
    """Fused-conv entry point: x (B,H,W,C), w (kh,kw,C,O) -> (B,OH,OW,O).

    ``bias`` (O,) and ``activation`` form the fused epilogue, applied inside
    the kernel's output stage (see kernel.py)."""
    b, h, ww, c = x.shape
    kh, kw, _, o = w.shape
    sh, sw = spec.stride
    ph, pw = spec.padding
    oh, ow = spec.out_hw(h, ww)

    toh, bc, bo = blocks or pick_blocks(
        h + 2 * ph, ww + 2 * pw, c, o, oh, ow, jnp.dtype(x.dtype).itemsize
    )
    toh = min(toh, oh)
    ohp = ceil_to(oh, toh)
    cp, op = ceil_to(c, bc), ceil_to(o, bo)
    need_h = (ohp - 1) * sh + kh
    need_w = (ow - 1) * sw + kw
    x_p = jnp.pad(
        x,
        (
            (0, 0),
            (ph, max(need_h - h - ph, 0)),
            (pw, max(need_w - ww - pw, 0)),
            (0, cp - c),
        ),
    )
    w_p = jnp.pad(w, ((0, 0), (0, 0), (0, cp - c), (0, op - o)))
    bias_p = None
    if bias is not None:
        bias_p = jnp.pad(bias, (0, op - o)).reshape(1, op)
    out = conv2d_im2col_gemm_pallas(
        x_p, w_p, sh, sw, oh, ow, toh, bc, bo,
        out_dtype=out_dtype, interpret=interpret,
        bias=bias_p, activation=activation,
    )
    return out[:, :oh, :, :o]
