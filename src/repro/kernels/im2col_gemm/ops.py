"""Jitted wrapper for the fused im2col+GEMM conv kernel.

Pads input/weights to HW-aligned block multiples, picks block sizes from the
co-design model (channel blocks sized so the *full* per-program footprint —
input slab, weight block, bias row, output block and accumulator — fits the
VMEM budget), runs the kernel, crops the output.

The pad/crop bookkeeping is split out of the jitted body
(`pad_conv_operands` / `conv2d_im2col_padded_call` / the final crop) so the
network executor (core/netplan.py) can own the layer boundaries: a planned
network pads once at entry, flows block-padded activations between layers,
and crops once at exit.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.conv_spec import ConvSpec
from repro.core.vmem_model import ACC_BYTES, im2col_kernel_vmem_bytes
from repro.hw import V5E
from repro.kernels.im2col_gemm.kernel import conv2d_im2col_gemm_pallas
from repro.util import ceil_to, pad_bias_row


def pick_blocks(
    hp: int, wp: int, c: int, o: int, oh: int, ow: int, dtype_bytes: int = 4,
    vmem_budget: Optional[int] = None, kh: int = 3, kw: int = 3,
    out_dtype_bytes: Optional[int] = None,
) -> Tuple[int, int, int]:
    """(toh, bc, bo): biggest channel slab + row tile fitting the VMEM budget.

    This is the conv-kernel instance of the paper's block-size tuning
    (Table II): the input slab (Hp*Wp*bc) plays the role of the packed B
    panel, the accumulator (toh*OW*bo) the role of the C block.  Budgets the
    **full** per-program footprint via
    ``vmem_model.im2col_kernel_vmem_bytes`` — including the (kh, kw, bc, bo)
    weight block and the bias row the old heuristic ignored (mirroring the
    PR 3 fix to the Winograd ``pick_blocks``).  The channel slab shrinks
    first (it is what the weight block is quadratic in), then the
    out-channel block, then the row tile; nothing shrinks below the
    (sublane, lane) granularity floor (8, 128).
    """
    budget = vmem_budget if vmem_budget is not None else V5E.vmem_bytes
    bc = min(ceil_to(c, 8), 128)
    bo = min(ceil_to(o, 128), 256)
    toh = min(oh, 64)

    def fits() -> bool:
        return im2col_kernel_vmem_bytes(
            hp, wp, toh, ow, bc, bo, kh, kw, dtype_bytes,
            out_dtype_bytes=out_dtype_bytes,
        ) <= budget

    while not fits() and bc > 8:
        bc = max(8, bc // 2)
    while not fits() and bo > 128:
        bo = max(128, ceil_to(bo // 2, 128))
    while not fits() and toh > 1:
        toh = max(1, toh // 2)
    return max(toh, 1), max(bc, 8), bo


def padded_input_hw(
    h: int, w: int, spec: ConvSpec, toh: int
) -> Tuple[int, int, int]:
    """(ohp, need_h, need_w): the kernel's row-tiled output height and the
    physical input dims every row tile's window needs to stay in bounds."""
    oh, ow = spec.out_hw(h, w)
    sh, sw = spec.stride
    ohp = ceil_to(oh, min(toh, oh))
    need_h = (ohp - 1) * sh + spec.kh
    need_w = (ow - 1) * sw + spec.kw
    return ohp, need_h, need_w


def pad_conv_operands(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    blocks: Tuple[int, int, int],
    bias: Optional[jnp.ndarray] = None,
):
    """Block-align (x, w, bias) for ``conv2d_im2col_padded_call``.

    Applies the conv's own spatial padding plus the trailing row/column pad
    the row-tiled grid needs, and pads channels to the (bc, bo) block
    multiples.  Runs under the caller's jit; the executor skips it entirely
    when the incoming activation already satisfies the layout.
    """
    b, h, ww, c = x.shape
    o = w.shape[-1]
    toh, bc, bo = blocks
    ph, pw = spec.padding
    _, need_h, need_w = padded_input_hw(h, ww, spec, toh)
    cp, op = ceil_to(c, bc), ceil_to(o, bo)
    x_p = jnp.pad(
        x,
        (
            (0, 0),
            (ph, max(need_h - h - ph, 0)),
            (pw, max(need_w - ww - pw, 0)),
            (0, cp - c),
        ),
    )
    w_p = jnp.pad(w, ((0, 0), (0, 0), (0, cp - c), (0, op - o)))
    bias_p = pad_bias_row(bias, op)
    return x_p, w_p, bias_p


def conv2d_im2col_padded_call(
    x_p: jnp.ndarray,
    w_p: jnp.ndarray,
    spec: ConvSpec,
    oh: int,
    ow: int,
    blocks: Tuple[int, int, int],
    out_dtype=None,
    interpret: bool = False,
    bias_p: Optional[jnp.ndarray] = None,
    activation: str = "linear",
    scale_p: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """The kernel call on pre-padded operands: no padding, no cropping.

    ``x_p`` must already carry the conv's spatial padding, the trailing
    row/col pad from ``padded_input_hw`` and channels padded to the bc
    multiple; ``w_p``/``bias_p`` must be padded to the same channel blocks.
    ``scale_p`` (1, Op) selects the int8 dequant path (see kernel.py).
    Returns the raw (B, OHp, OW, Op) kernel output — the caller (public
    wrapper or network executor) owns the row/channel crops.
    """
    toh, bc, bo = blocks
    sh, sw = spec.stride
    return conv2d_im2col_gemm_pallas(
        x_p, w_p, sh, sw, oh, ow, min(toh, oh), bc, bo,
        out_dtype=out_dtype, interpret=interpret,
        bias=bias_p, activation=activation, scale=scale_p,
    )


@functools.partial(
    jax.jit,
    static_argnames=("spec", "blocks", "interpret", "out_dtype", "activation"),
)
def conv2d_pallas_im2col(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    blocks: Optional[Tuple[int, int, int]] = None,
    out_dtype=None,
    interpret: bool = False,
    bias: Optional[jnp.ndarray] = None,
    activation: str = "linear",
    scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Fused-conv entry point: x (B,H,W,C), w (kh,kw,C,O) -> (B,OH,OW,O).

    ``bias`` (O,) and ``activation`` form the fused epilogue, applied inside
    the kernel's output stage (see kernel.py).  ``scale`` (O,) selects the
    int8 dequant path: int8 x/w, int32 accumulation, fp32 output."""
    b, h, ww, c = x.shape
    kh, kw, _, o = w.shape
    ph, pw = spec.padding
    oh, ow = spec.out_hw(h, ww)

    blocks = blocks or pick_blocks(
        h + 2 * ph, ww + 2 * pw, c, o, oh, ow, jnp.dtype(x.dtype).itemsize,
        kh=kh, kw=kw,
    )
    x_p, w_p, bias_p = pad_conv_operands(x, w, spec, blocks, bias=bias)
    scale_p = pad_bias_row(scale, w_p.shape[-1])
    out = conv2d_im2col_padded_call(
        x_p, w_p, spec, oh, ow, blocks,
        out_dtype=out_dtype, interpret=interpret,
        bias_p=bias_p, activation=activation, scale_p=scale_p,
    )
    return out[:, :oh, :, :o]


def im2col_call_descriptor(
    h: int, w: int, spec: ConvSpec, blocks: Tuple[int, int, int],
    cp: int, op: int, batch: int = 1, dtype_bytes: int = 4,
    bias: bool = True, scale: bool = False,
) -> dict:
    """Static description of the pallas_call ``conv2d_im2col_padded_call``
    emits for a (batch, h, w, cp) activation already channel-padded to the
    bc multiple, against weights padded to (cp, op).

    The verifier's expected side: kernel body name, grid, modeled VMEM
    footprint (``vmem_model.im2col_kernel_vmem_bytes``) and the modeled HBM
    traffic from the block/grid fetch algebra — the input slab and weight
    block re-fetch on every grid step (their index maps touch the innermost
    in-channel axis), the epilogue rows once per (batch, row, out-channel)
    step, the output once per block.
    """
    oh, ow = spec.out_hw(h, w)
    ph, pw = spec.padding
    toh, bc, bo = blocks
    eff_toh = min(toh, oh)
    ohp, need_h, need_w = padded_input_hw(h, w, spec, eff_toh)
    hp = max(need_h, h + ph)      # leading pad ph, trailing max(need-h-ph, 0)
    wp = max(need_w, w + pw)
    grid = (batch, ohp // eff_toh, op // bo, cp // bc)
    nsteps = batch * (ohp // eff_toh) * (op // bo)
    full = nsteps * (cp // bc)
    rows = int(scale) + int(bias)
    out_bytes = ACC_BYTES if dtype_bytes == 1 else dtype_bytes
    traffic = (
        dtype_bytes * full * (hp * wp * bc + spec.kh * spec.kw * bc * bo)
        + ACC_BYTES * rows * nsteps * bo          # epilogue rows
        + out_bytes * nsteps * eff_toh * ow * bo  # output blocks
    )
    name = (
        "_conv" + ("_q8" if scale else "") + ("_bias" if bias else "")
        + "_kernel"
    )
    return {
        "family": "im2col",
        "name": name,
        "grid": grid,
        "model_vmem_bytes": im2col_kernel_vmem_bytes(
            hp, wp, eff_toh, ow, bc, bo, spec.kh, spec.kw, dtype_bytes,
            bias=bias or scale,
        ),
        "traffic_bytes": traffic,
        "vmem_one_sided": False,
        # Kernel-interior contract: the in-channel grid axis (innermost) is
        # the K reduction, accumulated in VMEM scratch; the full reduction
        # depth spans every tap of every padded in-channel — the quantity
        # the int8 overflow pass certifies.
        "reduction_axes": (3,),
        "k_elems": spec.kh * spec.kw * cp,
    }
