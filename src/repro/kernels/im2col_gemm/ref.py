"""Pure-jnp oracle for the fused im2col+GEMM conv kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.conv_spec import ConvSpec
from repro.core.im2col import conv2d_im2col


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray, spec: ConvSpec) -> jnp.ndarray:
    """The unfused reference: explicit im2col then GEMM (core/im2col.py)."""
    return conv2d_im2col(x, w, spec)
