"""Jitted public wrapper around the blocked GEMM kernels.

Handles HW-alignment padding (the TPU analogue of the paper's loop-tail /
`vsetvl` handling: we pad to block multiples instead of predicating) and
block autotuning via the co-design model when no block is given.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.vmem_model import (
    ACC_BYTES,
    BlockConfig,
    GemmShape,
    autotune_gemm,
    gemm_kernel_vmem_bytes,
)
from repro.hw import V5E
from repro.kernels.gemm.kernel import matmul_pallas
from repro.util import ceil_to, pad_bias_row


def default_block(m: int, n: int, k: int, dtype_bytes: int = 4) -> BlockConfig:
    """Autotuned block for this shape under the v5e VMEM budget, clamped to
    the (padded) problem so tiny test shapes don't over-pad."""
    cfg, _ = autotune_gemm(GemmShape(m, n, k), V5E, dtype_bytes=dtype_bytes)
    bm = min(cfg.bm, ceil_to(m, 8))
    bn = min(cfg.bn, ceil_to(n, 128))
    bk = min(cfg.bk, ceil_to(k, 128))
    return BlockConfig(bm, bn, bk)


def pad_gemm_operands(
    a: jnp.ndarray,
    b: jnp.ndarray,
    block: Tuple[int, int, int],
    bias: Optional[jnp.ndarray] = None,
):
    """Block-align (a, b, bias) for ``matmul_padded_call``.

    Runs under the caller's jit.  Split out of ``blocked_matmul`` so the
    network executor (core/netplan.py) can skip it when the operands already
    satisfy the planned layout (pre-padded activations / offline-padded
    weights) and no pad ops enter the jaxpr at the layer boundary.
    """
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = block
    mp, np_, kp = ceil_to(m, bm), ceil_to(n, bn), ceil_to(k, bk)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else a
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else b
    bias_p = pad_bias_row(bias, np_)
    return a_p, b_p, bias_p


def matmul_padded_call(
    a_p: jnp.ndarray,
    b_p: jnp.ndarray,
    block: Tuple[int, int, int],
    variant: str = "6loop",
    out_dtype=None,
    interpret: bool = False,
    bias_p: Optional[jnp.ndarray] = None,
    activation: str = "linear",
    scale_p: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """The kernel call on block-aligned operands: no padding, no cropping.

    a_p (Mp, Kp), b_p (Kp, Np) with Mp % bm == Kp % bk == Np % bn == 0;
    bias_p (1, Np) or None; scale_p (1, Np) selects the int8 dequant path.
    Returns the raw (Mp, Np) kernel output — the caller owns any crop back
    to logical dims.
    """
    bm, bn, bk = block
    if variant == "3loop":
        bk = a_p.shape[1]
    return matmul_pallas(
        a_p, b_p, bm, bn, bk, variant=variant, out_dtype=out_dtype,
        interpret=interpret, bias=bias_p, activation=activation,
        scale=scale_p,
    )


@functools.partial(
    jax.jit,
    static_argnames=("block", "variant", "interpret", "out_dtype", "activation"),
)
def blocked_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    block: Optional[Tuple[int, int, int]] = None,
    variant: str = "6loop",
    out_dtype=None,
    interpret: bool = False,
    bias: Optional[jnp.ndarray] = None,
    activation: str = "linear",
    scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """C = act(A @ B + bias) with BLIS-like VMEM blocking.

    Args:
      a: (M, K); b: (K, N).
      block: (bm, bn, bk) or None to autotune (co-design model).
      variant: '6loop' (K-blocked, VMEM accumulation) or '3loop' (full-K
        panel per output block).
      bias: optional (N,) vector fused into the kernel's output stage.
      activation: 'linear' | 'relu' | 'leaky', fused likewise.
      scale: optional (N,) dequant row — int8 a/b, int32 accumulation,
        act(acc * scale + bias) epilogue writing fp32.
    """
    m, k = a.shape
    _, n = b.shape
    if block is None:
        cfg = default_block(m, n, k, jnp.dtype(a.dtype).itemsize)
        block = (cfg.bm, cfg.bn, cfg.bk)
    a_p, b_p, bias_p = pad_gemm_operands(a, b, block, bias=bias)
    scale_p = pad_bias_row(scale, b_p.shape[1])
    out = matmul_padded_call(
        a_p, b_p, block, variant=variant, out_dtype=out_dtype,
        interpret=interpret, bias_p=bias_p, activation=activation,
        scale_p=scale_p,
    )
    return out[:m, :n]


def gemm_call_descriptor(
    mp: int, np_: int, kp: int, block: Tuple[int, int, int],
    dtype_bytes: int = 4, bias: bool = False, scale: bool = False,
    variant: str = "6loop",
) -> dict:
    """Static description of the pallas_call ``matmul_padded_call`` emits.

    The verifier's expected side: for block-aligned operands (Mp, Kp) x
    (Kp, Np) it predicts the kernel body name, the grid, the modeled VMEM
    footprint and the modeled HBM traffic — the same fetch algebra the
    jaxpr-recovered actuals follow (an operand whose index map depends on
    grid axes up to ``a`` is re-fetched once per step of ``grid[:a+1]``).
    """
    bm, bn, bk = block
    if variant == "3loop":
        bk = kp
    nm, nn, nk = mp // bm, np_ // bn, kp // bk
    rows = int(scale) + int(bias)
    out_bytes = ACC_BYTES if dtype_bytes == 1 else dtype_bytes
    if variant == "3loop":
        grid = (nm, nn)
        traffic = (
            dtype_bytes * (mp * kp + nm * nn * kp * bn)       # A once, B per j
            + ACC_BYTES * rows * nm * nn * bn                 # epilogue rows
            + out_bytes * mp * np_                            # output write
        )
    else:
        grid = (nm, nn, nk)
        traffic = (
            dtype_bytes * nm * nn * nk * (bm * bk + bk * bn)  # A/B per step
            + ACC_BYTES * rows * nm * nn * bn                 # epilogue rows
            + out_bytes * mp * np_                            # output write
        )
    name = (
        "_matmul"
        + ("_q8" if scale else "")
        + ("_bias" if bias else "")
        + "_kernel_"
        + variant
    )
    return {
        "family": "gemm",
        "name": name,
        "grid": grid,
        "model_vmem_bytes": gemm_kernel_vmem_bytes(
            bm, bn, bk, dtype_bytes, epilogue_rows=rows,
            three_loop=variant == "3loop",
        ),
        "traffic_bytes": traffic,
        "vmem_one_sided": False,
        # Kernel-interior contract (the verifier's `kernel` rung): the
        # 6-loop variant reduces over the K grid axis into VMEM scratch;
        # the 3-loop variant streams the full K panel (no reduction axis).
        # ``k_elems`` is the reduction depth the int8 overflow pass
        # certifies against the traced operand shapes.
        "reduction_axes": () if variant == "3loop" else (2,),
        "k_elems": kp,
    }
