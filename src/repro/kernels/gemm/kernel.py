"""Blocked GEMM Pallas kernels — the paper's 3-loop / 6-loop GEMMs on TPU.

The 6-loop BLIS mapping (paper Fig. 3 -> TPU):
  - j1/i1/k1 cache-blocking loops  -> the pallas grid (nm, nn, nk)
  - packing of A/B panels          -> implicit HBM->VMEM block copies
                                      (hardware-tiled, contiguous)
  - prefetch into L1/L2            -> Pallas software pipelining
                                      (next block DMA overlaps compute)
  - micro-kernel (vfmacc chain)    -> one MXU `jnp.dot` per block step,
                                      fp32 accumulation in VMEM scratch
  - unroll factor / vector length  -> block shape (bm, bn)

The 3-loop variant (paper Fig. 2) streams the full K panel per output
block: no K-grid, no accumulator scratch.  The co-design study
(core/codesign.py) decides which wins for a given shape + VMEM budget —
reproducing the paper's "optimizations are not portable" finding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_spec import apply_activation
from repro.kernels.compat import CompilerParams


def _accumulate_k_block(a_ref, b_ref, acc_ref):
    """Shared 6-loop body: zero the VMEM accumulator on the first K step,
    then add this (bm, bk) x (bk, bn) block product."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_kernel_6loop(a_ref, b_ref, c_ref, acc_ref, *, activation: str):
    """Grid (nm, nn, nk), K innermost: accumulate A@B blocks in VMEM."""
    _accumulate_k_block(a_ref, b_ref, acc_ref)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        # Fused epilogue on the VMEM-resident fp32 accumulator (paper §IV.A:
        # absorb adjacent data movement into the micro-kernel's output stage).
        c_ref[...] = apply_activation(acc_ref[...], activation).astype(c_ref.dtype)


def _matmul_bias_kernel_6loop(a_ref, b_ref, bias_ref, c_ref, acc_ref, *,
                              activation: str):
    """6-loop variant with a fused (1, bn) bias row + activation epilogue."""
    _accumulate_k_block(a_ref, b_ref, acc_ref)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        out = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        c_ref[...] = apply_activation(out, activation).astype(c_ref.dtype)


def _matmul_kernel_3loop(a_ref, b_ref, c_ref, *, activation: str):
    """Grid (nm, nn): one full-K panel per output block (paper Fig. 2)."""
    out = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    c_ref[...] = apply_activation(out, activation).astype(c_ref.dtype)


def _matmul_bias_kernel_3loop(a_ref, b_ref, bias_ref, c_ref, *, activation: str):
    """3-loop variant with a fused bias + activation epilogue."""
    out = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    out = out + bias_ref[...].astype(jnp.float32)
    c_ref[...] = apply_activation(out, activation).astype(c_ref.dtype)


# --- int8 variants -----------------------------------------------------------
# Same loop structures, integer arithmetic: int8 x int8 blocks accumulate in
# int32 (MXU native rate is 2x bf16), and the write-back stage dequantizes —
# out = act(acc * scale + bias) — so the quantized GEMM still costs exactly
# one HBM round trip for C, now in fp32.  ``scale`` is the (1, bn) folded
# activation x weight scale row (core/quant.py); ``bias`` stays fp32.


def _accumulate_k_block_q8(a_ref, b_ref, acc_ref):
    """6-loop int8 body: int32 VMEM accumulator over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.int32
    )


def _dequant_epilogue(acc, scale_ref, bias_ref, activation: str):
    """Fused dequant + bias + activation on the int32 accumulator."""
    out = acc.astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    if bias_ref is not None:
        out = out + bias_ref[...].astype(jnp.float32)
    return apply_activation(out, activation)


def _matmul_q8_kernel_6loop(a_ref, b_ref, scale_ref, c_ref, acc_ref, *,
                            activation: str):
    _accumulate_k_block_q8(a_ref, b_ref, acc_ref)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        out = _dequant_epilogue(acc_ref[...], scale_ref, None, activation)
        c_ref[...] = out.astype(c_ref.dtype)


def _matmul_q8_bias_kernel_6loop(a_ref, b_ref, scale_ref, bias_ref, c_ref,
                                 acc_ref, *, activation: str):
    _accumulate_k_block_q8(a_ref, b_ref, acc_ref)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        out = _dequant_epilogue(acc_ref[...], scale_ref, bias_ref, activation)
        c_ref[...] = out.astype(c_ref.dtype)


def _matmul_q8_kernel_3loop(a_ref, b_ref, scale_ref, c_ref, *, activation: str):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.int32)
    out = _dequant_epilogue(acc, scale_ref, None, activation)
    c_ref[...] = out.astype(c_ref.dtype)


def _matmul_q8_bias_kernel_3loop(a_ref, b_ref, scale_ref, bias_ref, c_ref, *,
                                 activation: str):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.int32)
    out = _dequant_epilogue(acc, scale_ref, bias_ref, activation)
    c_ref[...] = out.astype(c_ref.dtype)


def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int,
    bn: int,
    bk: int,
    variant: str = "6loop",
    out_dtype=None,
    interpret: bool = False,
    bias=None,
    activation: str = "linear",
    scale=None,
) -> jnp.ndarray:
    """Blocked matmul; dims must already be padded to block multiples.

    ``bias`` (1, N) and ``activation`` form the fused epilogue, applied to
    the fp32 accumulator in the output stage (no extra HBM round trip).

    Passing ``scale`` (1, N) selects the int8 path: ``a``/``b`` must be
    int8, accumulation is int32, and the epilogue dequantizes —
    act(acc * scale + bias) — writing ``out_dtype`` (defaults to fp32).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bias is None or bias.shape == (1, n), (n, getattr(bias, "shape", None))
    quantized = scale is not None
    if quantized:
        assert a.dtype == jnp.int8 and b.dtype == jnp.int8, (a.dtype, b.dtype)
        assert scale.shape == (1, n), (n, scale.shape)
        out_dtype = out_dtype or jnp.float32
    else:
        out_dtype = out_dtype or a.dtype
    out_shape = jax.ShapeDtypeStruct((m, n), out_dtype)
    extras = (() if scale is None else (scale,)) + (
        () if bias is None else (bias,)
    )

    if variant == "3loop":
        if quantized:
            body = (_matmul_q8_bias_kernel_3loop if bias is not None
                    else _matmul_q8_kernel_3loop)
        else:
            body = (_matmul_bias_kernel_3loop if bias is not None
                    else _matmul_kernel_3loop)
        kern = functools.partial(body, activation=activation)
        in_specs = [
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ]
        for _ in extras:
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
        return pl.pallas_call(
            kern,
            grid=(m // bm, n // bn),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=out_shape,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel")
            ),
            interpret=interpret,
        )(a, b, *extras)

    if quantized:
        body = (_matmul_q8_bias_kernel_6loop if bias is not None
                else _matmul_q8_kernel_6loop)
    else:
        body = (_matmul_bias_kernel_6loop if bias is not None
                else _matmul_kernel_6loop)
    kern = functools.partial(body, activation=activation)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    for _ in extras:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, k // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32 if quantized else jnp.float32)
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b, *extras)
