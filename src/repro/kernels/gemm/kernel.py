"""Blocked GEMM Pallas kernels — the paper's 3-loop / 6-loop GEMMs on TPU.

The 6-loop BLIS mapping (paper Fig. 3 -> TPU):
  - j1/i1/k1 cache-blocking loops  -> the pallas grid (nm, nn, nk)
  - packing of A/B panels          -> implicit HBM->VMEM block copies
                                      (hardware-tiled, contiguous)
  - prefetch into L1/L2            -> Pallas software pipelining
                                      (next block DMA overlaps compute)
  - micro-kernel (vfmacc chain)    -> one MXU `jnp.dot` per block step,
                                      fp32 accumulation in VMEM scratch
  - unroll factor / vector length  -> block shape (bm, bn)

The 3-loop variant (paper Fig. 2) streams the full K panel per output
block: no K-grid, no accumulator scratch.  The co-design study
(core/codesign.py) decides which wins for a given shape + VMEM budget —
reproducing the paper's "optimizations are not portable" finding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.conv_spec import apply_activation
from repro.kernels.compat import CompilerParams


def _accumulate_k_block(a_ref, b_ref, acc_ref):
    """Shared 6-loop body: zero the VMEM accumulator on the first K step,
    then add this (bm, bk) x (bk, bn) block product."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_kernel_6loop(a_ref, b_ref, c_ref, acc_ref, *, activation: str):
    """Grid (nm, nn, nk), K innermost: accumulate A@B blocks in VMEM."""
    _accumulate_k_block(a_ref, b_ref, acc_ref)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        # Fused epilogue on the VMEM-resident fp32 accumulator (paper §IV.A:
        # absorb adjacent data movement into the micro-kernel's output stage).
        c_ref[...] = apply_activation(acc_ref[...], activation).astype(c_ref.dtype)


def _matmul_bias_kernel_6loop(a_ref, b_ref, bias_ref, c_ref, acc_ref, *,
                              activation: str):
    """6-loop variant with a fused (1, bn) bias row + activation epilogue."""
    _accumulate_k_block(a_ref, b_ref, acc_ref)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        out = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        c_ref[...] = apply_activation(out, activation).astype(c_ref.dtype)


def _matmul_kernel_3loop(a_ref, b_ref, c_ref, *, activation: str):
    """Grid (nm, nn): one full-K panel per output block (paper Fig. 2)."""
    out = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    c_ref[...] = apply_activation(out, activation).astype(c_ref.dtype)


def _matmul_bias_kernel_3loop(a_ref, b_ref, bias_ref, c_ref, *, activation: str):
    """3-loop variant with a fused bias + activation epilogue."""
    out = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    out = out + bias_ref[...].astype(jnp.float32)
    c_ref[...] = apply_activation(out, activation).astype(c_ref.dtype)


def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int,
    bn: int,
    bk: int,
    variant: str = "6loop",
    out_dtype=None,
    interpret: bool = False,
    bias=None,
    activation: str = "linear",
) -> jnp.ndarray:
    """Blocked matmul; dims must already be padded to block multiples.

    ``bias`` (1, N) and ``activation`` form the fused epilogue, applied to
    the fp32 accumulator in the output stage (no extra HBM round trip).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bias is None or bias.shape == (1, n), (n, getattr(bias, "shape", None))
    out_dtype = out_dtype or a.dtype
    out_shape = jax.ShapeDtypeStruct((m, n), out_dtype)

    if variant == "3loop":
        kern = functools.partial(
            _matmul_bias_kernel_3loop if bias is not None else _matmul_kernel_3loop,
            activation=activation,
        )
        in_specs = [
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ]
        if bias is not None:
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
        return pl.pallas_call(
            kern,
            grid=(m // bm, n // bn),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=out_shape,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel")
            ),
            interpret=interpret,
        )(a, b, *(() if bias is None else (bias,)))

    kern = functools.partial(
        _matmul_bias_kernel_6loop if bias is not None else _matmul_kernel_6loop,
        activation=activation,
    )
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, k // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b, *(() if bias is None else (bias,)))
