"""Blocked GEMM Pallas kernels — the paper's 3-loop / 6-loop GEMMs on TPU.

The 6-loop BLIS mapping (paper Fig. 3 -> TPU):
  - j1/i1/k1 cache-blocking loops  -> the pallas grid (nm, nn, nk)
  - packing of A/B panels          -> implicit HBM->VMEM block copies
                                      (hardware-tiled, contiguous)
  - prefetch into L1/L2            -> Pallas software pipelining
                                      (next block DMA overlaps compute)
  - micro-kernel (vfmacc chain)    -> one MXU `jnp.dot` per block step,
                                      fp32 accumulation in VMEM scratch
  - unroll factor / vector length  -> block shape (bm, bn)

The 3-loop variant (paper Fig. 2) streams the full K panel per output
block: no K-grid, no accumulator scratch.  The co-design study
(core/codesign.py) decides which wins for a given shape + VMEM budget —
reproducing the paper's "optimizations are not portable" finding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _matmul_kernel_6loop(a_ref, b_ref, c_ref, acc_ref):
    """Grid (nm, nn, nk), K innermost: accumulate A@B blocks in VMEM."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


def _matmul_kernel_3loop(a_ref, b_ref, c_ref):
    """Grid (nm, nn): one full-K panel per output block (paper Fig. 2)."""
    c_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(c_ref.dtype)


def matmul_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int,
    bn: int,
    bk: int,
    variant: str = "6loop",
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked matmul; dims must already be padded to block multiples."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    out_dtype = out_dtype or a.dtype
    out_shape = jax.ShapeDtypeStruct((m, n), out_dtype)

    if variant == "3loop":
        return pl.pallas_call(
            _matmul_kernel_3loop,
            grid=(m // bm, n // bn),
            in_specs=[
                pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=out_shape,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "parallel")
            ),
            interpret=interpret,
        )(a, b)

    return pl.pallas_call(
        _matmul_kernel_6loop,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b)
