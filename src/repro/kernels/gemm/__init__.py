from repro.kernels.gemm.ops import blocked_matmul, default_block
from repro.kernels.gemm.ref import matmul_ref

__all__ = ["blocked_matmul", "default_block", "matmul_ref"]
