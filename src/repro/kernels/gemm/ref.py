"""Pure-jnp oracle for the blocked GEMM kernels."""
from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """fp32-accumulated matmul, the semantics the kernels must match."""
    out = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)
