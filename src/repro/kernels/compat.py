"""Version shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (~0.5);
the kernels target the new name, this alias keeps them importable on the
older jaxlib baked into the CI/dev image.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:
    try:
        CompilerParams = pltpu.TPUCompilerParams
    except AttributeError as e:  # name the version problem at import time
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; this jax version is unsupported by the "
            "Pallas kernels"
        ) from e

__all__ = ["CompilerParams"]
