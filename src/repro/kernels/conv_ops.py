"""Kernel-level dispatcher used by core.conv2d(impl='pallas').

Routes per the paper's selector: 1x1 -> blocked GEMM (direct), 3x3 stride-1
-> Winograd kernels, everything else -> fused im2col+GEMM kernel.  When a
``ConvPlan`` is supplied the kernels run with its autotuned block sizes
instead of their built-in heuristics.

With an explicit ``Layout`` pair (core/netplan.py) the dispatcher runs the
network executor's contract instead of the self-contained wrappers: the
input activation (and the offline-prepared weights/bias) already carry
block-padded channels, so no channel pads enter the jaxpr here, and with a
non-trivial ``out_layout`` the channel crop is deferred — the padded
activation flows straight into the next layer's pallas_call.
"""
from __future__ import annotations

from typing import Optional, TYPE_CHECKING

import jax.numpy as jnp

from repro.core.conv_spec import ConvAlgorithm, ConvSpec, Epilogue
from repro.util import ceil_to, pad_bias_row

if TYPE_CHECKING:
    from repro.core.netplan import Layout
    from repro.core.planner import ConvPlan


def conv2d_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    algo: ConvAlgorithm,
    interpret: Optional[bool] = None,
    plan: Optional["ConvPlan"] = None,
    epilogue: Optional[Epilogue] = None,
    in_layout: Optional["Layout"] = None,
    out_layout: Optional["Layout"] = None,
    pretransformed: bool = False,
) -> jnp.ndarray:
    """x (B,H,W,C), w (kh,kw,C,O) -> (B,OH,OW,O) via Pallas kernels.

    ``epilogue`` (bias + activation, plus the int8 dequant ``scale``) is
    forwarded into each kernel family's output stage — no separate
    elementwise pass over HBM.  An int8 ``x`` requires an epilogue scale and
    never routes to Winograd: the F(6, 3) transform amplifies the data range
    past the int8 error budget (core/quant.py::winograd_int8_budget_ok), so
    the planner rewrites such layers to im2col/direct or keeps them fp32.
    ``pretransformed`` declares offline Winograd-transformed weights
    ((8, 8, C, O)); it is an explicit contract, never inferred from the
    weight shape (raw kh == 8 kernels share that shape).
    """
    import jax

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    blocks = plan.kernel_blocks if plan is not None else None
    bias = epilogue.bias if epilogue is not None else None
    activation = epilogue.activation if epilogue is not None else "linear"
    scale = epilogue.scale if epilogue is not None else None
    if x.dtype == jnp.int8:
        assert scale is not None, "int8 conv requires an epilogue dequant scale"
        assert algo is not ConvAlgorithm.WINOGRAD, (
            "int8 never routes to Winograd (transform-stage error budget)"
        )

    if in_layout is not None or out_layout is not None:
        return _conv2d_pallas_laidout(
            x, w, spec, algo, blocks, interpret, bias, activation,
            in_layout, out_layout, plan, pretransformed, scale,
        )

    if algo is ConvAlgorithm.DIRECT:
        from repro.kernels.gemm import blocked_matmul

        sh, sw = spec.stride
        ph, pw = spec.padding
        # Pad BEFORE subsampling, exactly like core.im2col.conv2d_direct_1x1:
        # dropping spec.padding here silently shrank the output (wrong shape
        # *and* values for any padded 1x1 layer).
        if ph or pw:
            x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
        if (sh, sw) != (1, 1):
            x = x[:, ::sh, ::sw, :]
        b, oh, ow, c = x.shape
        out = blocked_matmul(
            x.reshape(b * oh * ow, c),
            w.reshape(c, spec.out_channels),
            block=blocks,
            interpret=interpret,
            bias=bias,
            activation=activation,
            scale=scale,
        )
        return out.reshape(b, oh, ow, spec.out_channels)

    if algo is ConvAlgorithm.WINOGRAD:
        from repro.kernels.winograd import conv2d_winograd_pallas

        # The single-pass fused megakernel is the default; a plan can pin
        # the 3-pass pipeline (e.g. a measure-mode planner that timed both).
        fused = plan.winograd_fused if plan is not None else True
        return conv2d_winograd_pallas(
            x, w, spec, blocks=blocks, interpret=interpret,
            pretransformed=pretransformed,
            bias=bias, activation=activation, fused=fused,
        )

    from repro.kernels.im2col_gemm import conv2d_pallas_im2col

    return conv2d_pallas_im2col(
        x, w, spec, blocks=blocks, interpret=interpret,
        bias=bias, activation=activation, scale=scale,
    )


def _conv2d_pallas_laidout(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    algo: ConvAlgorithm,
    blocks,
    interpret: bool,
    bias: Optional[jnp.ndarray],
    activation: str,
    in_layout: Optional["Layout"],
    out_layout: Optional["Layout"],
    plan: Optional["ConvPlan"],
    pretransformed: bool = False,
    scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Executor path: channels pre-padded in, channel crop deferred out.

    Contract (enforced by core/netplan): ``x``'s channel count equals
    ``in_layout.phys_c`` and divides the plan's channel block; ``w``/``bias``
    were padded offline to (in phys, out phys); the out-channel padding is
    zeros-in → act(0 + 0) = 0 out, so a deferred crop is exact.  Whatever
    padding remains here (row-tile tails, tile-count alignment, the M tail
    of the direct GEMM) is intra-layer data movement the boundary cannot
    remove.
    """
    o_keep = (
        out_layout.phys_c
        if out_layout is not None and out_layout.pad_c
        else spec.out_channels
    )
    if in_layout is not None:
        assert x.shape[-1] == in_layout.phys_c, (x.shape, in_layout)
    assert w.shape[2] == x.shape[-1], (w.shape, x.shape)

    if algo is ConvAlgorithm.DIRECT:
        from repro.kernels.gemm.ops import (
            default_block,
            matmul_padded_call,
            pad_gemm_operands,
        )

        sh, sw = spec.stride
        ph, pw = spec.padding
        if ph or pw:
            x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
        if (sh, sw) != (1, 1):
            x = x[:, ::sh, ::sw, :]
        b, oh, ow, cp = x.shape
        a = x.reshape(b * oh * ow, cp)
        w2 = w.reshape(cp, w.shape[-1])
        m = a.shape[0]
        if blocks is None:
            cfg = default_block(
                m, w2.shape[1], cp, jnp.dtype(x.dtype).itemsize
            )
            blocks = (cfg.bm, cfg.bn, cfg.bk)
        a_p, b_p, bias_p = pad_gemm_operands(a, w2, blocks, bias=bias)
        scale_p = pad_bias_row(scale, b_p.shape[1])
        out = matmul_padded_call(
            a_p, b_p, blocks, interpret=interpret,
            bias_p=bias_p, activation=activation, scale_p=scale_p,
        )
        if out.shape != (m, o_keep):
            out = out[:m, :o_keep]
        return out.reshape(b, oh, ow, o_keep)

    if algo is ConvAlgorithm.WINOGRAD:
        from repro.core.winograd import transform_weights
        from repro.kernels.winograd.ops import (
            conv2d_winograd_padded_call,
            pick_blocks,
        )

        assert scale is None, "int8 never routes to Winograd"

        b, h, ww, cp = x.shape
        oh, ow = spec.out_hw(h, ww)
        ph, pw = spec.padding
        if ph or pw:
            x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
        # Offline-prepared weights arrive pre-transformed as (8, 8, Cp, Op);
        # the executor carries the flag explicitly (no shape sniffing).
        u = w if pretransformed else transform_weights(w, x.dtype)
        if blocks is None:
            t = b * -(-oh // 6) * -(-ow // 6)
            blocks = pick_blocks(
                t, cp, u.shape[-1], dtype_bytes=jnp.dtype(x.dtype).itemsize
            )
        bt, bc, bo = blocks
        op = ceil_to(u.shape[-1], bo)
        if op != u.shape[-1]:
            u = jnp.pad(u, ((0, 0), (0, 0), (0, 0), (0, op - u.shape[-1])))
        bias_p = pad_bias_row(bias, op)
        fused = plan.winograd_fused if plan is not None else True
        y = conv2d_winograd_padded_call(
            x, u, oh, ow, blocks, interpret=interpret,
            bias_p=bias_p, activation=activation, fused=fused,
        )
        return y[..., :o_keep] if y.shape[-1] != o_keep else y

    from repro.kernels.im2col_gemm.ops import (
        conv2d_im2col_padded_call,
        padded_input_hw,
        pick_blocks,
    )

    b, h, ww, cp = x.shape
    kh, kw, _, o_phys = w.shape
    oh, ow = spec.out_hw(h, ww)
    ph, pw = spec.padding
    if blocks is None:
        blocks = pick_blocks(
            h + 2 * ph, ww + 2 * pw, cp, o_phys, oh, ow,
            jnp.dtype(x.dtype).itemsize, kh=kh, kw=kw,
        )
    toh, bc, bo = blocks
    _, need_h, need_w = padded_input_hw(h, ww, spec, toh)
    pads = (
        (0, 0),
        (ph, max(need_h - h - ph, 0)),
        (pw, max(need_w - ww - pw, 0)),
        (0, 0),
    )
    x_p = jnp.pad(x, pads) if any(p != (0, 0) for p in pads) else x
    op = ceil_to(o_phys, bo)
    w_p = (
        jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, op - o_phys)))
        if op != o_phys else w
    )
    bias_p = pad_bias_row(bias, op)
    scale_p = pad_bias_row(scale, op)
    out = conv2d_im2col_padded_call(
        x_p, w_p, spec, oh, ow, blocks, interpret=interpret,
        bias_p=bias_p, activation=activation, scale_p=scale_p,
    )
    if out.shape[1] != oh:
        out = out[:, :oh]
    return out[..., :o_keep] if out.shape[-1] != o_keep else out
