"""Kernel-level dispatcher used by core.conv2d(impl='pallas').

Routes per the paper's selector: 1x1 -> blocked GEMM (direct), 3x3 stride-1
-> Winograd kernels, everything else -> fused im2col+GEMM kernel.  When a
``ConvPlan`` is supplied the kernels run with its autotuned block sizes
instead of their built-in heuristics.
"""
from __future__ import annotations

from typing import Optional, TYPE_CHECKING

import jax.numpy as jnp

from repro.core.conv_spec import ConvAlgorithm, ConvSpec, Epilogue

if TYPE_CHECKING:
    from repro.core.planner import ConvPlan


def conv2d_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: ConvSpec,
    algo: ConvAlgorithm,
    interpret: Optional[bool] = None,
    plan: Optional["ConvPlan"] = None,
    epilogue: Optional[Epilogue] = None,
) -> jnp.ndarray:
    """x (B,H,W,C), w (kh,kw,C,O) -> (B,OH,OW,O) via Pallas kernels.

    ``epilogue`` (bias + activation) is forwarded into each kernel family's
    output stage — no separate elementwise pass over HBM.
    """
    import jax

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    blocks = plan.kernel_blocks if plan is not None else None
    bias = epilogue.bias if epilogue is not None else None
    activation = epilogue.activation if epilogue is not None else "linear"

    if algo is ConvAlgorithm.DIRECT:
        from repro.kernels.gemm import blocked_matmul

        sh, sw = spec.stride
        ph, pw = spec.padding
        # Pad BEFORE subsampling, exactly like core.im2col.conv2d_direct_1x1:
        # dropping spec.padding here silently shrank the output (wrong shape
        # *and* values for any padded 1x1 layer).
        if ph or pw:
            x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
        if (sh, sw) != (1, 1):
            x = x[:, ::sh, ::sw, :]
        b, oh, ow, c = x.shape
        out = blocked_matmul(
            x.reshape(b * oh * ow, c),
            w.reshape(c, spec.out_channels),
            block=blocks,
            interpret=interpret,
            bias=bias,
            activation=activation,
        )
        return out.reshape(b, oh, ow, spec.out_channels)

    if algo is ConvAlgorithm.WINOGRAD:
        from repro.kernels.winograd import conv2d_winograd_pallas

        # The single-pass fused megakernel is the default; a plan can pin
        # the 3-pass pipeline (e.g. a measure-mode planner that timed both).
        fused = plan.winograd_fused if plan is not None else True
        return conv2d_winograd_pallas(
            x, w, spec, blocks=blocks, interpret=interpret,
            bias=bias, activation=activation, fused=fused,
        )

    from repro.kernels.im2col_gemm import conv2d_pallas_im2col

    return conv2d_pallas_im2col(
        x, w, spec, blocks=blocks, interpret=interpret,
        bias=bias, activation=activation,
    )
