"""ExecutionOptions: the one co-design surface of the `repro.api` facade.

The paper's argument is that algorithm choice, blocking, and hardware
parameters must be decided *together*; before this facade those decisions
were scattered across ~10 uncoordinated kwargs (``conv2d``'s routing
arguments, the planner's policy fields, the executor's interpret/devices,
the serving engine's bucket ladder).  ``ExecutionOptions`` is the single
frozen record of every knob that changes how a compiled model executes —
hashable, JSON round-trippable (``save()``/``load()`` ride it), and the
only thing ``repro.compile`` needs besides the model and its params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.core.planner import DEFAULT_CACHE_PATH, _dtype_name

_IMPLS = ("jax", "pallas")
_MODES = ("cost", "measure")
_DTYPES = ("float32", "bfloat16", "float16", "int8")
_VALIDATE = ("off", "plan", "kernel", "full")
_FALLBACK = ("ladder", "off")


@dataclasses.dataclass(frozen=True)
class ExecutionOptions:
    """Every execution decision for one compiled model, in one place.

    Planning policy (forwarded to the ``Planner`` / v4 plan cache):
      impl            'jax' (pure jnp) or 'pallas' (TPU kernels).
      mode            'cost' (analytic VMEM model) or 'measure' (time each
                      eligible algorithm on the live backend).
      cache_path      persistent v4 plan-cache JSON (None = no persistence).
      vmem_budget     VMEM bytes for block autotuning (None = chip default).
      fuse_epilogue   bias + activation fused into the kernels' output stage.
      winograd_fused  single-pass Winograd megakernel policy: None = auto
                      (tuner decides), True/False = forced.

    Execution:
      interpret       run Pallas kernels in interpret mode (None = auto:
                      interpret off-TPU).
      pretransform    apply the offline Winograd weight transform during
                      parameter preparation (paper §VII.A excludes it from
                      timing); the flag is carried explicitly — never
                      sniffed from weight shapes.
      batch           the batch size compiled eagerly by ``compile``.
      buckets         the serving bucket ladder (``CompiledModel.serve``).
      shard_batch     shard the batch over all visible devices when the
                      batch divides the device count (shard_map mesh).
      pipeline_stages layer-pipelined multi-chip execution: split the
                      network into this many contiguous stages (0 = off,
                      the default).  The partition is cost-balanced from
                      the planner's per-layer predicted seconds
                      (core/netplan.partition_network), cached in the v6
                      plan cache, and executed GPipe-style over a 1-D
                      'stage' device mesh — each stage's devices hold only
                      that stage's prepared params.  Needs at least
                      ``pipeline_stages`` visible devices at executor build
                      time.
      microbatch      microbatch count for the pipeline schedule: 'auto'
                      (default — the cost-model chooser minimizing modeled
                      latency = per-tick max-stage time summed over the
                      fill/steady/drain ticks plus per-tick overhead) or a
                      fixed positive count that must divide the batch.
                      Ignored while ``pipeline_stages`` is 0.
      dtype           execution dtype name ('float32', 'bfloat16', 'int8').
                      'int8' requests quantized inference: the planner
                      resolves it per layer (a layer where int8 does not
                      win stays fp32), weights are quantized offline with
                      per-output-channel scales, and inputs stay fp32
                      (see ``input_dtype``) — activations are quantized at
                      each int8 layer's entry.
      validate        compile-time plan verification (repro.analysis):
                      'off' (default), 'plan' (layout decisions + modeled
                      VMEM footprints under budget, no tracing), 'kernel'
                      (trace the forward and prove the kernel-interior
                      properties of every pallas_call — write-disjoint
                      output index maps, block windows inside operand
                      bounds, accumulator init/flush guards, int8 overflow
                      certification), or 'full' (everything: the plan
                      byte passes — structure / VMEM / traffic / elision /
                      dtype — plus the kernel-interior suite).  Any error
                      finding raises ``PlanVerificationError`` before the
                      executor can run.

    Serving resilience (serving/resilience.py; all inert at the defaults):
      max_queue       bounded admission: ``submit`` raises a typed
                      ``Backpressure`` once the queue holds this many
                      requests (None = unbounded, the pre-resilience
                      behavior).
      default_deadline_s
                      default per-request latency budget in seconds; an
                      expired request is evicted with a ``DeadlineExceeded``
                      result instead of being served stale.  Per-request
                      ``submit(deadline_s=...)`` overrides.  None = no
                      deadline.
      fallback        'ladder' routes executor failures down the degradation
                      ladder (pallas → pallas-interpret → pure-XLA fp32
                      reference; jit → eager decode for LMs) behind a
                      per-bucket circuit breaker; 'off' fails requests on
                      the first unrecovered fault instead of degrading.
      retries         transient-failure retries per ladder rung before
                      descending (>= 0).
    """

    impl: str = "jax"
    mode: str = "cost"
    interpret: Optional[bool] = None
    cache_path: Optional[str] = DEFAULT_CACHE_PATH
    vmem_budget: Optional[int] = None
    fuse_epilogue: bool = True
    winograd_fused: Optional[bool] = None
    pretransform: bool = True
    batch: int = 1
    buckets: Tuple[int, ...] = (1, 4, 8)
    shard_batch: bool = True
    pipeline_stages: int = 0
    microbatch: Any = "auto"            # 'auto' | positive int
    dtype: str = "float32"
    validate: str = "off"
    max_queue: Optional[int] = None
    default_deadline_s: Optional[float] = None
    fallback: str = "ladder"
    retries: int = 1

    def __post_init__(self) -> None:
        if self.validate not in _VALIDATE:
            raise ValueError(
                f"validate must be one of {_VALIDATE}, got {self.validate!r}"
            )
        if self.impl not in _IMPLS:
            raise ValueError(f"impl must be one of {_IMPLS}, got {self.impl!r}")
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {self.mode!r}")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if not self.buckets or any(int(b) <= 0 for b in self.buckets):
            raise ValueError(
                f"buckets must be a non-empty tuple of positive batch "
                f"sizes, got {self.buckets!r}"
            )
        # Normalize: buckets sorted+deduped, dtype to its canonical name —
        # options that mean the same thing compare (and hash) equal.
        object.__setattr__(
            self, "buckets", tuple(sorted({int(b) for b in self.buckets}))
        )
        object.__setattr__(self, "dtype", _dtype_name(self.dtype))
        if self.dtype not in _DTYPES:
            raise ValueError(
                f"dtype must be one of {_DTYPES}, got {self.dtype!r}"
            )
        if self.fallback not in _FALLBACK:
            raise ValueError(
                f"fallback must be one of {_FALLBACK}, got {self.fallback!r}"
            )
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue must be None or >= 1, got {self.max_queue}"
            )
        if self.pipeline_stages < 0 or self.pipeline_stages == 1:
            raise ValueError(
                f"pipeline_stages must be 0 (off) or >= 2, got "
                f"{self.pipeline_stages}"
            )
        if self.microbatch != "auto" and (
            not isinstance(self.microbatch, int) or self.microbatch < 1
        ):
            raise ValueError(
                f"microbatch must be 'auto' or a positive int, got "
                f"{self.microbatch!r}"
            )
        if self.default_deadline_s is not None and self.default_deadline_s <= 0:
            raise ValueError(
                f"default_deadline_s must be None or > 0, got "
                f"{self.default_deadline_s}"
            )

    @property
    def input_dtype(self) -> str:
        """The dtype ``run()``/serving cast incoming batches to.

        int8 is an *internal* execution precision: callers hand in fp32
        images and quantization happens per layer against calibrated
        scales, so the input-facing dtype stays float32.  Casting the
        input batch itself to int8 would destroy it.
        """
        return "float32" if self.dtype == "int8" else self.dtype

    def replace(self, **changes: Any) -> ExecutionOptions:
        return dataclasses.replace(self, **changes)

    # -- persistence (CompiledModel.save()/load() ride this) -----------------

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["buckets"] = list(self.buckets)
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> ExecutionOptions:
        known = {f.name for f in dataclasses.fields(cls)}
        kwargs = {k: v for k, v in d.items() if k in known}
        if "buckets" in kwargs:
            kwargs["buckets"] = tuple(kwargs["buckets"])
        return cls(**kwargs)

    # -- the planner this option set implies ----------------------------------

    def make_planner(self):
        """A Planner carrying exactly this option set's policy fields.

        ``autosave=False``: the facade persists once per planning burst
        (one merge+write for a whole network / bucket ladder), not once per
        layer miss.
        """
        from repro.core.planner import Planner

        return Planner(
            mode=self.mode,
            impl=self.impl,
            cache_path=self.cache_path,
            vmem_budget=self.vmem_budget,
            autosave=False,
            fuse_epilogue=self.fuse_epilogue,
            winograd_fused=self.winograd_fused,
        )
