"""``compile(model, params, options) -> CompiledModel`` — the facade core.

One call runs the whole co-design lifecycle the paper argues must be a
single decision: plan (per-layer ConvPlans + whole-network layout elision,
warm v4 cache) → prepare (bn fold, block padding, offline Winograd weight
pre-transform — all outside the jit) → jit (sharded ``run_network`` per
batch shape).  The result exposes the four verbs serving needs:

  .run(x)          jitted inference at x's batch size (compiled shapes are
                   cached per batch; ``options.batch`` is compiled eagerly)
  .serve(...)      a CNNServingEngine (bucket ladder) / ServingEngine
                   (continuous batching) built *from* this compilation —
                   no re-plumbing of planner, cache, buckets, or mesh
  .plan_report()   the resolved co-design decisions, machine-readable
  .save()/load()   persist the option surface + model identity; the plan
                   cache (v4) carries the tuning, so load() re-tunes nothing

LM configs (the transformer/recurrent zoo) compile through the same entry
point: ``run`` is the jitted full-sequence forward, ``serve`` the
continuous-batching engine's prefill/decode path.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Sequence, Tuple

from repro.api.model import CNNModel, as_model, is_lm_config
from repro.api.options import ExecutionOptions

SAVE_FORMAT = "repro.api/1"


def _jnp_dtype(name: str):
    import jax.numpy as jnp

    return jnp.dtype(name)


class CompiledModel:
    """Common surface of a compiled model; ``compile`` returns a subclass."""

    model: Any
    params: Any
    options: ExecutionOptions

    def run(self, x):
        raise NotImplementedError

    def __call__(self, x):
        return self.run(x)

    def serve(self, **kw):
        raise NotImplementedError

    def plan_report(self) -> Dict[str, Any]:
        raise NotImplementedError

    def verify_report(self, batch: Optional[int] = None,
                      level: Optional[str] = None):
        raise NotImplementedError(
            f"{type(self).__name__} does not support static plan verification"
        )

    def save(self, path: Optional[str] = None) -> str:
        raise NotImplementedError

    def _save_payload(self, kind: str, model_desc: Dict[str, Any],
                      path: Optional[str]) -> str:
        payload = {
            "format": SAVE_FORMAT,
            "kind": kind,
            "model": model_desc,
            "options": self.options.to_json(),
        }
        if path is None:
            base = os.path.dirname(self.options.cache_path or "") or "."
            path = os.path.join(
                base, f"{model_desc.get('name', 'model')}.compiled.json"
            )
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        return path


class CompiledCNN(CompiledModel):
    """A CNN compiled end-to-end: NetworkPlan + NetworkExecutor per batch.

    ``compile`` plans ``options.batch`` eagerly (the cold-start tunes land
    in the v4 cache immediately); other batch sizes — ``run`` on a new
    batch, ``serve``'s bucket ladder — plan and jit on first use and are
    cached, so the compiled-shape set stays bounded and explicit.
    """

    def __init__(
        self,
        model: CNNModel,
        params: Sequence[Dict],
        options: ExecutionOptions,
        planner=None,
        devices: Optional[Sequence[Any]] = None,
        calibration: Optional[Any] = None,
    ):
        self.model = model
        self.params = list(params)
        self.options = options
        # int8 activation-scale calibration batch (B, H, W, C) fp32; None
        # uses a deterministic synthetic batch (core/quant.py).  Unused —
        # and free — when no layer resolves to int8.
        self.calibration = calibration
        # Ownership decides persistence: a planner we created is ours to
        # save; a caller-supplied (possibly shared) planner keeps its own
        # persistence discipline — compiling must not rewrite its cache
        # file as a side effect.
        self._own_planner = planner is None
        self.planner = planner if planner is not None else options.make_planner()
        self._devices = devices
        self._netplans: Dict[int, Any] = {}
        self._executors: Dict[int, Any] = {}
        self._pipeplans: Dict[int, Any] = {}
        self._pipe_executors: Dict[int, Any] = {}
        # Eager by design: compile() means the default batch is planned and
        # its executor prepared (params folded/padded/pre-transformed) —
        # cold-start tunes land in the v4 cache now, not at first request.
        self._executor_for(options.batch)
        self.save_plans()

    # -- planning -------------------------------------------------------------

    def network_plan(self, batch: Optional[int] = None):
        """The (cached) whole-network plan for one batch size."""
        from repro.core.netplan import plan_network

        b = int(batch) if batch is not None else self.options.batch
        if b not in self._netplans:
            self._netplans[b] = plan_network(
                self.model.layers, *self.model.input_hw, self.planner,
                in_channels=self.model.in_channels, batch=b,
                dtype=self.options.dtype,
            )
        return self._netplans[b]

    def executor(self, batch: Optional[int] = None):
        """The (cached) jitted NetworkExecutor for one batch size."""
        from repro.core.netplan import NetworkExecutor

        b = int(batch) if batch is not None else self.options.batch
        if b not in self._executors:
            netplan = self.network_plan(b)
            devices = self._devices
            if devices is None and not self.options.shard_batch:
                import jax

                devices = jax.devices()[:1]
            self._executors[b] = NetworkExecutor(
                netplan, self.params, interpret=self.options.interpret,
                devices=devices, pretransform=self.options.pretransform,
                calibration=self.calibration,
            )
            # Persistence stays with the *burst*, not the bucket: __init__,
            # run(), and the serving engine call save_plans() once after
            # their planning is done — a cold bucket ladder costs one cache
            # merge+write, not one per executor.
            if self.options.validate != "off":
                from repro.analysis import PlanVerificationError

                report = self.verify_report(
                    batch=b, level=self.options.validate
                )
                if not report.ok:
                    del self._executors[b]
                    raise PlanVerificationError(report)
        return self._executors[b]

    def pipeline_plan(self, batch: Optional[int] = None):
        """The (cached) cost-balanced stage partition for one batch size.

        Requires ``options.pipeline_stages >= 2``.  Warm-cached in the v6
        plan cache keyed by (network digest, n_stages, chip, dtype) —
        ``planner.pipeline_hits`` counts reconstructions that re-partitioned
        nothing.
        """
        from repro.core.netplan import plan_pipeline

        if self.options.pipeline_stages < 2:
            raise ValueError(
                "pipeline_plan() requires ExecutionOptions("
                f"pipeline_stages=...) >= 2, got "
                f"{self.options.pipeline_stages}"
            )
        b = int(batch) if batch is not None else self.options.batch
        if b not in self._pipeplans:
            self._pipeplans[b] = plan_pipeline(
                self.model.layers, *self.model.input_hw, self.planner,
                self.options.pipeline_stages,
                in_channels=self.model.in_channels, batch=b,
                dtype=self.options.dtype, netplan=self.network_plan(b),
            )
        return self._pipeplans[b]

    def pipeline_executor(self, batch: Optional[int] = None):
        """The (cached) jitted PipelineExecutor for one batch size."""
        from repro.distributed.pipeline import PipelineExecutor

        b = int(batch) if batch is not None else self.options.batch
        if b not in self._pipe_executors:
            pipeplan = self.pipeline_plan(b)
            if self.options.validate != "off":
                # The partition has its own static legality contract
                # (verify_pipeline).  At validate='kernel'/'full' the
                # per-stage forwards are also traced at microbatch size and
                # the kernel-interior passes run over every stage's
                # pallas_calls — the prepared params come from an interpret
                # NetworkExecutor, the same subject verify_report() uses.
                from repro.analysis import (
                    PlanVerificationError,
                    verify_pipeline,
                )

                lvl = (
                    "kernel" if self.options.validate in ("kernel", "full")
                    else "plan"
                )
                kw = {}
                if lvl == "kernel":
                    from repro.core.netplan import NetworkExecutor

                    ex = self._executors.get(b) or NetworkExecutor(
                        self.network_plan(b), self.params, interpret=True,
                        devices=self._devices,
                        pretransform=self.options.pretransform,
                        calibration=self.calibration,
                    )
                    kw = dict(
                        params=ex.params, pretransformed=ex.pretransformed
                    )
                report = verify_pipeline(
                    self.network_plan(b), pipeplan, name=self.model.name,
                    level=lvl, **kw,
                )
                if not report.ok:
                    raise PlanVerificationError(report)
            n_micro = (
                None if self.options.microbatch == "auto"
                else int(self.options.microbatch)
            )
            self._pipe_executors[b] = PipelineExecutor(
                self.network_plan(b), pipeplan, self.params,
                interpret=self.options.interpret, devices=self._devices,
                pretransform=self.options.pretransform,
                calibration=self.calibration, n_micro=n_micro,
            )
        return self._pipe_executors[b]

    def _executor_for(self, batch: Optional[int] = None):
        """The executor ``run()``/serving dispatch to: the pipeline one when
        ``pipeline_stages`` is set, the data-parallel one otherwise."""
        if self.options.pipeline_stages >= 2:
            return self.pipeline_executor(batch)
        return self.executor(batch)

    def verify_report(self, batch: Optional[int] = None,
                      level: Optional[str] = None):
        """Statically verify this compilation (repro.analysis).

        Runs the plan verifier over the executor's *prepared* state — the
        exact params and pretransform flags the jitted forward consumes —
        and returns the structured ``VerifyReport`` (findings + per-kernel
        footprint/traffic metrics).  ``level`` defaults to 'full' (trace
        the forward and run every pass); pass 'plan' for the trace-free
        subset or 'kernel' for the kernel-interior proofs only (race /
        bounds / accum / int8 overflow).  Independent of
        ``options.validate``: that option makes compilation *gate* on
        this report, this method just produces it.
        """
        from repro.analysis import verify_network

        lvl = level if level not in (None, "off") else "full"
        b = int(batch) if batch is not None else self.options.batch
        netplan = self.network_plan(b)
        if lvl == "plan":
            return verify_network(
                netplan, level="plan",
                vmem_budget=self.options.vmem_budget,
                name=self.model.name,
            )
        # Build (or reuse) the executor outside the validate gate: its
        # prepared params are the verification subject.
        if b in self._executors:
            ex = self._executors[b]
        else:
            from repro.core.netplan import NetworkExecutor

            ex = NetworkExecutor(
                netplan, self.params, interpret=True,
                devices=self._devices,
                pretransform=self.options.pretransform,
                calibration=self.calibration,
            )
        return verify_network(
            netplan, ex.params, pretransformed=ex.pretransformed,
            level=lvl, vmem_budget=self.options.vmem_budget,
            name=self.model.name,
        )

    def save_plans(self, force: bool = False) -> None:
        """Persist the planner's v4 cache when there is something to write.

        No-op unless this compilation owns the planner (caller-supplied
        planners manage their own persistence) and new tunes/network
        entries landed since the last save — so planning bursts cost one
        merge+write, not one per bucket.
        """
        if not self._own_planner or not self.planner.cache_path:
            return
        if force or getattr(self.planner, "_dirty", True):
            self.planner.save()

    # -- the four verbs -------------------------------------------------------

    def run(self, x):
        """Jitted whole-network inference on an (B, H, W, C) batch."""
        import jax.numpy as jnp

        # input_dtype, not dtype: under int8 the batch stays fp32 and is
        # quantized per layer inside the executor.
        x = jnp.asarray(x, _jnp_dtype(self.options.input_dtype))
        if x.ndim != 4:
            raise ValueError(
                f"run() expects (B, H, W, C), got shape {tuple(x.shape)}"
            )
        executor = self._executor_for(int(x.shape[0]))
        self.save_plans()       # no-op unless this batch tuned new plans
        return executor(x)

    def serve(self, buckets: Optional[Tuple[int, ...]] = None, **kw):
        """A CNNServingEngine over this compilation's bucket ladder.

        Everything else the engine needs (impl, interpret, dtype, mesh,
        planner, cache, resilience policy — ``max_queue``,
        ``default_deadline_s``, ``fallback``, ``retries``) comes from this
        compilation — that is the point.  ``engine.health()`` reports the
        resilience state; ``kw`` passes test hooks (``clock=``, ``faults=``,
        ``probe_after=``) through to the engine.
        """
        from repro.serving.cnn_engine import CNNServingEngine

        return CNNServingEngine.from_compiled(self, buckets=buckets, **kw)

    def plan_report(self, batch: Optional[int] = None) -> Dict[str, Any]:
        """The resolved co-design decisions, machine-readable.

        One row per conv layer: algorithm, impl, kernel blocks, predicted
        (or measured) seconds, plan provenance, and whether the layer's
        output boundary was elided (padded channels flow to the next
        pallas_call).  Plus planner/network cache counters — a warm process
        reports ``tunes == 0``.

        With ``pipeline_stages`` set, every layer row gains a ``stage``
        column and the report a ``pipeline`` block: stage bounds,
        per-stage predicted seconds, the resolved microbatch count, the
        modeled bubble fraction and end-to-end latency.
        """
        netplan = self.network_plan(batch)
        pipeplan = (
            self.pipeline_plan(batch)
            if self.options.pipeline_stages >= 2 else None
        )

        def stage_of(index: int):
            if pipeplan is None:
                return None
            for si, (a, z) in enumerate(pipeplan.stage_bounds):
                if a <= index < z:
                    return si
            return None

        rows = []
        for s in netplan.steps:
            if s.plan is None:
                continue
            row = {
                "index": s.index,
                "algorithm": s.plan.algorithm.value,
                "impl": s.plan.impl,
                "dtype": s.plan.dtype,
                "kernel": getattr(s.layer, "kernel", None),
                "stride": getattr(s.layer, "stride", None),
                "in_hw": list(s.in_hw),
                "kernel_blocks": list(s.plan.kernel_blocks),
                "predicted_s": s.plan.predicted_s,
                "source": s.plan.source,
                "winograd_fused": s.plan.winograd_fused,
                "elided": not s.out_layout.trivial,
            }
            if pipeplan is not None:
                row["stage"] = stage_of(s.index)
            rows.append(row)
        report = {
            "model": self.model.name,
            "kind": "cnn",
            "batch": netplan.batch,
            "impl": netplan.impl,
            "dtype": netplan.dtype_name,
            "elided_boundaries": netplan.elided_boundaries,
            "predicted_total_s": sum(r["predicted_s"] for r in rows),
            "layers": rows,
            "tunes": self.planner.stats["tunes"],
            "hits": self.planner.stats["hits"],
            "network_hits": self.planner.network_hits,
            "pipeline_hits": self.planner.pipeline_hits,
        }
        if pipeplan is not None:
            report["pipeline"] = {
                "n_stages": pipeplan.n_stages,
                "stage_bounds": [list(b) for b in pipeplan.stage_bounds],
                "stage_seconds": list(pipeplan.stage_seconds),
                "n_micro": pipeplan.n_micro,
                "bubble_fraction": pipeplan.bubble_fraction(),
                "modeled_latency_s": pipeplan.modeled_latency_s(),
            }
        return report

    def save(self, path: Optional[str] = None) -> str:
        """Persist this compilation: plan cache (the tuning) + a small JSON
        artifact (model identity + the full option surface).  ``load``
        reconstructs with zero re-tunes."""
        self.save_plans()
        return self._save_payload(
            "cnn",
            {
                "name": self.model.name,
                "digest": self.model.digest,
                "input_hw": list(self.model.input_hw),
                "in_channels": self.model.in_channels,
            },
            path,
        )


class CompiledLM(CompiledModel):
    """An LM config compiled through the same facade: jitted full-sequence
    forward for ``run``, the continuous-batching engine for ``serve``."""

    def __init__(self, cfg, params, options: ExecutionOptions):
        import jax

        from repro.models import transformer as tf

        self.model = cfg
        self.params = params
        self.options = options
        self._tf = tf
        self._fwd = jax.jit(lambda p, batch: tf.forward(cfg, p, batch)[0])

    def run(self, tokens):
        """Full-sequence logits.  ``tokens``: (B, S) int32, or a model-input
        dict for frontend architectures (audio frames, vision patches)."""
        import jax.numpy as jnp

        batch = tokens if isinstance(tokens, dict) else {
            "tokens": jnp.asarray(tokens, jnp.int32)
        }
        return self._fwd(self.params, batch)

    def serve(self, batch_size: Optional[int] = None, capacity: int = 256,
              **engine_opts):
        """A continuous-batching ServingEngine (prefill/decode) for this
        model.  ``batch_size`` defaults to the largest option bucket."""
        from repro.serving.engine import ServingEngine

        return ServingEngine.from_compiled(
            self, batch_size=batch_size, capacity=capacity, **engine_opts,
        )

    def plan_report(self) -> Dict[str, Any]:
        return {
            "model": self.model.name,
            "kind": "lm",
            "num_layers": self.model.num_layers,
            "layer_pattern": list(self.model.pattern_layers),
            "supports_decode": self.model.supports_decode,
            "dtype": self.options.dtype,
        }

    def save(self, path: Optional[str] = None) -> str:
        return self._save_payload("lm", {"name": self.model.name}, path)


def compile(  # noqa: A001 - deliberate: repro.compile is the public verb
    model: Any,
    params: Any,
    options: Optional[ExecutionOptions] = None,
    *,
    input_hw: Optional[Tuple[int, int]] = None,
    in_channels: int = 3,
    name: Optional[str] = None,
    planner=None,
    devices: Optional[Sequence[Any]] = None,
    calibration: Optional[Any] = None,
) -> CompiledModel:
    """The single public entry point: plan → prepare → jit, once.

    ``model``: a ``CNNModel`` (configs export them: ``vgg16.MODEL``,
    ``yolov3.TINY_MODEL``), an LM ``ModelConfig``, or a bare CNN layer
    table plus ``input_hw``.  ``options`` defaults to ``ExecutionOptions()``
    (pure-JAX impl, cost-model planning, persistent cache).  ``planner``
    and ``devices`` are runtime resources (not serialized): pass a shared
    Planner to pool caches across compilations, or an explicit device list
    to pin the batch mesh.  ``calibration`` is an optional fp32 batch used
    to calibrate int8 activation scales when ``options.dtype == 'int8'``
    (None = deterministic synthetic batch); ignored otherwise.
    """
    m = as_model(model, input_hw=input_hw, in_channels=in_channels, name=name)
    opts = options if options is not None else ExecutionOptions()
    if is_lm_config(m):
        return CompiledLM(m, params, opts)
    return CompiledCNN(
        m, params, opts, planner=planner, devices=devices,
        calibration=calibration,
    )


def load(
    path: str,
    model: Any,
    params: Any,
    *,
    input_hw: Optional[Tuple[int, int]] = None,
    in_channels: int = 3,
    planner=None,
    devices: Optional[Sequence[Any]] = None,
) -> CompiledModel:
    """Rebuild a CompiledModel from a ``save()`` artifact.

    The artifact stores the option surface and the model identity; the v4
    plan cache (``options.cache_path``) holds the tuning, so a warm load
    re-tunes nothing.  Raises ``ValueError`` when ``model`` does not match
    the saved identity (layer-table digest for CNNs, config name for LMs).
    """
    with open(path) as f:
        data = json.load(f)
    if data.get("format") != SAVE_FORMAT:
        raise ValueError(
            f"{path}: not a {SAVE_FORMAT} artifact "
            f"(format={data.get('format')!r})"
        )
    opts = ExecutionOptions.from_json(data.get("options", {}))
    saved = data.get("model", {})
    if data.get("kind") == "cnn" and input_hw is None and saved.get(
        "input_hw"
    ):
        # The artifact records the geometry; a bare layer table inherits it
        # rather than demanding it twice.  (A CNNModel descriptor keeps its
        # own — mismatches are rejected below, with guidance.)
        input_hw = tuple(saved["input_hw"])
        in_channels = int(saved.get("in_channels", in_channels))
    m = as_model(model, input_hw=input_hw, in_channels=in_channels)
    if data.get("kind") == "cnn":
        if not isinstance(m, CNNModel):
            raise ValueError(f"{path} was saved from a CNN; got {type(m)}")
        if saved.get("digest") and saved["digest"] != m.digest:
            raise ValueError(
                f"{path}: saved layer-table digest {saved['digest']} does "
                f"not match the provided model ({m.digest}) — same artifact, "
                f"different network"
            )
        # Geometry is identity too: plans are (H, W, C)-keyed, so a silent
        # mismatch would cold-retune everything instead of loading warm.
        if saved.get("input_hw") and tuple(saved["input_hw"]) != tuple(
            m.input_hw
        ):
            raise ValueError(
                f"{path}: saved at input_hw {tuple(saved['input_hw'])} but "
                f"the provided model targets {tuple(m.input_hw)} — pass "
                f"model.with_input_hw({tuple(saved['input_hw'])}) (or omit "
                f"input_hw to inherit the artifact's)"
            )
        if saved.get("in_channels") and int(saved["in_channels"]) != int(
            m.in_channels
        ):
            raise ValueError(
                f"{path}: saved with in_channels={saved['in_channels']}, "
                f"provided model has {m.in_channels}"
            )
    elif data.get("kind") == "lm" and getattr(m, "name", None) != saved.get("name"):
        raise ValueError(
            f"{path}: saved LM config {saved.get('name')!r} does not "
            f"match the provided {getattr(m, 'name', None)!r}"
        )
    return compile(m, params, opts, planner=planner, devices=devices)
