"""repro.api — the unified compile-and-run facade.

    import repro

    compiled = repro.compile(model, params, repro.ExecutionOptions(...))
    y = compiled.run(x)                  # jitted, planned, sharded
    engine = compiled.serve()            # bucket-ladder serving
    report = compiled.plan_report()      # the resolved co-design decisions
    artifact = compiled.save()           # options + identity; cache v4 holds
    repro.load(artifact, model, params)  # ... the tuning: zero re-tunes

See docs/api.md for the lifecycle and the migration table from the legacy
entry points (``cnn_infer`` / ``plan_layers`` / the configs' plan helpers /
direct ``CNNServingEngine`` construction — all removed after their
one-release deprecation window; the facade is the only entry point).
"""
from repro.api.compiled import (
    SAVE_FORMAT,
    CompiledCNN,
    CompiledLM,
    CompiledModel,
    compile,
    load,
)
from repro.api.model import CNNModel, Model, as_model
from repro.api.options import ExecutionOptions

__all__ = [
    "SAVE_FORMAT",
    "CNNModel",
    "CompiledCNN",
    "CompiledLM",
    "CompiledModel",
    "ExecutionOptions",
    "Model",
    "as_model",
    "compile",
    "load",
]
