"""The facade's model descriptors.

``compile`` accepts anything satisfying the :class:`Model` protocol — in
practice one of the two families this repo grows:

  CNNModel      a Darknet-style layer table (configs/vgg16.py,
                configs/yolov3.py) plus its input geometry.  The configs
                export ready-made instances (``vgg16.MODEL``,
                ``yolov3.TINY_MODEL``, ``yolov3.MODEL_20``).
  ModelConfig   the transformer/recurrent zoo (configs/base.py) — every
                LM/audio/VLM architecture already satisfies the protocol
                as-is; no wrapper needed.

``as_model`` is the coercion used by ``compile``: it also accepts a bare
layer-table sequence (with an explicit ``input_hw``) so quick experiments
don't need to build a descriptor first.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Optional, Protocol, Sequence, Tuple, runtime_checkable


@runtime_checkable
class Model(Protocol):
    """What ``compile`` requires of a model descriptor: a stable ``name``.

    The two concrete families add their own compile-relevant fields —
    ``CNNModel`` carries (layers, input_hw, in_channels); LM configs are
    ``repro.configs.base.ModelConfig`` (recognized by ``supports_decode``).
    """

    name: str


@dataclasses.dataclass(frozen=True)
class CNNModel:
    """A CNN as the facade sees it: layer table + input geometry."""

    layers: Tuple[Any, ...]
    input_hw: Tuple[int, int]
    in_channels: int = 3
    name: str = "cnn"

    def __post_init__(self) -> None:
        object.__setattr__(self, "layers", tuple(self.layers))
        object.__setattr__(self, "input_hw", tuple(self.input_hw))
        if len(self.input_hw) != 2:
            raise ValueError(f"input_hw must be (H, W), got {self.input_hw!r}")

    def with_input_hw(self, hw: Tuple[int, int]) -> CNNModel:
        return dataclasses.replace(self, input_hw=tuple(hw))

    def init_params(self, rng, dtype: Any = None):
        """Random params for this layer table (thin init_cnn veneer)."""
        import jax.numpy as jnp

        from repro.models.cnn import init_cnn

        return init_cnn(
            rng, self.layers, in_channels=self.in_channels,
            dtype=dtype if dtype is not None else jnp.float32,
        )

    @property
    def digest(self) -> str:
        """Layer-table digest — the same identity the v4 network cache keys
        on; ``save()``/``load()`` use it to refuse a mismatched model."""
        return hashlib.sha1(repr(tuple(self.layers)).encode()).hexdigest()[:16]


def is_lm_config(model: Any) -> bool:
    """True for the transformer/recurrent zoo's ModelConfig (duck-typed so
    the facade never imports the LM stack for CNN work)."""
    return hasattr(model, "supports_decode") and hasattr(model, "layer_pattern")


def as_model(
    model: Any,
    input_hw: Optional[Tuple[int, int]] = None,
    in_channels: int = 3,
    name: Optional[str] = None,
) -> Any:
    """Coerce ``compile``'s ``model`` argument to a descriptor.

    Accepts a CNNModel / ModelConfig as-is, or a bare CNN layer-table
    sequence together with ``input_hw``.
    """
    if isinstance(model, CNNModel):
        return model
    if is_lm_config(model):
        return model
    if isinstance(model, Sequence) and model and all(
        hasattr(l, "kind") for l in model
    ):
        if input_hw is None:
            raise ValueError(
                "a bare CNN layer table needs input_hw=(H, W); or pass a "
                "CNNModel (e.g. configs.vgg16.MODEL)"
            )
        return CNNModel(
            layers=tuple(model), input_hw=tuple(input_hw),
            in_channels=in_channels, name=name or "cnn",
        )
    raise TypeError(
        f"compile() expects a CNNModel, an LM ModelConfig, or a CNN layer "
        f"table; got {type(model).__name__}"
    )
