"""internvl2-2b [vlm]: 24L d=2048 16H (GQA kv=8) ff=8192 vocab=92553.

InternViT-300M frontend is a STUB per assignment: input_specs provide 256
precomputed patch embeddings (dim 1024) per image, projected and prepended
to the token sequence; the InternLM2 backbone is implemented fully.
[arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision_patches",
    frontend_dim=1024,
    num_patches=256,
    mlp_type="swiglu",
)
