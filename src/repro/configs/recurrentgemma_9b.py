"""recurrentgemma-9b [hybrid]: 38L d=4096 16H (MQA kv=1) ff=12288
vocab=256000.  Griffin pattern: (RG-LRU, RG-LRU, local-attention) repeated,
window 2048, head_dim 256, sqrt(d)-scaled embeddings.  Sub-quadratic ->
runs long_500k.  [arXiv:2402.19427; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    d_rnn=4096,
    conv_width=4,
    embed_scale=True,
    mlp_type="geglu",
)
