"""VGG16 (paper's image-classification network): 13 conv (all 3x3 stride-1,
all Winograd-eligible) + 3 FC layers."""
from repro.models.cnn import CNNLayer

C = CNNLayer


def _conv(ch):
    return C("conv", out_channels=ch, kernel=3, stride=1, batch_norm=True,
             activation="relu")


LAYERS = (
    _conv(64), _conv(64), C("maxpool", size=2, stride=2),
    _conv(128), _conv(128), C("maxpool", size=2, stride=2),
    _conv(256), _conv(256), _conv(256), C("maxpool", size=2, stride=2),
    _conv(512), _conv(512), _conv(512), C("maxpool", size=2, stride=2),
    _conv(512), _conv(512), _conv(512), C("maxpool", size=2, stride=2),
    C("fc", out_channels=4096, activation="relu", batch_norm=False),
    C("fc", out_channels=4096, activation="relu", batch_norm=False),
    C("fc", out_channels=1000, activation="linear", batch_norm=False),
)

INPUT_HW = (224, 224)
NAME = "vgg16"

# The facade descriptor: ``repro.compile(vgg16.MODEL, params, options)``.
from repro.api.model import CNNModel as _CNNModel  # noqa: E402

MODEL = _CNNModel(LAYERS, INPUT_HW, in_channels=3, name=NAME)
