"""VGG16 (paper's image-classification network): 13 conv (all 3x3 stride-1,
all Winograd-eligible) + 3 FC layers."""
from repro.models.cnn import CNNLayer

C = CNNLayer


def _conv(ch):
    return C("conv", out_channels=ch, kernel=3, stride=1, batch_norm=True,
             activation="relu")


LAYERS = (
    _conv(64), _conv(64), C("maxpool", size=2, stride=2),
    _conv(128), _conv(128), C("maxpool", size=2, stride=2),
    _conv(256), _conv(256), _conv(256), C("maxpool", size=2, stride=2),
    _conv(512), _conv(512), _conv(512), C("maxpool", size=2, stride=2),
    _conv(512), _conv(512), _conv(512), C("maxpool", size=2, stride=2),
    C("fc", out_channels=4096, activation="relu", batch_norm=False),
    C("fc", out_channels=4096, activation="relu", batch_norm=False),
    C("fc", out_channels=1000, activation="linear", batch_norm=False),
)

INPUT_HW = (224, 224)
NAME = "vgg16"


def plan_network(planner, input_hw=INPUT_HW, batch=1, in_channels=3,
                 dtype="float32"):
    """Per-layer ConvPlans for VGG16 at ``input_hw`` (see core/planner.py).

    Returns a plans list aligned with LAYERS, ready for
    ``cnn_forward(plans=...)`` — the whole network runs fully planned.
    """
    from repro.models.cnn import plan_layers

    return plan_layers(LAYERS, *input_hw, planner, in_channels=in_channels,
                       batch=batch, dtype=dtype)


def network_plan(planner, input_hw=INPUT_HW, batch=1, in_channels=3,
                 dtype="float32"):
    """Whole-network NetworkPlan for VGG16 (see core/netplan.py): per-layer
    ConvPlans plus the inter-layer layout-persistence decisions, warm-cached
    as a v4 network entry.  Feed to ``NetworkExecutor`` for the planned
    end-to-end inference path."""
    from repro.core.netplan import plan_network

    return plan_network(LAYERS, *input_hw, planner, in_channels=in_channels,
                        batch=batch, dtype=dtype)
