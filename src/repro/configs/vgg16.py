"""VGG16 (paper's image-classification network): 13 conv (all 3x3 stride-1,
all Winograd-eligible) + 3 FC layers."""
from repro.models.cnn import CNNLayer

C = CNNLayer


def _conv(ch):
    return C("conv", out_channels=ch, kernel=3, stride=1, batch_norm=True,
             activation="relu")


LAYERS = (
    _conv(64), _conv(64), C("maxpool", size=2, stride=2),
    _conv(128), _conv(128), C("maxpool", size=2, stride=2),
    _conv(256), _conv(256), _conv(256), C("maxpool", size=2, stride=2),
    _conv(512), _conv(512), _conv(512), C("maxpool", size=2, stride=2),
    _conv(512), _conv(512), _conv(512), C("maxpool", size=2, stride=2),
    C("fc", out_channels=4096, activation="relu", batch_norm=False),
    C("fc", out_channels=4096, activation="relu", batch_norm=False),
    C("fc", out_channels=1000, activation="linear", batch_norm=False),
)

INPUT_HW = (224, 224)
NAME = "vgg16"

# The facade descriptor: ``repro.compile(vgg16.MODEL, params, options)``.
from repro.api.model import CNNModel as _CNNModel  # noqa: E402

MODEL = _CNNModel(LAYERS, INPUT_HW, in_channels=3, name=NAME)


def plan_network(planner, input_hw=INPUT_HW, batch=1, in_channels=3,
                 dtype="float32"):
    """Deprecated shim: compile the network through the facade instead
    (``repro.compile(vgg16.MODEL, params, options)``); per-layer plans are
    in ``.network_plan().steps``.  Delegates unchanged for one release."""
    from repro._deprecation import warn_once
    from repro.models.cnn import _plan_layers

    warn_once("configs.vgg16.plan_network",
              "repro.compile(vgg16.MODEL, params, options)")
    return _plan_layers(LAYERS, *input_hw, planner, in_channels=in_channels,
                        batch=batch, dtype=dtype)


def network_plan(planner, input_hw=INPUT_HW, batch=1, in_channels=3,
                 dtype="float32"):
    """Deprecated shim: ``repro.compile(vgg16.MODEL, params, options)``
    resolves the same NetworkPlan (``.network_plan()``).  Delegates
    unchanged for one release."""
    from repro._deprecation import warn_once
    from repro.core.netplan import plan_network as _plan_network

    warn_once("configs.vgg16.network_plan",
              "repro.compile(vgg16.MODEL, params, options).network_plan()")
    return _plan_network(LAYERS, *input_hw, planner, in_channels=in_channels,
                         batch=batch, dtype=dtype)
