"""Architecture registry, reduced smoke variants, and input_specs.

``get_config(name)`` returns the exact assigned full-size config;
``smoke_config(name)`` a structurally-identical reduced variant for CPU
tests; ``input_specs(cfg, shape)`` the ShapeDtypeStruct stand-ins for every
model input of a (arch x shape) cell — weak-type-correct, shardable, no
device allocation (the dry-run lowers against these).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, cell_is_runnable

ARCHS = (
    "hubert-xlarge",
    "granite-34b",
    "qwen1.5-0.5b",
    "llama3.2-1b",
    "gemma2-27b",
    "arctic-480b",
    "granite-moe-1b-a400m",
    "internvl2-2b",
    "recurrentgemma-9b",
    "xlstm-125m",
)

_MODULES = {
    "hubert-xlarge": "hubert_xlarge",
    "granite-34b": "granite_34b",
    "qwen1.5-0.5b": "qwen15_05b",
    "llama3.2-1b": "llama32_1b",
    "gemma2-27b": "gemma2_27b",
    "arctic-480b": "arctic_480b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "internvl2-2b": "internvl2_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "xlstm-125m": "xlstm_125m",
}


def get_config(name: str) -> ModelConfig:
    import importlib

    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def smoke_config(name: str, seq_len: int = 32) -> ModelConfig:
    """Reduced config of the same family: small width/layers/experts/vocab,
    same block pattern and feature flags."""
    cfg = get_config(name)
    pat = cfg.layer_pattern
    num_layers = min(cfg.num_layers, 2 * len(pat) + 1)
    heads = 4
    kv = max(1, round(heads * cfg.num_kv_heads / cfg.num_heads))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=min(cfg.d_ff, 128),
        vocab_size=128,
        num_experts=min(cfg.num_experts, 8),
        top_k=min(cfg.top_k, 4) if cfg.top_k else 0,
        moe_dense_ff=min(cfg.moe_dense_ff, 64),
        d_rnn=64 if cfg.d_rnn else 0,
        frontend_dim=16 if cfg.frontend_dim else 0,
        num_patches=4 if cfg.num_patches else 0,
        local_window=min(cfg.local_window, seq_len // 2),
        attn_chunked_threshold=cfg.attn_chunked_threshold,
        dtype="float32",
    )


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    train/prefill: full-sequence inputs.  decode: one new token; the KV/state
    cache specs are derived separately via ``jax.eval_shape`` on init_cache
    (see launch/dryrun.py) so no memory is allocated.
    """
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}

    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "audio_frames":
        specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), f32)
        if shape.kind == "train":
            specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
            specs["mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
        return specs

    s_text = s - cfg.num_patches if cfg.frontend == "vision_patches" else s
    specs["tokens"] = jax.ShapeDtypeStruct((b, s_text), i32)
    if cfg.frontend == "vision_patches":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.frontend_dim), f32
        )
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s_text), i32)
    return specs


def all_cells():
    """Every (arch, shape) pair with its runnability verdict."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = cell_is_runnable(cfg, shape)
            yield arch, shape.name, ok, reason


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "get_config",
    "smoke_config",
    "input_specs",
    "all_cells",
    "cell_is_runnable",
]
