"""YOLOv3 layer tables (the paper's object-detection network).

- LAYERS_20: the first 20 Darknet-53 layers (15 conv + shortcuts), the
  exact slice the paper uses for its gem5 hardware sweeps (§VI.B).
- TINY_LAYERS: full YOLOv3-tiny (13 conv), used for the 14x-speedup
  reproduction (§VI.A) and the quickstart example.
- TABLE_IV: the paper's published per-layer GEMM dims (M, N, K, AI, %peak)
  for YOLOv3 at 608x608 — the oracle for benchmarks/table4_ai.py.
"""
from repro.models.cnn import CNNLayer

C = CNNLayer


def _c(ch, k=3, s=1):
    return C("conv", out_channels=ch, kernel=k, stride=s, batch_norm=True,
             activation="leaky")


# First 20 layers of Darknet-53 (conv + residual shortcuts).
LAYERS_20 = (
    _c(32, 3, 1),            # 0
    _c(64, 3, 2),            # 1
    _c(32, 1, 1),            # 2
    _c(64, 3, 1),            # 3
    C("shortcut", from_layers=(1,)),   # 4
    _c(128, 3, 2),           # 5
    _c(64, 1, 1),            # 6
    _c(128, 3, 1),           # 7
    C("shortcut", from_layers=(5,)),   # 8
    _c(64, 1, 1),            # 9
    _c(128, 3, 1),           # 10
    C("shortcut", from_layers=(8,)),   # 11
    _c(256, 3, 2),           # 12
    _c(128, 1, 1),           # 13
    _c(256, 3, 1),           # 14
    C("shortcut", from_layers=(12,)),  # 15
    _c(128, 1, 1),           # 16
    _c(256, 3, 1),           # 17
    C("shortcut", from_layers=(15,)),  # 18
    _c(128, 1, 1),           # 19
)

# Full YOLOv3-tiny.
TINY_LAYERS = (
    _c(16), C("maxpool", size=2, stride=2),
    _c(32), C("maxpool", size=2, stride=2),
    _c(64), C("maxpool", size=2, stride=2),
    _c(128), C("maxpool", size=2, stride=2),
    _c(256), C("maxpool", size=2, stride=2),          # idx 8 = route source
    _c(512), C("maxpool", size=2, stride=1),
    _c(1024),                                          # 12
    _c(256, 1, 1),                                     # 13 = route source
    _c(512),                                           # 14
    C("conv", out_channels=255, kernel=1, batch_norm=False,
      activation="linear"),                            # 15 detection head 1
    C("route", from_layers=(13,)),                     # 16
    _c(128, 1, 1),                                     # 17
    C("upsample", size=2),                             # 18
    C("route", from_layers=(18, 8)),                   # 19
    _c(256),                                           # 20
    C("conv", out_channels=255, kernel=1, batch_norm=False,
      activation="linear"),                            # 21 detection head 2
)

INPUT_HW = (608, 608)
TINY_INPUT_HW = (416, 416)
NAME = "yolov3"

# Facade descriptors: ``repro.compile(yolov3.TINY_MODEL, params, options)``.
from repro.api.model import CNNModel as _CNNModel  # noqa: E402

MODEL_20 = _CNNModel(LAYERS_20, INPUT_HW, in_channels=3, name="yolov3-20")
TINY_MODEL = _CNNModel(TINY_LAYERS, TINY_INPUT_HW, in_channels=3,
                       name="yolov3-tiny")


# Paper Table IV: the 14 discrete YOLOv3 conv-layer GEMMs (M, N, K) with the
# paper's measured AI and % of A64FX single-core peak.
TABLE_IV = (
    ("L1", 32, 369664, 27, 7.32, 46),
    ("L2", 64, 92416, 288, 26, 72),
    ("L3", 32, 92416, 64, 11, 50),
    ("L5", 128, 23104, 576, 52, 77),
    ("L6", 64, 23104, 128, 21, 70),
    ("L10", 256, 5776, 1152, 101, 81),
    ("L11", 128, 5776, 256, 42, 75),
    ("L38", 256, 1444, 512, 76, 82),
    ("L44", 1024, 361, 4608, 126, 83),
    ("L45", 512, 361, 1024, 88, 78),
    ("L59", 255, 361, 1024, 65, 75),
    ("L61", 256, 1444, 768, 85, 91),
    ("L62", 512, 1444, 2304, 162, 83),
    ("L75", 255, 5776, 256, 63, 75),
)
