"""granite-34b [dense]: 88L d=6144 48H (GQA kv=1, i.e. MQA) ff=24576
vocab=49152.  Llama-architecture code model.  [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="swiglu",
)
