"""xlstm-125m [ssm]: 12L d=768 4H ff=0 (blocks carry their own projections)
vocab=50304.  mLSTM:sLSTM = 7:1 pattern.  Sub-quadratic -> runs long_500k.
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=("mlstm",) * 7 + ("slstm",),
    mlp_type="none",
)
