"""Model / run configuration schema shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # Block pattern, cycled over layers.  Entries: 'attn' (global), 'local'
    # (sliding window), 'rglru', 'mlstm', 'slstm'.
    layer_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 4096

    # Attention options
    qkv_bias: bool = False           # qwen1.5
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0  # gemma2: 50.0
    final_logit_softcap: float = 0.0 # gemma2: 30.0
    use_post_norm: bool = False      # gemma2 sandwich norms
    embed_scale: bool = False        # gemma families scale embeds by sqrt(d)

    # MLP
    mlp_type: str = "swiglu"         # swiglu | gelu | none

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0            # arctic's parallel dense residual MLP
    capacity_factor: float = 1.25
    moe_sharded_dispatch: bool = False  # DP-sharded dispatch buffers (§Perf)

    # Recurrent families
    d_rnn: int = 0                   # rglru width (0 -> d_model)
    conv_width: int = 4

    # Modality frontends (stubs per assignment: precomputed embeddings)
    frontend: str = "none"           # none | audio_frames | vision_patches
    frontend_dim: int = 0
    num_patches: int = 0             # vlm: patches prepended to the sequence

    encoder_only: bool = False       # hubert
    causal: bool = True
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # Engineering knobs (perf-iteration surface)
    remat: str = "full"              # none | full | dots
    attn_chunked_threshold: int = 8192
    scan_layers: bool = True
    loss_vocab_chunk: int = 0        # 0 = unchunked cross-entropy

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def resolved_d_rnn(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def pattern_layers(self) -> Tuple[str, ...]:
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    @property
    def subquadratic(self) -> bool:
        """True if no layer needs global quadratic attention (long_500k ok)."""
        return all(t != "attn" for t in self.pattern_layers)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND."""
        d, f, hd = self.d_model, self.d_ff, self.resolved_head_dim
        h, kv = self.num_heads, self.num_kv_heads
        n = self.vocab_size * d  # embeddings (tied head)
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for t in self.pattern_layers:
            if t in ("attn", "local"):
                n += d * hd * (h + 2 * kv) + h * hd * d
            elif t == "rglru":
                dr = self.resolved_d_rnn
                n += 2 * d * dr + dr * d + self.conv_width * dr + 2 * dr * dr + dr
            elif t == "mlstm":
                n += d * 2 * d + 3 * d * d + d * d
            elif t == "slstm":
                n += d * 4 * d + h * (d // h) * 4 * (d // h) + d * d
            if self.num_experts:
                n += d * self.num_experts
                n += self.num_experts * 3 * d * f
                if self.moe_dense_ff:
                    n += 3 * d * self.moe_dense_ff
            elif f > 0:
                n += (3 if self.mlp_type in ("swiglu", "geglu") else 2) * d * f
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_layer_unused = (self.num_experts - self.top_k) * 3 * d * f
        return self.param_count() - len(self.pattern_layers) * per_layer_unused


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment skip rules; reason recorded in EXPERIMENTS.md."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full quadratic attention: 500k context infeasible"
    return True, ""
