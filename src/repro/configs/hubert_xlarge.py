"""hubert-xlarge [audio]: 48L d=1280 16H (kv=16) ff=5120 vocab=504.

Encoder-only transformer (same backbone as wav2vec2-XL); the convolutional
waveform frontend is a STUB per the assignment: input_specs provide
precomputed frame embeddings (dim 512).  Trains with masked-frame
prediction over 504 cluster targets.  [arXiv:2106.07447; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    mlp_type="gelu",
    encoder_only=True,
    causal=False,
    frontend="audio_frames",
    frontend_dim=512,
    tie_embeddings=False,
)
