"""gemma2-27b [dense]: 46L d=4608 32H (GQA kv=16) ff=36864 vocab=256000.

Local(4096-window)/global alternating attention, attention logit softcap 50,
final logit softcap 30, sandwich (post) norms, sqrt(d)-scaled embeddings,
head_dim fixed at 128.  [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    layer_pattern=("local", "attn"),
    local_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    use_post_norm=True,
    embed_scale=True,
    mlp_type="geglu",
)
