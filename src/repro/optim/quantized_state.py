"""Block-wise int8 quantization for optimizer moments (8-bit Adam).

A distributed-optimization memory trick: Adam's m/v tensors are stored as
int8 with one fp32 scale per block of 256 elements (last axis), cutting
optimizer-state HBM by ~3.5x — what makes arctic-480b trainable on a
single 256-chip pod (see DESIGN.md §5 and EXPERIMENTS.md memory table).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


@jax.tree_util.register_pytree_node_class
class QTensor:
    """int8 payload + per-block fp32 scales; original shape kept static."""

    def __init__(self, q, scale, shape):
        self.q = q            # int8, (-1, BLOCK)
        self.scale = scale    # fp32, (-1,)
        self.shape = tuple(shape)

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    def __repr__(self):
        return f"QTensor(shape={self.shape})"


def _pad_len(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


def quantize(x: jnp.ndarray) -> QTensor:
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = _pad_len(n) - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale, shape=shape)


def dequantize(t: QTensor) -> jnp.ndarray:
    flat = (t.q.astype(jnp.float32) * t.scale[:, None]).reshape(-1)
    n = 1
    for s in t.shape:
        n *= s
    return flat[:n].reshape(t.shape)
