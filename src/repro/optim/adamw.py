"""Hand-rolled AdamW with global-norm clipping and configurable moment
storage (fp32 / bf16 / int8 block-quantized).

State is a pytree mirroring params, so the distributed partition rules
(distributed/sharding.py) shard it exactly like the params — plus the
ZeRO rule that further shards moments across the DP axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.optim.quantized_state import QTensor, dequantize, quantize


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def _store(x: jnp.ndarray, moment_dtype: str):
    if moment_dtype == "int8":
        return quantize(x)
    return x.astype(jnp.dtype(moment_dtype))


def _load(x, moment_dtype: str) -> jnp.ndarray:
    if moment_dtype == "int8":
        return dequantize(x)
    return x.astype(jnp.float32)


def init(cfg: AdamWConfig, params) -> AdamWState:
    zeros = jax.tree.map(lambda p: _store(jnp.zeros(p.shape, jnp.float32),
                                          cfg.moment_dtype), params)
    zeros_v = jax.tree.map(lambda p: _store(jnp.zeros(p.shape, jnp.float32),
                                            cfg.moment_dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros_v)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def update(
    cfg: AdamWConfig,
    grads,
    state: AdamWState,
    params,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cfg.lr(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(p, g, m_q, v_q):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _load(m_q, cfg.moment_dtype) + (1 - cfg.b1) * g
        v = cfg.b2 * _load(v_q, cfg.moment_dtype) + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms
        new_p = pf - lr * (upd + decay * pf)
        return (new_p.astype(p.dtype),
                _store(m, cfg.moment_dtype),
                _store(v, cfg.moment_dtype))

    is_q = lambda x: isinstance(x, QTensor)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m, is_leaf=is_q)
    flat_v = jax.tree.leaves(state.v, is_leaf=is_q)
    outs = [leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
