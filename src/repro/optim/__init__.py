from repro.optim.adamw import AdamWConfig, AdamWState, init, update, global_norm
from repro.optim.schedules import constant, warmup_cosine
