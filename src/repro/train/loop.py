"""Training loop with checkpoint/restart, heartbeats, and straggler hooks.

Single-process execution here; the fault-tolerance machinery (heartbeat
files, failure detection, elastic re-mesh planning) lives in
distributed/ft.py and is driven from this loop so the control flow is the
one a multi-host deployment would run.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict

import jax

from repro.checkpoint import AsyncCheckpointWriter, CheckpointStore
from repro.configs.base import ModelConfig, ShapeSpec
from repro.data import batch_for
from repro.distributed.ft import Heartbeat, StragglerMonitor
from repro.models import transformer as tf
from repro.optim import adamw
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainRunConfig:
    steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    seed: int = 0
    out_dir: str = "/tmp/repro_run"
    grad_accum: int = 1
    resume: bool = True
    heartbeat_every: int = 1


def train(
    cfg: ModelConfig,
    shape: ShapeSpec,
    opt_cfg: adamw.AdamWConfig,
    run: TrainRunConfig,
    in_shardings=None,
    donate: bool = True,
) -> Dict[str, float]:
    """Run the loop; returns final metrics.  Restores from the newest
    checkpoint in ``run.out_dir`` when present (crash/elastic restart)."""
    os.makedirs(run.out_dir, exist_ok=True)
    store = CheckpointStore(os.path.join(run.out_dir, "ckpt"))
    writer = AsyncCheckpointWriter(store)
    hb = Heartbeat(os.path.join(run.out_dir, "heartbeats"), rank=0)
    straggler = StragglerMonitor(window=20, threshold=2.0)

    rng = jax.random.PRNGKey(run.seed)
    params = tf.init_params(cfg, rng)
    opt_state = adamw.init(opt_cfg, params)
    start_step = 0
    if run.resume and store.latest_step() is not None:
        start_step, restored = store.restore(
            {"params": params, "opt_state": opt_state}
        )
        params, opt_state = restored["params"], restored["opt_state"]

    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, run.grad_accum),
        donate_argnums=(0, 1) if donate else (),
    )

    metrics_log = open(  # noqa: SIM115  (long-lived handle, closed at loop exit)
        os.path.join(run.out_dir, "metrics.jsonl"), "a")
    last: Dict[str, float] = {}
    for step in range(start_step, run.steps):
        t0 = time.monotonic()
        batch = batch_for(cfg, shape, step, seed=run.seed)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % run.log_every == 0 or step == run.steps - 1:
            last = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            rec = {"step": step, "sec": round(dt, 4), **last}
            metrics_log.write(json.dumps(rec) + "\n")
            metrics_log.flush()
        if step % run.heartbeat_every == 0:
            hb.beat(step)
        straggler.record(time.monotonic() - t0)
        if (step + 1) % run.checkpoint_every == 0 or step == run.steps - 1:
            writer.save(step + 1, {"params": params, "opt_state": opt_state},
                        extra={"arch": cfg.name, "shape": shape.name})
    writer.wait()
    metrics_log.close()
    last["slow_steps"] = float(straggler.slow_count)
    return last
