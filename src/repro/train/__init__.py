from repro.train.step import loss_fn, make_prefill_step, make_serve_step, make_train_step
from repro.train.loop import TrainRunConfig, train
