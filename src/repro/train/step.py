"""Loss and train/serve step builders for every (arch x shape) kind.

``make_train_step`` returns the pure function the dry-run lowers and the
train loop jits: (params, opt_state, batch) -> (params', opt_state',
metrics).  Supports microbatch gradient accumulation (scan with summed
grads — the psum of each microbatch overlaps the next microbatch's
compute under XLA's scheduler) and chunked-vocab cross-entropy.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.optim import adamw

AUX_LB_COEF = 0.01
AUX_Z_COEF = 1e-4


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE over (optionally masked) positions; logits (..., V) any dtype."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    return jnp.mean(nll)


def _chunked_ce(cfg: ModelConfig, params, hidden, labels,
                mask: Optional[jnp.ndarray], chunk: int) -> jnp.ndarray:
    """CE without materializing the full (B,S,V) logits: scan over sequence
    chunks, computing each chunk's logits on the fly (beyond-paper memory
    optimization for the 150k/256k-vocab archs)."""
    b, s, d = hidden.shape
    n = s // chunk
    hs = hidden[:, : n * chunk].reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)
    ms = (mask[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)
          if mask is not None else jnp.ones_like(ls, jnp.float32))

    def step(carry, inp):
        tot, cnt = carry
        h, l, m = inp
        logits = tf.apply_head(cfg, params, h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        mf = m.astype(jnp.float32)
        return (tot + jnp.sum((logz - gold) * mf), cnt + mf.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                 (hs, ls, ms))
    # Remainder positions (s % chunk) fall back to direct computation.
    if s % chunk:
        h, l = hidden[:, n * chunk:], labels[:, n * chunk:]
        m = mask[:, n * chunk:] if mask is not None else None
        logits = tf.apply_head(cfg, params, h)
        rem = cross_entropy(logits, l, m)
        mf = (m.astype(jnp.float32).sum() if m is not None
              else jnp.float32(l.size))
        tot, cnt = tot + rem * mf, cnt + mf
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    """Task loss per family: next-token LM, masked audio prediction, VLM."""
    if cfg.frontend == "audio_frames":
        logits, aux = tf.forward(cfg, params, batch)
        loss = cross_entropy(logits, batch["targets"], batch.get("mask"))
    elif cfg.loss_vocab_chunk:
        hidden, aux = tf.forward_hidden(cfg, params, batch)
        if cfg.frontend == "vision_patches":
            hidden = hidden[:, cfg.num_patches:]
        loss = _chunked_ce(cfg, params, hidden, batch["labels"], None,
                           cfg.loss_vocab_chunk)
    else:
        logits, aux = tf.forward(cfg, params, batch)
        if cfg.frontend == "vision_patches":
            logits = logits[:, cfg.num_patches:]
        loss = cross_entropy(logits, batch["labels"])
    total = loss
    if cfg.num_experts:
        total = total + AUX_LB_COEF * aux["load_balance"] + AUX_Z_COEF * aux["router_z"]
    metrics = {"loss": loss, "total_loss": total}
    if cfg.num_experts:
        metrics["moe_dropped_frac"] = aux["dropped_frac"]
    return total, metrics


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    grad_accum: int = 1,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(cfg, p, batch), has_aux=True)(
            params
        )

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            def split(x):
                return x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def accum(carry, mb):
                g_sum, loss_sum = carry
                (loss, m), g = grads_of(params, mb)
                return (jax.tree.map(jnp.add, g_sum, g), loss_sum + m["loss"]), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, loss_sum), _ = jax.lax.scan(
                accum, (zeros, jnp.float32(0)), micro
            )
            grads = jax.tree.map(lambda g: g / grad_accum, g_sum)
            metrics = {"loss": loss_sum / grad_accum}
        else:
            (loss, metrics), grads = grads_of(params, batch)
        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params
        )
        return new_params, new_opt, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        logits, _ = tf.forward(cfg, params, batch)
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """One-token decode: (params, cache, tokens, pos) -> (logits, cache')."""

    def serve_step(params, cache, tokens, pos):
        return tf.decode_step(cfg, params, cache, tokens, pos)

    return serve_step
