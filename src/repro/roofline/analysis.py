"""Three-term roofline analysis from compiled dry-run artifacts.

  compute    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
  memory     = HLO_bytes_per_device / HBM_bw_per_chip
  collective = wire_bytes_per_device / ICI_link_bw

Notes on sources (see EXPERIMENTS.md §Roofline):
- ``compiled.cost_analysis()`` reports *per-device, post-SPMD* flops/bytes.
- collective bytes are parsed from ``compiled.as_text()`` (optimized HLO):
  per-device ring-model wire bytes per op:
     all-reduce          2*S*(G-1)/G     (S = per-device result bytes)
     all-gather          S*(G-1)/G       (S = gathered result bytes)
     reduce-scatter      S*(G-1)         (S = scattered result bytes)
     all-to-all          S*(G-1)/G
     collective-permute  S
- XLA cost analysis counts while-loop (lax.scan) bodies ONCE (verified
  empirically); ``scan_correction`` recompiles one scan body and adds
  (trip_count - 1) x its stats.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Dict, List, Optional

from repro.hw import V5E, ChipSpec

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s*"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _array_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int

    @property
    def wire_bytes(self) -> float:
        s, g = self.result_bytes, max(self.group_size, 1)
        if self.kind == "collective-permute":
            return float(s)  # point-to-point: no replica_groups attribute
        if g == 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * s * (g - 1) / g
        if self.kind == "all-gather":
            return s * (g - 1) / g
        if self.kind == "reduce-scatter":
            return float(s * (g - 1))
        if self.kind == "all-to-all":
            return s * (g - 1) / g
        return float(s)  # collective-permute


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops = []
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                g = len([x for x in gl.group(1).split(",") if x.strip() != ""])
        ops.append(CollectiveOp(
            kind=m.group("kind"),
            result_bytes=_array_bytes(m.group("result")),
            group_size=g,
        ))
    return ops


@dataclasses.dataclass
class CellStats:
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_counts: Optional[Dict[str, int]] = None
    arg_bytes: float = 0.0
    temp_bytes: float = 0.0
    out_bytes: float = 0.0

    def __add__(self, other: CellStats) -> CellStats:
        counts = dict(self.collective_counts or {})
        for k, v in (other.collective_counts or {}).items():
            counts[k] = counts.get(k, 0) + v
        return CellStats(
            self.flops_per_device + other.flops_per_device,
            self.bytes_per_device + other.bytes_per_device,
            self.collective_wire_bytes + other.collective_wire_bytes,
            counts,
            max(self.arg_bytes, other.arg_bytes),
            max(self.temp_bytes, other.temp_bytes),
            max(self.out_bytes, other.out_bytes),
        )

    def scale(self, k: float) -> CellStats:
        return CellStats(
            self.flops_per_device * k,
            self.bytes_per_device * k,
            self.collective_wire_bytes * k,
            {kk: int(v * k) for kk, v in (self.collective_counts or {}).items()},
            self.arg_bytes, self.temp_bytes, self.out_bytes,
        )


def extract_stats(compiled) -> CellStats:
    cost = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text())
    counts: Dict[str, int] = {}
    wire = 0.0
    for op in colls:
        counts[op.kind] = counts.get(op.kind, 0) + 1
        wire += op.wire_bytes
    stats = CellStats(
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_wire_bytes=wire,
        collective_counts=counts,
    )
    with contextlib.suppress(Exception):
        mem = compiled.memory_analysis()
        stats.arg_bytes = float(mem.argument_size_in_bytes)
        stats.temp_bytes = float(mem.temp_size_in_bytes)
        stats.out_bytes = float(mem.output_size_in_bytes)
    return stats


@dataclasses.dataclass
class RooflineReport:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_global: float
    chips: int
    stats: CellStats

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_frac(self) -> float:
        """compute term / achieved bound = fraction of roofline attained."""
        return self.compute_s / max(self.bound_time_s, 1e-30)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops_global, 1.0)

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "roofline_frac": self.roofline_frac,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.hlo_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "chips": self.chips,
            "flops_per_device": self.stats.flops_per_device,
            "bytes_per_device": self.stats.bytes_per_device,
            "collective_wire_bytes": self.stats.collective_wire_bytes,
            "collective_counts": self.stats.collective_counts,
            "arg_bytes_per_device": self.stats.arg_bytes,
            "temp_bytes_per_device": self.stats.temp_bytes,
        }


def roofline(stats: CellStats, chips: int, model_flops: float,
             hw: ChipSpec = V5E, dtype: str = "bfloat16") -> RooflineReport:
    peak = hw.peak_flops_bf16 if dtype in ("bfloat16", "float16") else hw.peak_flops_fp32
    return RooflineReport(
        compute_s=stats.flops_per_device / peak,
        memory_s=stats.bytes_per_device / hw.hbm_bandwidth,
        collective_s=stats.collective_wire_bytes / hw.ici_link_bandwidth,
        model_flops=model_flops,
        hlo_flops_global=stats.flops_per_device * chips,
        chips=chips,
        stats=stats,
    )


def model_flops_for(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N_active*B (one decode step)."""
    n_active = cfg.active_param_count()
    d_tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * d_tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * d_tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq
