from repro.roofline.analysis import (
    CellStats,
    RooflineReport,
    extract_stats,
    model_flops_for,
    parse_collectives,
    roofline,
)
