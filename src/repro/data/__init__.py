from repro.data.tokens import batch_for, markov_tokens
from repro.data.images import image_batch
