"""Synthetic image pipeline for the CNN examples/benchmarks."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def image_batch(step: int, batch: int, h: int, w: int, channels: int = 3,
                seed: int = 0) -> jnp.ndarray:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    # Smooth structured images (sum of low-frequency waves + noise).
    k1, k2 = jax.random.split(key)
    yy = jnp.linspace(0, 6.28, h)[None, :, None, None]
    xx = jnp.linspace(0, 6.28, w)[None, None, :, None]
    phase = jax.random.uniform(k1, (batch, 1, 1, channels), maxval=6.28)
    img = jnp.sin(yy + phase) * jnp.cos(2 * xx - phase)
    return (img + 0.1 * jax.random.normal(k2, (batch, h, w, channels))).astype(
        jnp.float32
    )
