"""Deterministic, shardable synthetic data pipeline.

Batches are pure functions of (seed, step): every host can regenerate any
step's data independently — exactly the property elastic restart needs (no
data-loader state in checkpoints beyond the step counter).

The token stream has learnable structure (a noisy affine Markov chain over
the vocab) so end-to-end training demonstrably reduces loss.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec


def _key(seed: int, step: int, tag: int = 0):
    return jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), tag)


def markov_tokens(key, batch: int, seq: int, vocab: int,
                  noise: float = 0.2) -> jnp.ndarray:
    """tokens[t+1] = (a*tokens[t] + c) % vocab with prob 1-noise."""
    k1, k2, k3 = jax.random.split(key, 3)
    a, c = 7, 31
    t0 = jax.random.randint(k1, (batch,), 0, vocab)
    flips = jax.random.bernoulli(k2, noise, (batch, seq))
    rand = jax.random.randint(k3, (batch, seq), 0, vocab)

    def step(tok, inp):
        flip, rnd = inp
        nxt = jnp.where(flip, rnd, (a * tok + c) % vocab)
        return nxt, nxt

    _, toks = jax.lax.scan(step, t0, (flips.T, rand.T))
    return toks.T.astype(jnp.int32)  # (batch, seq)


def batch_for(cfg: ModelConfig, shape: ShapeSpec, step: int,
              seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Materialize one global batch matching configs.input_specs."""
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "decode":
        return {"tokens": jax.random.randint(_key(seed, step), (b, 1), 0,
                                             cfg.vocab_size, jnp.int32)}

    if cfg.frontend == "audio_frames":
        k1, k2, k3 = jax.random.split(_key(seed, step), 3)
        out = {"frames": jax.random.normal(k1, (b, s, cfg.frontend_dim), jnp.float32)}
        if shape.kind == "train":
            out["targets"] = jax.random.randint(k2, (b, s), 0, cfg.vocab_size,
                                                jnp.int32)
            out["mask"] = jax.random.bernoulli(k3, 0.08, (b, s))
        return out

    s_text = s - cfg.num_patches if cfg.frontend == "vision_patches" else s
    stream = markov_tokens(_key(seed, step), b, s_text + 1, cfg.vocab_size)
    out = {"tokens": stream[:, :-1]}
    if cfg.frontend == "vision_patches":
        out["patch_embeds"] = jax.random.normal(
            _key(seed, step, 1), (b, cfg.num_patches, cfg.frontend_dim), jnp.float32
        )
    if shape.kind == "train":
        out["labels"] = stream[:, 1:]
    return out
