"""One-shot deprecation warnings for the legacy entry points.

The `repro.api` facade (PR 5) supersedes the per-subsystem entry points
(``cnn_infer`` / ``plan_layers`` / the configs' ``plan_network`` helpers /
direct ``CNNServingEngine`` construction).  Each shim keeps working for one
release and fires **exactly one** ``DeprecationWarning`` per process per
entry point — loud enough to drive migration, quiet enough not to spam a
serving loop that calls the shim per request.
"""
from __future__ import annotations

import warnings
from typing import Set

_warned: Set[str] = set()


def warn_once(name: str, instead: str, stacklevel: int = 3) -> None:
    """Emit one DeprecationWarning per process for ``name``."""
    if name in _warned:
        return
    _warned.add(name)
    warnings.warn(
        f"{name} is deprecated and will be removed in a future release; "
        f"use {instead} instead.",
        DeprecationWarning,
        stacklevel=stacklevel,
    )


def reset() -> None:
    """Forget which warnings fired (test helper)."""
    _warned.clear()
