"""CLI: statically verify a config-zoo model's compiled plan.

    python -m repro.analysis vgg16 --dtype int8 --level full
    python -m repro.analysis yolov3-tiny --input-hw 128 128 --json

Plans the model (cost mode, no device execution — kernels are traced, never
run), prepares parameters exactly like the executor, runs the verifier, and
prints the report.  Exit status 1 on any error finding — the CI gate.
"""
from __future__ import annotations

import argparse
import sys

MODELS = ("vgg16", "yolov3-tiny", "yolov3-20")


def _resolve_model(name: str):
    if name == "vgg16":
        from repro.configs.vgg16 import MODEL

        return MODEL
    if name == "yolov3-tiny":
        from repro.configs.yolov3 import TINY_MODEL

        return TINY_MODEL
    if name == "yolov3-20":
        from repro.configs.yolov3 import MODEL_20

        return MODEL_20
    raise SystemExit(f"unknown model {name!r}; choose from {MODELS}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="compile-time plan verifier over the config zoo",
    )
    ap.add_argument("model", choices=MODELS)
    ap.add_argument("--dtype", default="float32",
                    choices=("float32", "int8"))
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--input-hw", type=int, nargs=2, metavar=("H", "W"),
                    help="override the model's input geometry "
                         "(e.g. a reduced size for quick CI runs)")
    ap.add_argument("--level", default="full",
                    choices=("plan", "kernel", "full"),
                    help="'plan' = layout/footprint checks only (no trace); "
                         "'kernel' = kernel-interior proofs (race, bounds, "
                         "accum, int8 overflow); 'full' = everything")
    ap.add_argument("--json", action="store_true",
                    help="emit the full machine-readable report")
    ap.add_argument("--cache-path", default=None,
                    help="plan-cache JSON (default: no persistence — the "
                         "verifier must not mutate a shared cache)")
    args = ap.parse_args(argv)

    import jax

    import repro
    from repro.analysis import dump_json
    from repro.api import ExecutionOptions

    model = _resolve_model(args.model)
    if args.input_hw:
        model = model.with_input_hw(tuple(args.input_hw))
    params = model.init_params(jax.random.PRNGKey(0))
    opts = ExecutionOptions(
        impl="pallas", mode="cost", interpret=True,
        cache_path=args.cache_path, batch=args.batch, dtype=args.dtype,
    )
    compiled = repro.compile(model, params, opts)
    report = compiled.verify_report(level=args.level)
    if args.json:
        print(dump_json(report))
    else:
        print(report.summary())
        for row in report.kernels:
            print(
                "  step {step:>3} {kernel:<28} grid {grid!s:<18} "
                "vmem {vmem_bytes:>9} B (model {vmem_model_bytes}) "
                "traffic {traffic_bytes} B".format(**row)
            )
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
