"""The verifier's analysis passes.

Each pass appends ``Finding``s to a shared ``VerifyReport``; all quantities
come from two independent derivations of the same compiled artifact — the
*expected* side from the NetworkPlan via the kernel wrappers' descriptor
functions, the *actual* side from the traced jaxpr (``analysis.trace``) —
so a disagreement is a real contract violation, never a tautology.

Tolerance policy (documented in docs/architecture.md): VMEM model drift is
gated at ``max(32 KiB, 2%)`` — the slack covers sub-block constants the
cost model deliberately ignores (Winograd's BT/AT matrices, epilogue row
double-buffering) while still catching any real block-sizing error, which
moves footprints by whole block multiples (hundreds of KiB).  The VMEM
*budget* check is exact: one byte over is an error.  Traffic is gated at
``max(4 KiB, 2%)``; the ideal-reuse ratio (actual / cost-model bytes on
logical shapes) is reported as a metric but never gated, because physical
channel padding legitimately inflates it (a 3-channel stem planned at a
128-lane block reads 42x the logical bytes — that is the plan, not a bug).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.report import Finding, VerifyReport
from repro.analysis.trace import PallasCallRecord, channel_boundary_ops

VMEM_TOL_ABS = 32 * 1024
VMEM_TOL_REL = 0.02
TRAFFIC_TOL_ABS = 4 * 1024
TRAFFIC_TOL_REL = 0.02


def structure_pass(
    report: VerifyReport,
    records: List[PallasCallRecord],
    descs: List[Dict[str, Any]],
) -> List[Tuple[PallasCallRecord, Dict[str, Any]]]:
    """Match traced pallas_calls to the plan's expected kernels, in order.

    Returns the (record, descriptor) pairs the per-kernel passes run over;
    a count or name mismatch is itself a finding (the plan and the compiled
    artifact disagree about *which* kernels run, so byte-level comparisons
    on the mismatched tail would be noise).
    """
    if len(records) != len(descs):
        report.add(Finding(
            pass_name="structure", severity="error",
            message=(
                "compiled network emits a different pallas_call count than "
                "the plan expects"
            ),
            expected=len(descs), actual=len(records),
        ))
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]] = []
    for rec, desc in zip(records, descs):
        # A pure q8-marker mismatch is a *dtype* defect (the plan's declared
        # precision disagrees with the compiled kernel), not a structural
        # one — keep the pair so dtype_pass can pin it precisely.
        if rec.name.replace("_q8", "") != desc["name"].replace("_q8", ""):
            report.add(Finding(
                pass_name="structure", severity="error",
                message=(
                    f"kernel body mismatch: plan expects {desc['name']!r}, "
                    f"trace found {rec.name!r}"
                ),
                step=desc.get("step"), kernel=rec.name,
            ))
            continue
        pairs.append((rec, desc))
    return pairs


def dtype_consistent_pairs(
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]],
) -> List[Tuple[PallasCallRecord, Dict[str, Any]]]:
    """Pairs whose compiled precision matches the plan's declared precision.

    The byte-level passes (VMEM, traffic) only run over these: when a step's
    declared dtype is wrong, every itemsize-derived expected quantity is
    wrong with it, and reporting those mismatches would bury the one real
    finding (the dtype pass's) in arithmetic noise.
    """
    return [
        (rec, desc) for rec, desc in pairs
        if ("_q8" in rec.name) == ("_q8" in desc["name"])
    ]


def vmem_pass(
    report: VerifyReport,
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]],
    budget: int,
) -> None:
    """Prove every kernel's true footprint fits the budget and tracks the
    cost model's prediction."""
    for rec, desc in pairs:
        actual = rec.vmem_bytes()
        if actual > budget:
            report.add(Finding(
                pass_name="vmem", severity="error",
                message="kernel footprint exceeds the planner's VMEM budget",
                step=desc.get("step"), kernel=rec.name,
                expected=budget, actual=actual,
            ))
        model = desc["model_vmem_bytes"]
        tol = max(VMEM_TOL_ABS, VMEM_TOL_REL * model)
        drift = (
            actual - model if desc.get("vmem_one_sided")
            else abs(actual - model)
        )
        if drift > tol:
            report.add(Finding(
                pass_name="vmem", severity="error",
                message=(
                    "kernel footprint drifted from the "
                    "vmem_model prediction beyond tolerance"
                ),
                step=desc.get("step"), kernel=rec.name,
                expected=model, actual=actual,
            ))


def traffic_pass(
    report: VerifyReport,
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]],
) -> None:
    """Cross-check each kernel's grid x block HBM bytes against the plan.

    The expected side is recomputed from *reference* layouts
    (``descriptors.reference_netplan``), so corrupt stored ``Layout``s that
    inflate physical channels surface here as byte mismatches.
    """
    for rec, desc in pairs:
        actual = rec.traffic_bytes()
        expected = desc.get("ref_traffic_bytes")
        if expected is None:
            continue
        tol = max(TRAFFIC_TOL_ABS, TRAFFIC_TOL_REL * expected)
        if abs(actual - expected) > tol:
            report.add(Finding(
                pass_name="traffic", severity="error",
                message=(
                    "kernel HBM traffic disagrees with the plan's "
                    "block/grid accounting"
                ),
                step=desc.get("step"), kernel=rec.name,
                expected=expected, actual=actual,
            ))


def kernel_metrics(
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]], budget: int
) -> List[Dict[str, Any]]:
    """Always-recorded per-kernel rows (findings or not)."""
    rows = []
    for rec, desc in pairs:
        traffic = rec.traffic_bytes()
        ideal = desc.get("ideal_traffic_bytes")
        rows.append({
            "step": desc.get("step"),
            "kernel": rec.name,
            "grid": list(rec.grid),
            "vmem_bytes": rec.vmem_bytes(),
            "vmem_model_bytes": desc["model_vmem_bytes"],
            "vmem_budget": budget,
            "traffic_bytes": traffic,
            "traffic_expected_bytes": desc.get("ref_traffic_bytes"),
            "traffic_ideal_bytes": ideal,
            "reuse_ratio": (round(traffic / ideal, 3) if ideal else None),
        })
    return rows


def elision_pass(
    report: VerifyReport,
    netplan,
    reference,
    closed_jaxpr: Optional[Any],
) -> None:
    """Prove the PR-4 layout-elision contract.

    Two halves: (a) every stored boundary *decision* (keep channels padded
    vs crop to logical) matches what ``build_network_plan`` derives from the
    same per-layer plans — a forced un-elided boundary is a planning-level
    violation even though the executor faithfully runs it; (b) the traced
    jaxpr's census of channel-axis pads/crops on activation-derived tensors
    equals ``netplan.expected_channel_ops`` — extra ops are executor drift,
    missing ops are movement the plan promised but the code can't emit.
    """
    from repro.core.netplan import expected_channel_ops

    for s, r in zip(netplan.steps, reference.steps):
        if s.layer.kind != "conv":
            continue
        stored, ref = not s.out_layout.trivial, not r.out_layout.trivial
        if stored != ref:
            report.add(Finding(
                pass_name="elision", severity="error",
                message=(
                    "boundary planned un-elided but the layout rules elide it"
                    if ref else
                    "boundary planned elided but the layout rules forbid it"
                ),
                step=s.index,
                expected=int(ref), actual=int(stored),
            ))
    if closed_jaxpr is None:
        return
    actual_ops = channel_boundary_ops(closed_jaxpr)
    expected_ops = expected_channel_ops(netplan)
    for kind in ("pad", "crop"):
        na = sum(1 for o in actual_ops if o.kind == kind)
        ne = sum(1 for o in expected_ops if o["kind"] == kind)
        if na != ne:
            report.add(Finding(
                pass_name="elision", severity="error",
                message=(
                    f"channel-axis {kind} count in the traced forward "
                    "disagrees with the plan's boundary accounting"
                ),
                expected=ne, actual=na,
            ))


def _kernel_eqns(jaxpr):
    from repro.analysis.trace import iter_eqns

    return iter_eqns(jaxpr, into_pallas=True)


# ---------------------------------------------------------------------------
# Kernel-interior passes (the ``kernel`` rung): race, bounds, accum, overflow.
# The facts come from analysis.grid — affine index-map recovery, guard
# resolution from the kernel jaxpr's pl.when conds, exact rational rank.

#: Symmetric int8 quantization magnitude (core/quant.py clips both
#: activations and weights to [-127, 127]).
Q8_MAX = 127
INT32_MAX = 2**31 - 1


def _output_flush_ok(accesses, ref: int, axis: int, last: int) -> bool:
    """Is every write to output ``ref`` guarded on ``pid(axis) == last``?"""
    writes = [a for a in accesses if a.ref == ref and a.kind == "write"]
    return bool(writes) and all(
        any(
            g.axis == axis and g.step == last and not g.negated
            for g in a.guards
        )
        for a in writes
    )


def race_pass(
    report: VerifyReport,
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]],
) -> None:
    """Write-disjointness: no two grid programs write the same output block.

    Two obligations per output operand: (a) the index map restricted to the
    grid axes it *does* use is injective (exact rational-rank certificate,
    with a concrete two-program collision witness on failure); (b) every
    grid axis *absent* from the map is a genuine reduction axis — declared
    sequential ('arbitrary') to Mosaic, backed by an accumulator scratch,
    and flushed to the output only under the recovered last-step
    ``pl.program_id`` guard.  The planned reduction axes from the kernel
    descriptor must agree with what the trace shows.
    """
    from repro.analysis import grid as G

    for rec, desc in pairs:
        n_in = len(rec.inputs)
        accesses = G.ref_accesses(rec)
        declared = desc.get("reduction_axes")
        for oi, op in enumerate(rec.outputs):
            red = G.reduction_axes(rec, op)
            if declared is not None and set(red) - set(declared):
                extra = sorted(set(red) - set(declared))
                report.add(Finding(
                    pass_name="race", severity="error",
                    message=(
                        f"grid axes {extra} are absent from the output index "
                        "map but the plan does not declare them reduction "
                        "axes"
                    ),
                    step=desc.get("step"), kernel=rec.name,
                ))
            amap = G.affine_index_map(op.index_map_jaxpr, rec.grid)
            if amap is None:
                if op.index_map_jaxpr is not None:
                    report.add(Finding(
                        pass_name="race", severity="warning",
                        message=(
                            "output index map is not affine; injectivity "
                            "unproved"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                    ))
            else:
                status, witness = G.injectivity_witness(
                    amap, rec.grid, op.dep_axes
                )
                if status == "collision":
                    p, q = witness
                    report.add(Finding(
                        pass_name="race", severity="error",
                        message=(
                            "output index map is not injective: grid "
                            f"programs {p} and {q} write the same output "
                            "block"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                    ))
                elif status == "unknown":
                    report.add(Finding(
                        pass_name="race", severity="warning",
                        message=(
                            "output index map rank-deficient but no "
                            "collision witness found in the search window"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                    ))
            for r in red:
                sem = rec.dimension_semantics
                if sem is not None and sem[r] != "arbitrary":
                    report.add(Finding(
                        pass_name="race", severity="error",
                        message=(
                            f"reduction axis {r} is declared "
                            f"{sem[r]!r} to Mosaic; a parallelized "
                            "reduction races on the shared output block"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                    ))
                if not rec.scratch:
                    report.add(Finding(
                        pass_name="race", severity="error",
                        message=(
                            f"grid axis {r} is absent from the output index "
                            "map but the kernel has no accumulator scratch"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                    ))
                    continue
                if not _output_flush_ok(
                    accesses, n_in + oi, r, rec.grid[r] - 1
                ):
                    report.add(Finding(
                        pass_name="race", severity="error",
                        message=(
                            "output is written outside the last-step guard "
                            f"of reduction axis {r}; intermediate partial "
                            "sums would reach HBM"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                    ))


def bounds_pass(
    report: VerifyReport,
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]],
) -> None:
    """Every ``index_map x block_shape`` window stays inside the (padded)
    operand bounds at all grid corners — affine maps make the corner check
    exact (see analysis.grid)."""
    from repro.analysis import grid as G

    for rec, desc in pairs:
        for kind, ops in (("input", rec.inputs), ("output", rec.outputs)):
            for pos, op in enumerate(ops):
                if op.index_map_jaxpr is None:
                    continue
                violations, proved = G.window_violations(op, rec.grid)
                if violations:
                    v = violations[0]
                    report.add(Finding(
                        pass_name="bounds", severity="error",
                        message=(
                            f"{kind} operand {pos} block window escapes the "
                            f"operand bounds: at grid point {v.point}, dim "
                            f"{v.dim} covers [{v.start}, {v.stop}) of "
                            f"extent {v.extent} "
                            f"({len(violations)} offending grid point(s))"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                        expected=v.extent, actual=v.stop,
                    ))
                elif not proved:
                    report.add(Finding(
                        pass_name="bounds", severity="warning",
                        message=(
                            f"{kind} operand {pos} index map is not affine "
                            "and the grid is too large to enumerate; "
                            "bounds unproved"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                    ))


def accum_pass(
    report: VerifyReport,
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]],
) -> None:
    """Accumulator hazards: scratch must be initialized on the first
    reduction step before any read, and reduction axes must be innermost.

    The initializing write's guard is recovered from the kernel body's
    ``pl.program_id`` predicate — a flipped guard (init on the *last* step)
    means every earlier reduction step reads garbage from the previous
    output block's accumulation.  Reduction axes must trail every
    multi-step parallel axis: Pallas revisits an output block consecutively
    only when the axes its index map ignores iterate innermost.
    """
    from repro.analysis import grid as G

    for rec, desc in pairs:
        red = sorted({
            a for op in rec.outputs for a in G.reduction_axes(rec, op)
        })
        for r in red:
            after = [
                a for a in range(r + 1, len(rec.grid))
                if rec.grid[a] > 1 and a not in red
            ]
            if after:
                report.add(Finding(
                    pass_name="accum", severity="error",
                    message=(
                        f"reduction axis {r} is not innermost: parallel "
                        f"axes {after} iterate inside it, so the scratch "
                        "accumulator is clobbered between partial sums"
                    ),
                    step=desc.get("step"), kernel=rec.name,
                ))
        if not rec.scratch:
            continue
        accesses = G.ref_accesses(rec)
        base = len(rec.inputs) + len(rec.outputs)
        for si in range(len(rec.scratch)):
            acc = [a for a in accesses if a.ref == base + si]
            if not acc:
                continue
            first = acc[0]
            if first.kind == "read":
                report.add(Finding(
                    pass_name="accum", severity="error",
                    message=(
                        f"scratch {si} is read before any initializing "
                        "write"
                    ),
                    step=desc.get("step"), kernel=rec.name,
                ))
                continue
            bad = [
                g for g in first.guards
                if (g.step != 0 and not g.negated)
                or (g.step == 0 and g.negated)
            ]
            if bad:
                g = bad[0]
                report.add(Finding(
                    pass_name="accum", severity="error",
                    message=(
                        f"scratch {si} initializing write is guarded on "
                        f"step {g.step} of grid axis {g.axis}"
                        f"{' (negated)' if g.negated else ''}; reads on "
                        "the first reduction step see stale data"
                    ),
                    step=desc.get("step"), kernel=rec.name,
                ))
            elif first.opaque:
                report.add(Finding(
                    pass_name="accum", severity="warning",
                    message=(
                        f"scratch {si} initializing write sits under a "
                        "predicate the analyzer could not resolve"
                    ),
                    step=desc.get("step"), kernel=rec.name,
                ))


def _traced_k_elems(rec: PallasCallRecord, desc: Dict[str, Any]):
    """Reduction depth K from the traced operand shapes, per family."""
    family = desc.get("family")
    if family == "gemm" and rec.inputs:
        return rec.inputs[0].array_shape[1]          # A is (Mp, Kp)
    if family == "im2col" and len(rec.inputs) >= 2:
        kh, kw, cp = rec.inputs[1].array_shape[:3]   # w is (kh, kw, Cp, Op)
        return kh * kw * cp
    return None


def overflow_pass(
    report: VerifyReport,
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]],
) -> None:
    """int8 overflow certification by interval arithmetic.

    A q8 kernel accumulates ``K`` products of values in [-127, 127] into
    int32, so ``|acc| <= K * 127^2``; the pass proves that bound stays
    under ``2^31 - 1`` for the *traced* reduction depth (kh*kw*Cin at the
    padded channel count — padding lanes are zero, so the physical K is the
    worst case and also the sound one).  The descriptor's declared
    ``k_elems`` must match the traced shapes, pinning plan/trace drift.
    The fused dequant epilogue is fp32-safe a fortiori: the certified
    int32 bound times any representable calibration scale is far below
    fp32 max.
    """
    for rec, desc in pairs:
        if "_q8" not in rec.name:
            continue
        k = _traced_k_elems(rec, desc)
        declared = desc.get("k_elems")
        if declared is not None and k is not None and int(declared) != int(k):
            report.add(Finding(
                pass_name="overflow", severity="error",
                message=(
                    "plan-declared reduction depth disagrees with the "
                    "traced operand shapes"
                ),
                step=desc.get("step"), kernel=rec.name,
                expected=int(declared), actual=int(k),
            ))
        k = k if k is not None else declared
        if k is None:
            report.add(Finding(
                pass_name="overflow", severity="warning",
                message=(
                    "reduction depth unrecoverable from plan or trace; "
                    "int32 accumulator bound unproved"
                ),
                step=desc.get("step"), kernel=rec.name,
            ))
            continue
        bound = int(k) * Q8_MAX * Q8_MAX
        if bound > INT32_MAX:
            report.add(Finding(
                pass_name="overflow", severity="error",
                message=(
                    f"int32 accumulator can overflow: K*127^2 = {bound} "
                    f"exceeds {INT32_MAX} at reduction depth K={k}"
                ),
                step=desc.get("step"), kernel=rec.name,
                expected=INT32_MAX, actual=bound,
            ))


def interior_metrics(
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]],
) -> List[Dict[str, Any]]:
    """Per-kernel rows of the kernel-interior facts (always recorded)."""
    from repro.analysis import grid as G

    rows = []
    for rec, desc in pairs:
        red = sorted({
            a for op in rec.outputs for a in G.reduction_axes(rec, op)
        })
        row: Dict[str, Any] = {
            "reduction_axes": red,
            "dimension_semantics": (
                list(rec.dimension_semantics)
                if rec.dimension_semantics is not None else None
            ),
            "bounds_points_checked": len(G.grid_corners(rec.grid)),
        }
        if "_q8" in rec.name:
            k = _traced_k_elems(rec, desc) or desc.get("k_elems")
            if k is not None:
                bound = int(k) * Q8_MAX * Q8_MAX
                row["acc_bound"] = bound
                row["acc_headroom"] = round(INT32_MAX / bound, 3)
        rows.append(row)
    return rows


def dtype_pass(
    report: VerifyReport,
    pairs: List[Tuple[PallasCallRecord, Dict[str, Any]]],
    netplan,
    closed_jaxpr: Optional[Any] = None,
) -> None:
    """int8 accumulation legality + upcast lint.

    For every kernel on an int8-planned step: the q8 kernel body must be
    selected, operands must arrive int8, every ``dot_general`` must consume
    int8 and produce int32 (the MXU accumulate path — an fp32 product would
    silently re-quantize), scratch accumulators must be int32, and the
    epilogue must emit fp32.  fp32 steps must not pick up q8 kernels or int8
    avals.  Network-wide, no float64 aval may appear anywhere (a stray
    Python float in an epilogue upcasts the whole layer silently).
    """
    steps = {s.index: s for s in netplan.steps}
    for rec, desc in pairs:
        step = steps.get(desc.get("step"))
        quantized = (
            step is not None and step.plan is not None
            and step.plan.dtype == "int8"
        )
        name_q8 = "_q8" in rec.name
        if quantized != name_q8:
            report.add(Finding(
                pass_name="dtype", severity="error",
                message=(
                    "int8-planned step compiled to a non-q8 kernel"
                    if quantized else
                    "fp32-planned step compiled to a q8 kernel"
                ),
                step=desc.get("step"), kernel=rec.name,
            ))
            continue
        in_dtypes = [op.dtype for op in rec.inputs]
        if quantized:
            if sum(1 for d in in_dtypes if d == "int8") < 2:
                report.add(Finding(
                    pass_name="dtype", severity="error",
                    message="int8 kernel does not consume int8 operands",
                    step=desc.get("step"), kernel=rec.name,
                ))
            for s in rec.scratch:
                if s.dtype != "int32":
                    report.add(Finding(
                        pass_name="dtype", severity="error",
                        message=(
                            "int8 kernel accumulator scratch is "
                            f"{s.dtype}, not int32"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                    ))
        else:
            if any(d == "int8" for d in in_dtypes):
                report.add(Finding(
                    pass_name="dtype", severity="error",
                    message="fp32 kernel consumes int8 operands",
                    step=desc.get("step"), kernel=rec.name,
                ))
        for op in rec.outputs:
            if op.dtype != "float32":
                report.add(Finding(
                    pass_name="dtype", severity="error",
                    message=f"kernel epilogue emits {op.dtype}, not float32",
                    step=desc.get("step"), kernel=rec.name,
                ))
        for eqn in _kernel_eqns(rec.kernel_jaxpr):
            if eqn.primitive.name != "dot_general":
                continue
            lhs, rhs = (str(v.aval.dtype) for v in eqn.invars[:2])
            out = str(eqn.outvars[0].aval.dtype)
            if quantized:
                if (lhs, rhs) != ("int8", "int8") or out != "int32":
                    report.add(Finding(
                        pass_name="dtype", severity="error",
                        message=(
                            f"int8 kernel dot_general is {lhs}x{rhs}->{out}, "
                            "must be int8xint8->int32"
                        ),
                        step=desc.get("step"), kernel=rec.name,
                    ))
            elif out == "float64":
                report.add(Finding(
                    pass_name="dtype", severity="error",
                    message="dot_general accumulates in float64",
                    step=desc.get("step"), kernel=rec.name,
                ))
    if closed_jaxpr is not None:
        for eqn in _kernel_eqns(closed_jaxpr.jaxpr):
            for v in eqn.outvars:
                if str(getattr(v.aval, "dtype", "")) == "float64":
                    report.add(Finding(
                        pass_name="dtype", severity="error",
                        message=(
                            f"float64 value produced by {eqn.primitive.name} "
                            "in the compiled network"
                        ),
                    ))
                    return
