"""Compile-time plan verifier: static analysis over traced jaxprs.

Proves, before anything executes, that a compiled NetworkPlan keeps its
promises: every kernel's true VMEM footprint fits the planner's budget and
tracks the cost model, the grid x block HBM traffic matches the plan's
accounting, the inter-layer layout-elision contract holds (no unplanned
channel pads/crops between kernels), and int8 layers accumulate legally.

    from repro.analysis import verify_network
    report = verify_network(netplan, prepared_params)
    assert report.clean, report.summary()

Or through the facade: ``ExecutionOptions(validate="full")`` /
``CompiledModel.verify_report()``.  CLI: ``python -m repro.analysis vgg16``.
"""
from repro.analysis.report import (
    Finding,
    PASSES,
    PlanVerificationError,
    VerifyReport,
    dump_json,
)
from repro.analysis.trace import (
    BOUNDARY_PRIMS,
    ChannelOp,
    OperandInfo,
    PallasCallRecord,
    ScratchInfo,
    boundary_ops,
    channel_boundary_ops,
    iter_eqns,
    pallas_calls,
    trace_forward,
)
from repro.analysis.descriptors import (
    network_descriptors,
    reference_netplan,
    step_descriptors,
)
from repro.analysis.verifier import LEVELS, verify_network, verify_pipeline

__all__ = [
    "BOUNDARY_PRIMS",
    "ChannelOp",
    "Finding",
    "LEVELS",
    "OperandInfo",
    "PASSES",
    "PallasCallRecord",
    "PlanVerificationError",
    "ScratchInfo",
    "VerifyReport",
    "boundary_ops",
    "channel_boundary_ops",
    "dump_json",
    "iter_eqns",
    "network_descriptors",
    "pallas_calls",
    "reference_netplan",
    "step_descriptors",
    "trace_forward",
    "verify_network",
    "verify_pipeline",
]
