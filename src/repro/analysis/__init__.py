"""Compile-time plan verifier: static analysis over traced jaxprs.

Proves, before anything executes, that a compiled NetworkPlan keeps its
promises: every kernel's true VMEM footprint fits the planner's budget and
tracks the cost model, the grid x block HBM traffic matches the plan's
accounting, the inter-layer layout-elision contract holds (no unplanned
channel pads/crops between kernels), and int8 layers accumulate legally.
The kernel-interior rung (``level="kernel"``, ``analysis.grid``) goes
inside each pallas_call: output index maps are injective over non-reduction
grid axes (no write races), every block window stays inside its operand's
padded bounds at all grid corners, accumulator scratch is initialized
before it is read with the reduction axis innermost, and int8 accumulators
are interval-certified against int32 overflow.

    from repro.analysis import verify_network
    report = verify_network(netplan, prepared_params)
    assert report.clean, report.summary()

Or through the facade: ``ExecutionOptions(validate="full")`` /
``CompiledModel.verify_report()``.  CLI: ``python -m repro.analysis vgg16``.
"""
from repro.analysis.report import (
    Finding,
    PASSES,
    PlanVerificationError,
    VerifyReport,
    dump_json,
)
from repro.analysis.trace import (
    BOUNDARY_PRIMS,
    ChannelOp,
    OperandInfo,
    PallasCallRecord,
    ScratchInfo,
    boundary_ops,
    channel_boundary_ops,
    iter_eqns,
    pallas_calls,
    trace_forward,
)
from repro.analysis.descriptors import (
    network_descriptors,
    reference_netplan,
    step_descriptors,
)
from repro.analysis.grid import (
    AffineMap,
    Guard,
    RefAccess,
    WindowViolation,
    affine_index_map,
    grid_corners,
    injectivity_witness,
    ref_accesses,
    reduction_axes,
    window_violations,
)
from repro.analysis.verifier import (
    KERNEL_PASSES,
    LEVELS,
    verify_network,
    verify_pipeline,
)

__all__ = [
    "AffineMap",
    "BOUNDARY_PRIMS",
    "ChannelOp",
    "Finding",
    "Guard",
    "KERNEL_PASSES",
    "LEVELS",
    "OperandInfo",
    "PASSES",
    "PallasCallRecord",
    "PlanVerificationError",
    "RefAccess",
    "ScratchInfo",
    "VerifyReport",
    "WindowViolation",
    "affine_index_map",
    "boundary_ops",
    "channel_boundary_ops",
    "dump_json",
    "grid_corners",
    "injectivity_witness",
    "iter_eqns",
    "network_descriptors",
    "pallas_calls",
    "ref_accesses",
    "reduction_axes",
    "reference_netplan",
    "step_descriptors",
    "trace_forward",
    "verify_network",
    "window_violations",
    "verify_pipeline",
]
