"""Structured findings for the compile-time plan verifier.

A verification run produces a ``VerifyReport``: a list of ``Finding``s (one
per violated invariant — a clean network yields an empty list) plus
per-kernel metric rows (footprints, traffic, reuse ratios) that are always
recorded, findings or not.  Findings are machine-readable on purpose: the
CI gate, the facade's ``validate=`` hook and the mutation tests all consume
the same structures.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

#: The plan-level passes (vmem/traffic/elision/dtype) plus the structural
#: pre-pass that matches pallas_calls to plan steps (a mismatch there
#: invalidates the others), the pipeline pass (stage-partition legality,
#: ``verify_pipeline``), and the kernel-interior passes of the ``kernel``
#: rung: race (write-disjointness of output index maps), bounds (block
#: windows inside operand bounds at all grid corners), accum (scratch
#: initialized before read, reduction innermost) and overflow (int8
#: accumulator interval certification).
PASSES = (
    "structure", "vmem", "traffic", "elision", "dtype", "pipeline",
    "race", "bounds", "accum", "overflow",
)
SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant.

    ``expected`` / ``actual`` carry the two sides of a byte (or count)
    comparison when the pass is quantitative; ``step`` is the NetworkPlan
    step index the finding anchors to (None for network-level findings) and
    ``kernel`` the pallas_call body name when one is implicated.
    """

    pass_name: str
    severity: str
    message: str
    step: Optional[int] = None
    kernel: Optional[str] = None
    expected: Optional[float] = None
    actual: Optional[float] = None

    def __post_init__(self):
        assert self.pass_name in PASSES, self.pass_name
        assert self.severity in SEVERITIES, self.severity

    def to_json(self) -> Dict[str, Any]:
        d = {
            "pass": self.pass_name,
            "severity": self.severity,
            "message": self.message,
        }
        if self.step is not None:
            d["step"] = self.step
        if self.kernel is not None:
            d["kernel"] = self.kernel
        if self.expected is not None:
            d["expected"] = self.expected
        if self.actual is not None:
            d["actual"] = self.actual
        return d

    def __str__(self) -> str:
        loc = []
        if self.step is not None:
            loc.append(f"step {self.step}")
        if self.kernel:
            loc.append(self.kernel)
        where = f" [{', '.join(loc)}]" if loc else ""
        qty = ""
        if self.expected is not None or self.actual is not None:
            qty = f" (expected {self.expected}, actual {self.actual})"
        return f"{self.severity}:{self.pass_name}{where}: {self.message}{qty}"


@dataclasses.dataclass
class VerifyReport:
    """The verifier's output: findings + always-on per-kernel metrics.

    ``ok`` is True iff no *error* findings (warnings don't fail a build);
    ``clean`` is True iff there are no findings at all — the acceptance bar
    for the reference networks.
    """

    findings: List[Finding] = dataclasses.field(default_factory=list)
    kernels: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    passes_run: Tuple[str, ...] = ()
    level: str = "full"
    network: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)

    @property
    def clean(self) -> bool:
        return not self.findings

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def by_pass(self, pass_name: str) -> List[Finding]:
        return [f for f in self.findings if f.pass_name == pass_name]

    def to_json(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "clean": self.clean,
            "level": self.level,
            "passes": list(self.passes_run),
            "network": dict(self.network),
            "findings": [f.to_json() for f in self.findings],
            "kernels": [dict(r) for r in self.kernels],
        }

    def summary(self) -> str:
        head = (
            f"verify[{self.level}] {self.network.get('name', '?')}: "
            f"{len(self.kernels)} kernels, "
            f"{len(self.findings)} finding(s) "
            f"({'ok' if self.ok else 'FAIL'})"
        )
        lines = [head] + ["  " + str(f) for f in self.findings]
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.summary()


class PlanVerificationError(RuntimeError):
    """Raised by the facade when ``ExecutionOptions.validate`` is on and the
    verifier reports error findings: the compiled artifact provably violates
    a plan invariant, so it must not run."""

    def __init__(self, report: VerifyReport):
        self.report = report
        super().__init__(report.summary())


def dump_json(report: VerifyReport) -> str:
    return json.dumps(report.to_json(), indent=2, sort_keys=True)
