"""Kernel-interior grid analysis: the machinery behind the ``kernel`` rung.

The plan-level verifier (PR 7) treats each ``pallas_call`` as a black box —
it proves byte budgets and boundary contracts but not that the BlockSpec
tiling itself is *sound*.  This module recovers, from the traced pallas_call
parameters alone (no execution), the facts the kernel-interior passes gate
on:

- **Affine index-map recovery** (``affine_index_map``): every index map is
  a jaxpr over the grid indices; evaluating it concretely at the zero
  vector, the unit vectors and the grid corners either certifies an exact
  affine form ``idx = c0 + A @ program_ids`` or reports the map non-affine.
  Affinity is what turns box-wide claims into corner checks: an affine
  function over an integer box attains each output coordinate's extremes at
  the box corners, so bounds proofs need only ``2^n`` evaluations
  (``n = len(grid) <= 4`` here, i.e. at most 16 points).

- **Injectivity certificates** (``injectivity_witness``): an output index
  map restricted to its varying grid axes is injective iff its coefficient
  columns are linearly independent (exact rational rank, no floats).  On
  rank deficiency the search for an integer null vector inside the grid box
  produces a concrete two-program collision witness when one exists.

- **Block-window bounds** (``window_violations``): for affine maps, each
  ``index_map x block_shape`` window is checked at every grid corner
  against the (padded) operand bounds; non-affine maps fall back to full
  grid enumeration when the grid is small enough, else the claim is
  reported unprovable (a warning, never a silent pass).

- **Guard recovery** (``ref_accesses``): ``pl.when(pl.program_id(a) == s)``
  traces to a ``cond`` whose predicate chains back through
  ``convert_element_type`` to ``eq(program_id[axis=a], literal)`` — note
  the *last-step* literal, because ``pl.num_programs`` folds at trace time.
  Walking the kernel jaxpr with that resolution yields every read/write of
  every kernel ref together with its enclosing guard stack, which is what
  the race pass (guarded flush) and the accumulator pass (read-before-init)
  interrogate.

Everything returns plain data; the gating policy lives in
``analysis.passes``.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from fractions import Fraction
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.trace import PallasCallRecord, _is_literal, _subjaxprs

#: Full-grid enumeration ceiling for non-affine index maps — beyond this the
#: bounds claim is reported unprovable instead of silently sampled.
MAX_ENUM_POINTS = 4096

#: Ref-access primitive families inside Pallas kernel jaxprs.
_READ_PRIMS = ("get", "load", "masked_load")
_WRITE_PRIMS = ("swap", "store", "masked_swap")


def eval_index_map(index_map_jaxpr, point: Sequence[int]) -> Tuple[int, ...]:
    """Evaluate an index-map ClosedJaxpr at one concrete grid point."""
    from jax.core import eval_jaxpr

    out = eval_jaxpr(
        index_map_jaxpr.jaxpr, index_map_jaxpr.consts,
        *[int(p) for p in point],
    )
    return tuple(int(v) for v in out)


@dataclasses.dataclass(frozen=True)
class AffineMap:
    """Certified affine form of an index map: ``idx = offset + coeffs @ p``.

    ``coeffs[d][a]`` is output dimension ``d``'s coefficient on grid axis
    ``a``.  Only constructed after verification at every grid corner plus
    the box midpoint, so ``apply`` is exact on the whole grid box.
    """

    offset: Tuple[int, ...]
    coeffs: Tuple[Tuple[int, ...], ...]

    def apply(self, point: Sequence[int]) -> Tuple[int, ...]:
        return tuple(
            c0 + sum(c * int(p) for c, p in zip(row, point))
            for c0, row in zip(self.offset, self.coeffs)
        )


def grid_corners(grid: Sequence[int]) -> List[Tuple[int, ...]]:
    """The ``2^n`` extreme points of the grid box (deduplicated for
    extent-1 axes)."""
    axes = [(0,) if g <= 1 else (0, int(g) - 1) for g in grid]
    return list(itertools.product(*axes))


def affine_index_map(index_map_jaxpr, grid: Sequence[int]) -> Optional[AffineMap]:
    """Recover and certify the affine form of an index map, or None.

    Probes the map at the zero vector and the unit vectors to read off the
    offset and coefficient columns, then verifies the resulting affine form
    at every grid corner and at the box midpoint.  A disagreement anywhere
    means the map is not affine over the box (e.g. uses mod/div of a
    program id) and the caller must fall back to enumeration.
    """
    if index_map_jaxpr is None:
        return None
    n = len(grid)
    try:
        zero = eval_index_map(index_map_jaxpr, (0,) * n)
        cols = []
        for a in range(n):
            unit = tuple(1 if i == a else 0 for i in range(n))
            probe = eval_index_map(index_map_jaxpr, unit)
            cols.append(tuple(p - z for p, z in zip(probe, zero)))
        amap = AffineMap(
            offset=zero,
            coeffs=tuple(
                tuple(cols[a][d] for a in range(n)) for d in range(len(zero))
            ),
        )
        mid = tuple(int(g) // 2 for g in grid)
        for pt in grid_corners(grid) + [mid]:
            if amap.apply(pt) != eval_index_map(index_map_jaxpr, pt):
                return None
    except Exception:
        return None
    return amap


def _rational_rank(vectors: Sequence[Sequence[int]]) -> int:
    """Exact rank of a set of integer vectors (Gaussian elimination over Q)."""
    rows = [[Fraction(x) for x in v] for v in vectors]
    if not rows:
        return 0
    rank = 0
    for c in range(len(rows[0])):
        piv = next((r for r in range(rank, len(rows)) if rows[r][c]), None)
        if piv is None:
            continue
        rows[rank], rows[piv] = rows[piv], rows[rank]
        for r in range(len(rows)):
            if r != rank and rows[r][c]:
                f = rows[r][c] / rows[rank][c]
                rows[r] = [x - f * y for x, y in zip(rows[r], rows[rank])]
        rank += 1
        if rank == len(rows):
            break
    return rank


def injectivity_witness(
    amap: AffineMap, grid: Sequence[int], axes: Sequence[int],
) -> Tuple[str, Optional[Tuple[Tuple[int, ...], Tuple[int, ...]]]]:
    """Is the affine map injective over the given grid axes' box?

    Returns ``("injective", None)`` when the coefficient columns on ``axes``
    are linearly independent (a proof — independent columns are injective
    over the whole integer lattice, a fortiori over the box).  On rank
    deficiency, searches bounded integer null vectors for a concrete
    collision: ``("collision", (p, q))`` gives two distinct grid points
    whose output block indices coincide.  ``("unknown", None)`` means rank
    deficiency without a witness inside the search window — possible for
    maps with large coprime coefficients, never for the projection maps
    real kernels use.
    """
    axes = [a for a in axes if grid[a] > 1]
    if not axes:
        return "injective", None
    columns = [
        [amap.coeffs[d][a] for d in range(len(amap.offset))] for a in axes
    ]
    if _rational_rank(columns) == len(axes):
        return "injective", None
    search = [
        range(-(min(int(grid[a]) - 1, 3)), min(int(grid[a]) - 1, 3) + 1)
        for a in axes
    ]
    for d in itertools.product(*search):
        if not any(d):
            continue
        if all(
            sum(col[dim] * dd for col, dd in zip(columns, d)) == 0
            for dim in range(len(amap.offset))
        ):
            p = [0] * len(grid)
            q = [0] * len(grid)
            for a, dd in zip(axes, d):
                p[a] = max(0, -dd)
                q[a] = p[a] + dd
            return "collision", (tuple(p), tuple(q))
    return "unknown", None


@dataclasses.dataclass(frozen=True)
class WindowViolation:
    """One block window escaping its operand's (padded) bounds."""

    point: Tuple[int, ...]        # the offending grid point
    dim: int                      # operand dimension
    start: int                    # window start element (inclusive)
    stop: int                     # window stop element (exclusive)
    extent: int                   # operand extent along dim


def window_violations(
    op, grid: Sequence[int],
) -> Tuple[List[WindowViolation], bool]:
    """(violations, proved) for one operand's block windows over the grid.

    Affine maps are checked at the grid corners only — exact, because each
    window-start coordinate is affine in the program ids and so attains its
    extremes at box corners.  Non-affine maps enumerate the full grid when
    it has at most ``MAX_ENUM_POINTS`` points; otherwise ``proved`` is
    False and the caller should report the claim unprovable.
    """
    amap = affine_index_map(op.index_map_jaxpr, grid)
    if amap is not None:
        points = grid_corners(grid)
        evaluate = amap.apply
    else:
        if op.index_map_jaxpr is None or math.prod(grid) > MAX_ENUM_POINTS:
            return [], False
        points = list(itertools.product(*[range(int(g)) for g in grid]))
        evaluate = lambda pt: eval_index_map(op.index_map_jaxpr, pt)  # noqa: E731
    violations: List[WindowViolation] = []
    for pt in points:
        idx = evaluate(pt)
        for d, (i, bs, n) in enumerate(
            zip(idx, op.block_shape, op.array_shape)
        ):
            start = int(i) * int(bs)
            if start < 0 or start + int(bs) > int(n):
                violations.append(WindowViolation(
                    point=tuple(pt), dim=d,
                    start=start, stop=start + int(bs), extent=int(n),
                ))
    return violations, True


# ---------------------------------------------------------------------------
# Guard recovery: pl.when predicates and per-ref access order


@dataclasses.dataclass(frozen=True)
class Guard:
    """One resolved ``pl.when(pl.program_id(axis) == step)`` predicate.

    ``negated`` marks accesses on the *false* branch of the cond (pl.when's
    false branch is empty, but the walk is generic).
    """

    axis: int
    step: int
    negated: bool = False


@dataclasses.dataclass(frozen=True)
class RefAccess:
    """One read or write of a kernel ref, in program order.

    ``guards`` is the stack of resolved enclosing predicates; ``opaque`` is
    True when some enclosing cond's predicate resisted resolution, so the
    access's guard condition is not fully known (passes report that as a
    warning, never as a silent pass).
    """

    ref: int                      # position in the kernel jaxpr's invars
    kind: str                     # "read" | "write"
    guards: Tuple[Guard, ...]
    opaque: bool = False


def _resolve_guard(var, producers: Dict[int, Any]) -> Optional[Guard]:
    """Chase a cond predicate back to ``eq(program_id[axis], literal)``."""
    for _ in range(8):              # bounded chase; chains are short
        if _is_literal(var):
            return None
        eqn = producers.get(id(var))
        if eqn is None:
            return None
        if eqn.primitive.name == "convert_element_type":
            var = eqn.invars[0]
            continue
        if eqn.primitive.name == "eq":
            a, b = eqn.invars
            for x, y in ((a, b), (b, a)):
                if _is_literal(x) or not _is_literal(y):
                    continue
                pe = producers.get(id(x))
                if pe is not None and pe.primitive.name == "program_id":
                    return Guard(
                        axis=int(pe.params["axis"]), step=int(y.val)
                    )
            return None
        return None
    return None


def _access_walk(
    jaxpr,
    refmap: Dict[int, int],
    guards: Tuple[Guard, ...],
    opaque: bool,
    out: List[RefAccess],
) -> None:
    producers = {id(ov): e for e in jaxpr.eqns for ov in e.outvars}
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "cond":
            g = _resolve_guard(eqn.invars[0], producers)
            for bi, branch in enumerate(eqn.params["branches"]):
                bjx = branch.jaxpr
                sub_ref = {
                    id(sv): refmap[id(ev)]
                    for sv, ev in zip(bjx.invars, eqn.invars[1:])
                    if not _is_literal(ev) and id(ev) in refmap
                }
                if g is None:
                    _access_walk(bjx, sub_ref, guards, True, out)
                else:
                    bg = g if bi == 1 else dataclasses.replace(
                        g, negated=True
                    )
                    _access_walk(bjx, sub_ref, guards + (bg,), opaque, out)
            continue
        if name in _READ_PRIMS or name in _WRITE_PRIMS:
            v = eqn.invars[0]
            if not _is_literal(v) and id(v) in refmap:
                out.append(RefAccess(
                    ref=refmap[id(v)],
                    kind="read" if name in _READ_PRIMS else "write",
                    guards=guards,
                    opaque=opaque,
                ))
            continue
        for sub in _subjaxprs(eqn.params):
            if len(sub.invars) != len(eqn.invars):
                continue
            sub_ref = {
                id(sv): refmap[id(ev)]
                for sv, ev in zip(sub.invars, eqn.invars)
                if not _is_literal(ev) and id(ev) in refmap
            }
            _access_walk(sub, sub_ref, guards, opaque, out)


def ref_accesses(record: PallasCallRecord) -> List[RefAccess]:
    """Every read/write of every kernel ref, in program order, with guards.

    Ref positions follow the kernel jaxpr's invars: inputs, then outputs,
    then scratch — so output ``i`` is ref ``len(inputs) + i`` and scratch
    ``j`` is ref ``len(inputs) + len(outputs) + j``.
    """
    jx = record.kernel_jaxpr
    refmap = {id(v): i for i, v in enumerate(jx.invars)}
    out: List[RefAccess] = []
    _access_walk(jx, refmap, (), False, out)
    return out


def reduction_axes(record: PallasCallRecord, out_op) -> Tuple[int, ...]:
    """Grid axes with more than one step absent from an output's index map —
    the axes over which the kernel must be accumulating, not racing."""
    return tuple(
        a for a in range(len(record.grid))
        if record.grid[a] > 1 and a not in out_op.dep_axes
    )
