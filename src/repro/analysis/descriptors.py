"""Expected-side kernel descriptors for a NetworkPlan.

For every planned pallas conv step this module predicts — without tracing
anything — exactly which pallas_call(s) the executor will emit: kernel body
name, grid, modeled VMEM footprint and modeled HBM traffic.  The math lives
next to each kernel family's wrapper (``gemm_call_descriptor`` /
``im2col_call_descriptor`` / ``winograd_call_descriptors``); this module
owns only the dispatch that mirrors ``kernels/conv_ops._conv2d_pallas_laidout``
(same algorithm routing, same block fallbacks, same physical channel
counts), so descriptor drift against the wrappers is a one-file diff.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.core.conv_spec import ConvAlgorithm
from repro.core.netplan import NetworkPlan, NetStep, resolve_algorithm
from repro.core.vmem_model import (
    GemmShape,
    im2col_gemm_traffic_bytes,
    itemsize,
    predict_gemm,
    winograd_traffic_bytes,
)
from repro.hw import V5E
from repro.util import ceil_to


def planned_pallas(step: NetStep) -> bool:
    """Does this step execute as pallas kernels under the network plan?"""
    return (
        step.layer.kind == "conv"
        and step.plan is not None
        and step.plan.impl == "pallas"
    )


def step_descriptors(
    netplan: NetworkPlan, step: NetStep, batch: Optional[int] = None
) -> List[Dict[str, Any]]:
    """The pallas_call descriptor list one conv step emits (program order).

    Empty for non-conv and non-pallas steps (fc layers run as plain XLA
    dots).  One descriptor for direct/im2col/fused-Winograd, three for the
    3-pass Winograd pipeline.
    """
    if not planned_pallas(step):
        return []
    b = netplan.batch if batch is None else batch
    plan, spec = step.plan, step.spec
    algo = resolve_algorithm(spec, plan, *step.in_hw)
    # Per-step precision: under an int8 *network* request a layer the
    # quantization policy kept fp32 still runs fp32 kernels.
    quantized = plan.dtype == "int8"
    d = itemsize(plan.dtype)
    h, w = step.in_hw
    oh, ow = spec.out_hw(h, w)
    cp = step.in_layout.phys_c          # activation channels entering
    o_phys = step.out_layout.phys_c     # offline weight padding target
    blocks = plan.kernel_blocks

    if algo is ConvAlgorithm.DIRECT:
        from repro.kernels.gemm.ops import gemm_call_descriptor

        bm, bn, bk = blocks
        m = b * oh * ow
        desc = gemm_call_descriptor(
            ceil_to(m, bm), ceil_to(o_phys, bn), ceil_to(cp, bk), blocks,
            dtype_bytes=d, bias=True, scale=quantized,
        )
        return [dict(desc, step=step.index)]

    if algo is ConvAlgorithm.WINOGRAD:
        from repro.kernels.winograd.ops import winograd_call_descriptors

        bt, bc, bo = blocks
        t = b * -(-oh // 6) * -(-ow // 6)
        descs = winograd_call_descriptors(
            t, cp, ceil_to(o_phys, bo), blocks,
            bias=True, fused=bool(plan.winograd_fused), dtype_bytes=d,
        )
        return [dict(x, step=step.index) for x in descs]

    from repro.kernels.im2col_gemm.ops import im2col_call_descriptor

    toh, bc, bo = blocks
    desc = im2col_call_descriptor(
        h, w, spec, blocks, cp, ceil_to(o_phys, bo), batch=b,
        dtype_bytes=d, bias=True, scale=quantized,
    )
    return [dict(desc, step=step.index)]


def ideal_traffic_bytes(netplan: NetworkPlan, step: NetStep) -> Optional[int]:
    """The cost model's *ideal-reuse* HBM bytes for one conv step.

    This is the quantity the planner prices layers with
    (``im2col_gemm_traffic_bytes`` / ``winograd_traffic_bytes`` / the
    direct-GEMM traffic term) on *logical* shapes.  The verifier reports
    actual/ideal as a per-kernel reuse-ratio metric but does not gate on it:
    block-padded physical channels (a 3-channel stem planned at a 128-wide
    block) legitimately inflate the ratio by an order of magnitude.
    """
    if not planned_pallas(step):
        return None
    plan, spec = step.plan, step.spec
    algo = resolve_algorithm(spec, plan, *step.in_hw)
    d = itemsize(plan.dtype)
    oh, ow = spec.out_hw(*step.in_hw)
    if algo is ConvAlgorithm.DIRECT:
        shape = GemmShape(
            netplan.batch * oh * ow, spec.out_channels,
            spec.in_channels * spec.kh * spec.kw,
        )
        est = predict_gemm(shape, plan.block, dtype_bytes=d)
        return int(round(est.memory_s * V5E.hbm_bandwidth))
    if algo is ConvAlgorithm.WINOGRAD:
        return winograd_traffic_bytes(
            oh, ow, spec.in_channels, spec.out_channels,
            batch=netplan.batch, dtype_bytes=d,
            fused=bool(plan.winograd_fused),
        )
    return im2col_gemm_traffic_bytes(
        oh, ow, spec.in_channels, spec.out_channels, spec.kh, spec.kw,
        batch=netplan.batch, dtype_bytes=d,
    )


def reference_netplan(netplan: NetworkPlan) -> NetworkPlan:
    """Rebuild the layout decisions from the stored per-layer plans.

    ``build_network_plan`` is deterministic given (layers, shapes, plans),
    so this reconstructs what the layouts *should* be — the expected side of
    the elision-decision check and of the traffic audit.  A NetworkPlan
    whose stored ``Layout``s were corrupted (inflated physical channels, a
    forced un-elided boundary) diverges from this reference even though its
    stored plans are untouched.
    """
    from repro.core.netplan import build_network_plan

    return build_network_plan(
        [s.layer for s in netplan.steps],
        *netplan.input_hw,
        in_channels=netplan.in_channels,
        batch=netplan.batch,
        plans=[s.plan for s in netplan.steps],
        impl=netplan.impl,
        dtype=netplan.dtype_name,
    )


def network_descriptors(
    netplan: NetworkPlan, reference: Optional[NetworkPlan] = None
) -> List[Dict[str, Any]]:
    """Flat, program-ordered descriptor list for the whole network.

    Names/grids/VMEM come from the *stored* plan (those are per-kernel
    facts); each descriptor additionally carries ``ref_traffic_bytes``
    computed from the reference layouts, the traffic audit's expected side.
    """
    reference = reference or reference_netplan(netplan)
    out: List[Dict[str, Any]] = []
    for step, ref_step in zip(netplan.steps, reference.steps):
        stored = step_descriptors(netplan, step)
        ref = step_descriptors(reference, ref_step)
        ideal = ideal_traffic_bytes(netplan, step)
        for i, desc in enumerate(stored):
            desc = dict(desc)
            desc["ref_traffic_bytes"] = (
                ref[i]["traffic_bytes"] if i < len(ref) else None
            )
            desc["ideal_traffic_bytes"] = ideal
            out.append(desc)
    return out
