"""jaxpr introspection for the compile-time plan verifier.

Everything here is *static*: we trace the compiled forward with
``jax.make_jaxpr`` (no device execution) and recover, per ``pallas_call``
equation, the grid, every operand's block shape and index-map grid-axis
dependence, and the scratch allocations — enough to reconstruct each
kernel's true VMEM footprint and its HBM traffic from first principles.

Also home of the pad/slice boundary walkers:

- ``boundary_ops`` — the promoted test-only walker from
  ``tests/test_netplan.py``: every pad/slice/dynamic_slice/gather outside
  pallas_call interiors, now descending into ``pjit`` / ``custom_jvp`` /
  ``cond`` call params (closed sub-jaxprs used to be silently skipped when
  they arrived as tuples or as ``ClosedJaxpr`` objects).
- ``channel_boundary_ops`` — the elision pass's census: pads/slices that
  change the *channel* (minor) axis of an activation-derived tensor, found
  by forward taint propagation from the input operand.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterator, List, Tuple

import jax

#: Data-movement primitives the layout-elision contract is about.
BOUNDARY_PRIMS = ("pad", "slice", "dynamic_slice", "gather")


def _is_literal(v) -> bool:
    """Literals carry ``val``; Vars don't (stable across jax versions)."""
    return hasattr(v, "val")


def _subjaxprs(params: Dict[str, Any]) -> Iterator[Any]:
    """Every Jaxpr/ClosedJaxpr reachable from an eqn's params.

    Handles the three shapes jax uses: a bare ``Jaxpr``, a ``ClosedJaxpr``
    (``pjit``, ``custom_jvp_call``'s ``call_jaxpr``) and tuples/lists of
    either (``cond`` branches, ``scan`` bodies).  The old test walker only
    recognized values with a ``.jaxpr`` attribute, so a nested fusion inside
    a pjit'd callee whose param arrived as a tuple was silently skipped.
    """
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for u in vs:
            # ClosedJaxpr first: it *also* forwards .eqns, but the walkers
            # need the underlying Jaxpr (its invars/outvars).
            if hasattr(u, "jaxpr") and hasattr(u.jaxpr, "eqns"):
                yield u.jaxpr
            elif hasattr(u, "eqns"):        # bare Jaxpr
                yield u
    return


def iter_eqns(jaxpr, *, into_pallas: bool = False) -> Iterator[Any]:
    """All equations of ``jaxpr`` and its sub-jaxprs, in program order.

    ``into_pallas=False`` (the default) treats each ``pallas_call`` as a
    leaf: its interior block-level data movement is the kernel's own
    business, not a network-boundary op.
    """
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            continue
        for sub in _subjaxprs(eqn.params):
            yield from iter_eqns(sub, into_pallas=into_pallas)


def boundary_ops(fn, *args) -> List[str]:
    """Names of pad/slice/dynamic_slice/gather ops outside pallas kernels.

    The production home of the jaxpr walk ``tests/test_netplan.py`` used to
    carry: trace ``fn(*args)`` and list every boundary primitive that would
    execute between kernels.  An elided two-conv chain traces to ``[]``.
    """
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    return [
        eqn.primitive.name
        for eqn in iter_eqns(jaxpr)
        if eqn.primitive.name in BOUNDARY_PRIMS
    ]


# ---------------------------------------------------------------------------
# pallas_call recovery


@dataclasses.dataclass(frozen=True)
class OperandInfo:
    """One streamed operand (input or output) of a pallas_call."""

    kind: str                     # "in" | "out"
    block_shape: Tuple[int, ...]
    array_shape: Tuple[int, ...]
    dtype: str
    itemsize: int
    dep_axes: Tuple[int, ...]     # grid axes the index map depends on
    #: The operand's index-map ClosedJaxpr, kept so the kernel-interior
    #: passes (analysis.grid) can evaluate the map at concrete grid points.
    #: None for hand-built records in tests; excluded from equality.
    index_map_jaxpr: Any = dataclasses.field(
        default=None, compare=False, repr=False
    )

    @property
    def block_bytes(self) -> int:
        return int(math.prod(self.block_shape)) * self.itemsize

    def fetches(self, grid: Tuple[int, ...]) -> int:
        """How many times the kernel fetches (or writes) this operand's
        blocks over the whole grid.

        The grid iterates row-major (last axis innermost) and Pallas elides
        the copy when consecutive steps map to the same block, so an operand
        whose index map depends on grid axes up to ``a`` is re-fetched once
        per step of the sub-grid ``grid[:a+1]`` — the BLIS panel-re-read
        count.  A constant index map (e.g. the Winograd BT/AT matrices)
        fetches exactly once.
        """
        if not self.dep_axes:
            return 1
        return int(math.prod(grid[: max(self.dep_axes) + 1]))

    def bytes_moved(self, grid: Tuple[int, ...]) -> int:
        return self.fetches(grid) * self.block_bytes


@dataclasses.dataclass(frozen=True)
class ScratchInfo:
    shape: Tuple[int, ...]
    dtype: str

    @property
    def nbytes(self) -> int:
        import numpy as np

        return int(math.prod(self.shape)) * np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class PallasCallRecord:
    """Everything the verifier needs about one compiled pallas_call."""

    name: str                     # kernel body function name
    grid: Tuple[int, ...]
    inputs: Tuple[OperandInfo, ...]
    outputs: Tuple[OperandInfo, ...]
    scratch: Tuple[ScratchInfo, ...]
    kernel_jaxpr: Any             # the kernel-interior jaxpr (dtype lint)
    #: Mosaic's per-grid-axis schedule declaration ('parallel' |
    #: 'arbitrary'), recovered from compiler_params — the kernel-interior
    #: race pass checks reduction axes are declared sequential.  None when
    #: the pallas_call carried no dimension_semantics.
    dimension_semantics: Any = dataclasses.field(default=None, compare=False)

    @property
    def operands(self) -> Tuple[OperandInfo, ...]:
        return self.inputs + self.outputs

    def vmem_bytes(self) -> int:
        """True per-program footprint: every streamed block double-buffered
        (Pallas revolving windows) plus the scratch allocations."""
        return (
            2 * sum(op.block_bytes for op in self.operands)
            + sum(s.nbytes for s in self.scratch)
        )

    def traffic_bytes(self) -> int:
        """Whole-grid HBM bytes implied by the block/grid structure."""
        return sum(op.bytes_moved(self.grid) for op in self.operands)


def _index_map_deps(index_map_jaxpr, n_axes: int) -> Tuple[int, ...]:
    """Which grid axes an index map's outputs transitively depend on."""
    jx = index_map_jaxpr.jaxpr
    needed = {id(v) for v in jx.outvars if not _is_literal(v)}
    for eqn in reversed(jx.eqns):
        if any(id(ov) in needed for ov in eqn.outvars):
            for iv in eqn.invars:
                if not _is_literal(iv):
                    needed.add(id(iv))
    return tuple(
        i for i, v in enumerate(jx.invars[:n_axes]) if id(v) in needed
    )


def _record_from_eqn(eqn) -> PallasCallRecord:
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    n_axes = len(grid)
    ops: List[OperandInfo] = []
    for pos, bm in enumerate(gm.block_mappings):
        asd = bm.array_shape_dtype
        import numpy as np

        ops.append(
            OperandInfo(
                kind="in" if pos < gm.num_inputs else "out",
                block_shape=tuple(int(d) for d in bm.block_shape),
                array_shape=tuple(int(d) for d in asd.shape),
                dtype=str(asd.dtype),
                itemsize=int(np.dtype(asd.dtype).itemsize),
                dep_axes=_index_map_deps(bm.index_map_jaxpr, n_axes),
                index_map_jaxpr=bm.index_map_jaxpr,
            )
        )
    kernel_jaxpr = eqn.params["jaxpr"]
    mosaic = (eqn.params.get("compiler_params") or {}).get("mosaic") or {}
    semantics = mosaic.get("dimension_semantics")
    if semantics is not None:
        semantics = tuple(str(s) for s in semantics)
    n_scratch = int(gm.num_scratch_operands)
    scratch: List[ScratchInfo] = []
    if n_scratch:
        for v in kernel_jaxpr.invars[-n_scratch:]:
            scratch.append(
                ScratchInfo(
                    shape=tuple(int(d) for d in v.aval.shape),
                    dtype=str(v.aval.dtype),
                )
            )
    return PallasCallRecord(
        name=eqn.params["name_and_src_info"].name,
        grid=grid,
        inputs=tuple(op for op in ops if op.kind == "in"),
        outputs=tuple(op for op in ops if op.kind == "out"),
        scratch=tuple(scratch),
        kernel_jaxpr=kernel_jaxpr,
        dimension_semantics=semantics,
    )


def pallas_calls(jaxpr) -> List[PallasCallRecord]:
    """All pallas_call records of a (sub-)jaxpr walk, in program order."""
    return [
        _record_from_eqn(eqn)
        for eqn in iter_eqns(jaxpr)
        if eqn.primitive.name == "pallas_call"
    ]


def trace_forward(fn, *args):
    """(closed_jaxpr, [PallasCallRecord]) for ``fn(*args)`` — trace only."""
    closed = jax.make_jaxpr(fn)(*args)
    return closed, pallas_calls(closed.jaxpr)


# ---------------------------------------------------------------------------
# Activation taint + channel-axis boundary census


@dataclasses.dataclass(frozen=True)
class ChannelOp:
    """One channel-axis pad or crop on an activation-derived tensor."""

    kind: str                     # "pad" | "crop"
    in_shape: Tuple[int, ...]
    out_shape: Tuple[int, ...]


def _census_walk(jaxpr, tainted: set, out: List[ChannelOp]) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        tainted_in = any(
            not _is_literal(v) and id(v) in tainted for v in eqn.invars
        )
        if prim in ("pad", "slice") and not _is_literal(eqn.invars[0]):
            src, dst = eqn.invars[0], eqn.outvars[0]
            s_in = getattr(src.aval, "shape", ())
            s_out = getattr(dst.aval, "shape", ())
            if (
                id(src) in tainted
                and len(s_in) == len(s_out)
                and len(s_in) >= 1
                and s_in[-1] != s_out[-1]
            ):
                out.append(
                    ChannelOp(
                        kind="pad" if s_out[-1] > s_in[-1] else "crop",
                        in_shape=tuple(int(d) for d in s_in),
                        out_shape=tuple(int(d) for d in s_out),
                    )
                )
        if tainted_in:
            for ov in eqn.outvars:
                tainted.add(id(ov))
        if prim == "pallas_call":
            continue                        # interior movement is the kernel's
        # Descend into call-like sub-jaxprs whose invars mirror the eqn's
        # (pjit, closed_call, custom_jvp/vjp call params) so channel ops
        # inside nested fusions are still counted.  ``cond`` — which also
        # carries ``lax.switch``, jax lowers both to cond_p — leads with the
        # branch-selector operand, so its branch jaxprs mirror
        # ``eqn.invars[1:]``; the old exact-length match silently skipped
        # them, hiding e.g. pipeline stage bodies (switch branches) from the
        # elision census.
        for sub in _subjaxprs(eqn.params):
            if len(sub.invars) == len(eqn.invars):
                operands = eqn.invars
            elif len(sub.invars) == len(eqn.invars) - 1:
                operands = eqn.invars[1:]   # cond/switch: drop the selector
            else:
                continue
            inner = {
                id(sv)
                for sv, ev in zip(sub.invars, operands)
                if not _is_literal(ev) and id(ev) in tainted
            }
            _census_walk(sub, inner, out)
            # conservative: sub-jaxpr outvars already handled above via
            # tainted_in -> outvars
    return


def channel_boundary_ops(closed_jaxpr, taint_invar: int = -1) -> List[ChannelOp]:
    """Channel-axis pads/crops on tensors derived from one input.

    ``taint_invar`` indexes the traced function's flattened invars;
    the verifier traces ``lambda params, x: run_network(...)`` so the
    activation is the *last* invar.  Weight/bias block-padding (untainted
    params) and spatial pads (non-minor axes) are excluded by construction —
    what remains is exactly the set of layer-boundary channel ops the PR-4
    elision contract governs.
    """
    jx = closed_jaxpr.jaxpr
    tainted = {id(jx.invars[taint_invar])}
    out: List[ChannelOp] = []
    _census_walk(jx, tainted, out)
    return out
