"""Entry points of the compile-time plan verifier.

``verify_network`` proves a NetworkPlan's invariants against the artifact
that will actually run: it traces the executor's forward with
``jax.make_jaxpr`` (no device execution, no kernel compilation) and runs
the structure / VMEM / traffic / elision / dtype passes over the recovered
``pallas_call`` parameters.  ``level="plan"`` skips the trace and checks
only what the plan alone can prove (layout decisions + modeled footprints
under budget) — cheap enough for every ``repro.compile``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

from repro.analysis.descriptors import network_descriptors, reference_netplan
from repro.analysis.passes import (
    dtype_consistent_pairs,
    dtype_pass,
    elision_pass,
    kernel_metrics,
    structure_pass,
    traffic_pass,
    vmem_pass,
)
from repro.analysis.report import Finding, VerifyReport
from repro.analysis.trace import trace_forward
from repro.hw import V5E

LEVELS = ("off", "plan", "full")


def verify_network(
    netplan,
    params: Optional[Sequence[Dict[str, Any]]] = None,
    pretransformed: Optional[Sequence[bool]] = None,
    level: str = "full",
    vmem_budget: Optional[int] = None,
    name: Optional[str] = None,
) -> VerifyReport:
    """Statically verify a NetworkPlan (and, at ``level='full'``, the traced
    forward it compiles to).

    ``params`` must be the *prepared* parameter list
    (``prepare_net_params`` output: block-padded, int8-quantized, optionally
    Winograd-pretransformed) — the verifier traces exactly what the executor
    runs.  ``pretransformed`` is the per-step flag tuple; None derives the
    standard flags from the plan.  ``vmem_budget`` defaults to the v5e VMEM
    size, matching the planner's default.
    """
    assert level in ("plan", "full"), level
    budget = vmem_budget if vmem_budget is not None else V5E.vmem_bytes
    reference = reference_netplan(netplan)
    descs = network_descriptors(netplan, reference)
    report = VerifyReport(
        level=level,
        network={
            "name": name or f"{len(netplan.steps)}-layer network",
            "batch": netplan.batch,
            "input_hw": list(netplan.input_hw),
            "dtype": netplan.dtype_name,
            "impl": netplan.impl,
            "expected_pallas_calls": len(descs),
            "vmem_budget": budget,
        },
    )

    if level == "plan":
        report.passes_run = ("vmem", "elision")
        elision_pass(report, netplan, reference, None)
        for desc in descs:
            if desc["model_vmem_bytes"] > budget:
                report.add(Finding(
                    pass_name="vmem", severity="error",
                    message=(
                        "modeled kernel footprint exceeds the planner's "
                        "VMEM budget"
                    ),
                    step=desc.get("step"), kernel=desc["name"],
                    expected=budget, actual=desc["model_vmem_bytes"],
                ))
        return report

    if params is None:
        raise ValueError("level='full' requires the prepared parameter list")

    import jax.numpy as jnp

    from repro.core.netplan import pretransform_flags, run_network

    if pretransformed is None:
        pretransformed = pretransform_flags(netplan, True)
    flags = tuple(bool(f) for f in pretransformed)
    # int8 networks still take an fp32 activation (quantization happens
    # inside the forward with calibrated scales).
    in_dtype = (
        "float32" if netplan.dtype_name == "int8" else netplan.dtype_name
    )
    x = jnp.zeros(
        (netplan.batch, *netplan.input_hw, netplan.in_channels),
        dtype=in_dtype,
    )

    def fwd(p, xx):
        return run_network(
            netplan, p, xx, interpret=True, pretransformed=flags
        )

    closed, records = trace_forward(fwd, list(params), x)

    report.passes_run = ("structure", "vmem", "traffic", "elision", "dtype")
    pairs = structure_pass(report, records, descs)
    # Byte-level passes only run where the declared precision matches the
    # compiled kernel — a dtype defect must surface as a dtype finding, not
    # as cascading itemsize noise in the VMEM/traffic comparisons.
    byte_pairs = dtype_consistent_pairs(pairs)
    vmem_pass(report, byte_pairs, budget)
    traffic_pass(report, byte_pairs)
    elision_pass(report, netplan, reference, closed)
    dtype_pass(report, pairs, netplan, closed)
    report.kernels = kernel_metrics(byte_pairs, budget)
    return report
