"""Entry points of the compile-time plan verifier.

``verify_network`` proves a NetworkPlan's invariants against the artifact
that will actually run: it traces the executor's forward with
``jax.make_jaxpr`` (no device execution, no kernel compilation) and runs
the structure / VMEM / traffic / elision / dtype passes over the recovered
``pallas_call`` parameters.  ``level="plan"`` skips the trace and checks
only what the plan alone can prove (layout decisions + modeled footprints
under budget) — cheap enough for every ``repro.compile``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

from repro.analysis.descriptors import network_descriptors, reference_netplan
from repro.analysis.passes import (
    accum_pass,
    bounds_pass,
    dtype_consistent_pairs,
    dtype_pass,
    elision_pass,
    interior_metrics,
    kernel_metrics,
    overflow_pass,
    race_pass,
    structure_pass,
    traffic_pass,
    vmem_pass,
)
from repro.analysis.report import Finding, VerifyReport
from repro.analysis.trace import trace_forward
from repro.hw import V5E

LEVELS = ("off", "plan", "kernel", "full")

#: The kernel-interior pass suite (the ``kernel`` rung's additions).
KERNEL_PASSES = ("race", "bounds", "accum", "overflow")


def _run_kernel_passes(report, pairs) -> None:
    race_pass(report, pairs)
    bounds_pass(report, pairs)
    accum_pass(report, pairs)
    overflow_pass(report, pairs)


def _merged_metrics(pairs, budget):
    rows = kernel_metrics(pairs, budget)
    for row, extra in zip(rows, interior_metrics(pairs)):
        row.update(extra)
    return rows


def verify_network(
    netplan,
    params: Optional[Sequence[Dict[str, Any]]] = None,
    pretransformed: Optional[Sequence[bool]] = None,
    level: str = "full",
    vmem_budget: Optional[int] = None,
    name: Optional[str] = None,
) -> VerifyReport:
    """Statically verify a NetworkPlan (and, beyond ``level='plan'``, the
    traced forward it compiles to).

    The rungs, cheapest first: ``"plan"`` checks what the plan alone can
    prove (layout decisions + modeled footprints under budget, no trace);
    ``"kernel"`` traces the forward and proves the kernel-interior
    properties (write-disjointness/race, block-window bounds, accumulator
    hazards, int8 overflow certification) on every recovered pallas_call;
    ``"full"`` runs everything — the plan-vs-trace byte passes (structure /
    vmem / traffic / elision / dtype) *and* the kernel-interior suite.

    ``params`` must be the *prepared* parameter list
    (``prepare_net_params`` output: block-padded, int8-quantized, optionally
    Winograd-pretransformed) — the verifier traces exactly what the executor
    runs.  ``pretransformed`` is the per-step flag tuple; None derives the
    standard flags from the plan.  ``vmem_budget`` defaults to the v5e VMEM
    size, matching the planner's default.
    """
    assert level in ("plan", "kernel", "full"), level
    budget = vmem_budget if vmem_budget is not None else V5E.vmem_bytes
    reference = reference_netplan(netplan)
    descs = network_descriptors(netplan, reference)
    report = VerifyReport(
        level=level,
        network={
            "name": name or f"{len(netplan.steps)}-layer network",
            "batch": netplan.batch,
            "input_hw": list(netplan.input_hw),
            "dtype": netplan.dtype_name,
            "impl": netplan.impl,
            "expected_pallas_calls": len(descs),
            "vmem_budget": budget,
        },
    )

    if level == "plan":
        report.passes_run = ("vmem", "elision")
        elision_pass(report, netplan, reference, None)
        for desc in descs:
            if desc["model_vmem_bytes"] > budget:
                report.add(Finding(
                    pass_name="vmem", severity="error",
                    message=(
                        "modeled kernel footprint exceeds the planner's "
                        "VMEM budget"
                    ),
                    step=desc.get("step"), kernel=desc["name"],
                    expected=budget, actual=desc["model_vmem_bytes"],
                ))
        return report

    if params is None:
        raise ValueError(
            f"level={level!r} requires the prepared parameter list"
        )

    import jax.numpy as jnp

    from repro.core.netplan import pretransform_flags, run_network

    if pretransformed is None:
        pretransformed = pretransform_flags(netplan, True)
    flags = tuple(bool(f) for f in pretransformed)
    # int8 networks still take an fp32 activation (quantization happens
    # inside the forward with calibrated scales).
    in_dtype = (
        "float32" if netplan.dtype_name == "int8" else netplan.dtype_name
    )
    x = jnp.zeros(
        (netplan.batch, *netplan.input_hw, netplan.in_channels),
        dtype=in_dtype,
    )

    def fwd(p, xx):
        return run_network(
            netplan, p, xx, interpret=True, pretransformed=flags
        )

    closed, records = trace_forward(fwd, list(params), x)

    pairs = structure_pass(report, records, descs)
    # Byte-level and kernel-interior passes only run where the declared
    # precision matches the compiled kernel — a dtype defect must surface as
    # a dtype finding, not as cascading noise in the other passes.
    byte_pairs = dtype_consistent_pairs(pairs)

    if level == "kernel":
        report.passes_run = ("structure",) + KERNEL_PASSES
        _run_kernel_passes(report, byte_pairs)
        report.kernels = _merged_metrics(byte_pairs, budget)
        return report

    report.passes_run = (
        ("structure", "vmem", "traffic", "elision", "dtype") + KERNEL_PASSES
    )
    vmem_pass(report, byte_pairs, budget)
    traffic_pass(report, byte_pairs)
    elision_pass(report, netplan, reference, closed)
    dtype_pass(report, pairs, netplan, closed)
    _run_kernel_passes(report, byte_pairs)
    report.kernels = _merged_metrics(byte_pairs, budget)
    return report


def verify_pipeline(
    netplan,
    pipeplan,
    name: Optional[str] = None,
    params: Optional[Sequence[Dict[str, Any]]] = None,
    pretransformed: Optional[Sequence[bool]] = None,
    level: str = "plan",
):
    """Statically verify a stage partition against its NetworkPlan.

    At ``level="plan"`` (no tracing): proves the stage bounds are a
    contiguous cover, every cut lands on a legal boundary (trivial producer
    layout — no elision chain crosses a chip edge — and no ``from_layers``
    span reaching back into an earlier stage), the recorded per-stage
    seconds match the per-step ``predicted_s`` sums, and the microbatch
    count tiles the batch.  Cheap enough to gate every pipeline-executor
    build.

    At ``level="kernel"`` (requires the prepared ``params``): additionally
    traces every stage's ``run_network(start=, stop=)`` slice at microbatch
    size — the exact bodies the GPipe switch dispatches — and runs the
    kernel-interior passes (race / bounds / accum / overflow) over each
    stage's recovered pallas_calls.
    """
    from repro.core.netplan import legal_cut_points, step_seconds

    report = VerifyReport(
        level="plan",
        network={
            "name": name or f"{len(netplan.steps)}-layer network",
            "batch": netplan.batch,
            "input_hw": list(netplan.input_hw),
            "dtype": netplan.dtype_name,
            "impl": netplan.impl,
            "n_stages": pipeplan.n_stages,
            "n_micro": pipeplan.n_micro,
        },
    )
    report.passes_run = ("pipeline",)

    def err(message, **kw):
        report.add(Finding(
            pass_name="pipeline", severity="error", message=message, **kw
        ))

    n = len(netplan.steps)
    bounds = pipeplan.stage_bounds
    if not bounds or bounds[0][0] != 0 or bounds[-1][1] != n:
        err(f"stage bounds {bounds} do not cover the {n}-step network")
        return report
    prev_end = 0
    for a, z in bounds:
        if a != prev_end or a >= z:
            err(f"stage bounds {bounds} are not a contiguous cover")
            return report
        prev_end = z
    legal = set(legal_cut_points(netplan))
    for a, _ in bounds[1:]:
        if a not in legal:
            step = netplan.steps[a - 1]
            why = (
                "inside a layout-elision chain"
                if not step.out_layout.trivial
                else "crossing a route/shortcut dependency span"
            )
            err(f"cut at step {a} is illegal ({why})", step=a)
    per_step = step_seconds(netplan)
    for si, ((a, z), rec) in enumerate(zip(bounds, pipeplan.stage_seconds)):
        want = float(sum(per_step[a:z]))
        if abs(rec - want) > 1e-9 + 1e-6 * max(abs(want), 1.0):
            report.add(Finding(
                pass_name="pipeline", severity="error",
                message=(
                    f"stage {si} recorded seconds disagree with the plan's "
                    f"per-step predicted_s sum"
                ),
                step=a, expected=want, actual=float(rec),
            ))
    if pipeplan.n_micro < 1 or netplan.batch % pipeplan.n_micro:
        err(
            f"n_micro={pipeplan.n_micro} does not tile batch "
            f"{netplan.batch}"
        )

    assert level in ("plan", "kernel"), level
    if level == "kernel":
        if params is None:
            raise ValueError(
                "level='kernel' requires the prepared parameter list"
            )
        if report.ok:
            _verify_pipeline_kernels(
                report, netplan, pipeplan, params, pretransformed
            )
    return report


def _verify_pipeline_kernels(
    report, netplan, pipeplan, params, pretransformed
) -> None:
    """Trace every stage slice at microbatch size and run the
    kernel-interior passes over each stage's pallas_calls."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.descriptors import step_descriptors
    from repro.core.netplan import pretransform_flags, run_network

    if pretransformed is None:
        pretransformed = pretransform_flags(netplan, True)
    flags = tuple(bool(f) for f in pretransformed)
    mb = netplan.batch // pipeplan.n_micro
    act_dtype = (
        "float32" if netplan.dtype_name == "int8" else netplan.dtype_name
    )
    cur = jax.ShapeDtypeStruct(
        (mb, *netplan.input_hw, netplan.in_channels), act_dtype
    )
    all_pairs = []
    for a, z in pipeplan.stage_bounds:
        stage_params = list(params[a:z])

        def stage_fwd(p, xx, a=a, z=z):
            return run_network(
                netplan, p, xx, interpret=True, pretransformed=flags,
                start=a, stop=z,
            )

        x = jnp.zeros(cur.shape, cur.dtype)
        closed, records = trace_forward(stage_fwd, stage_params, x)
        descs = [
            d
            for s in netplan.steps[a:z]
            for d in step_descriptors(netplan, s, batch=mb)
        ]
        pairs = structure_pass(report, records, descs)
        all_pairs.extend(dtype_consistent_pairs(pairs))
        cur = jax.eval_shape(stage_fwd, stage_params, cur)
    _run_kernel_passes(report, all_pairs)
    report.kernels = _merged_metrics(all_pairs, V5E.vmem_bytes)
    report.level = "kernel"
    report.passes_run = ("pipeline", "structure") + KERNEL_PASSES
