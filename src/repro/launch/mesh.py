"""Production meshes.  Functions, not module constants: importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=(data,model) single pod; (2,16,16)=(pod,data,model) for two
    pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh_for(devices: int, model_parallel: int = 1):
    """Generic helper for tests/examples on whatever devices exist."""
    assert devices % model_parallel == 0
    return jax.make_mesh(
        (devices // model_parallel, model_parallel),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
