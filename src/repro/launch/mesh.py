"""Production meshes.  Functions, not module constants: importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=(data,model) single pod; (2,16,16)=(pod,data,model) for two
    pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh_for(devices: int, model_parallel: int = 1):
    """Generic helper for tests/examples on whatever devices exist."""
    assert devices % model_parallel == 0
    return jax.make_mesh(
        (devices // model_parallel, model_parallel),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def make_stage_mesh(n_stages: int, devices=None):
    """A 1-D ('stage',) mesh for layer-pipelined execution.

    Takes the first ``n_stages`` of ``devices`` (default: all visible).
    Built from an explicit device array — no ``axis_types`` — so it works
    on jax versions without ``jax.sharding.AxisType``.
    """
    import numpy as np

    if devices is None:
        devices = jax.devices()
    devices = list(devices)
    if len(devices) < n_stages:
        raise ValueError(
            f"pipeline needs {n_stages} devices, only {len(devices)} visible"
        )
    return jax.sharding.Mesh(np.array(devices[:n_stages]), ("stage",))
