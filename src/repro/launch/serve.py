"""Serving launcher: batched decode with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 6 --new-tokens 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as tf
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = (configs.smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    assert cfg.supports_decode, f"{cfg.name} is encoder-only: no serving"
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, args.batch, args.capacity,
                           temperature=args.temperature)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=args.prompt_len)
        engine.submit(prompt, max_new_tokens=args.new_tokens)
    t0 = time.monotonic()
    results = engine.run()
    dt = time.monotonic() - t0
    total = sum(len(v) for v in results.values())
    print(f"[serve] {len(results)} requests, {total} tokens "
          f"in {dt:.2f}s ({total/max(dt,1e-9):.1f} tok/s)")
    for uid, toks in sorted(results.items()):
        print(f"  req {uid}: {toks}")


if __name__ == "__main__":
    main()
