"""Optimized full sweep: the validated beyond-paper configuration per arch,
applied to every runnable cell (tag 'opt'), for the §Perf before/after table.

Validated recipe (EXPERIMENTS.md §Perf hillclimbs):
  - bf16 Adam moments for >5B archs (int8's flat-block dequant reshape
    defeats SPMD sharding propagation -> replication; bf16 shards like
    params)                                             [confirmed, 29x mem]
  - chunked-vocab cross-entropy for vocab >= 49k        [confirmed]
  - DP-only sharding for <2.5B-param archs (per-layer TP collectives
    dominate small models)                              [confirmed, 11x coll]
  - masked scatter-add MoE dispatch with DP sharding    [confirmed]
  - grad_accum=8 on big-model train cells (HBM fit)     [confirmed]
  - remat stays 'full' ('dots' refuted: more resident bytes, no compute win)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse

from repro import configs
from repro.configs.base import SHAPES
from repro.launch.dryrun import RESULTS_DIR, run_cell

SMALL = 2.5e9


def overrides_for(arch: str, shape_name: str, chips: int = 256) -> dict:
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    o: dict = {}
    big = cfg.param_count() > 5e9
    small = cfg.param_count() < SMALL
    kind = shape.kind
    if kind == "train":
        o["moment_dtype"] = "bfloat16" if big else "float32"
        if cfg.vocab_size >= 49152:
            o["loss_vocab_chunk"] = 1024
        if big:
            o["grad_accum"] = 8
    if cfg.num_experts:
        o["moe_sharded_dispatch"] = True
    if small and not cfg.num_experts:
        # Small dense archs drop TP where it pays (measured, both meshes):
        #  - train with batch covering every chip -> pure DP (11x less
        #    collective on llama3.2-1b);
        #  - prefill -> data x sequence(context) parallelism (1.3-3.5x);
        #  - decode and batch<chips train keep default TP (dp variants
        #    REFUTED there: replicated weight reads dominate decode, and
        #    dp_seq's backward gathers regressed qwen train multi 0.6x).
        if kind == "train" and shape.global_batch % chips == 0:
            o["sharding_mode"] = "dp_only"
        elif kind == "prefill":
            o["sharding_mode"] = "dp_seq"
    return o


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch in configs.ARCHS:
        for shape_name in SHAPES:
            for mk in meshes:
                o = overrides_for(arch, shape_name,
                                  chips=512 if mk == "multi" else 256)
                run_cell(arch, shape_name, mk, o, "opt", args.out,
                         skip_existing=args.skip_existing)


if __name__ == "__main__":
    main()
