"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST run as its own process: the two lines below force 512 host platform
devices BEFORE jax initializes (smoke tests and benches must see 1 device,
so this is never set globally).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b \
      --shape train_4k --mesh multi --overrides '{"remat":"dots"}' --tag rematdots

Per cell this lowers the right step function (train_step / prefill_step /
serve_step) against ShapeDtypeStruct inputs with full production
shardings, compiles it, prints memory_analysis + cost_analysis, parses
collective wire bytes out of the optimized HLO, applies the scan-body
trip-count correction, and writes results/dryrun/<cell>.json (+ .hlo.gz).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, cell_is_runnable
from repro.distributed import sharding as shd
from repro.distributed.context import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tf
from repro.optim import AdamWConfig, adamw, constant
from repro.roofline import analysis as ra
from repro.train import step as step_lib

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _moment_dtype(cfg) -> str:
    """Memory plan for >5B-param archs: bf16 Adam moments.

    int8 block-quantized moments were the original plan but REFUTED at
    scale: the flat-block dequant reshape defeats SPMD sharding propagation
    and XLA replicates the fp32 dequantized tensors (EXPERIMENTS.md §Perf,
    arctic hillclimb).  bf16 moments shard exactly like their params.
    int8 remains available (and tested) for single-host training.
    """
    return "bfloat16" if cfg.param_count() > 5e9 else "float32"


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _loss_dummy_positions(s):
    return jnp.arange(s)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict, body_correction: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg = configs.get_config(arch)
    run_overrides = dict(overrides)
    grad_accum = int(run_overrides.pop("grad_accum", 1))
    moment_dtype = run_overrides.pop("moment_dtype", _moment_dtype(cfg))
    sharding_mode = run_overrides.pop("sharding_mode", "default")
    if run_overrides:
        cfg = dataclasses.replace(cfg, **run_overrides)

    ok, reason = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    opt_cfg = AdamWConfig(lr=constant(1e-4), moment_dtype=moment_dtype)

    t0 = time.monotonic()
    params_abs = _abstract(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    batch_abs = configs.input_specs(cfg, shape)
    if sharding_mode in ("dp_only", "dp_seq"):
        # Params replicated; batch over the largest divisible axis subset;
        # dp_seq also shards the sequence dim over 'model' (context
        # parallelism); ZeRO moments over every axis.
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed.context import largest_divisible_subset

        p_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params_abs)
        batch_axes = (tuple(mesh.axis_names) if sharding_mode == "dp_only"
                      else tuple(a for a in mesh.axis_names if a != "model"))
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

        def batch_one(leaf):
            if leaf.ndim < 1:
                return NamedSharding(mesh, P())
            kept = largest_divisible_subset(leaf.shape[0], batch_axes, sizes)
            entry = kept if len(kept) > 1 else (kept[0] if kept else None)
            rest = [None] * (leaf.ndim - 1)
            if (sharding_mode == "dp_seq" and leaf.ndim >= 2
                    and leaf.shape[1] % sizes.get("model", 1) == 0):
                rest[0] = "model"  # sequence/context parallel
            return NamedSharding(mesh, P(entry, *rest))

        b_sh = jax.tree.map(batch_one, batch_abs)
    else:
        p_sh = shd.param_sharding(params_abs, mesh)
        b_sh = shd.batch_sharding(batch_abs, mesh)

    from repro.distributed.context import set_axis_mode

    set_axis_mode(sharding_mode if sharding_mode in ("dp_only", "dp_seq")
                  else "default")
    try:
        return _lower_and_analyze(
            arch, shape, shape_name, cfg, mesh, chips, multi_pod, opt_cfg,
            params_abs, p_sh, batch_abs, b_sh, sharding_mode, grad_accum,
            moment_dtype, overrides, body_correction, t0,
        )
    finally:
        set_axis_mode("default")


def _lower_and_analyze(arch, shape, shape_name, cfg, mesh, chips, multi_pod,
                       opt_cfg, params_abs, p_sh, batch_abs, b_sh,
                       sharding_mode, grad_accum, moment_dtype, overrides,
                       body_correction, t0):
    with use_mesh(mesh):
        if shape.kind == "train":
            opt_abs = _abstract(lambda p: adamw.init(opt_cfg, p), params_abs)
            zero_axes = (tuple(mesh.axis_names)
                         if sharding_mode in ("dp_only", "dp_seq") else shd.DP)
            o_sh = shd.opt_state_sharding(opt_abs, params_abs, mesh,
                                          dp_axes=zero_axes, psh=p_sh)
            fn = step_lib.make_train_step(cfg, opt_cfg, grad_accum)
            lowered = jax.jit(
                fn, in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
            ).lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            fn = step_lib.make_prefill_step(cfg)
            lowered = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(
                params_abs, batch_abs
            )
        else:  # decode
            cache_abs = _abstract(
                lambda: tf.init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            c_sh = shd.cache_sharding(cache_abs, mesh)
            tok_sh = shd.batch_sharding(batch_abs, mesh)["tokens"]
            pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
            fn = step_lib.make_serve_step(cfg)
            lowered = jax.jit(
                fn,
                in_shardings=(p_sh, c_sh, tok_sh, None),
                out_shardings=(None, c_sh),
            ).lower(params_abs, cache_abs, batch_abs["tokens"], pos_abs)

        lower_s = time.monotonic() - t0
        t1 = time.monotonic()
        compiled = lowered.compile()
        compile_s = time.monotonic() - t1

        stats = ra.extract_stats(compiled)
        mem = compiled.memory_analysis()

        # Scan-body trip-count correction (XLA counts while bodies once).
        n_periods, pat, tail = tf._period_split(cfg)
        body = None
        if body_correction and n_periods > 1:
            body = _body_stats(cfg, shape, mesh, params_abs, p_sh, grad_accum)
            stats = stats + body.scale(n_periods - 1)

    report = ra.roofline(stats, chips, ra.model_flops_for(cfg, shape),
                         dtype=cfg.dtype)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "skipped": False,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "moment_dtype": moment_dtype if shape.kind == "train" else None,
        "overrides": overrides,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "total_per_device_gib": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes)
                / 2**30, 3,
            ),
        },
        "scan_correction_periods": n_periods if n_periods > 1 else 0,
        "roofline": report.as_dict(),
    }
    return result


def _body_stats(cfg, shape, mesh, params_abs, p_sh, grad_accum) -> ra.CellStats:
    """Compile one scan-period body under the same shardings and extract its
    per-device stats; the caller scales by (n_periods - 1)."""
    n_periods, pat, tail = tf._period_split(cfg)
    drop = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), t
    )
    pp_abs = drop(params_abs["period"])

    from jax.sharding import NamedSharding, PartitionSpec as P

    def drop_sh(t):
        return jax.tree.map(
            lambda ns: NamedSharding(mesh, P(*ns.spec[1:])), t,
            is_leaf=lambda x: isinstance(x, NamedSharding),
        )

    pp_sh = drop_sh(p_sh["period"])
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    if cfg.frontend == "vision_patches" and shape.kind != "decode":
        s = shape.seq_len  # patches already included in seq budget
    from repro.distributed.context import get_axis_mode, largest_divisible_subset

    x_abs = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    mode = get_axis_mode()
    if mode == "dp_only":
        dp = tuple(mesh.axis_names)
    elif mode == "dp_seq":
        dp = tuple(a for a in mesh.axis_names if a != "model")
    else:
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    kept = largest_divisible_subset(b, dp, sizes)
    entry = kept if len(kept) > 1 else (kept[0] if kept else None)
    seq_entry = ("model" if mode == "dp_seq"
                 and s % sizes.get("model", 1) == 0 else None)
    x_sh = NamedSharding(mesh, P(entry, seq_entry, None))
    positions = jnp.arange(s)

    def fwd_once(pp, x):
        for j, bt in enumerate(pat):
            x, _, _ = tf._apply_layer(cfg, pp[f"{j}:{bt}"], x, bt,
                                      positions, None, None)
        return x

    if shape.kind == "train":
        wrapped = tf._remat_wrap(cfg, fwd_once)

        def body(pp, x):
            def scalar(pp_, x_):
                return wrapped(pp_, x_).astype(jnp.float32).sum()

            return jax.grad(scalar, argnums=(0, 1))(pp, x)

    elif shape.kind == "prefill":
        body = fwd_once
    else:
        cache_abs = _abstract(
            lambda: tf.init_cache(cfg, shape.global_batch, shape.seq_len)
        )
        cc_abs = drop(cache_abs["period"]) if "period" in cache_abs else None
        cc_sh = drop_sh(shd.cache_sharding(cache_abs, mesh)["period"])

        def body(pp, cc, x):
            ncc = {}
            for j, bt in enumerate(pat):
                key = f"{j}:{bt}"
                x, nc, _ = tf._apply_layer(cfg, pp[key], x, bt, None,
                                           cc[key], jnp.int32(0))
                ncc[key] = nc
            return x, ncc

        compiled = jax.jit(body, in_shardings=(pp_sh, cc_sh, x_sh)).lower(
            pp_abs, cc_abs, x_abs
        ).compile()
        return ra.extract_stats(compiled)

    compiled = jax.jit(body, in_shardings=(pp_sh, x_sh)).lower(
        pp_abs, x_abs
    ).compile()
    return ra.extract_stats(compiled)


def run_cell(arch, shape_name, mesh_kind, overrides, tag, out_dir,
             skip_existing=False, save_hlo=False):
    multi = mesh_kind == "multi"
    name = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
    if tag:
        name += f"__{tag}"
    out_path = os.path.join(out_dir, name + ".json")
    if skip_existing and os.path.exists(out_path):
        print(f"[skip existing] {name}")
        return
    print(f"[cell] {name} ...", flush=True)
    t0 = time.monotonic()
    try:
        result = build_cell(arch, shape_name, multi, overrides)
    except Exception as e:
        result = {"arch": arch, "shape": shape_name, "skipped": False,
                  "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()}
    result["wall_s"] = round(time.monotonic() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    status = ("SKIP: " + result["reason"]) if result.get("skipped") else (
        "ERROR: " + result["error"] if "error" in result else
        f"ok compile={result['compile_s']}s dominant="
        f"{result['roofline']['dominant']} "
        f"frac={result['roofline']['roofline_frac']:.3f}"
    )
    print(f"[done] {name}: {status} ({result['wall_s']}s)", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--overrides", default="{}")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    overrides = json.loads(args.overrides)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in configs.ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]
    for arch, shape_name in cells:
        for mk in meshes:
            run_cell(arch, shape_name, mk, overrides, args.tag, args.out,
                     skip_existing=args.skip_existing)


if __name__ == "__main__":
    main()
