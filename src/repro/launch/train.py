"""Training launcher: run any assigned arch (full or smoke-scaled) through
the fault-tolerant loop on whatever devices exist.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 200 --seq-len 128 --batch 16 --out /tmp/run1

On a real cluster each host runs this same entry point under
jax.distributed; here it drives the identical code path on local devices.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax

from repro import configs
from repro.configs.base import ShapeSpec
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import TrainRunConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--out", default="/tmp/repro_train")
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--remat", default=None, choices=[None, "none", "full", "dots"])
    args = ap.parse_args()

    cfg = (configs.smoke_config(args.arch, seq_len=args.seq_len)
           if args.smoke else configs.get_config(args.arch))
    if args.remat:
        cfg = dataclasses.replace(cfg, remat=args.remat)
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    opt = AdamWConfig(
        lr=warmup_cosine(args.lr, args.warmup, args.steps),
        moment_dtype=args.moment_dtype,
    )
    run = TrainRunConfig(
        steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        out_dir=args.out,
        grad_accum=args.grad_accum,
    )
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{jax.device_count()} device(s)")
    metrics = train(cfg, shape, opt, run)
    print(json.dumps(metrics, indent=1))


if __name__ == "__main__":
    main()
