"""Shared benchmark utilities: timing, CSV emission, layer-dim sources."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def time_jit(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call of a jitted fn on this CPU."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived — the contract of benchmarks.run."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def yolov3_20_gemms(input_hw=(608, 608)):
    """GEMM dims of the first-20-layer YOLOv3 slice (the paper's hw-sweep
    workload)."""
    from repro.configs import yolov3
    from repro.models.cnn import conv_layer_dims

    return conv_layer_dims(yolov3.LAYERS_20, *input_hw)


def vgg16_gemms(input_hw=(224, 224)):
    from repro.configs import vgg16
    from repro.models.cnn import conv_layer_dims

    return conv_layer_dims(vgg16.LAYERS, *input_hw)
