"""Shared benchmark utilities: timing, CSV emission, layer-dim sources."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

# Every emit() lands here as a structured row so drivers can dump the whole
# run as machine-readable JSON (write_bench_json) — the perf trajectory is
# tracked from files, not scraped from stdout.
ROWS: List[Dict[str, Any]] = []


def time_jit(fn: Callable, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call of a jitted fn on this CPU."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = "", **meta: Any) -> None:
    """CSV row: name,us_per_call,derived — the contract of benchmarks.run.

    Keyword ``meta`` (e.g. ``provenance={"source": plan.source, ...}``) is
    not printed; it rides along into the JSON row for machine consumers.
    """
    print(f"{name},{seconds * 1e6:.1f},{derived}")
    row: Dict[str, Any] = {"name": name, "seconds": seconds,
                           "derived": derived}
    row.update(meta)
    ROWS.append(row)


def write_bench_json(path: str = "BENCH_e2e.json",
                     extra: Optional[Dict[str, Any]] = None,
                     rows: Optional[List[Dict[str, Any]]] = None) -> str:
    """Dump benchmark rows (plus run-level ``extra`` fields) as JSON:
    {"rows": [{name, seconds, derived, ...}], ...}.

    ``rows`` defaults to everything emitted so far in this process; a
    benchmark that labels its output (e.g. with a model name) should pass
    its own slice — ``ROWS[start:]`` from before its first emit — so
    earlier sections' rows are not mislabeled into its file.
    """
    payload: Dict[str, Any] = {
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "rows": list(ROWS if rows is None else rows),
    }
    payload.update(extra or {})
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return path


def yolov3_20_gemms(input_hw=(608, 608)):
    """GEMM dims of the first-20-layer YOLOv3 slice (the paper's hw-sweep
    workload)."""
    from repro.configs import yolov3
    from repro.models.cnn import conv_layer_dims

    return conv_layer_dims(yolov3.LAYERS_20, *input_hw)


def vgg16_gemms(input_hw=(224, 224)):
    from repro.configs import vgg16
    from repro.models.cnn import conv_layer_dims

    return conv_layer_dims(vgg16.LAYERS, *input_hw)
