"""End-to-end planned CNN inference: the planner driving a whole network.

The paper's bottom line is a fully co-designed network run: every conv layer
executes the algorithm + blocking the per-layer analysis chose (§VII, Figs
9-10).  This benchmark reproduces that shape with the planning subsystem
(core/planner.py):

  1. A Planner resolves a ConvPlan per conv layer (cost-model autotune on a
     cold cache; pure lookups on a warm one) — printed as a per-layer table
     of (algorithm, block config, predicted cost).
  2. The network runs end-to-end through ``cnn_forward(plans=...)`` and the
     total latency is reported.
  3. A second Planner is opened on the same cache file and re-plans the
     network: it must hit the persistent cache with **zero re-tunes**, which
     the emitted ``warm_retunes`` row asserts.

Models: vgg16 (default, paper's classification network), yolov3-tiny, and
yolov3-20 (the first-20-layer Darknet-53 slice the paper sweeps in gem5).

Run directly:  PYTHONPATH=src python -m benchmarks.e2e_cnn --model vgg16
"""
from __future__ import annotations

import argparse
from typing import Optional, Tuple

from benchmarks.common import emit, time_jit


def _network(model: str):
    """(layer table, default input hw, in_channels) for a model name."""
    from repro.configs import vgg16, yolov3

    if model == "vgg16":
        return vgg16.LAYERS, vgg16.INPUT_HW, 3
    if model == "yolov3-tiny":
        return yolov3.TINY_LAYERS, yolov3.TINY_INPUT_HW, 3
    if model == "yolov3-20":
        return yolov3.LAYERS_20, yolov3.INPUT_HW, 3
    raise ValueError(f"unknown model {model!r}")


def run(
    model: str = "vgg16",
    input_hw: Optional[Tuple[int, int]] = None,
    batch: int = 1,
    impl: str = "jax",
    mode: str = "cost",
    cache_path: Optional[str] = None,
    reps: int = 2,
    batch_sweep: Optional[Tuple[int, ...]] = None,
) -> None:
    import jax
    import jax.numpy as jnp

    from repro.core.planner import DEFAULT_CACHE_PATH, Planner
    from repro.models.cnn import cnn_forward, cnn_infer, init_cnn, plan_layers

    layers, default_hw, in_ch = _network(model)
    h, w = input_hw or default_hw
    cache = cache_path if cache_path is not None else DEFAULT_CACHE_PATH

    # -- 1. plan the whole network (cold: tunes; warm: pure cache hits) ------
    planner = Planner(mode=mode, impl=impl, cache_path=cache, autosave=False)
    plans = plan_layers(layers, h, w, planner, in_channels=in_ch, batch=batch)
    planner.save()   # one merge+write for the whole net, not one per layer
    conv_i = 0
    for i, (l, plan) in enumerate(zip(layers, plans)):
        if plan is None:
            continue
        blk = plan.block
        emit(
            f"e2e_{model}_L{conv_i:02d}",
            plan.predicted_s,
            f"{plan.algorithm.value} {l.kernel}x{l.kernel}/s{l.stride} "
            f"bm{blk.bm} bn{blk.bn} bk{blk.bk} "
            f"kblocks={'x'.join(map(str, plan.kernel_blocks))} [{plan.source}]",
        )
        conv_i += 1
    total_pred = sum(p.predicted_s for p in plans if p is not None)
    emit(f"e2e_{model}_predicted_total", total_pred,
         f"tunes={planner.stats['tunes']} hits={planner.stats['hits']}")

    # -- 1b. fused-vs-3-pass-vs-im2col over the Winograd-eligible layer set --
    # Modeled totals for the 3x3/stride-1 layers run three ways: im2col+GEMM,
    # the 3-pass Winograd pipeline (V/M via HBM), and the single-pass fused
    # megakernel (V/M in VMEM) — the headline single-kernel win.
    from repro.core.codesign import predict_conv_time
    from repro.core.conv_spec import ConvAlgorithm, ConvSpec
    from repro.models.cnn import conv_layer_dims

    t_im2col = t_3pass = t_fused = 0.0
    n_elig = 0
    for d in conv_layer_dims(layers, h, w, in_ch):
        if d["kernel"] != 3 or d["stride"] != 1:
            continue
        spec = ConvSpec(d["cin"], d["cout"], (3, 3), (1, 1), (1, 1))
        t_im2col += predict_conv_time(
            spec, d["h"], d["w"], ConvAlgorithm.IM2COL_GEMM, batch=batch)
        t_3pass += predict_conv_time(
            spec, d["h"], d["w"], ConvAlgorithm.WINOGRAD, batch=batch,
            winograd_fused=False)
        t_fused += predict_conv_time(
            spec, d["h"], d["w"], ConvAlgorithm.WINOGRAD, batch=batch,
            winograd_fused=True)
        n_elig += 1
    if n_elig:
        emit(f"e2e_{model}_wino_fused_vs_3pass", t_fused,
             f"3x3s1_layers={n_elig} im2col_s={t_im2col:.6f} "
             f"3pass_s={t_3pass:.6f} fused_s={t_fused:.6f} "
             f"fused_vs_3pass={t_3pass / t_fused:.2f}x "
             f"fused_vs_im2col={t_im2col / t_fused:.2f}x")

    # -- 2. run the network end-to-end through the plans ---------------------
    rng = jax.random.PRNGKey(0)
    params = init_cnn(rng, layers, in_channels=in_ch)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, h, w, in_ch))
    fwd = jax.jit(
        lambda xx: cnn_forward(params, layers, xx, impl=impl, plans=plans)
    )
    t = time_jit(fwd, x, reps=reps, warmup=1)
    emit(f"e2e_{model}_total", t,
         f"{model} {h}x{w} b{batch} impl={impl} planned end-to-end")

    # -- 2b. fused epilogue: batchnorm folded offline, bias+act in-kernel ----
    # Folding runs once ahead of serving (like the paper's offline Winograd
    # weight transform, §VII.A), so it is excluded from the timed loop.
    from repro.models.cnn import fold_batchnorm

    folded = jax.block_until_ready(
        jax.jit(lambda p: fold_batchnorm(p, layers))(params)
    )
    plans_t = tuple(plans)
    fused = jax.jit(
        lambda xx: cnn_infer(folded, layers, xx, impl=impl, plans=plans_t,
                             fold_bn=False)
    )
    t_fused = time_jit(fused, x, reps=reps, warmup=1)
    speedup = t / t_fused if t_fused > 0 else float("inf")
    emit(f"e2e_{model}_total_fused", t_fused,
         f"{model} {h}x{w} b{batch} impl={impl} bn-folded fused epilogue "
         f"({speedup:.2f}x vs unfused)")

    # -- 2c. network executor: whole-graph planned, layout-persistent --------
    # The NetworkPlan elides the crop+re-pad pairs between compatible conv
    # layers (channel-block persistence, row tiles snapped to divisors of
    # OH) and the executor prepares params offline (fold + pad + Winograd
    # pre-transform).  The honest per-layer baseline is the *fused* path on
    # bn-folded params with plans re-resolved at each batch (plans are
    # batch-keyed) — so the ratio isolates the layer-boundary work, not
    # epilogue fusion the per-layer path also has.
    from repro.core.netplan import NetworkExecutor, plan_network

    for bn in (batch_sweep or (batch,)):
        planner_b = Planner(mode=mode, impl=impl, cache_path=cache,
                            autosave=False)
        netplan = plan_network(layers, h, w, planner_b, in_channels=in_ch,
                               batch=bn)
        plans_b = plan_layers(layers, h, w, planner_b, in_channels=in_ch,
                              batch=bn)
        planner_b.save()
        executor = NetworkExecutor(netplan, params)
        xb = jax.random.normal(jax.random.PRNGKey(2), (bn, h, w, in_ch))
        t_exec = time_jit(executor, xb, reps=reps, warmup=1)
        fwd_b = jax.jit(lambda xx, pb=tuple(plans_b): cnn_forward(
            folded, layers, xx, impl=impl, plans=pb, fuse_epilogue=True))
        t_perlayer = time_jit(fwd_b, xb, reps=reps, warmup=1)
        emit(f"e2e_{model}_b{bn}_perlayer", t_perlayer,
             f"{model} {h}x{w} b{bn} impl={impl} per-layer planned (fused, "
             f"bn-folded)")
        emit(f"e2e_{model}_b{bn}_executor", t_exec,
             f"{model} {h}x{w} b{bn} impl={impl} network executor "
             f"elided={netplan.elided_boundaries} "
             f"vs_perlayer={t_perlayer / t_exec if t_exec > 0 else 0:.2f}x")

    # -- 3. warm-cache proof: a fresh planner must re-tune nothing -----------
    planner2 = Planner(mode=mode, impl=impl, cache_path=cache)
    plan_layers(layers, h, w, planner2, in_channels=in_ch, batch=batch)
    plan_network(layers, h, w, planner2, in_channels=in_ch, batch=batch)
    retunes = planner2.stats["tunes"]
    emit(f"e2e_{model}_warm_retunes", 0.0,
         f"retunes={retunes} hits={planner2.stats['hits']} "
         f"network_hits={planner2.network_hits}")
    assert retunes == 0, (
        f"warm plan cache re-tuned {retunes} layers — persistence is broken"
    )
    assert planner2.network_hits >= 1, (
        "warm network-level cache entry missing — netplan persistence broken"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="vgg16",
                    choices=["vgg16", "yolov3-tiny", "yolov3-20"])
    ap.add_argument("--hw", type=int, default=None,
                    help="square input resolution (default: model's own)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--impl", default="jax", choices=["jax", "pallas"])
    ap.add_argument("--mode", default="cost", choices=["cost", "measure"])
    ap.add_argument("--cache", default=None,
                    help="plan-cache JSON path (default: REPRO_PLAN_CACHE or "
                         ".cache/conv_plans.json)")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--batch-sweep", default=None,
                    help="comma list of batch sizes, e.g. 1,4,8: emit an "
                         "e2e_<model>_b<N>_executor row (network executor, "
                         "layout persistence) next to the per-layer planned "
                         "total for each N")
    args = ap.parse_args()
    run(
        model=args.model,
        input_hw=(args.hw, args.hw) if args.hw else None,
        batch=args.batch,
        impl=args.impl,
        mode=args.mode,
        cache_path=args.cache,
        reps=args.reps,
        batch_sweep=(tuple(int(b) for b in args.batch_sweep.split(","))
                     if args.batch_sweep else None),
    )


if __name__ == "__main__":
    main()
