"""End-to-end planned CNN inference through the `repro.api` facade.

The paper's bottom line is a fully co-designed network run: every conv layer
executes the algorithm + blocking the per-layer analysis chose (§VII, Figs
9-10).  This benchmark reproduces that shape through the public entry point:

  1. ``repro.compile(model, params, options)`` plans the whole network
     (cost-model autotune on a cold cache; pure lookups on a warm one) —
     ``plan_report()`` is printed as a per-layer table of (algorithm, block
     config, predicted cost, provenance).
  2. The network runs end-to-end three ways: per-layer planned (unfused),
     per-layer fused (bn folded, epilogue in-kernel), and the compiled
     executor (``compiled.run``: layout persistence + offline-prepared
     params), per batch-sweep entry.
  3. A second ``repro.compile`` on the same cache must re-plan the network
     with **zero re-tunes**, which the emitted ``warm_retunes`` row asserts.
  4. Every row also lands in machine-readable ``BENCH_e2e.json``
     (name/seconds/plan provenance) so the perf trajectory is tracked.

Models: vgg16 (default, paper's classification network), yolov3-tiny, and
yolov3-20 (the first-20-layer Darknet-53 slice the paper sweeps in gem5).

Run directly:  PYTHONPATH=src python -m benchmarks.e2e_cnn --model vgg16
"""
from __future__ import annotations

import argparse
from typing import Optional, Tuple

from benchmarks.common import emit, time_jit, write_bench_json


def _model(model: str):
    """The facade CNNModel descriptor for a model name."""
    from repro.configs import vgg16, yolov3

    if model == "vgg16":
        return vgg16.MODEL
    if model == "yolov3-tiny":
        return yolov3.TINY_MODEL
    if model == "yolov3-20":
        return yolov3.MODEL_20
    raise ValueError(f"unknown model {model!r}")


def run(
    model: str = "vgg16",
    input_hw: Optional[Tuple[int, int]] = None,
    batch: int = 1,
    impl: str = "jax",
    mode: str = "cost",
    cache_path: Optional[str] = None,
    reps: int = 2,
    batch_sweep: Optional[Tuple[int, ...]] = None,
    json_path: Optional[str] = None,
    predict_only: bool = False,
    pipeline_sweep: Optional[Tuple[int, ...]] = None,
) -> None:
    import jax

    import repro
    from benchmarks import common
    from repro.core.planner import DEFAULT_CACHE_PATH
    from repro.models.cnn import cnn_forward, fold_batchnorm, init_cnn

    rows_start = len(common.ROWS)       # this run's slice of the row log
    desc = _model(model)
    if input_hw is not None:
        desc = desc.with_input_hw(input_hw)
    h, w = desc.input_hw
    layers, in_ch = desc.layers, desc.in_channels
    cache = cache_path if cache_path is not None else DEFAULT_CACHE_PATH
    options = repro.ExecutionOptions(
        impl=impl, mode=mode, cache_path=cache, batch=batch,
    )

    # -- 1. compile: plan the whole network (cold: tunes; warm: hits) --------
    rng = jax.random.PRNGKey(0)
    params = init_cnn(rng, layers, in_channels=in_ch)
    compiled = repro.compile(desc, params, options)
    report = compiled.plan_report()
    for conv_i, row in enumerate(report["layers"]):
        emit(
            f"e2e_{model}_L{conv_i:02d}",
            row["predicted_s"],
            f"{row['algorithm']} {row['kernel']}x{row['kernel']}"
            f"/s{row['stride']} "
            f"kblocks={'x'.join(map(str, row['kernel_blocks']))} "
            f"[{row['source']}]",
            provenance=row,
        )
    emit(f"e2e_{model}_predicted_total", report["predicted_total_s"],
         f"tunes={report['tunes']} hits={report['hits']}",
         provenance={"tunes": report["tunes"], "hits": report["hits"]})

    # -- 1b. fused-vs-3-pass-vs-im2col over the Winograd-eligible layer set --
    # Modeled totals for the 3x3/stride-1 layers run three ways: im2col+GEMM,
    # the 3-pass Winograd pipeline (V/M via HBM), and the single-pass fused
    # megakernel (V/M in VMEM) — the headline single-kernel win.
    from repro.core.codesign import predict_conv_time
    from repro.core.conv_spec import ConvAlgorithm, ConvSpec
    from repro.models.cnn import conv_layer_dims

    t_im2col = t_3pass = t_fused = 0.0
    n_elig = 0
    for d in conv_layer_dims(layers, h, w, in_ch):
        if d["kernel"] != 3 or d["stride"] != 1:
            continue
        spec = ConvSpec(d["cin"], d["cout"], (3, 3), (1, 1), (1, 1))
        t_im2col += predict_conv_time(
            spec, d["h"], d["w"], ConvAlgorithm.IM2COL_GEMM, batch=batch)
        t_3pass += predict_conv_time(
            spec, d["h"], d["w"], ConvAlgorithm.WINOGRAD, batch=batch,
            winograd_fused=False)
        t_fused += predict_conv_time(
            spec, d["h"], d["w"], ConvAlgorithm.WINOGRAD, batch=batch,
            winograd_fused=True)
        n_elig += 1
    if n_elig:
        emit(f"e2e_{model}_wino_fused_vs_3pass", t_fused,
             f"3x3s1_layers={n_elig} im2col_s={t_im2col:.6f} "
             f"3pass_s={t_3pass:.6f} fused_s={t_fused:.6f} "
             f"fused_vs_3pass={t_3pass / t_fused:.2f}x "
             f"fused_vs_im2col={t_im2col / t_fused:.2f}x")

    # -- 1c. int8: the quantized compilation's resolved per-layer decisions --
    # Modeled (cost-model) rows like section 1 — deterministic, so they land
    # in the committed baseline and the regression gate.  The planner
    # resolves dtype per layer: entry/head layers whose fp32 output writes
    # dominate stay fp32, everything else quantizes and its predicted time
    # reflects the int8 MAC rate + halved operand traffic.
    options8 = options.replace(dtype="int8")
    compiled8 = repro.compile(desc, params, options8)
    report8 = compiled8.plan_report()
    for conv_i, row in enumerate(report8["layers"]):
        emit(
            f"e2e_{model}_int8_L{conv_i:02d}",
            row["predicted_s"],
            f"{row['algorithm']} {row['kernel']}x{row['kernel']}"
            f"/s{row['stride']} dtype={row['dtype']} [{row['source']}]",
            provenance=row,
        )
    n_q = sum(1 for r in report8["layers"] if r["dtype"] == "int8")
    t32 = report["predicted_total_s"]
    t8 = report8["predicted_total_s"]
    emit(f"e2e_{model}_int8_predicted_total", t8,
         f"quantized_layers={n_q}/{len(report8['layers'])} "
         f"vs_fp32={t32 / t8 if t8 > 0 else 0:.2f}x",
         provenance={"quantized_layers": n_q,
                     "fp32_predicted_total_s": t32})
    compiled8.save_plans()

    # -- 1d. serving resilience: healthy-path degradation counters -----------
    # One request through the serving engine; ``seconds`` is the sum of the
    # resilience degradation counters — 0.0 on a healthy stack — so the
    # regression gate's exact-equality rule for zero-second rows catches a
    # silently-degraded baseline (any fallback, eviction, retry, or
    # request failure flips the row non-zero and fails the build).
    import numpy as np

    eng = compiled.serve(buckets=(1,))
    eng.submit(np.zeros((h, w, in_ch), np.float32))
    eng.run()
    health = eng.health()
    degraded = float(
        health["fallback_depth"] + health["evictions"]
        + health["rejections"] + health["retries"]
        + health["request_failures"] + health["fallback_batches"]
    )
    emit(f"e2e_{model}_serving_resilience", degraded,
         f"fallback_depth={health['fallback_depth']} "
         f"evictions={health['evictions']} retries={health['retries']} "
         f"failures={health['request_failures']} "
         f"ladder={'>'.join(health['ladder'])}",
         provenance=health)

    # -- 1e. pipeline sweep: cost-balanced stage partitions, modeled ---------
    # Deterministic rows (planner cost model only — no devices needed): the
    # stage partitioner splits the NetworkPlan at legal cut points balancing
    # planner-predicted seconds, and the row's ``seconds`` is the GPipe
    # tick-synchronous modeled latency at the auto-chosen microbatch count.
    # Committed to the baseline so a partitioner or cost-model regression
    # (worse balance, lost cut legality, broken n_micro chooser) fails the
    # regression gate.
    if pipeline_sweep:
        from repro.core.netplan import choose_n_micro, partition_network

        netplan_p = compiled.network_plan(batch)
        for n_stages in pipeline_sweep:
            pipeplan = partition_network(netplan_p, n_stages)
            n_micro = choose_n_micro(pipeplan.stage_seconds, batch)
            emit(
                f"e2e_{model}_pipeline_s{n_stages}",
                pipeplan.modeled_latency_s(n_micro),
                f"stages={'/'.join(f'{a}:{z}' for a, z in pipeplan.stage_bounds)} "
                f"n_micro={n_micro} "
                f"bubble={pipeplan.bubble_fraction(n_micro):.3f} "
                f"max_stage_s={max(pipeplan.stage_seconds):.6g}",
                provenance={
                    "stage_bounds": [list(b) for b in pipeplan.stage_bounds],
                    "stage_seconds": list(pipeplan.stage_seconds),
                    "n_micro": n_micro,
                },
            )

    # -- 1f. kernel-interior proofs: zero-cost verification row --------------
    # The kernel-level static analyzer runs over the *Pallas* compilation of
    # the same network (interpret mode, trace-only — nothing executes), fp32
    # and int8, and ``seconds`` is the total error-finding count: 0.0 while
    # every pallas_call's write-disjointness, block-bounds, accumulator-guard
    # and int8-overflow proof holds.  The regression gate's exact-equality
    # rule for zero-second rows turns any new finding into a build failure.
    import time

    n_err = n_kernels = 0
    prov = {}
    t0 = time.monotonic()
    for tag, opts_v in (("fp32", options), ("int8", options8)):
        compiled_v = repro.compile(
            desc, params, opts_v.replace(impl="pallas", interpret=True))
        rep = compiled_v.verify_report(level="kernel")
        errs = sum(1 for f in rep.findings if f.severity == "error")
        n_err += errs
        n_kernels += len(rep.kernels)
        prov[tag] = {
            "kernels": len(rep.kernels),
            "errors": errs,
            "warnings": sum(
                1 for f in rep.findings if f.severity == "warning"),
            "passes_run": list(rep.passes_run),
        }
    prov["wall_s"] = round(time.monotonic() - t0, 3)
    emit(f"e2e_{model}_verify_kernel", float(n_err),
         f"kernels={n_kernels} errors={n_err} (fp32+int8, pallas interpret, "
         f"level=kernel, {prov['wall_s']:.1f}s)",
         provenance=prov)

    if predict_only:
        # Modeled rows only: skip the wall-clock sections (2, 2b, 2c) but
        # keep the warm-cache proof — everything emitted is deterministic,
        # which is what the committed baseline + regression gate need.
        _warm_proof(repro, desc, params, options, model, batch_sweep, batch)
        if json_path:
            print(f"# wrote "
                  f"{write_bench_json(json_path, extra={'model': model}, rows=common.ROWS[rows_start:])}")
        return

    # -- 2. per-layer planned run (unfused): the pre-executor reference ------
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, h, w, in_ch))
    plans_t = tuple(s.plan for s in compiled.network_plan(batch).steps)
    fwd = jax.jit(
        lambda xx: cnn_forward(params, layers, xx, impl=impl, plans=plans_t)
    )
    t = time_jit(fwd, x, reps=reps, warmup=1)
    emit(f"e2e_{model}_total", t,
         f"{model} {h}x{w} b{batch} impl={impl} planned end-to-end")

    # -- 2b. fused epilogue: batchnorm folded offline, bias+act in-kernel ----
    # Folding runs once ahead of serving (like the paper's offline Winograd
    # weight transform, §VII.A), so it is excluded from the timed loop.
    folded = jax.block_until_ready(
        jax.jit(lambda p: fold_batchnorm(p, layers))(params)
    )
    fused = jax.jit(
        lambda xx: cnn_forward(folded, layers, xx, impl=impl, plans=plans_t,
                               fuse_epilogue=True)
    )
    t_fused = time_jit(fused, x, reps=reps, warmup=1)
    speedup = t / t_fused if t_fused > 0 else float("inf")
    emit(f"e2e_{model}_total_fused", t_fused,
         f"{model} {h}x{w} b{batch} impl={impl} bn-folded fused epilogue "
         f"({speedup:.2f}x vs unfused)")

    # -- 2c. the compiled executor: whole-graph planned, layout-persistent ---
    # ``compiled.run`` is the facade's deployment path: NetworkPlan (layout
    # elision, row tiles snapped to divisors of OH) + offline-prepared
    # params.  The honest per-layer baseline is the *fused* path on
    # bn-folded params with plans re-resolved at each batch (plans are
    # batch-keyed) — so the ratio isolates the layer-boundary work, not
    # epilogue fusion the per-layer path also has.
    for bn in (batch_sweep or (batch,)):
        netplan_b = compiled.network_plan(bn)
        xb = jax.random.normal(jax.random.PRNGKey(2), (bn, h, w, in_ch))
        t_exec = time_jit(compiled.run, xb, reps=reps, warmup=1)
        plans_b = tuple(s.plan for s in netplan_b.steps)
        fwd_b = jax.jit(lambda xx, pb=plans_b: cnn_forward(
            folded, layers, xx, impl=impl, plans=pb, fuse_epilogue=True))
        t_perlayer = time_jit(fwd_b, xb, reps=reps, warmup=1)
        emit(f"e2e_{model}_b{bn}_perlayer", t_perlayer,
             f"{model} {h}x{w} b{bn} impl={impl} per-layer planned (fused, "
             f"bn-folded)")
        emit(f"e2e_{model}_b{bn}_executor", t_exec,
             f"{model} {h}x{w} b{bn} impl={impl} compiled executor "
             f"elided={netplan_b.elided_boundaries} "
             f"vs_perlayer={t_perlayer / t_exec if t_exec > 0 else 0:.2f}x",
             provenance={"elided_boundaries": netplan_b.elided_boundaries,
                         "batch": bn})
    compiled.save_plans()

    _warm_proof(repro, desc, params, options, model, batch_sweep, batch)

    if json_path:
        print(f"# wrote "
              f"{write_bench_json(json_path, extra={'model': model}, rows=common.ROWS[rows_start:])}")


def _warm_proof(repro, desc, params, options, model, batch_sweep, batch):
    """Warm-cache proof: a fresh compile must re-tune nothing."""
    compiled2 = repro.compile(desc, params, options)
    for bn in (batch_sweep or (batch,)):
        compiled2.network_plan(bn)
    report2 = compiled2.plan_report()
    retunes = report2["tunes"]
    emit(f"e2e_{model}_warm_retunes", 0.0,
         f"retunes={retunes} hits={report2['hits']} "
         f"network_hits={report2['network_hits']}",
         provenance={"retunes": retunes,
                     "network_hits": report2["network_hits"]})
    assert retunes == 0, (
        f"warm plan cache re-tuned {retunes} layers — persistence is broken"
    )
    assert report2["network_hits"] >= 1, (
        "warm network-level cache entry missing — netplan persistence broken"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="vgg16",
                    choices=["vgg16", "yolov3-tiny", "yolov3-20"])
    ap.add_argument("--hw", type=int, default=None,
                    help="square input resolution (default: model's own)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--impl", default="jax", choices=["jax", "pallas"])
    ap.add_argument("--mode", default="cost", choices=["cost", "measure"])
    ap.add_argument("--cache", default=None,
                    help="plan-cache JSON path (default: REPRO_PLAN_CACHE or "
                         ".cache/conv_plans.json)")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--batch-sweep", default=None,
                    help="comma list of batch sizes, e.g. 1,4,8: emit an "
                         "e2e_<model>_b<N>_executor row (compiled executor, "
                         "layout persistence) next to the per-layer planned "
                         "total for each N")
    ap.add_argument("--pipeline-sweep", default=None,
                    help="comma list of stage counts, e.g. 2,4: emit an "
                         "e2e_<model>_pipeline_s<N> row (cost-balanced stage "
                         "partition, modeled GPipe latency) for each N — "
                         "deterministic, lands in the committed baseline")
    ap.add_argument("--json", default="BENCH_e2e.json",
                    help="machine-readable output path (empty to disable)")
    ap.add_argument("--predict-only", action="store_true",
                    help="emit only the deterministic modeled rows (plan "
                         "report, int8 decisions, warm-retunes proof) — no "
                         "wall-clock timing; what the committed baseline "
                         "and benchmarks.check_regression gate on")
    args = ap.parse_args()
    run(
        model=args.model,
        input_hw=(args.hw, args.hw) if args.hw else None,
        batch=args.batch,
        impl=args.impl,
        mode=args.mode,
        cache_path=args.cache,
        reps=args.reps,
        batch_sweep=(tuple(int(b) for b in args.batch_sweep.split(","))
                     if args.batch_sweep else None),
        json_path=args.json or None,
        predict_only=args.predict_only,
        pipeline_sweep=(tuple(int(s) for s in args.pipeline_sweep.split(","))
                        if args.pipeline_sweep else None),
    )


if __name__ == "__main__":
    main()
