"""Beyond-paper: the (arch x shape x mesh) roofline table from dry-run JSONs.

Reads results/dryrun/*.json (written by launch/dryrun.py) and emits one row
per cell: the three roofline terms, dominant bound, roofline fraction, and
useful-FLOPs ratio.  ``--markdown`` prints the EXPERIMENTS.md table.
"""
from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(results_dir=None, tag=None):
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir or RESULTS, "*.json"))):
        r = json.load(open(path))
        parts = os.path.basename(path)[:-5].split("__")
        r["_tag"] = parts[3] if len(parts) > 3 else ""
        if tag is not None and r["_tag"] != tag:
            continue
        cells.append(r)
    return cells


def run(markdown: bool = False) -> None:
    cells = load_cells(tag="")
    if markdown:
        print("| arch | shape | mesh | compute_s | memory_s | collective_s |"
              " dominant | frac | useful | GiB/dev |")
        print("|---|---|---|---|---|---|---|---|---|---|")
    for r in cells:
        name = f"{r['arch']}/{r['shape']}/{r.get('mesh', '-')}"
        if r.get("skipped"):
            if markdown:
                print(f"| {r['arch']} | {r['shape']} | {r.get('mesh','-')} |"
                      f" skipped: {r['reason']} |||||||")
            else:
                emit(f"lm/{name}", 0.0, f"skipped:{r['reason']}")
            continue
        if "error" in r:
            emit(f"lm/{name}", 0.0, f"error:{r['error'][:60]}")
            continue
        rl = r["roofline"]
        if markdown:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                  f" {rl['compute_s']:.4f} | {rl['memory_s']:.4f} |"
                  f" {rl['collective_s']:.4f} | {rl['dominant']} |"
                  f" {rl['roofline_frac']:.3f} | {rl['useful_flops_ratio']:.2f} |"
                  f" {r['memory']['total_per_device_gib']:.1f} |")
        else:
            emit(f"lm/{name}", rl["compute_s"],
                 f"dominant={rl['dominant']};frac={rl['roofline_frac']:.3f};"
                 f"useful={rl['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    run(markdown="--markdown" in sys.argv)
