"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.emit).
Sections:
  breakdown          paper §II.B   (GEMM share of inference time)
  table2_blocksizes  paper Table II (BLIS block tuning, VMEM model)
  table3_veclen      paper Fig 6    (vector-length scaling)
  fig_cache_sweep    paper Figs 7-10 (cache x veclen co-design, both algos)
  table4_ai          paper Table IV (per-layer AI + %peak)
  winograd_vs_im2col paper §VII     (2.4x / 1.35x / 1.5x claims)
  e2e_cnn            paper Figs 9-10 (planned end-to-end network; small
                     resolution here — full runs via benchmarks.e2e_cnn)
  lm_roofline        beyond-paper   (assigned-arch dry-run roofline table)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        breakdown,
        e2e_cnn,
        fig_cache_sweep,
        lm_roofline,
        table2_blocksizes,
        table3_veclen,
        table4_ai,
        winograd_vs_im2col,
    )

    sections = [
        ("breakdown", breakdown.run),
        ("table2_blocksizes", table2_blocksizes.run),
        ("table3_veclen", table3_veclen.run),
        ("fig_cache_sweep", fig_cache_sweep.run),
        ("table4_ai", table4_ai.run),
        ("winograd_vs_im2col", winograd_vs_im2col.run),
        ("e2e_cnn", lambda: e2e_cnn.run(model="vgg16", input_hw=(64, 64),
                                        reps=1)),
        ("lm_roofline", lm_roofline.run),
    ]
    failures = 0
    for name, fn in sections:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR")
            traceback.print_exc()
    # Every emitted row, machine-readable — the perf trajectory is tracked
    # from this file, not scraped from stdout.
    from benchmarks.common import write_bench_json

    print(f"# wrote {write_bench_json('BENCH_e2e.json', extra={'driver': 'benchmarks.run', 'failures': failures})}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
