"""Paper §VII: Winograd vs optimized im2col+GEMM.

Paper claims (A64FX, SVE): 2.4x on 3x3/stride-1 layers, 1.35x YOLOv3
end-to-end, 1.5x VGG16 end-to-end (weight transform offline).

Three measurements here:
  1. MEASURED on this CPU: jitted pure-JAX winograd vs im2col conv at real
     YOLOv3/VGG16 layer sizes (XLA:CPU timing is a proxy, but the FLOP
     advantage is algorithm-level and shows through).
  2. MODELED for TPU v5e: FLOP+traffic roofline of im2col vs the 3-pass
     Winograd pipeline (V/M round-trip HBM) vs the single-pass fused
     megakernel (V/M stay in VMEM) — each Winograd variant at the block
     tuple the planner autotuned for it, resolved through the persistent
     plan cache (a second resolve must re-tune nothing).
  3. Network-level Amdahl projection from the eligible-FLOPs fraction.

Run directly:  PYTHONPATH=src python -m benchmarks.winograd_vs_im2col
CI smoke:      ... --layers 1 --modeled-only
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit, time_jit, vgg16_gemms, yolov3_20_gemms
from repro.core.conv_spec import ConvAlgorithm, ConvSpec
from repro.core.im2col import conv2d_im2col
from repro.core.winograd import conv2d_winograd, transform_weights
from repro.core.vmem_model import predict_winograd

# Representative 3x3/stride-1 YOLOv3 layers (paper's winograd-eligible set).
LAYER_SET = [
    dict(h=152, w=152, cin=64, cout=128),
    dict(h=76, w=76, cin=128, cout=256),
    dict(h=38, w=38, cin=256, cout=512),
]


def _measured(layer) -> tuple:
    spec = ConvSpec(layer["cin"], layer["cout"], (3, 3), (1, 1), (1, 1))
    x = jax.random.normal(jax.random.PRNGKey(0),
                          (1, layer["h"], layer["w"], layer["cin"]))
    w = jax.random.normal(jax.random.PRNGKey(1),
                          (3, 3, layer["cin"], layer["cout"])) * 0.05
    u = transform_weights(w)  # offline, like the paper
    im2col_fn = jax.jit(lambda a, b: conv2d_im2col(a, b, spec))
    wino_fn = jax.jit(
        lambda a, b: conv2d_winograd(a, b, spec, pretransformed=True)
    )
    t_i = time_jit(im2col_fn, x, w, reps=3)
    t_w = time_jit(wino_fn, x, u, reps=3)
    return t_i, t_w


def _modeled(layer, planner) -> tuple:
    """v5e modeled seconds + plans for one layer.

    Returns ``(t_i, t_w3, t_wf, ratio_f3, plan_f, plan_3)``: im2col, 3-pass
    winograd and fused-megakernel roofline seconds from the repo's shared
    model (``predict_conv_time`` — the same numbers the planner's algorithm
    selection and e2e_cnn.py use, so the rows are mutually consistent), plus
    the fused-vs-3-pass ratio from the block-aware ``predict_winograd``
    estimates at each realization's planner-autotuned tuple (same model
    fidelity on both sides of *that* ratio: panel re-reads + grid startup).
    """
    from repro.core.codesign import predict_conv_time

    h, w, cin, cout = layer["h"], layer["w"], layer["cin"], layer["cout"]
    spec = ConvSpec(cin, cout, (3, 3), (1, 1), (1, 1),
                    algorithm=ConvAlgorithm.WINOGRAD)
    oh, ow = spec.out_hw(h, w)
    tiles = -(-oh // 6) * -(-ow // 6)

    t_i = predict_conv_time(spec, h, w, ConvAlgorithm.IM2COL_GEMM)
    t_w3 = predict_conv_time(spec, h, w, ConvAlgorithm.WINOGRAD,
                             winograd_fused=False)
    t_wf = predict_conv_time(spec, h, w, ConvAlgorithm.WINOGRAD,
                             winograd_fused=True)
    # Each realization runs at the block tuple the planner tuned *for it*
    # (the fused megakernel budgets its M-accumulator scratch, so the tuples
    # can differ); plans round-trip through the shared persistent cache.
    plan_f = planner["fused"].plan(spec, h, w)
    plan_3 = planner["3pass"].plan(spec, h, w)
    est_f = predict_winograd(tiles, cin, cout, plan_f.kernel_blocks, fused=True)
    est_3 = predict_winograd(tiles, cin, cout, plan_3.kernel_blocks, fused=False)
    ratio_f3 = est_3.total_s / est_f.total_s
    return t_i, t_w3, t_wf, ratio_f3, plan_f, plan_3


def run(layers: int | None = None, modeled_only: bool = False,
        cache_path: str | None = None) -> None:
    from repro.core.planner import DEFAULT_CACHE_PATH, Planner

    cache = cache_path if cache_path is not None else DEFAULT_CACHE_PATH
    # autosave=False: one merge+write per planner after the layer loop,
    # not one locked read-merge-rewrite of the shared file per miss.
    planners = {
        "fused": Planner(cache_path=cache, winograd_fused=True,
                         autosave=False),
        "3pass": Planner(cache_path=cache, winograd_fused=False,
                         autosave=False),
    }
    layer_set = LAYER_SET[:layers] if layers is not None else LAYER_SET
    ratios_m = []
    for layer in layer_set:
        m_i, m_w3, m_wf, ratio_f3, plan_f, plan_3 = _modeled(layer, planners)
        t_i, t_w = (0.0, 0.0) if modeled_only else _measured(layer)
        if not modeled_only:
            ratios_m.append(t_i / t_w)
        emit(
            f"winograd/3x3s1_{layer['h']}x{layer['w']}x{layer['cin']}",
            t_w,
            (f"im2col_s={t_i:.4f};measured_speedup="
             f"{(t_i / t_w) if t_w else 0:.2f};"
             f"v5e_3pass_speedup={m_i / m_w3:.2f};"
             f"v5e_fused_speedup={m_i / m_wf:.2f};"
             f"fused_vs_3pass={ratio_f3:.2f};"
             f"fused_blocks={'x'.join(map(str, plan_f.kernel_blocks))};"
             f"3pass_blocks={'x'.join(map(str, plan_3.kernel_blocks))};"
             f"paper=2.4"),
        )

    planners["fused"].save()
    planners["3pass"].save()

    # Warm-cache proof: fresh planners on the same file re-tune nothing.
    warm = {
        "fused": Planner(cache_path=cache, winograd_fused=True),
        "3pass": Planner(cache_path=cache, winograd_fused=False),
    }
    for layer in layer_set:
        spec = ConvSpec(layer["cin"], layer["cout"], (3, 3), (1, 1), (1, 1),
                        algorithm=ConvAlgorithm.WINOGRAD)
        warm["fused"].plan(spec, layer["h"], layer["w"])
        warm["3pass"].plan(spec, layer["h"], layer["w"])
    retunes = warm["fused"].stats["tunes"] + warm["3pass"].stats["tunes"]
    emit("winograd/warm_retunes", 0.0,
         f"retunes={retunes};hits="
         f"{warm['fused'].stats['hits'] + warm['3pass'].stats['hits']}")
    assert retunes == 0, "warm winograd plan cache re-tuned — persistence broken"

    if modeled_only:
        return

    # Network level: fraction of conv FLOPs in 3x3 s1 layers scales the gain
    # (paper: YOLOv3 1.35x with 38/75 layers eligible; VGG16 1.5x with all).
    for net, dims, paper in (("yolov3_20", yolov3_20_gemms(), 1.35),
                             ("vgg16", vgg16_gemms(), 1.5)):
        elig = sum(2 * d["M"] * d["N"] * d["K"] for d in dims
                   if d["kernel"] == 3 and d["stride"] == 1)
        total = sum(2 * d["M"] * d["N"] * d["K"] for d in dims)
        per_layer = sum(ratios_m) / len(ratios_m)
        amdahl = 1.0 / ((1 - elig / total) + (elig / total) / per_layer)
        emit(f"winograd/network_{net}", 0.0,
             f"eligible_flops={elig / total:.2f};"
             f"projected_speedup={amdahl:.2f};paper={paper}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    def _positive(v: str) -> int:
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("--layers must be >= 1")
        return n

    ap.add_argument("--layers", type=_positive, default=None,
                    help="run only the first N layers of the set")
    ap.add_argument("--modeled-only", action="store_true",
                    help="skip the measured CPU timing (CI smoke)")
    ap.add_argument("--cache", default=None, help="plan-cache JSON path")
    args = ap.parse_args()
    run(layers=args.layers, modeled_only=args.modeled_only,
        cache_path=args.cache)


if __name__ == "__main__":
    main()
