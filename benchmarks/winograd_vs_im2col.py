"""Paper §VII: Winograd vs optimized im2col+GEMM.

Paper claims (A64FX, SVE): 2.4x on 3x3/stride-1 layers, 1.35x YOLOv3
end-to-end, 1.5x VGG16 end-to-end (weight transform offline).

Two measurements here:
  1. MEASURED on this CPU: jitted pure-JAX winograd vs im2col conv at real
     YOLOv3/VGG16 layer sizes (XLA:CPU timing is a proxy, but the FLOP
     advantage is algorithm-level and shows through).
  2. MODELED for TPU v5e: FLOP+traffic roofline of both algorithms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit, vgg16_gemms, yolov3_20_gemms
from repro.core.conv_spec import ConvSpec
from repro.core.im2col import conv2d_im2col
from repro.core.winograd import conv2d_winograd, transform_weights, winograd_flops
from repro.core.vmem_model import winograd_traffic_bytes
from repro.hw import V5E

# Representative 3x3/stride-1 YOLOv3 layers (paper's winograd-eligible set).
LAYER_SET = [
    dict(h=152, w=152, cin=64, cout=128),
    dict(h=76, w=76, cin=128, cout=256),
    dict(h=38, w=38, cin=256, cout=512),
]


def _measured(layer) -> tuple:
    spec = ConvSpec(layer["cin"], layer["cout"], (3, 3), (1, 1), (1, 1))
    x = jax.random.normal(jax.random.PRNGKey(0),
                          (1, layer["h"], layer["w"], layer["cin"]))
    w = jax.random.normal(jax.random.PRNGKey(1),
                          (3, 3, layer["cin"], layer["cout"])) * 0.05
    u = transform_weights(w)  # offline, like the paper
    im2col_fn = jax.jit(lambda a, b: conv2d_im2col(a, b, spec))
    wino_fn = jax.jit(
        lambda a, b: conv2d_winograd(a, b, spec, pretransformed=True)
    )
    t_i = time_jit(im2col_fn, x, w, reps=3)
    t_w = time_jit(wino_fn, x, u, reps=3)
    return t_i, t_w


def _modeled(layer) -> tuple:
    """v5e roofline seconds: im2col, unfused winograd (V/M via HBM, the
    paper's structure), and fused winograd (transforms stay in VMEM — our
    Pallas adaptation, see DESIGN.md §2)."""
    oh, ow, cin, cout = layer["h"], layer["w"], layer["cin"], layer["cout"]
    fl = winograd_flops(oh, ow, cin, cout)
    bw, peak = V5E.hbm_bandwidth, V5E.peak_flops_fp32
    im2col_bytes = 4 * (oh * ow * 9 * cin + 9 * cin * cout + oh * ow * cout)
    t_i = max(fl["direct_flops"] / peak, im2col_bytes / bw)
    t_w = max(fl["winograd_flops"] / peak,
              winograd_traffic_bytes(oh, ow, cin, cout) / bw)
    tiles = -(-oh // 6) * -(-ow // 6)
    fused_bytes = 4 * (tiles * 64 * cin + 64 * cin * cout + tiles * 36 * cout)
    t_wf = max(fl["winograd_flops"] / peak, fused_bytes / bw)
    return t_i, t_w, t_wf


def run() -> None:
    ratios_m, ratios_mod = [], []
    for layer in LAYER_SET:
        t_i, t_w = _measured(layer)
        m_i, m_w, m_wf = _modeled(layer)
        ratios_m.append(t_i / t_w)
        ratios_mod.append(m_i / m_wf)
        emit(
            f"winograd/3x3s1_{layer['h']}x{layer['w']}x{layer['cin']}",
            t_w,
            f"im2col_s={t_i:.4f};measured_speedup={t_i / t_w:.2f};"
            f"v5e_unfused_speedup={m_i / m_w:.2f};"
            f"v5e_fused_speedup={m_i / m_wf:.2f};paper=2.4",
        )

    # Network level: fraction of conv FLOPs in 3x3 s1 layers scales the gain
    # (paper: YOLOv3 1.35x with 38/75 layers eligible; VGG16 1.5x with all).
    for net, dims, paper in (("yolov3_20", yolov3_20_gemms(), 1.35),
                             ("vgg16", vgg16_gemms(), 1.5)):
        elig = sum(2 * d["M"] * d["N"] * d["K"] for d in dims
                   if d["kernel"] == 3 and d["stride"] == 1)
        total = sum(2 * d["M"] * d["N"] * d["K"] for d in dims)
        per_layer = sum(ratios_m) / len(ratios_m)
        amdahl = 1.0 / ((1 - elig / total) + (elig / total) / per_layer)
        emit(f"winograd/network_{net}", 0.0,
             f"eligible_flops={elig / total:.2f};"
             f"projected_speedup={amdahl:.2f};paper={paper}")


if __name__ == "__main__":
    run()
