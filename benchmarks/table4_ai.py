"""Paper Table IV: per-layer arithmetic intensity + sustained %-of-peak.

Uses the paper's own published (M, N, K) per YOLOv3 layer; computes AI with
the paper's formula (must match their AI column exactly) and the attainable
%-of-peak under the v5e roofline via the co-design model.  The paper's
A64FX % column is included in the derived field for comparison — the
*ordering* (higher AI -> higher %) must agree even though the machines
differ.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.yolov3 import TABLE_IV
from repro.core.conv_spec import arithmetic_intensity
from repro.core.vmem_model import GemmShape, autotune_gemm
from repro.hw import V5E


def run() -> None:
    ours, papers = [], []
    for name, m, n, k, ai_paper, pct_paper in TABLE_IV:
        ai = arithmetic_intensity(m, n, k)
        _, est = autotune_gemm(GemmShape(m, n, k))
        ai_crit = V5E.peak_flops_fp32 / V5E.hbm_bandwidth
        pct = 100.0 * min(1.0, ai / ai_crit) * est.mxu_utilization
        ours.append(pct)
        papers.append(pct_paper)
        emit(f"table4/{name}", est.total_s,
             f"M={m};N={n};K={k};AI={ai:.1f};paper_AI={ai_paper};"
             f"v5e_pct_peak={pct:.0f};a64fx_pct_peak={pct_paper}")
    # rank correlation between our %peak and the paper's (monotone agreement)
    import numpy as np

    r = np.corrcoef(np.argsort(np.argsort(ours)),
                    np.argsort(np.argsort(papers)))[0, 1]
    emit("table4/rank_correlation_vs_paper", 0.0, f"spearman={r:.2f}")


if __name__ == "__main__":
    run()
