"""Paper Fig 6 / Table III: vector-length scaling at fixed cache.

TPU mapping: vector length -> lane-dim block width bn (128..2048 elems),
fixed VMEM budget standing in for the 1MB L2.  Reports speedup over the
narrowest width and where scaling saturates — the paper sees 2.5x from
512b->16384b with saturation beyond 8192b once L2 misses bite; the model
reproduces the same shape: wide blocks exhaust the VMEM budget, forcing
smaller K-blocks and more HBM traffic.
"""
from __future__ import annotations

from benchmarks.common import emit, yolov3_20_gemms
from repro.core.codesign import MB, sweep_vector_length
from repro.core.vmem_model import GemmShape


def run() -> None:
    layers = yolov3_20_gemms()
    widths = (128, 256, 512, 1024, 2048)
    # 2 MiB: the smallest budget at which every width has a feasible
    # double-buffered block (the paper's "1MB L2" analogue).
    budget = 2 * MB
    totals = {w: 0.0 for w in widths}
    for d in layers:
        shape = GemmShape(d["M"], d["N"], d["K"])
        for p in sweep_vector_length(shape, vmem_budget=budget, widths=widths):
            totals[p.bn] += p.estimate.total_s
    base = totals[widths[0]]
    prev = None
    for w in widths:
        if totals[w] <= 0:
            emit(f"table3/width_{w}", 0.0, "infeasible_at_budget")
            continue
        speedup = base / totals[w]
        saturated = prev is not None and totals[w] > 0.97 * prev
        emit(f"table3/width_{w}", totals[w],
             f"speedup_vs_128={speedup:.2f};saturated={saturated}")
        prev = totals[w]


if __name__ == "__main__":
    run()
