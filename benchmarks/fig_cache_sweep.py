"""Paper Figs 7/8 (+9/10): L2-cache-size sweep x vector length, for
im2col+GEMM and Winograd.

TPU mapping: VMEM budget (1..64 MiB) x block width, over the YOLOv3
first-20-layer GEMMs and the VGG16 conv stack.  Reproduced findings:
  - larger budgets help more at wider blocks (Fig 7/8);
  - Winograd saturates at a smaller budget than im2col+GEMM (Figs 9/10,
    'Winograd has lower cache requirements').
"""
from __future__ import annotations

from benchmarks.common import emit, vgg16_gemms, yolov3_20_gemms
from repro.core.codesign import MB, sweep_cache_size
from repro.core.vmem_model import GemmShape, winograd_traffic_bytes
from repro.core.winograd import winograd_flops
from repro.hw import V5E

BUDGETS = (1 * MB, 4 * MB, 16 * MB, 64 * MB)


def _im2col_total(layers, budget):
    total = 0.0
    for d in layers:
        pts = sweep_cache_size(GemmShape(d["M"], d["N"], d["K"]),
                               budgets=(budget,))[budget]
        total += min(p.estimate.total_s for p in pts)
    return total


def _winograd_total(layers, budget):
    """Winograd time model: tuple-GEMM via the block model at the given
    budget + transform traffic (bandwidth-bound)."""
    total = 0.0
    for d in layers:
        if d["kernel"] != 3 or d["stride"] != 1:
            pts = sweep_cache_size(GemmShape(d["M"], d["N"], d["K"]),
                                   budgets=(budget,))[budget]
            total += min(p.estimate.total_s for p in pts)
            continue
        oh = ow = int(round(d["N"] ** 0.5))
        fl = winograd_flops(oh, ow, d["cin"], d["cout"])
        tiles = -(-oh // 6) * -(-ow // 6)
        # 64 independent (tiles x cin) @ (cin x cout) GEMMs.
        pts = sweep_cache_size(GemmShape(tiles, d["cout"], d["cin"]),
                               budgets=(budget,))[budget]
        tuple_t = 64 * min(p.estimate.total_s for p in pts)
        tf_t = (winograd_traffic_bytes(oh, ow, d["cin"], d["cout"])
                / V5E.hbm_bandwidth
                + fl["transform_flops"] / V5E.peak_flops_fp32)
        total += tuple_t + tf_t
    return total


def run() -> None:
    yolo = yolov3_20_gemms()
    vgg = vgg16_gemms()
    base_i = _im2col_total(yolo, BUDGETS[0])
    base_w = _winograd_total(vgg, BUDGETS[0])
    sat_budget_i = sat_budget_w = None
    prev_i = prev_w = None
    for b in BUDGETS:
        ti = _im2col_total(yolo, b)
        tw = _winograd_total(vgg, b)
        emit(f"fig7/yolo_im2col_vmem_{b // MB}MB", ti,
             f"speedup_vs_1MB={base_i / ti:.2f}")
        emit(f"fig10/vgg_winograd_vmem_{b // MB}MB", tw,
             f"speedup_vs_1MB={base_w / tw:.2f}")
        if prev_i is not None and ti > 0.98 * prev_i and sat_budget_i is None:
            sat_budget_i = b
        if prev_w is not None and tw > 0.98 * prev_w and sat_budget_w is None:
            sat_budget_w = b
        prev_i, prev_w = ti, tw
    emit("fig9_10/winograd_saturates_earlier", 0.0,
         f"winograd_sat={sat_budget_w and sat_budget_w // MB}MB;"
         f"im2col_sat={sat_budget_i and sat_budget_i // MB}MB")


if __name__ == "__main__":
    run()
