"""Paper Table II: 6-loop block-size tuning, on the TPU co-design model.

The paper tunes (blockM, blockN, blockK) of the BLIS-like GEMM on RISC-V
and reports relative exec time per block choice.  Here the same sweep runs
against the analytical VMEM model (the gem5 analogue) for the YOLOv3
first-4-layer GEMMs — and, like the paper, reports times relative to the
best configuration.  The paper's exact block table is included for the
structural comparison (vector-ISA blocks don't transfer numerically).
"""
from __future__ import annotations

from benchmarks.common import emit, yolov3_20_gemms
from repro.core.vmem_model import BlockConfig, GemmShape, predict_gemm

# The paper's Table II block candidates (M x N x K order).
PAPER_BLOCKS = [
    (128, 1024, 256), (16, 1024, 128), (16, 512, 128),
    (16, 512, 256), (32, 512, 128), (64, 1024, 128),
]
# TPU-aligned equivalents (bm multiple of 8; bn/bk multiples of 128).
TPU_BLOCKS = [
    (128, 1024, 256), (16, 1024, 128), (16, 512, 128),
    (16, 512, 256), (32, 512, 128), (64, 1024, 128),
    (256, 2048, 512), (8, 128, 128),
]


def run() -> None:
    layers = yolov3_20_gemms()[:4]  # paper uses YOLOv3 first 4 conv layers
    results = []
    for bm, bn, bk in TPU_BLOCKS:
        total = 0.0
        for d in layers:
            est = predict_gemm(GemmShape(d["M"], d["N"], d["K"]),
                               BlockConfig(bm, bn, bk))
            total += est.total_s
        results.append(((bm, bn, bk), total))
    best = min(t for _, t in results)
    for (bm, bn, bk), total in results:
        rel = best / total  # 1.0 = best (paper's "normalized performance")
        emit(f"table2/block_{bm}x{bn}x{bk}", total,
             f"normalized_perf={rel:.2f}")


if __name__ == "__main__":
    run()
