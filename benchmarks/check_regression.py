"""Benchmark regression gate over the committed BENCH_e2e.json baseline.

The perf trajectory is tracked from files, not scraped from stdout
(benchmarks/common.py); this module closes the loop: a committed baseline
(``benchmarks/baseline/BENCH_e2e.json``) is compared row-by-row against a
freshly generated candidate, and any modeled row that got slower beyond the
tolerance fails the build.

Only *deterministic* rows are gated (the default ``--pattern``): the
per-layer cost-model predictions (``e2e_<model>_L<NN>``, including the
``_int8_`` variants) and the ``*_predicted_total`` aggregates.  These are
pure arithmetic over static shapes and chip constants — identical on every
machine — so a drift means the cost model, the planner policy, or a layer's
resolved plan actually changed, never that CI ran on a slow runner.
Wall-clock rows are deliberately excluded.

Usage (the CI step):

    python -m benchmarks.check_regression \
        --regen /tmp/BENCH_e2e.json \
        --baseline benchmarks/baseline/BENCH_e2e.json

``--regen PATH`` regenerates the candidate first (both paper networks,
predict-only, a throwaway plan cache) and then compares; pass ``--candidate``
instead to compare an existing file.  To refresh the committed baseline
after an intentional model change:

    python -m benchmarks.check_regression --regen benchmarks/baseline/BENCH_e2e.json --no-compare
"""
from __future__ import annotations

import argparse
import json
import re
import sys
import tempfile
from typing import Any, Dict, List, Optional, Tuple

# Deterministic modeled rows only — see module docstring.  The
# serving_resilience row is a zero-cost proof (seconds = sum of the
# engine's degradation counters, 0.0 healthy): gating it catches a
# baseline that silently serves from a fallback rung.  The verify_kernel
# row is the same shape for the kernel-interior static analyzer (seconds =
# error-finding count over the Pallas compilation, fp32 + int8): any new
# race/bounds/accumulator/overflow finding flips it non-zero and fails
# the exact-equality rule.
DEFAULT_PATTERN = (
    r"^e2e_.*_L\d+$|^e2e_.*_predicted_total$|^e2e_.*_serving_resilience$"
    r"|^e2e_.*_pipeline_s\d+$|^e2e_.*_verify_kernel$"
)
DEFAULT_TOLERANCE = 0.05
# The committed baseline's generation recipe; regen must match it exactly
# or every row would spuriously drift.
BASELINE_MODELS = ("vgg16", "yolov3-tiny")
BASELINE_HW = 64
BASELINE_BATCH = 1
BASELINE_PIPELINE_SWEEP = (2, 4)


def load_rows(path: str) -> Dict[str, Dict[str, Any]]:
    """{row name: row} from a BENCH JSON file (benchmarks.common schema)."""
    with open(path) as f:
        data = json.load(f)
    rows = data.get("rows", [])
    out: Dict[str, Dict[str, Any]] = {}
    for r in rows:
        out[r["name"]] = r
    return out


def compare(
    baseline: Dict[str, Dict[str, Any]],
    candidate: Dict[str, Dict[str, Any]],
    pattern: str = DEFAULT_PATTERN,
    tolerance: float = DEFAULT_TOLERANCE,
) -> Tuple[List[str], List[str]]:
    """(regressions, notices) comparing candidate seconds against baseline.

    A gated row regresses when it is slower than baseline * (1 + tolerance)
    or missing from the candidate entirely (a silently dropped layer row is
    a coverage regression, not an improvement).  Faster-than-baseline rows
    come back as notices — an intentional model change should refresh the
    committed baseline so future regressions are measured from the new
    level, but it does not fail the build.
    """
    rx = re.compile(pattern)
    regressions: List[str] = []
    notices: List[str] = []
    gated = [n for n in baseline if rx.search(n)]
    if not gated:
        regressions.append(
            f"baseline has no rows matching {pattern!r} — empty gate"
        )
    for name in sorted(gated):
        base_s = float(baseline[name]["seconds"])
        if name not in candidate:
            regressions.append(f"{name}: missing from candidate")
            continue
        cand_s = float(candidate[name]["seconds"])
        if base_s <= 0.0:
            # Zero-cost proof rows (e.g. warm_retunes) gate on presence.
            if cand_s != base_s:
                regressions.append(
                    f"{name}: expected {base_s}, got {cand_s}"
                )
            continue
        ratio = cand_s / base_s
        if ratio > 1.0 + tolerance:
            regressions.append(
                f"{name}: {base_s:.6e}s -> {cand_s:.6e}s "
                f"({ratio:.3f}x, tolerance {1 + tolerance:.2f}x)"
            )
        elif ratio < 1.0 / (1.0 + tolerance):
            notices.append(
                f"{name}: improved {base_s:.6e}s -> {cand_s:.6e}s "
                f"({ratio:.3f}x) — consider refreshing the baseline"
            )
    return regressions, notices


def regenerate(json_path: str, cache_path: Optional[str] = None) -> str:
    """Re-run the baseline recipe (both networks, predict-only) into one
    BENCH JSON at ``json_path``.  Uses a throwaway plan cache by default so
    the run is reproducible from cold."""
    from benchmarks import common
    from benchmarks.e2e_cnn import run
    from benchmarks.common import write_bench_json

    if cache_path is None:
        cache_path = tempfile.mktemp(prefix="bench_plans_", suffix=".json")
    start = len(common.ROWS)
    for model in BASELINE_MODELS:
        run(
            model=model,
            input_hw=(BASELINE_HW, BASELINE_HW),
            batch=BASELINE_BATCH,
            impl="jax",
            mode="cost",
            cache_path=cache_path,
            predict_only=True,
            json_path=None,       # one combined file below, not per model
            pipeline_sweep=BASELINE_PIPELINE_SWEEP,
        )
    return write_bench_json(
        json_path,
        extra={"models": list(BASELINE_MODELS), "hw": BASELINE_HW,
               "batch": BASELINE_BATCH, "predict_only": True,
               "pipeline_sweep": list(BASELINE_PIPELINE_SWEEP)},
        rows=common.ROWS[start:],
    )


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="benchmarks/baseline/BENCH_e2e.json")
    ap.add_argument("--candidate", default=None,
                    help="existing BENCH JSON to compare (or use --regen)")
    ap.add_argument("--regen", default=None, metavar="PATH",
                    help="regenerate the candidate to PATH first (both "
                         "paper networks, predict-only, throwaway cache)")
    ap.add_argument("--pattern", default=DEFAULT_PATTERN,
                    help="regex of row names to gate (default: the "
                         "deterministic modeled rows)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed relative slowdown (default 0.05 = 5%%)")
    ap.add_argument("--no-compare", action="store_true",
                    help="with --regen: write the file and stop (baseline "
                         "refresh)")
    args = ap.parse_args(argv)

    candidate_path = args.candidate
    if args.regen:
        candidate_path = regenerate(args.regen)
        print(f"# regenerated candidate: {candidate_path}")
        if args.no_compare:
            return 0
    if candidate_path is None:
        ap.error("need --candidate or --regen")

    regressions, notices = compare(
        load_rows(args.baseline), load_rows(candidate_path),
        pattern=args.pattern, tolerance=args.tolerance,
    )
    for n in notices:
        print(f"NOTICE  {n}")
    for r in regressions:
        print(f"REGRESSION  {r}")
    if regressions:
        print(f"# {len(regressions)} regression(s) vs {args.baseline}")
        return 1
    print(f"# ok: no regressions vs {args.baseline} "
          f"(tolerance {args.tolerance:.0%}, pattern {args.pattern!r})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
