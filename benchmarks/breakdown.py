"""Paper §II.B: execution-time breakdown of CNN inference.

The paper profiles YOLOv3 on A64FX and finds GEMM = 93.4% of compute time.
We reproduce the breakdown for YOLOv3-tiny on this CPU: time the full
forward, then the conv-free variant (all other Darknet kernels), and
attribute the difference to conv(im2col+GEMM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.configs import yolov3
from repro.data import image_batch
from repro.models.cnn import cnn_forward, init_cnn


def run() -> None:
    layers = yolov3.TINY_LAYERS
    params = init_cnn(jax.random.PRNGKey(0), layers)
    x = image_batch(0, 1, 416, 416)

    full = jax.jit(lambda p, xx: cnn_forward(p, layers, xx, impl="jax"))
    t_full = time_jit(full, params, x, reps=3)

    # conv-free proxy: replace each conv's GEMM result with a zeros tensor of
    # the right shape (keeps BN/activation/pool/route costs).
    import repro.models.cnn as cnn_mod

    orig = cnn_mod.conv2d

    def fake_conv(xx, w, spec, **kw):
        oh, ow = spec.out_hw(xx.shape[1], xx.shape[2])
        return jnp.zeros((xx.shape[0], oh, ow, spec.out_channels), xx.dtype)

    cnn_mod.conv2d = fake_conv
    try:
        rest = jax.jit(lambda p, xx: cnn_forward(p, layers, xx, impl="jax"))
        t_rest = time_jit(rest, params, x, reps=3)
    finally:
        cnn_mod.conv2d = orig

    conv_share = 100.0 * max(t_full - t_rest, 0.0) / t_full
    emit("breakdown/full_forward", t_full, f"conv_share={conv_share:.1f}%")
    emit("breakdown/non_conv_kernels", t_rest,
         f"paper_gemm_share=93.4%;ours={conv_share:.1f}%")


if __name__ == "__main__":
    run()
